"""Serving driver: batched near-neighbor search over C-MinHash signatures.

Builds an index over a corpus and serves batched queries (the paper's
approximate-near-neighbor application, Sec. 1). Reports recall@k against
brute-force Jaccard and end-to-end batch latency.

    PYTHONPATH=src python examples/similarity_search.py [--docs 400 --queries 64]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np                                        # noqa: E402

from repro.data.shingle import batch_shingles             # noqa: E402
from repro.data.synthetic import corpus_with_duplicates   # noqa: E402
from repro.serve.search import SearchConfig, \
    SimilaritySearchService                               # noqa: E402


def _true_jaccard_rows(idx_a, idx_all):
    sa = [set(r[r >= 0].tolist()) for r in idx_a]
    sb = [set(r[r >= 0].tolist()) for r in idx_all]
    out = np.zeros((len(sa), len(sb)), np.float32)
    for i, A in enumerate(sa):
        for j, B in enumerate(sb):
            u = len(A | B)
            out[i, j] = len(A & B) / u if u else 0.0
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=400)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--topk", type=int, default=5)
    args = ap.parse_args()

    docs, _ = corpus_with_duplicates(args.docs, vocab=30_000, doc_len=256,
                                     dup_fraction=0.5, cluster_size=2, seed=1)
    idx = batch_shingles(docs, n=3, d=1 << 14)
    svc = SimilaritySearchService(SearchConfig(d=1 << 14, k=256, n_bands=64,
                                               rows_per_band=4))
    t0 = time.perf_counter()
    svc.add_sparse(idx)
    print(f"indexed {svc.size} docs in {time.perf_counter() - t0:.2f}s "
          f"(2 permutations, K=256)")

    # batched queries: the docs themselves (self + twin should rank top)
    q = idx[: args.queries]
    t0 = time.perf_counter()
    ids, scores = svc.query_sparse(q, top_k=args.topk)
    dt = time.perf_counter() - t0
    print(f"served {args.queries} queries in {dt * 1e3:.1f} ms "
          f"({args.queries / dt:.0f} q/s)")

    truth = _true_jaccard_rows(q, idx)
    hit = total = 0
    for qi in range(args.queries):
        order = np.argsort(-truth[qi])
        best_other = order[order != qi][0]
        if truth[qi, best_other] >= 0.3:        # a real near neighbor exists
            total += 1
            hit += int(best_other in ids[qi])
    print(f"recall@{args.topk} of true nearest neighbor (J>=0.3): "
          f"{hit}/{total} = {hit / max(total, 1) * 100:.0f}%")
    print(f"top-1 self-retrieval: "
          f"{(ids[:, 0] == np.arange(args.queries)).mean() * 100:.0f}%")


if __name__ == "__main__":
    main()
