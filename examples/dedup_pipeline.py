"""Production data pipeline: near-duplicate removal with C-MinHash + LSH.

Generates a corpus with planted near-duplicate clusters, dedups it with the
2-permutation sketch engine, and reports pair precision/recall against the
planted truth.

    PYTHONPATH=src python examples/dedup_pipeline.py [--docs 300]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.data.dedup import DedupConfig, dedup_corpus, dedup_metrics  # noqa: E402
from repro.data.synthetic import corpus_with_duplicates                # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=300)
    ap.add_argument("--dup-fraction", type=float, default=0.3)
    args = ap.parse_args()

    docs, labels = corpus_with_duplicates(
        args.docs, vocab=30_000, doc_len=256,
        dup_fraction=args.dup_fraction, seed=0)
    cfg = DedupConfig(d=1 << 14, k=256, n_bands=64, rows_per_band=4,
                      threshold=0.5)
    print(f"dedup: {args.docs} docs, shingle universe 2^14, K={cfg.k}, "
          f"{cfg.n_bands}x{cfg.rows_per_band} bands (2 permutations total)")
    t0 = time.perf_counter()
    res = dedup_corpus(docs, cfg)
    dt = time.perf_counter() - t0
    m = dedup_metrics(res, labels)
    print(f"  kept {m['kept']}/{m['total']} docs "
          f"({res.n_candidates} candidates, {res.n_verified} verified)")
    print(f"  pair precision = {m['precision']:.3f}, recall = {m['recall']:.3f}")
    print(f"  {args.docs / dt:.0f} docs/s end-to-end on CPU")


if __name__ == "__main__":
    main()
