"""Quickstart: estimate Jaccard similarity with two permutations.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.core import (SketchConfig, SketchEngine,                 # noqa: E402
                        jaccard_from_signatures, true_jaccard_dense)


def main() -> None:
    rng = np.random.default_rng(0)
    d, k = 4096, 512

    # two binary vectors with ~70% overlap
    v = (rng.random(d) < 0.08).astype(np.int8)
    w = v.copy()
    flip = rng.random(d) < 0.02
    w[flip] = 1 - w[flip]
    batch = jnp.asarray(np.stack([v, w]))

    engine = SketchEngine(SketchConfig(d=d, k=k, seed=42))
    sigs = engine.signatures_dense(batch)           # (2, K) int32

    est = float(jaccard_from_signatures(sigs[0], sigs[1]))
    truth = float(true_jaccard_dense(batch[0], batch[1]))
    print(f"C-MinHash-(sigma,pi) with K={k} hashes from TWO permutations")
    print(f"  estimated J = {est:.4f}")
    print(f"  true      J = {truth:.4f}")
    print(f"  |error|     = {abs(est - truth):.4f}")
    print(f"  hashing parameter memory: {engine.parameter_bytes / 1024:.0f} KiB "
          f"(classical MinHash would need "
          f"{SketchEngine.classical_parameter_bytes(d, k) / 2**20:.1f} MiB)")


if __name__ == "__main__":
    main()
