"""Reproduce the paper's sanity check (Fig. 6): empirical vs theoretical
variance, and Theorem 3.4's uniform superiority over MinHash.

    PYTHONPATH=src python examples/variance_validation.py
"""

import sys

sys.path.insert(0, "src")

from benchmarks.bench_variance import empirical_variance  # noqa: E402
from repro.core import theory                              # noqa: E402


def main() -> None:
    D, K, n_rep = 128, 64, 40_000
    print(f"D={D}, K={K}, {n_rep} replications per cell")
    print(f"{'f':>4} {'a':>4} {'J':>6} | {'emp (s,p)':>10} {'thm 3.1':>10} "
          f"| {'emp (0,p)':>10} {'thm 2.2':>10} | {'Var MH':>10}")
    for f, a in [(32, 16), (64, 16), (64, 48), (96, 24)]:
        j = a / f
        emp_s, _ = empirical_variance(D, f, a, K, n_rep, 0, use_sigma=True)
        th_s = theory.var_sigma_pi(D, f, a, K, method="mc",
                                   n_samples=200_000)
        emp_0, _ = empirical_variance(D, f, a, K, n_rep, 1, use_sigma=False)
        x = theory.structured_location_vector(D, f, a)
        th_0 = theory.var_0pi(x, K)
        vm = theory.var_minhash(j, K)
        print(f"{f:>4} {a:>4} {j:>6.3f} | {emp_s:>10.3e} {th_s:>10.3e} "
              f"| {emp_0:>10.3e} {th_0:>10.3e} | {vm:>10.3e}")
        assert th_s < vm, "Theorem 3.4 violated?!"
    print("\nTheory matches simulation; Var(sigma,pi) < Var_MH everywhere "
          "(Theorem 3.4); the (0,pi) variant is data-dependent (Sec. 2).")


if __name__ == "__main__":
    main()
