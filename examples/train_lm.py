"""End-to-end training driver: C-MinHash dedup -> fault-tolerant LM training.

Default is a quick CPU run (~25M params, 40 steps). ``--model 100m --steps 300``
runs the full exercise if you have the patience (or a TPU).

    PYTHONPATH=src python examples/train_lm.py [--steps 40] [--model small]
"""

import argparse
import sys
import tempfile

sys.path.insert(0, "src")

import dataclasses              # noqa: E402

import numpy as np              # noqa: E402

from repro.configs import get_config, reduced            # noqa: E402
from repro.configs.base import TrainConfig               # noqa: E402
from repro.data.dedup import DedupConfig, dedup_corpus   # noqa: E402
from repro.data.loader import PrefetchIterator, \
    deduped_token_batches                                 # noqa: E402
from repro.data.synthetic import corpus_with_duplicates  # noqa: E402
from repro.models import build                            # noqa: E402
from repro.train.train_loop import TrainLoop              # noqa: E402

MODELS = {
    # ~25M params: quick CPU demo
    "small": dict(layers=6, d_model=384, vocab=8192),
    # ~110M params: the "real" run (use on accelerators)
    "100m": dict(layers=12, d_model=768, vocab=32000),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--model", choices=MODELS, default="small")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args()

    m = MODELS[args.model]
    cfg = reduced(get_config("llama3_2_1b"), layers=m["layers"],
                  d_model=m["d_model"], vocab=m["vocab"])
    cfg = dataclasses.replace(cfg, n_heads=8, n_kv_heads=4,
                              head_dim=m["d_model"] // 8,
                              d_ff=4 * m["d_model"], q_chunk=128)
    bundle = build(cfg)
    print(f"model: {cfg.param_count() / 1e6:.1f}M params "
          f"({cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab_size})")

    # stage 1: dedup the corpus with the paper's 2-permutation sketch
    docs, _ = corpus_with_duplicates(400, vocab=cfg.vocab_size_real,
                                     doc_len=512, dup_fraction=0.25, seed=0)
    res = dedup_corpus(docs, DedupConfig(d=1 << 14, k=256, n_bands=64,
                                         rows_per_band=4, threshold=0.5))
    print(f"dedup: kept {len(res.keep)}/{len(docs)} documents")

    # stage 2: fault-tolerant training on the deduped stream
    data = PrefetchIterator(deduped_token_batches(
        docs, res.keep, args.batch, args.seq, vocab=cfg.vocab_size_real))
    tc = TrainConfig(total_steps=args.steps, warmup_steps=max(args.steps // 10, 1),
                     learning_rate=3e-4, checkpoint_every=max(args.steps // 4, 1))
    workdir = args.workdir or tempfile.mkdtemp(prefix="cminhash_lm_")
    print(f"workdir: {workdir} (re-run with --workdir to resume)")
    out = TrainLoop(bundle, tc, data, workdir).run()
    losses = out["losses"]
    if losses:
        k = max(len(losses) // 10, 1)
        print(f"loss: first-{k}-avg {np.mean(losses[:k]):.4f} -> "
              f"last-{k}-avg {np.mean(losses[-k:]):.4f}")


if __name__ == "__main__":
    main()
