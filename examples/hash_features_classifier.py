"""Large-scale learning on b-bit C-MinHash features (Li et al., NIPS 2011 —
the application the paper's Sec. 1 cites for K = 512/1024).

Two classes of binary vectors with class-dependent feature patterns; a
logistic model on K*2^b one-hot hashed features separates them while touching
only 2 permutations and b bits per hash.

    PYTHONPATH=src python examples/hash_features_classifier.py
"""

import sys

sys.path.insert(0, "src")

import jax                                             # noqa: E402
import jax.numpy as jnp                                # noqa: E402
import numpy as np                                     # noqa: E402

from repro.core.engine import SketchConfig, SketchEngine   # noqa: E402
from repro.core.linear_model import (HashedLinearConfig,   # noqa: E402
                                     accuracy, fit_logistic)


def make_data(rng, n, templates, flip=0.02):
    """Samples = class template + per-sample feature flips."""
    t0, t1 = templates
    y = rng.integers(0, 2, n)
    x = np.where(y[:, None] == 0, t0, t1)
    x = x ^ (rng.random((n, len(t0))) < flip)
    return x.astype(np.int8), y.astype(np.int32)


def main() -> None:
    rng = np.random.default_rng(0)
    d, k = 4096, 256
    templates = (rng.random(d) < 0.05, rng.random(d) < 0.05)
    x_train, y_train = make_data(rng, 512, templates)
    x_test, y_test = make_data(rng, 256, templates)

    engine = SketchEngine(SketchConfig(d=d, k=k, seed=7))
    s_train = engine.signatures_dense(jnp.asarray(x_train))
    s_test = engine.signatures_dense(jnp.asarray(x_test))

    print(f"K={k} hashes from 2 permutations "
          f"({engine.parameter_bytes / 1024:.0f} KiB of hash parameters)")
    print(f"{'b':>3} {'features':>9} {'bytes/doc':>9} {'test acc':>8}")
    for b in (1, 2, 4, 8):
        cfg = HashedLinearConfig(b=b)
        wb = fit_logistic(s_train, jnp.asarray(y_train), cfg)
        acc = accuracy(wb, s_test, jnp.asarray(y_test), b)
        print(f"{b:>3} {k * (1 << b):>9} {k * b // 8:>9} {acc:>8.3f}")
    print("(raw representation would be", d // 8, "bytes/doc)")


if __name__ == "__main__":
    main()
