"""Paper Figure 7: Jaccard-estimation MAE on datasets with text-like and
image-like statistics, MinHash vs C-MinHash-(0,pi) vs C-MinHash-(sigma,pi).

The paper's UCI-NIPS / BBC / MNIST / CIFAR corpora are not redistributable in
this offline container; we generate four synthetic corpora matching their
relevant statistics (sparse Zipf features for text; spatially-correlated,
structured on-runs for binarized images — the case where sigma matters).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import minhash
from repro.core.estimators import true_jaccard_dense
from repro.core.permutations import make_two_permutations
from repro.kernels import ops, ref
from repro.data.synthetic import imagelike_binary_dataset, \
    textlike_binary_dataset

from .common import emit


def _pairwise_mae(sigs: np.ndarray, truth: np.ndarray) -> float:
    k = sigs.shape[1]
    est = np.asarray(ref.collision_count_ref(
        jnp.asarray(sigs), jnp.asarray(sigs))) / k
    iu = np.triu_indices(len(sigs), 1)
    return float(np.abs(est[iu] - truth[iu]).mean())


def run(n_docs: int = 48, n_reps: int = 10) -> None:
    rng = np.random.default_rng(0)
    D = 2048
    # improvement grows with f (non-zeros) and K — Fig. 5 — so the dense
    # image-like sets are where (sigma,pi) visibly beats MinHash, and the
    # very sparse text set is where the two are expected to tie (ratio -> 1
    # for f << D).
    datasets = {
        "textA": textlike_binary_dataset(rng, n_docs, D, mean_nnz=80),
        "textB": textlike_binary_dataset(rng, n_docs, D, mean_nnz=250),
        "imageA": imagelike_binary_dataset(rng, n_docs, D, block=16),
        "imageB": imagelike_binary_dataset(rng, n_docs, D, block=64, p_on=0.5),
    }
    for name, data in datasets.items():
        vj = jnp.asarray(data)
        truth = np.zeros((n_docs, n_docs), np.float32)
        for i in range(n_docs):
            truth[i] = np.asarray(true_jaccard_dense(vj[i][None], vj))
        for K in (64, 256, 512):
            results = {"MH": [], "C0": [], "Csigma": []}
            t0 = time.perf_counter()
            for rep in range(n_reps):
                key = jax.random.PRNGKey(rep)
                sigma, pi = make_two_permutations(key, D)
                perms = minhash.make_k_permutations(key, D, K)
                s_mh = np.asarray(minhash.minhash_dense(vj, perms))
                s_c0 = np.asarray(ops.cminhash_signatures(vj, pi, K, None))
                s_cs = np.asarray(ops.cminhash_signatures(vj, pi, K, sigma))
                results["MH"].append(_pairwise_mae(s_mh, truth))
                results["C0"].append(_pairwise_mae(s_c0, truth))
                results["Csigma"].append(_pairwise_mae(s_cs, truth))
            us = (time.perf_counter() - t0) * 1e6 / (3 * n_reps)
            mh, c0, cs = (float(np.mean(results[x]))
                          for x in ("MH", "C0", "Csigma"))
            emit(f"fig7_mae_{name}_K{K}", us,
                 f"MH={mh:.4f}|C0pi={c0:.4f}|Csigmapi={cs:.4f}"
                 f"|win={(mh - cs) / mh * 100:.1f}%")


if __name__ == "__main__":
    run()
