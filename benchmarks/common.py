"""Shared benchmark utilities: timing, CSV row emission, smoke mode.

Smoke mode (``REPRO_BENCH_SMOKE=1`` or ``run.py --smoke``) is the CI setting:
1 warmup + 1 timed iteration and tiny shapes, so the benchmark *scripts* run
end-to-end on every push (dispatch/autotune regressions fail fast) without
timing flakiness mattering — numbers from smoke runs are not comparable.
"""

from __future__ import annotations

import os
import time

import jax

_SMOKE_ENV = "REPRO_BENCH_SMOKE"


def smoke() -> bool:
    return os.environ.get(_SMOKE_ENV, "") not in ("", "0")


def set_smoke(on: bool = True) -> None:
    os.environ[_SMOKE_ENV] = "1" if on else "0"


def time_call(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time (us) of a jax callable (blocks on results)."""
    if smoke():
        warmup, iters = 1, 1
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
