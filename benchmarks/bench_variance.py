"""Paper Figure 6 (and Figure 2's shape): empirical vs theoretical variance of
C-MinHash-(0,pi) and C-MinHash-(sigma,pi) on structured (D, f, a) pairs,
against classical MinHash.

Derived column: emp_var|theory_var|var_MH — the empirical/theory agreement is
the sanity check; theory < MH is Theorem 3.4.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import theory

from .common import emit


def _pair_from_location(x):
    xs = np.where(x == theory.X)[0]
    v = (x == theory.O).copy()
    w = (x == theory.O).copy()
    v[xs[::2]] = True
    w[xs[1::2]] = True
    return v, w


def empirical_variance(D, f, a, K, n_rep, seed, use_sigma):
    rng = np.random.default_rng(seed)
    x = theory.structured_location_vector(D, f, a)
    v, w = _pair_from_location(x)
    ests = np.empty(n_rep)
    B = 10000
    for off in range(0, n_rep, B):
        n = min(B, n_rep - off)
        pis = np.argsort(rng.random((n, D)), axis=1)
        if use_sigma:
            sig = np.argsort(rng.random((n, D)), axis=1)
            rows = np.arange(n)[:, None]
            vp = np.zeros((n, D), bool)
            wp = np.zeros((n, D), bool)
            vp[rows, sig[:, v]] = True
            wp[rows, sig[:, w]] = True
        else:
            vp = np.broadcast_to(v, (n, D)).copy()
            wp = np.broadcast_to(w, (n, D)).copy()
        coll = np.zeros(n)
        for k in range(1, K + 1):
            mv = np.roll(vp, -k, axis=1)
            mw = np.roll(wp, -k, axis=1)
            hv = np.where(mv, pis, 1 << 30).min(axis=1)
            hw = np.where(mw, pis, 1 << 30).min(axis=1)
            coll += hv == hw
        ests[off:off + n] = coll / K
    return float(ests.var()), float(ests.mean())


def run(n_rep: int = 60_000) -> None:
    D = 128
    for (f, a) in [(32, 16), (64, 16), (64, 48), (96, 24)]:
        for K in (32, 64):
            j = a / f
            vm = theory.var_minhash(j, K)
            for variant, use_sigma in (("0pi", False), ("sigmapi", True)):
                t0 = time.perf_counter()
                emp, mean = empirical_variance(D, f, a, K, n_rep, seed=0,
                                               use_sigma=use_sigma)
                us = (time.perf_counter() - t0) * 1e6 / n_rep
                if use_sigma:
                    th = theory.var_sigma_pi(D, f, a, K, method="mc",
                                             n_samples=200_000)
                else:
                    x = theory.structured_location_vector(D, f, a)
                    th = theory.var_0pi(x, K)
                emit(f"fig6_var_{variant}_D{D}_f{f}_a{a}_K{K}", us,
                     f"emp={emp:.3e}|theory={th:.3e}|MH={vm:.3e}"
                     f"|mean={mean:.4f}|J={j:.4f}")


if __name__ == "__main__":
    run()
