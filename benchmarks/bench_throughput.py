"""Systems benchmark (paper §5 discussion): signature-generation throughput and
the K-permutations -> 2-permutations memory win.

Classical MinHash must stream K*D permutation entries; C-MinHash streams the
data once against a single pi. The 'derived' column reports docs/s and the
parameter-memory ratio. CPU wall-clock is a proxy (the TPU path is the Pallas
kernel, validated in interpret mode; its roofline lives in EXPERIMENTS.md)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cminhash, minhash
from repro.core.engine import SketchConfig, SketchEngine
from repro.core.permutations import make_two_permutations

from .common import emit, time_call


def run() -> None:
    rng = np.random.default_rng(0)
    B, D, K = 64, 4096, 256
    dens = 0.05
    v = jnp.asarray((rng.random((B, D)) < dens).astype(np.int8))
    nnz = int(np.asarray(v).sum(1).max())
    idx_np = np.full((B, nnz), -1, np.int32)
    for i in range(B):
        z = np.where(np.asarray(v)[i])[0]
        idx_np[i, : len(z)] = z
    idx = jnp.asarray(idx_np)

    key = jax.random.PRNGKey(0)
    sigma, pi = make_two_permutations(key, D)
    perms = minhash.make_k_permutations(key, D, K)

    us = time_call(lambda: minhash.minhash_dense(v, perms))
    emit("throughput_minhash_dense", us, f"docs_per_s={B / us * 1e6:.0f}")
    us = time_call(lambda: minhash.minhash_sparse(idx, perms))
    emit("throughput_minhash_sparse", us, f"docs_per_s={B / us * 1e6:.0f}")
    us = time_call(lambda: cminhash.cminhash_dense(v, pi, K, sigma))
    emit("throughput_cminhash_dense", us, f"docs_per_s={B / us * 1e6:.0f}")
    us = time_call(lambda: cminhash.cminhash_sparse(idx, pi, K, sigma))
    emit("throughput_cminhash_sparse", us, f"docs_per_s={B / us * 1e6:.0f}")

    eng = SketchEngine(SketchConfig(d=D, k=K))
    ratio = SketchEngine.classical_parameter_bytes(D, K) / eng.parameter_bytes
    emit("memory_k_perms_vs_two", 0.0,
         f"classical={SketchEngine.classical_parameter_bytes(D, K)}B"
         f"|cminhash={eng.parameter_bytes}B|ratio={ratio:.0f}x")

    # the paper's §5 scenario: D = 2^30, K = 1024
    d30 = 1 << 30
    classical = SketchEngine.classical_parameter_bytes(d30, 1024)
    ours = 2 * d30 * 4
    emit("memory_paper_scenario_D2pow30_K1024", 0.0,
         f"classical={classical / 2**40:.1f}TiB|cminhash={ours / 2**30:.0f}GiB"
         f"|ratio={classical / ours:.0f}x")


if __name__ == "__main__":
    run()
