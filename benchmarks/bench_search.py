"""SketchStore vs dict-based LSH path: index-build throughput + query QPS.

The pre-SketchStore serving path bucketed signatures with per-item Python
``defaultdict`` loops; this benchmark keeps that path alive as the baseline
and measures the replacement at production-ish index sizes (default 100k
items): build items/s, candidate-generation queries/s (the array-ops hot path
the subsystem exists for), and end-to-end query QPS including packed scoring.
"""

from __future__ import annotations

import time
from collections import defaultdict

import numpy as np

from repro.core.lsh import band_hashes
from repro.store import SketchStore, StoreConfig

from .common import emit


# -- baseline: the pre-refactor dict path ------------------------------------

def _dict_build(hashes: np.ndarray) -> list[dict[int, list[int]]]:
    n, nb = hashes.shape
    buckets: list[dict[int, list[int]]] = [defaultdict(list)
                                           for _ in range(nb)]
    for i in range(n):
        row = hashes[i]
        for band in range(nb):
            buckets[band][int(row[band])].append(i)
    return buckets


def _dict_candidates(buckets, qhashes: np.ndarray) -> list[set[int]]:
    out = []
    for row in qhashes:
        mine: set[int] = set()
        for band, h in enumerate(row):
            mine.update(buckets[band].get(int(h), ()))
        out.append(mine)
    return out


def run(n_items: int = 100_000, n_queries: int = 256, k: int = 128,
        n_bands: int = 32, rows_per_band: int = 4) -> None:
    rng = np.random.default_rng(0)
    sigs = rng.integers(0, 1 << 20, (n_items, k), dtype=np.int32)
    # plant ~1% duplicate structure (clusters of <= 3) so buckets are not all
    # singletons but stay within bucket_width
    n_dup = max(n_items // 100, 2)
    picks = rng.choice(n_items, n_dup + n_dup // 2, replace=False)
    src, dup = picks[: n_dup // 2], picks[n_dup // 2:]
    sigs[dup] = sigs[np.repeat(src, 2)[: len(dup)]]
    qsigs = sigs[rng.choice(n_items, n_queries, replace=False)]
    hashes = band_hashes(sigs, n_bands, rows_per_band)
    qhashes = band_hashes(qsigs, n_bands, rows_per_band)

    # build
    t0 = time.perf_counter()
    buckets = _dict_build(hashes)
    t_dict_build = time.perf_counter() - t0

    def make_store():
        return SketchStore(StoreConfig.sized_for(
            n_items, k=k, n_bands=n_bands, rows_per_band=rows_per_band,
            bucket_width=4))
    # pack_codes is shape-specialized: warm the FULL (n_items, k) trace so
    # the timed build measures steady-state throughput, not XLA compile
    make_store().add(sigs)
    store = make_store()
    t0 = time.perf_counter()
    store.add(sigs)
    t_store_build = time.perf_counter() - t0

    emit("search_build_dict", t_dict_build * 1e6,
         f"items_per_s={n_items / t_dict_build:.0f}")
    emit("search_build_store", t_store_build * 1e6,
         f"items_per_s={n_items / t_store_build:.0f}"
         f"|rebuilds={store.n_rebuilds}|spilled={store.n_spilled}"
         f"|load={store.table.load_factor:.2f}")

    # candidate generation (the array-ops hot path): each path is timed as a
    # block of back-to-back batches (the serving pattern) and reported as the
    # median.  GC is paused while timing — the 3.2M-entry baseline dict makes
    # every collection scan the whole heap, swamping both measurements.
    import gc

    def timed_block(fn, iters=15):
        times = []
        gc.disable()
        try:
            for _ in range(iters):
                t0 = time.perf_counter()
                out = fn()
                times.append(time.perf_counter() - t0)
        finally:
            gc.enable()
        return sorted(times)[len(times) // 2], out

    t_dict_cand, ref_cands = timed_block(
        lambda: _dict_candidates(buckets, qhashes))
    t_store_cand, rows = timed_block(lambda: store.table.lookup(qhashes))

    # sanity: both paths propose identical candidate sets (spilled entries,
    # if any, are a conservative superset added back at query time)
    spilled = set(store.table.spilled_ids().tolist())
    for q in range(n_queries):
        got = set(rows[q][rows[q] >= 0].tolist())
        assert got <= ref_cands[q] <= got | spilled, \
            f"candidate mismatch at query {q}"

    speedup = t_dict_cand / t_store_cand
    emit("search_candgen_dict", t_dict_cand * 1e6 / n_queries,
         f"qps={n_queries / t_dict_cand:.0f}")
    emit("search_candgen_store", t_store_cand * 1e6 / n_queries,
         f"qps={n_queries / t_store_cand:.0f}|speedup={speedup:.1f}x")

    # end-to-end query (candidates + packed scoring + top-k)
    store.query(qsigs, top_k=10)           # warm the full query-batch trace
    t0 = time.perf_counter()
    store.query(qsigs, top_k=10)
    t_query = time.perf_counter() - t0
    emit("search_query_store", t_query * 1e6 / n_queries,
         f"qps={n_queries / t_query:.0f}|n_items={n_items}")


if __name__ == "__main__":
    run()
