"""SketchStore vs dict-based LSH path + the sharded serving plane.

The pre-SketchStore serving path bucketed signatures with per-item Python
``defaultdict`` loops; this benchmark keeps that path alive as the baseline
and measures the replacement at production-ish index sizes (default 100k
items): build items/s, candidate-generation queries/s (the array-ops hot path
the subsystem exists for), and end-to-end query QPS including packed scoring.

The ``--shards`` axis measures the partitioned plane (`ShardedSketchStore`):
per-S index build and end-to-end query throughput (candidate generation +
per-shard partial top-k + ``merge_topk``), asserting S-shard answers equal
the single-shard answers exactly.  The ``--transport`` axis runs the same
plane over real tcp shard workers (``repro.transport``) and records the
query wall-time split — submit/serialize (broadcast), per-shard partial
compute + gather (partial), and reduction (merge) — next to the inproc
split, so transport overhead is tracked per shard count from day one.

The ``search_query_fused`` row times the fused device query pipeline
(uint32-lane fold -> probe -> packed scoring, ``kernels/query_fused.py``)
against the legacy host fold on the same store — interleaved min-of-N, with
novel random queries appended so the brute-force fallback rows are inside
the parity check.  The sharded/tcp query rows ride the packed serving path
(``--query-impl``), record the coordinator ``fold_us`` next to the
broadcast/partial/merge split, and assert bit-identity against the
single-store HOST oracle at every (transport, S).

The ``--pipeline-depth`` axis measures end-to-end ingest (sign -> pack ->
scatter) through ``serve.search.IngestPipeline`` per depth and transport,
recording the sign/wait/scatter wall-time split — ``wait`` is the device
sync, which shrinks toward zero when the scatter of batch N covered batch
N+1's signing (the overlap the pipeline exists for).  Every (transport,
depth) run is asserted to answer queries **bit-identically** to the serial
(depth=1) inproc ingest of the same batches.  Rows are returned for the
``BENCH_search.json`` artifact (written by ``run.py``).

Standalone:  PYTHONPATH=src python -m benchmarks.bench_search --smoke
"""

from __future__ import annotations

import time
from collections import defaultdict

import numpy as np

from repro.core.lsh import band_hashes
from repro.obs import metrics as obs_metrics
from repro.store import ShardedSketchStore, SketchStore, StoreConfig

from .common import emit


# -- baseline: the pre-refactor dict path ------------------------------------

def _dict_build(hashes: np.ndarray) -> list[dict[int, list[int]]]:
    n, nb = hashes.shape
    buckets: list[dict[int, list[int]]] = [defaultdict(list)
                                           for _ in range(nb)]
    for i in range(n):
        row = hashes[i]
        for band in range(nb):
            buckets[band][int(row[band])].append(i)
    return buckets


def _dict_candidates(buckets, qhashes: np.ndarray) -> list[set[int]]:
    out = []
    for row in qhashes:
        mine: set[int] = set()
        for band, h in enumerate(row):
            mine.update(buckets[band].get(int(h), ()))
        out.append(mine)
    return out


def _timed_block(fn, iters=15):
    """Median wall time of back-to-back calls (the serving pattern), with GC
    paused — a multi-M-entry baseline dict makes every collection scan the
    whole heap, swamping both measurements."""
    import gc
    times = []
    gc.disable()
    try:
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn()
            times.append(time.perf_counter() - t0)
    finally:
        gc.enable()
    return sorted(times)[len(times) // 2], out


def _timing_split(sh, n_queries: int) -> str:
    """`last_timings` -> per-query fold/broadcast/partial/merge derived
    fields (fold_s is the coordinator-side band-hash fold; 0.0 on the sig
    path until the store folded at least one packed batch)."""
    t = sh.last_timings
    return "|".join(f"{key.split('_')[0]}_us="
                    f"{t.get(key, 0.0) * 1e6 / n_queries:.1f}"
                    for key in ("fold_s", "broadcast_s", "partial_s",
                                "merge_s"))


def _stage_quantiles(before: dict, after: dict,
                     names: tuple[str, ...]) -> dict:
    """p50/p90/p99 (in us) per stage histogram, from the registry delta
    between two snapshots — only the calls made between them count.
    Stages with no observations in the window are omitted.  ``n`` is the
    sample count behind the quantiles: a p99 over 5 observations is a max,
    not a tail — readers need the n to weigh it."""
    delta = obs_metrics.snapshot_delta(before, after)
    out: dict[str, dict[str, float]] = {}
    for name in names:
        h = delta["hists"].get(name)
        if not h or not h.get("count"):
            continue
        out[name] = {"n": int(h["count"]), **{
            f"p{int(q * 100)}_us": round(
                (obs_metrics.hist_quantile(h, q) or 0.0) * 1e6, 1)
            for q in (0.5, 0.9, 0.99)}}
    return out


def _query_stages(n_shards: int) -> tuple[str, ...]:
    return (("query.wall", "query.fold", "query.broadcast", "query.partial",
             "query.merge")
            + tuple(f"query.shard{i}.partial" for i in range(n_shards)))


def _bench_ingest_pipeline(em, depths: tuple[int, ...],
                           transports: tuple[str, ...],
                           n_docs: int, batch: int) -> None:
    """End-to-end pipelined ingest (sign -> pack -> scatter) per depth and
    transport, with the sign/wait/scatter split and a bit-identity assert
    of every run against serial (depth=1) inproc ingest."""
    import time as _time

    from repro.serve.search import SearchConfig, SimilaritySearchService

    d, k, nb, r, s = 1 << 14, 128, 32, 4, 2
    rng = np.random.default_rng(7)
    nnz = 160
    docs = np.sort(rng.integers(0, d, (n_docs, nnz), np.int32), axis=1)
    docs[n_docs - n_docs // 20:] = docs[: n_docs // 20]   # planted dups
    q = docs[rng.choice(n_docs, min(64, n_docs), replace=False)]
    batches = [docs[lo: lo + batch] for lo in range(0, n_docs, batch)]

    # signing is shape-specialized: warm every distinct batch shape once so
    # the timed runs measure steady-state ingest, not XLA compiles (the jit
    # caches are module-level, so one warm service covers every run)
    warm = SimilaritySearchService(SearchConfig(
        d=d, k=k, n_bands=nb, rows_per_band=r))
    for shape_rep in {bt.shape: bt for bt in batches + [q]}.values():
        np.asarray(warm._sign(shape_rep, "sparse"))

    def build(transport, depth):
        svc = SimilaritySearchService(SearchConfig(
            d=d, k=k, n_bands=nb, rows_per_band=r, n_shards=s,
            transport=transport))
        with svc:
            before = obs_metrics.default().snapshot()
            with svc.pipeline(depth=depth) as pipe:
                t0 = _time.perf_counter()
                for bt in batches:
                    pipe.submit(bt)
                pipe.flush()
                wall = _time.perf_counter() - t0
            lat = _stage_quantiles(
                before, obs_metrics.default().snapshot(),
                ("ingest.sign", "ingest.wait", "ingest.scatter"))
            ans = svc.query_sparse(q, top_k=10)
            return wall, dict(pipe.timings), lat, ans

    # serial inproc ingest is ALWAYS the parity baseline (run first even
    # when not requested as an emitted row)
    asked = [(tr, dep) for tr in transports for dep in depths]
    ordered = [("inproc", 1)] + [rd for rd in asked if rd != ("inproc", 1)]
    ref = None
    for transport, depth in ordered:
        wall, tm, lat, ans = build(transport, depth)
        if ref is None:
            ref = ans
        else:             # pipelining must never change an answer
            assert np.array_equal(ref[0], ans[0]), \
                f"ingest-pipeline ids diverge ({transport}, depth={depth})"
            assert np.array_equal(ref[1], ans[1]), \
                f"ingest-pipeline scores diverge ({transport}, depth={depth})"
        if (transport, depth) in asked:
            em(f"search_ingest_{transport}_d{depth}", wall * 1e6,
               f"items_per_s={n_docs / wall:.0f}|parity=exact|"
               f"sign_ms={tm['sign_s'] * 1e3:.1f}|"
               f"wait_ms={tm['wait_s'] * 1e3:.1f}|"
               f"scatter_ms={tm['scatter_s'] * 1e3:.1f}",
               latency=lat)


def run(n_items: int = 100_000, n_queries: int = 256, k: int = 128,
        n_bands: int = 32, rows_per_band: int = 4,
        shards: tuple[int, ...] = (2, 4),
        transports: tuple[str, ...] = ("inproc", "tcp"),
        pipeline_depths: tuple[int, ...] = (1, 2, 4),
        ingest_docs: int = 20_000, ingest_batch: int = 512,
        query_impl: str = "auto") -> list[dict]:
    rows_out: list[dict] = []

    def em(name, us, derived, **fields):
        emit(name, us, derived)
        rows_out.append({"name": name, "us_per_call": round(us, 1),
                         "derived": derived, **fields})

    rng = np.random.default_rng(0)
    sigs = rng.integers(0, 1 << 20, (n_items, k), dtype=np.int32)
    # plant ~1% duplicate structure (clusters of <= 3) so buckets are not all
    # singletons but stay within bucket_width
    n_dup = max(n_items // 100, 2)
    picks = rng.choice(n_items, n_dup + n_dup // 2, replace=False)
    src, dup = picks[: n_dup // 2], picks[n_dup // 2:]
    sigs[dup] = sigs[np.repeat(src, 2)[: len(dup)]]
    qsigs = sigs[rng.choice(n_items, n_queries, replace=False)]
    hashes = band_hashes(sigs, n_bands, rows_per_band)
    qhashes = band_hashes(qsigs, n_bands, rows_per_band)

    # build
    t0 = time.perf_counter()
    buckets = _dict_build(hashes)
    t_dict_build = time.perf_counter() - t0

    def make_cfg():
        return StoreConfig.sized_for(
            n_items, k=k, n_bands=n_bands, rows_per_band=rows_per_band,
            bucket_width=4)

    # pack_codes is shape-specialized: warm the FULL (n_items, k) trace so
    # the timed build measures steady-state throughput, not XLA compile
    SketchStore(make_cfg()).add(sigs)
    store = SketchStore(make_cfg())
    t0 = time.perf_counter()
    store.add(sigs)
    t_store_build = time.perf_counter() - t0

    em("search_build_dict", t_dict_build * 1e6,
       f"items_per_s={n_items / t_dict_build:.0f}")
    em("search_build_store", t_store_build * 1e6,
       f"items_per_s={n_items / t_store_build:.0f}"
       f"|rebuilds={store.n_rebuilds}|spilled={store.n_spilled}"
       f"|load={store.table.load_factor:.2f}")

    # candidate generation (the array-ops hot path)
    t_dict_cand, ref_cands = _timed_block(
        lambda: _dict_candidates(buckets, qhashes))
    t_store_cand, rows = _timed_block(lambda: store.table.lookup(qhashes))

    # sanity: both paths propose identical candidate sets (spilled entries,
    # if any, are a conservative superset added back at query time)
    spilled = set(store.table.spilled_ids().tolist())
    for q in range(n_queries):
        got = set(rows[q][rows[q] >= 0].tolist())
        assert got <= ref_cands[q] <= got | spilled, \
            f"candidate mismatch at query {q}"

    speedup = t_dict_cand / t_store_cand
    em("search_candgen_dict", t_dict_cand * 1e6 / n_queries,
       f"qps={n_queries / t_dict_cand:.0f}")
    em("search_candgen_store", t_store_cand * 1e6 / n_queries,
       f"qps={n_queries / t_store_cand:.0f}|speedup={speedup:.1f}x")

    # end-to-end query (candidates + packed scoring + top-k)
    store.query(qsigs, top_k=10)           # warm the full query-batch trace
    t0 = time.perf_counter()
    ref_ids, ref_scores = store.query(qsigs, top_k=10)
    t_query = time.perf_counter() - t0
    em("search_query_store", t_query * 1e6 / n_queries,
       f"qps={n_queries / t_query:.0f}|n_items={n_items}")

    # fused device query path vs the legacy host fold, same store.  Queries
    # are the b=32 packed form (a bitcast of the int32 signatures) at a
    # serving-sized batch — the host walk's cost is per-query, the device
    # path's is per-dispatch, so the crossover is batch size (~1k on CPU).
    # Parity is checked on a superset batch with novel random rows appended
    # (the brute-force fallback leg), but the TIMED batch excludes them:
    # brute re-scores the whole corpus on host for both impls and would
    # otherwise swamp the LSH-path numbers being compared.  Interleaved
    # min-of-N, same convention as the obs-overhead row below — the two
    # impls flip on one store so drift hits both equally.
    from repro.kernels.dispatch import select_query_impl
    dev_impl = query_impl if query_impl not in ("auto", "host") \
        else select_query_impl()
    nq_pk = int(min(n_items, max(4 * n_queries, n_queries)))
    qsigs_pk = sigs[rng.choice(n_items, nq_pk, replace=False)]
    qwords = np.ascontiguousarray(qsigs_pk).view(np.uint32)
    novel = rng.integers(0, 1 << 20, (max(n_queries // 8, 4), k),
                         dtype=np.int32)
    qwords_par = np.ascontiguousarray(
        np.vstack([qsigs_pk, novel])).view(np.uint32)
    store.query_impl = "host"
    store.query_packed(qwords, top_k=10)           # warm host trace
    ref_pk = store.query_packed(qwords, top_k=10)
    ref_pk_par = store.query_packed(qwords_par, top_k=10)
    store.query_impl = dev_impl
    fused_par = store.query_packed(qwords_par, top_k=10)  # warm + parity
    assert np.array_equal(ref_pk_par[0], fused_par[0]), "fused ids diverge"
    assert np.array_equal(ref_pk_par[1], fused_par[1]), \
        "fused scores diverge"
    import gc
    t_host_l: list[float] = []
    t_fused_l: list[float] = []
    gc.disable()
    try:
        for _ in range(10):
            store.query_impl = "host"
            t0 = time.perf_counter()
            store.query_packed(qwords, top_k=10)
            t_host_l.append(time.perf_counter() - t0)
            store.query_impl = dev_impl
            t0 = time.perf_counter()
            store.query_packed(qwords, top_k=10)
            t_fused_l.append(time.perf_counter() - t0)
    finally:
        gc.enable()
    t_host_pk, t_fused_pk = min(t_host_l), min(t_fused_l)
    em("search_query_fused", t_fused_pk * 1e6 / nq_pk,
       f"qps={nq_pk / t_fused_pk:.0f}|impl={dev_impl}|batch={nq_pk}|"
       f"host_us={t_host_pk * 1e6 / nq_pk:.1f}|"
       f"query_fused_speedup={t_host_pk / t_fused_pk:.2f}x|"
       f"parity=exact_incl_brute")
    store.query_impl = query_impl          # the run-level knob from here on

    # observability overhead: the same queries against an identical store
    # built with the registry DISABLED (shared null handles bound at
    # construction) — the no-op fast-path claim, measured, not asserted
    # (wall-clock asserts flake on shared boxes; test_obs.py bounds the
    # per-op cost instead).  Interleaved min-of-N: run-to-run drift on a
    # shared box is bigger than the effect, so alternate the two stores
    # and take each side's minimum (see kernels/dispatch.py on why
    # non-interleaved timings mislead here)
    old_reg = obs_metrics.set_default(obs_metrics.NULL)
    try:
        store_off = SketchStore(make_cfg())
        store_off.add(sigs)
        store_off.query(qsigs, top_k=10)   # warm
    finally:
        obs_metrics.set_default(old_reg)
    import gc
    t_on_l: list[float] = []
    t_off_l: list[float] = []
    gc.disable()
    try:
        for _ in range(50):
            t0 = time.perf_counter()
            store.query(qsigs, top_k=10)
            t_on_l.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            store_off.query(qsigs, top_k=10)
            t_off_l.append(time.perf_counter() - t0)
    finally:
        gc.enable()
    t_on, t_off = min(t_on_l), min(t_off_l)
    del store_off
    em("search_obs_overhead", t_on * 1e6 / n_queries,
       f"disabled_us={t_off * 1e6 / n_queries:.1f}|"
       f"overhead_pct={(t_on - t_off) / t_off * 100.0:.2f}")

    # sharded serving plane: build + candgen+merge throughput per shard count
    # and per transport (inproc loop vs real tcp shard workers on localhost)
    # (per-shard geometry sized for its own n_items/S slice — sizing every
    # shard for the full corpus would run S tables at 1/S load and flatter
    # the sharded timings; results are geometry-independent either way)
    for s in shards:
        cfg_s = StoreConfig.sized_for(
            -(-n_items // s), k=k, n_bands=n_bands,
            rows_per_band=rows_per_band, bucket_width=4)
        # sharded queries ride the packed serving path (the fused device
        # pipeline per the run-level --query-impl; the coordinator folds
        # once and broadcasts hashes).  Parity target is the single store
        # on the HOST oracle: the timed batch against ref_pk, and an
        # untimed superset with novel brute-fallback rows against
        # ref_pk_par — every (transport, S) row re-proves fused == host
        # bit-for-bit including the fallback leg before it is timed.
        if "inproc" in transports:
            sh = ShardedSketchStore(cfg_s, n_shards=s, query_impl=query_impl)
            t0 = time.perf_counter()
            sh.add(sigs)
            t_build = time.perf_counter() - t0
            par = sh.query_packed(qwords_par, top_k=10)
            assert np.array_equal(par[0], ref_pk_par[0]) and \
                np.array_equal(par[1], ref_pk_par[1]), f"shard-brute S={s}"
            sh.query_packed(qwords, top_k=10)  # warm per-shard traces
            before = obs_metrics.default().snapshot()
            t_q, (ids, scores) = _timed_block(
                lambda: sh.query_packed(qwords, top_k=10), iters=5)
            lat = _stage_quantiles(before, obs_metrics.default().snapshot(),
                                   _query_stages(s))
            # the merge contract: S shards answer exactly like one store
            assert np.array_equal(ids, ref_pk[0]), f"shard-merge ids S={s}"
            assert np.array_equal(scores, ref_pk[1]), \
                f"shard-merge scores S={s}"
            em(f"search_build_sharded_s{s}", t_build * 1e6,
               f"items_per_s={n_items / t_build:.0f}"
               f"|sizes={sh.shard_sizes().tolist()}")
            em(f"search_query_sharded_s{s}", t_q * 1e6 / nq_pk,
               f"qps={nq_pk / t_q:.0f}|n_shards={s}|merge=exact|"
               + _timing_split(sh, nq_pk), latency=lat)
        if "tcp" in transports:
            from repro.transport import (connect_sharded, shutdown_plane,
                                         spawn_workers)
            handles = spawn_workers(cfg_s, s, query_impl=query_impl)
            sh = None
            try:
                sh = connect_sharded([h.address for h in handles], cfg_s,
                                     query_impl=query_impl)
                t0 = time.perf_counter()
                sh.add(sigs)               # over the wire, ADD per shard
                t_build = time.perf_counter() - t0
                par = sh.query_packed(qwords_par, top_k=10)
                assert np.array_equal(par[0], ref_pk_par[0]) and \
                    np.array_equal(par[1], ref_pk_par[1]), f"tcp-brute S={s}"
                sh.query_packed(qwords, top_k=10)  # warm worker traces
                before = obs_metrics.default().snapshot()
                t_q, (ids, scores) = _timed_block(
                    lambda: sh.query_packed(qwords, top_k=10), iters=5)
                lat = _stage_quantiles(before,
                                       obs_metrics.default().snapshot(),
                                       _query_stages(s))
                # tcp answers must equal the single store bit-for-bit too
                assert np.array_equal(ids, ref_pk[0]), f"tcp-merge ids S={s}"
                assert np.array_equal(scores, ref_pk[1]), \
                    f"tcp-merge scores S={s}"
                em(f"search_build_tcp_s{s}", t_build * 1e6,
                   f"items_per_s={n_items / t_build:.0f}"
                   f"|sizes={sh.shard_sizes().tolist()}")
                em(f"search_query_tcp_s{s}", t_q * 1e6 / nq_pk,
                   f"qps={nq_pk / t_q:.0f}|n_shards={s}|merge=exact|"
                   + _timing_split(sh, nq_pk), latency=lat)
            finally:
                if sh is not None:
                    shutdown_plane(sh, handles)
                else:                      # connect failed: nothing to ack
                    for h in handles:
                        h.terminate()

    # pipelined end-to-end ingest (sign -> pack -> scatter) per depth
    if pipeline_depths:
        _bench_ingest_pipeline(em, pipeline_depths, transports,
                               ingest_docs, ingest_batch)

    return rows_out


def main(argv=None) -> None:
    import argparse

    from . import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 1 iter (CI mode; numbers not "
                         "comparable)")
    ap.add_argument("--shards", default="2,4",
                    help="comma-separated shard counts for the sharded axis")
    ap.add_argument("--transport", default="both",
                    choices=["both", "inproc", "tcp"],
                    help="which shard backends the sharded axis measures")
    ap.add_argument("--pipeline-depth", default="1,2,4",
                    help="comma-separated ingest pipeline depths "
                         "(1 = serial baseline; empty disables the axis)")
    ap.add_argument("--query-impl", default="auto",
                    choices=["auto", "jnp", "pallas", "host"],
                    help="query backend for the sharded/tcp rows (host = "
                         "legacy fold + planner walk; every row is parity-"
                         "checked against host either way)")
    ap.add_argument("--n-items", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        common.set_smoke(True)
    kw = {}
    if args.smoke:
        kw.update(n_items=2_000, n_queries=16,
                  ingest_docs=1_000, ingest_batch=128)
    if args.n_items is not None:
        kw["n_items"] = args.n_items
    if args.n_queries is not None:
        kw["n_queries"] = args.n_queries
    kw["shards"] = tuple(int(s) for s in args.shards.split(",") if s)
    kw["transports"] = ("inproc", "tcp") if args.transport == "both" \
        else (args.transport,)
    kw["pipeline_depths"] = tuple(
        int(d) for d in args.pipeline_depth.split(",") if d)
    kw["query_impl"] = args.query_impl
    print("name,us_per_call,derived")
    run(**kw)


if __name__ == "__main__":
    main()
