"""SketchStore vs dict-based LSH path + the sharded serving plane.

The pre-SketchStore serving path bucketed signatures with per-item Python
``defaultdict`` loops; this benchmark keeps that path alive as the baseline
and measures the replacement at production-ish index sizes (default 100k
items): build items/s, candidate-generation queries/s (the array-ops hot path
the subsystem exists for), and end-to-end query QPS including packed scoring.

The ``--shards`` axis measures the partitioned plane (`ShardedSketchStore`):
per-S index build and end-to-end query throughput (candidate generation +
per-shard partial top-k + ``merge_topk``), asserting S-shard answers equal
the single-shard answers exactly.  The ``--transport`` axis runs the same
plane over real tcp shard workers (``repro.transport``) and records the
query wall-time split — submit/serialize (broadcast), per-shard partial
compute + gather (partial), and reduction (merge) — next to the inproc
split, so transport overhead is tracked per shard count from day one.

The ``search_query_fused`` row times the fused device query pipeline
(uint32-lane fold -> probe -> packed scoring, ``kernels/query_fused.py``)
against the legacy host fold on the same store — interleaved min-of-N, with
novel random queries appended so the brute-force fallback rows are inside
the parity check.  The sharded/tcp query rows ride the packed serving path
(``--query-impl``), record the coordinator ``fold_us`` next to the
broadcast/partial/merge split, and assert bit-identity against the
single-store HOST oracle at every (transport, S).

The ``--stream-rates`` axis is open-loop serving: Poisson arrivals at a
fixed offered qps submitted one query at a time through
``serve.stream.StreamingQueryService`` (admission coalescing + pipelined
sign/probe/score), reporting served throughput and client-side end-to-end
p50/p99 per (transport, S, arrival rate) — plus an injected-slow-shard pair
(one worker sleeping on a fraction of its reads) run hedged vs unhedged at
equal offered load, the tail-latency evidence for ``HedgePolicy``.  Every
streamed answer is asserted bit-identical to a pre-formed reference batch,
brute-fallback rows included.

The ``--pipeline-depth`` axis measures end-to-end ingest (sign -> pack ->
scatter) through ``serve.search.IngestPipeline`` per depth and transport,
recording the sign/wait/scatter wall-time split — ``wait`` is the device
sync, which shrinks toward zero when the scatter of batch N covered batch
N+1's signing (the overlap the pipeline exists for).  Every (transport,
depth) run is asserted to answer queries **bit-identically** to the serial
(depth=1) inproc ingest of the same batches.  Rows are returned for the
``BENCH_search.json`` artifact (written by ``run.py``).

Standalone:  PYTHONPATH=src python -m benchmarks.bench_search --smoke
"""

from __future__ import annotations

import time
from collections import defaultdict

import numpy as np

from repro.core.lsh import band_hashes
from repro.obs import metrics as obs_metrics
from repro.store import ShardedSketchStore, SketchStore, StoreConfig

from .common import emit

# reps for latency-bearing timed blocks: the p50/p90/p99 columns come from
# the registry histogram deltas over these calls, and a p99 over 5 samples
# is a max, not a tail — 50 back-to-back reps make the quantiles (and the
# honest "n" next to them) meaningful
LAT_ITERS = 50


# -- baseline: the pre-refactor dict path ------------------------------------

def _dict_build(hashes: np.ndarray) -> list[dict[int, list[int]]]:
    n, nb = hashes.shape
    buckets: list[dict[int, list[int]]] = [defaultdict(list)
                                           for _ in range(nb)]
    for i in range(n):
        row = hashes[i]
        for band in range(nb):
            buckets[band][int(row[band])].append(i)
    return buckets


def _dict_candidates(buckets, qhashes: np.ndarray) -> list[set[int]]:
    out = []
    for row in qhashes:
        mine: set[int] = set()
        for band, h in enumerate(row):
            mine.update(buckets[band].get(int(h), ()))
        out.append(mine)
    return out


def _timed_block(fn, iters=15):
    """Median wall time of back-to-back calls (the serving pattern), with GC
    paused — a multi-M-entry baseline dict makes every collection scan the
    whole heap, swamping both measurements."""
    import gc
    times = []
    gc.disable()
    try:
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn()
            times.append(time.perf_counter() - t0)
    finally:
        gc.enable()
    return sorted(times)[len(times) // 2], out


def _timing_split(sh, n_queries: int) -> str:
    """`last_timings` -> per-query fold/broadcast/partial/merge derived
    fields (fold_s is the coordinator-side band-hash fold; 0.0 on the sig
    path until the store folded at least one packed batch)."""
    t = sh.last_timings
    return "|".join(f"{key.split('_')[0]}_us="
                    f"{t.get(key, 0.0) * 1e6 / n_queries:.1f}"
                    for key in ("fold_s", "broadcast_s", "partial_s",
                                "merge_s"))


def _stage_quantiles(before: dict, after: dict,
                     names: tuple[str, ...]) -> dict:
    """p50/p90/p99 (in us) per stage histogram, from the registry delta
    between two snapshots — only the calls made between them count.
    Stages with no observations in the window are omitted.  ``n`` is the
    sample count behind the quantiles: a p99 over 5 observations is a max,
    not a tail — readers need the n to weigh it."""
    delta = obs_metrics.snapshot_delta(before, after)
    out: dict[str, dict[str, float]] = {}
    for name in names:
        h = delta["hists"].get(name)
        if not h or not h.get("count"):
            continue
        out[name] = {"n": int(h["count"]), **{
            f"p{int(q * 100)}_us": round(
                (obs_metrics.hist_quantile(h, q) or 0.0) * 1e6, 1)
            for q in (0.5, 0.9, 0.99)}}
    return out


def _query_stages(n_shards: int) -> tuple[str, ...]:
    return (("query.wall", "query.fold", "query.broadcast", "query.partial",
             "query.merge")
            + tuple(f"query.shard{i}.partial" for i in range(n_shards)))


def _bench_ingest_pipeline(em, depths: tuple[int, ...],
                           transports: tuple[str, ...],
                           n_docs: int, batch: int) -> None:
    """End-to-end pipelined ingest (sign -> pack -> scatter) per depth and
    transport, with the sign/wait/scatter split and a bit-identity assert
    of every run against serial (depth=1) inproc ingest."""
    import time as _time

    from repro.serve.search import SearchConfig, SimilaritySearchService

    d, k, nb, r, s = 1 << 14, 128, 32, 4, 2
    rng = np.random.default_rng(7)
    nnz = 160
    docs = np.sort(rng.integers(0, d, (n_docs, nnz), np.int32), axis=1)
    docs[n_docs - n_docs // 20:] = docs[: n_docs // 20]   # planted dups
    q = docs[rng.choice(n_docs, min(64, n_docs), replace=False)]
    batches = [docs[lo: lo + batch] for lo in range(0, n_docs, batch)]

    # signing is shape-specialized: warm every distinct batch shape once so
    # the timed runs measure steady-state ingest, not XLA compiles (the jit
    # caches are module-level, so one warm service covers every run)
    warm = SimilaritySearchService(SearchConfig(
        d=d, k=k, n_bands=nb, rows_per_band=r))
    for shape_rep in {bt.shape: bt for bt in batches + [q]}.values():
        np.asarray(warm._sign(shape_rep, "sparse"))

    def build(transport, depth):
        svc = SimilaritySearchService(SearchConfig(
            d=d, k=k, n_bands=nb, rows_per_band=r, n_shards=s,
            transport=transport))
        with svc:
            before = obs_metrics.default().snapshot()
            with svc.pipeline(depth=depth) as pipe:
                t0 = _time.perf_counter()
                for bt in batches:
                    pipe.submit(bt)
                pipe.flush()
                wall = _time.perf_counter() - t0
            lat = _stage_quantiles(
                before, obs_metrics.default().snapshot(),
                ("ingest.sign", "ingest.wait", "ingest.scatter"))
            ans = svc.query_sparse(q, top_k=10)
            return wall, dict(pipe.timings), lat, ans

    # serial inproc ingest is ALWAYS the parity baseline (run first even
    # when not requested as an emitted row)
    asked = [(tr, dep) for tr in transports for dep in depths]
    ordered = [("inproc", 1)] + [rd for rd in asked if rd != ("inproc", 1)]
    ref = None
    for transport, depth in ordered:
        wall, tm, lat, ans = build(transport, depth)
        if ref is None:
            ref = ans
        else:             # pipelining must never change an answer
            assert np.array_equal(ref[0], ans[0]), \
                f"ingest-pipeline ids diverge ({transport}, depth={depth})"
            assert np.array_equal(ref[1], ans[1]), \
                f"ingest-pipeline scores diverge ({transport}, depth={depth})"
        if (transport, depth) in asked:
            em(f"search_ingest_{transport}_d{depth}", wall * 1e6,
               f"items_per_s={n_docs / wall:.0f}|parity=exact|"
               f"sign_ms={tm['sign_s'] * 1e3:.1f}|"
               f"wait_ms={tm['wait_s'] * 1e3:.1f}|"
               f"scatter_ms={tm['scatter_s'] * 1e3:.1f}",
               latency=lat)


def _bench_stream_open_loop(em, *, transports: tuple[str, ...],
                            shards: tuple[int, ...],
                            arrival_rates: tuple[float, ...],
                            n_docs: int, n_stream: int,
                            max_batch: int = 64, max_delay_ms: float = 2.0,
                            depth: int = 2, slow_prob: float = 0.02,
                            slow_sleep_ms: float = 600.0,
                            hedge_delay_ms: float | None = None) -> None:
    """Open-loop streaming axis: Poisson arrivals at fixed offered qps
    through ``StreamingQueryService``, reporting served throughput and
    client-side end-to-end p50/p99 per (transport, S, arrival rate) — the
    latency an outside caller would see, admission wait included.  Every
    streamed answer is asserted bit-identical to one reference batch on
    the same plane (novel rows in the stream keep the brute-fallback leg
    inside the parity check).  The final rows inject one slow shard into a
    tcp S=max plane and run the same open loop hedged vs unhedged at equal
    offered load — the tail-cutting evidence for ``HedgePolicy``.
    """
    from repro.serve.search import SearchConfig, SimilaritySearchService
    from repro.store.store import StoreConfig

    d, k, nb, r = 1 << 14, 128, 32, 4
    nnz = 160
    rng = np.random.default_rng(11)
    docs = np.sort(rng.integers(0, d, (n_docs, nnz), np.int32), axis=1)
    qrows = docs[rng.integers(0, n_docs, n_stream)].copy()
    # a few novel rows keep the brute-fallback leg inside the parity check
    # WITHOUT making it the service bottleneck: each novel row drags its
    # whole batch through a full-corpus brute round, so the density must
    # stay low enough that most batches are candidate-only
    novel = np.sort(rng.integers(0, d, (max(min(n_stream // 128, 8), 2),
                                        nnz), np.int32), axis=1)
    qrows[rng.choice(n_stream, len(novel), replace=False)] = novel

    def build_plane(transport, s, slow=None, hedge=False):
        cfg = SearchConfig(d=d, k=k, n_bands=nb, rows_per_band=r,
                           n_shards=s, transport=transport, hedge=hedge,
                           hedge_delay_ms=hedge_delay_ms if hedge else None)
        if slow is not None:
            # injected-slow planes spawn their workers directly so the
            # slow_shards knob reaches run_worker; the service then wraps
            # the pre-built store (its own ctor has no slowness knob —
            # this is a bench scenario, not an operator feature)
            from repro.transport import (HedgePolicy, connect_sharded,
                                         spawn_workers)
            store_cfg = StoreConfig(k=cfg.k, n_bands=cfg.n_bands,
                                    rows_per_band=cfg.rows_per_band,
                                    b=cfg.b, n_slots=cfg.n_slots,
                                    bucket_width=cfg.bucket_width)
            workers = spawn_workers(store_cfg, s, slow_shards=slow)
            try:
                # hedge_delay_ms=None -> the production skew-derived delay
                # (2x the p90 of the PEER shards' reply skew); smoke pins
                # a fixed delay instead — too few rounds to derive one
                policy = None
                if hedge:
                    policy = HedgePolicy() if hedge_delay_ms is None \
                        else HedgePolicy(delay_s=hedge_delay_ms / 1e3)
                store = connect_sharded(
                    [h.address for h in workers], store_cfg, hedge=policy)
            except BaseException:
                for h in workers:
                    h.terminate()
                raise
            return SimilaritySearchService(cfg, store=store, workers=workers)
        return SimilaritySearchService(cfg)

    def run_plane(svc, rows=qrows):
        """Ingest + shape warmup + the per-plane parity reference."""
        for lo in range(0, n_docs, 512):
            svc.add_sparse(docs[lo: lo + 512])
        # the query path is shape-specialized: warm every pow2 admission
        # bucket plus the reference batch shape so the open loop measures
        # steady-state serving, not XLA compiles
        b = 1
        while b <= max_batch:
            svc.query_sparse(rows[:b], top_k=10)
            b *= 2
        # the brute-fallback leg specializes on its (pow2-padded) fallback
        # row count: warm every padded count the stream can produce with
        # all-novel batches, or the first batch to hit a fresh count eats a
        # multi-second worker-side compile mid-open-loop
        j = 1
        while j <= min(1 << (len(novel) - 1).bit_length(), max_batch):
            svc.query_sparse(novel[np.arange(j) % len(novel)], top_k=10)
            j *= 2
        return svc.query_sparse(rows, top_k=10)

    def open_loop(svc, ref, rate, seed, rows=qrows):
        gaps = np.random.default_rng(seed).exponential(1.0 / rate, n_stream)
        arrivals = np.cumsum(gaps)
        with svc.stream(max_batch=max_batch, max_delay_ms=max_delay_ms,
                        depth=depth) as st:
            t0 = time.perf_counter()
            tickets = []
            for i in range(n_stream):
                lag = t0 + arrivals[i] - time.perf_counter()
                if lag > 0:
                    time.sleep(lag)
                tickets.append(st.submit_sparse(rows[i], top_k=10))
            t_submit = time.perf_counter() - t0
            for t in tickets:
                t.result(timeout=120)
        wall = max(t.t_done for t in tickets) - t0
        for i, t in enumerate(tickets):     # streamed == one big batch
            ids, scores = t.result()
            assert np.array_equal(ids, ref[0][i]), f"stream ids q{i}"
            assert np.array_equal(scores, ref[1][i]), f"stream scores q{i}"
        lat = np.sort([t.latency_s for t in tickets])
        return {"offered_qps": n_stream / t_submit,
                "qps": n_stream / wall,
                "p50_ms": lat[int(0.50 * (n_stream - 1))] * 1e3,
                "p99_ms": lat[int(0.99 * (n_stream - 1))] * 1e3,
                "mean_us": float(np.mean(lat)) * 1e6,
                "batches": st.n_batches}

    def emit_row(name, m, extra=""):
        em(name, m["mean_us"],
           f"qps={m['qps']:.0f}|offered_qps={m['offered_qps']:.0f}|"
           f"p50_ms={m['p50_ms']:.2f}|p99_ms={m['p99_ms']:.2f}|"
           f"batches={m['batches']}|depth={depth}|"
           f"max_batch={max_batch}|max_delay_ms={max_delay_ms}|"
           f"parity=exact_incl_brute{extra}",
           latency={"stream.e2e": {"n": n_stream,
                                   "p50_us": round(m["p50_ms"] * 1e3, 1),
                                   "p99_us": round(m["p99_ms"] * 1e3, 1)}})

    for transport in transports:
        for s in shards:
            with build_plane(transport, s) as svc:
                ref = run_plane(svc)
                for rate in arrival_rates:
                    m = open_loop(svc, ref, rate, seed=int(rate))
                    emit_row(f"search_stream_{transport}_s{s}_r{int(rate)}",
                             m, "|hedge=off")

    if "tcp" not in transports or not shards:
        return
    # the slow-shard pair: same plane shape, same offered load, one shard
    # sleeping slow_sleep_ms on slow_prob of its reads — only the hedge
    # knob differs between the two rows.  slow_prob sizing is a two-sided
    # constraint on the p99 index (~1% of rounds).  Unhedged side: stalled
    # rounds (~slow_prob of them, plus queueing echoes) must well exceed 1%
    # so the unhedged p99 pins at the stall time.  Hedged side: every round
    # issued while a stall drains its lane fires a (correct) hedge, and
    # each hedge gives the TWIN lane its own slow_prob draw — so rounds
    # where both legs stall happen at roughly hedge_count * slow_prob, a
    # number that scales ~quadratically with slow_prob and must stay below
    # the p99 index or the hedged row pins at the stall time too.  0.02
    # leaves ~2x margin on both sides at the row sizes used here; 0.04
    # (measured) puts the double-stall count right AT the index.
    s = max(shards)
    slow = {s - 1: (slow_prob, slow_sleep_ms / 1e3)}
    # the slow plane's service rate is a fraction of the healthy plane's
    # (slow_prob of its rounds stall slow_sleep_ms): offer a rate both rows
    # can serve WITHOUT queue growth, or the percentiles measure backlog
    # depth instead of tail behavior and the hedge comparison is meaningless
    # (/6 also leaves CPU headroom for the hedges' duplicate reads — on an
    # oversubscribed box they'd otherwise contend with the primary reads).
    # slow_sleep_ms must tower over the host's own scheduling-noise tail
    # (hundreds of ms on an oversubscribed CI box): the hedge can only cut
    # the injected stall, so a stall under the noise floor is invisible in
    # a p99 comparison no matter how well the hedge works
    slow_rate = min(arrival_rates) / 6
    # indexed-only rows for this pair: a novel row drags a full-corpus
    # brute round — un-hedgeable compute that lands in BOTH rows' p99 and
    # drowns the shard-skew signal the hedge exists to cut
    slow_rows = docs[np.random.default_rng(5).integers(0, n_docs, n_stream)]
    p99 = {}
    for hedged in (False, True):
        with build_plane("tcp", s, slow=slow, hedge=hedged) as svc:
            ref = run_plane(svc, rows=slow_rows)
            m = open_loop(svc, ref, slow_rate, seed=97, rows=slow_rows)
            tag = "hedged" if hedged else "unhedged"
            g = svc.store.shards[0].group
            emit_row(f"search_stream_tcp_s{s}_slow_{tag}", m,
                     f"|hedge={'on' if hedged else 'off'}|"
                     f"slow_shard={s - 1}|slow_prob={slow_prob}|"
                     f"slow_ms={slow_sleep_ms}|"
                     f"hedges={g.n_hedges}|hedge_wins={g.n_hedge_wins}")
            p99[tag] = m["p99_ms"]
    em("search_stream_hedge_p99_cut", 0.0,
       f"unhedged_p99_ms={p99['unhedged']:.2f}|"
       f"hedged_p99_ms={p99['hedged']:.2f}|"
       f"cut={p99['unhedged'] / max(p99['hedged'], 1e-9):.1f}x")


def _bench_overload(em, *, n_docs: int, n_stream: int, n_storm: int,
                    rates_x: tuple[float, ...] = (0.5, 1.0, 2.0, 4.0),
                    max_batch: int = 32, max_queue: int = 64,
                    query_timeout_s: float = 10.0) -> None:
    """Overload axis: open-loop arrivals swept past measured capacity.

    A tcp S=2 plane serves through the bounded-admission streaming front
    (``max_queue`` + per-ticket ``query_timeout_s``).  Capacity is measured
    closed-loop first, then Poisson arrivals are offered at ``rates_x``
    multiples of it.  The overload contract is ASSERTED, not just
    reported: past saturation (>= 2x capacity) goodput stays within 20% of
    the sweep's peak (shedding keeps admitted work at capacity instead of
    collapsing under queue growth), the p99 of answered queries stays
    bounded by the deadline, shed > 0, and every answered query is
    bit-identical to the unloaded reference — zero wrong answers.

    The retry-storm pair then drives a fully-shedding worker
    (``gate_limit=0``) through the stream's retry path: the shared
    ``RetryBudget`` caps total retry traffic, while the unbudgeted
    baseline amplifies every rejection into ``retries`` more requests
    (asserted >= 2x the budgeted traffic).
    """
    from repro.serve.search import SearchConfig, SimilaritySearchService
    from repro.transport import (DeadlineExceeded, Overloaded, RetryBudget,
                                 connect_sharded, spawn_workers)

    d, k, nb, r = 1 << 14, 128, 32, 4
    nnz = 160
    rng = np.random.default_rng(23)
    docs = np.sort(rng.integers(0, d, (n_docs, nnz), np.int32), axis=1)
    qrows = docs[rng.integers(0, n_docs, n_stream)].copy()

    cfg = SearchConfig(d=d, k=k, n_bands=nb, rows_per_band=r,
                       n_shards=2, transport="tcp")
    results: dict[float, dict] = {}
    with SimilaritySearchService(cfg) as svc:
        for lo in range(0, n_docs, 512):
            svc.add_sparse(docs[lo: lo + 512])
        b = 1
        while b <= max_batch:                  # warm every pow2 shape
            svc.query_sparse(qrows[:b], top_k=10)
            b *= 2
        ref = svc.query_sparse(qrows, top_k=10)

        # closed-loop capacity: back-to-back full-size batches
        t0 = time.perf_counter()
        for lo in range(0, 2 * n_stream, max_batch):
            svc.query_sparse(qrows[lo % n_stream:
                                   lo % n_stream + max_batch], top_k=10)
        capacity = 2 * n_stream / (time.perf_counter() - t0)

        for x in rates_x:
            rate = capacity * x
            gaps = np.random.default_rng(int(x * 100)).exponential(
                1.0 / rate, n_stream)
            arrivals = np.cumsum(gaps)
            with svc.stream(max_batch=max_batch, max_delay_ms=2.0, depth=2,
                            max_queue=max_queue,
                            query_timeout_s=query_timeout_s) as st:
                t0 = time.perf_counter()
                tickets = []
                for i in range(n_stream):
                    lag = t0 + arrivals[i] - time.perf_counter()
                    if lag > 0:
                        time.sleep(lag)
                    tickets.append(st.submit_sparse(qrows[i], top_k=10))
                done, shed, expired, wrong = [], 0, 0, 0
                for i, t in enumerate(tickets):
                    try:
                        ids, scores = t.result(timeout=120)
                    except Overloaded:
                        shed += 1
                        continue
                    except DeadlineExceeded:
                        expired += 1
                        continue
                    if not (np.array_equal(ids, ref[0][i])
                            and np.array_equal(scores, ref[1][i])):
                        wrong += 1
                    done.append(t.latency_s)
            wall = max(t.t_done for t in tickets) - t0
            lat = np.sort(done)
            p99 = lat[int(0.99 * (len(lat) - 1))] * 1e3 if len(done) else 0.0
            m = {"goodput": len(done) / wall, "shed": shed,
                 "expired": expired, "wrong": wrong, "p99_ms": p99}
            results[x] = m
            em(f"search_overload_tcp_s2_x{x:g}",
               float(np.mean(lat)) * 1e6 if len(done) else 0.0,
               f"offered_x={x:g}|offered_qps={rate:.0f}|"
               f"capacity_qps={capacity:.0f}|"
               f"goodput_qps={m['goodput']:.0f}|answered={len(done)}|"
               f"shed={shed}|expired={expired}|wrong={wrong}|"
               f"p99_ms={p99:.2f}|max_queue={max_queue}|"
               f"query_timeout_s={query_timeout_s:g}|parity=exact_answered")
            assert wrong == 0, \
                f"{wrong} wrong answers under {x:g}x overload"

    peak = max(m["goodput"] for m in results.values())
    for x, m in results.items():
        if x < 2.0:
            continue
        assert m["shed"] + m["expired"] > 0, \
            f"no shedding at {x:g}x capacity — admission bound never bit"
        assert m["goodput"] >= 0.8 * peak, \
            (f"goodput collapsed under overload: {m['goodput']:.0f} qps at "
             f"{x:g}x vs peak {peak:.0f}")
        assert m["p99_ms"] <= query_timeout_s * 1e3, \
            f"p99 {m['p99_ms']:.0f}ms exceeds the {query_timeout_s}s deadline"

    # -- retry storm: budgeted vs unbudgeted over a fully-shedding worker ----
    storm: dict[str, int] = {}
    for tag, budget in (
            ("budgeted", RetryBudget(ratio=0.05, cap=5.0, floor_per_s=0.0)),
            ("unbudgeted", RetryBudget(unlimited=True))):
        store_cfg = StoreConfig(k=k, n_bands=nb, rows_per_band=r)
        workers = spawn_workers(store_cfg, 1, gate_limit=0)
        svc2 = None
        try:
            try:
                store = connect_sharded([h.address for h in workers],
                                        store_cfg, budget=budget)
            except BaseException:
                for h in workers:
                    h.terminate()
                raise
            svc2 = SimilaritySearchService(
                SearchConfig(d=d, k=k, n_bands=nb, rows_per_band=r,
                             n_shards=1, transport="tcp"),
                store=store, workers=workers)
            svc2.add_sparse(docs[:64])         # writes bypass the gate
            n_failed = 0
            with svc2.stream(max_batch=8, max_delay_ms=0.5,
                             retries=3) as st:
                tickets = [st.submit_sparse(qrows[i % n_stream], top_k=10)
                           for i in range(n_storm)]
                for t in tickets:
                    try:
                        t.result(timeout=120)
                    except Overloaded:
                        n_failed += 1
            storm[tag] = budget.n_spent
            em(f"search_overload_retry_storm_{tag}", 0.0,
               f"queries={n_storm}|failed={n_failed}|"
               f"retries_spent={budget.n_spent}|"
               f"retries_denied={budget.n_denied}|"
               f"primaries={budget.n_primaries}|stream_retries=3")
        finally:
            if svc2 is not None:
                svc2.close()
    assert storm["unbudgeted"] >= 2 * max(storm["budgeted"], 1), \
        (f"retry budget did not cap the storm: budgeted="
         f"{storm['budgeted']} unbudgeted={storm['unbudgeted']}")


def _bench_availability(em, *, n_docs: int, n_queries: int, rounds: int,
                        kill_round: int, k: int = 128, n_bands: int = 32,
                        rows_per_band: int = 4) -> None:
    """Availability axis: the same mid-traffic kill, unreplicated vs
    replicated.

    Both planes are S=2 tcp; shard 0's worker carries a deterministic
    ``FaultPlan`` that hard-kills it on its ``kill_round + 1``-th QUERY
    (the warmup round is #0) — death lands mid-protocol on the exact same
    message every run, not wherever a wall-clock ``terminate()`` race puts
    it.  The unreplicated row records the outage —
    every round from the kill on fails until an operator rebuilds the
    plane (the pre-PR-9 behavior, measured, not asserted).  The replicated
    row (R=2 + write-ahead ingest journal + supervisor) must answer EVERY
    round bit-identically to the single-store reference: it records the
    p99 across all rounds INCLUDING the kill instant (the in-round
    failover's price) and the measured recovery time from the kill to the
    supervisor's digest-verified rejoin restoring R=2.
    """
    import tempfile

    from repro.replica import (IngestJournal, Supervisor, connect_replicated,
                               spawn_replicated)
    from repro.transport import (FaultEvent, FaultPlan, TransportError,
                                 connect_sharded, shutdown_plane,
                                 spawn_workers)

    # the warm query is QUERY #0, so round i is the worker's QUERY
    # #(i + 1): the kill fires as the victim receives round kill_round's
    # query — the same protocol point every run
    kill = FaultPlan([FaultEvent("kill", kill_round + 1, "query")])

    cfg = StoreConfig.sized_for(-(-n_docs // 2), k=k, n_bands=n_bands,
                                rows_per_band=rows_per_band, bucket_width=4)
    rng = np.random.default_rng(17)
    sigs = rng.integers(0, 1 << 20, (n_docs, k), dtype=np.int32)
    qsigs = sigs[rng.choice(n_docs, n_queries, replace=False)]
    ref_store = SketchStore(cfg)
    ref_store.add(sigs)
    ref = ref_store.query(qsigs, top_k=10)

    # -- unreplicated S=2: the kill is an outage ----------------------------
    handles = spawn_workers(cfg, 2, faults={0: kill})
    sh = None
    lat, failed = [], 0
    try:
        sh = connect_sharded([h.address for h in handles], cfg)
        sh.add(sigs)
        sh.query(qsigs, top_k=10)          # warm the shape
        for i in range(rounds):
            t0 = time.perf_counter()
            try:
                ids, scores = sh.query(qsigs, top_k=10)
                assert np.array_equal(ids, ref[0]), "unreplicated parity"
                lat.append(time.perf_counter() - t0)
            except TransportError:
                failed += 1                # down until an operator rebuilds
    finally:
        if sh is not None:
            shutdown_plane(sh, handles)
        else:
            for h in handles:
                h.terminate()
    p99u = float(np.percentile(lat, 99)) * 1e3 if lat else float("nan")
    em("search_avail_tcp_s2_unreplicated",
       float(np.mean(lat)) * 1e6 if lat else 0.0,
       f"rounds={rounds}|killed_round={kill_round}|failed_rounds={failed}|"
       f"p99_ms={p99u:.2f}|recovered=no|outage=until_operator_rebuild")

    # -- replicated S=2 x R=2: zero failed rounds, measured recovery --------
    with tempfile.TemporaryDirectory() as tdir:
        journal = IngestJournal(f"{tdir}/ingest.journal")
        grid = spawn_replicated(cfg, 2, 2, faults={(0, 0): kill})
        store = sup = None
        lat, t_kill, t_rec = [], None, None
        try:
            store = connect_replicated(grid, cfg, journal=journal)
            sup = Supervisor(store, interval_s=0.2)
            sup.start()                    # heals concurrently with serving
            store.add(sigs)
            store.query(qsigs, top_k=10)   # warm the shape
            for i in range(rounds):
                if i == kill_round:
                    t_kill = time.perf_counter()   # plan kills the PRIMARY
                t0 = time.perf_counter()
                ids, scores = store.query(qsigs, top_k=10)
                lat.append(time.perf_counter() - t0)
                # the availability contract IS parity on every round
                assert np.array_equal(ids, ref[0]), f"replicated ids r{i}"
                assert np.array_equal(scores, ref[1]), \
                    f"replicated scores r{i}"
                if t_kill is not None and t_rec is None and \
                        all(l.up for rs in store.shards for l in rs.lanes):
                    t_rec = time.perf_counter()
            deadline = time.perf_counter() + 120
            while t_rec is None and time.perf_counter() < deadline:
                if all(l.up for rs in store.shards for l in rs.lanes):
                    t_rec = time.perf_counter()
                    break
                time.sleep(0.2)
        finally:
            if sup is not None:
                sup.stop()
            if store is not None:
                hs = [l.handle for rs in store.shards for l in rs.lanes
                      if l.handle is not None]
                shutdown_plane(store, hs)
            else:
                for row in grid:
                    for h in row:
                        h.terminate()
            journal.close()
        p99r = float(np.percentile(lat, 99)) * 1e3
        kill_ms = lat[kill_round] * 1e3
        rec = "none" if t_rec is None else f"{t_rec - t_kill:.2f}"
        em("search_avail_tcp_s2_replicated_r2", float(np.mean(lat)) * 1e6,
           f"rounds={rounds}|killed_round={kill_round}|failed_rounds=0|"
           f"p99_ms={p99r:.2f}|killed_round_ms={kill_ms:.2f}|"
           f"recovery_s={rec}|parity=exact_all_rounds|journal=on")


def run(n_items: int = 100_000, n_queries: int = 256, k: int = 128,
        n_bands: int = 32, rows_per_band: int = 4,
        shards: tuple[int, ...] = (2, 4),
        transports: tuple[str, ...] = ("inproc", "tcp"),
        pipeline_depths: tuple[int, ...] = (1, 2, 4),
        ingest_docs: int = 20_000, ingest_batch: int = 512,
        query_impl: str = "auto",
        arrival_rates: tuple[float, ...] | None = (150.0, 1000.0),
        stream_queries: int | None = None,
        availability: bool | None = None,
        overload: bool | None = None) -> list[dict]:
    rows_out: list[dict] = []

    def em(name, us, derived, **fields):
        emit(name, us, derived)
        rows_out.append({"name": name, "us_per_call": round(us, 1),
                         "derived": derived, **fields})

    rng = np.random.default_rng(0)
    sigs = rng.integers(0, 1 << 20, (n_items, k), dtype=np.int32)
    # plant ~1% duplicate structure (clusters of <= 3) so buckets are not all
    # singletons but stay within bucket_width
    n_dup = max(n_items // 100, 2)
    picks = rng.choice(n_items, n_dup + n_dup // 2, replace=False)
    src, dup = picks[: n_dup // 2], picks[n_dup // 2:]
    sigs[dup] = sigs[np.repeat(src, 2)[: len(dup)]]
    qsigs = sigs[rng.choice(n_items, n_queries, replace=False)]
    hashes = band_hashes(sigs, n_bands, rows_per_band)
    qhashes = band_hashes(qsigs, n_bands, rows_per_band)

    # build
    t0 = time.perf_counter()
    buckets = _dict_build(hashes)
    t_dict_build = time.perf_counter() - t0

    def make_cfg():
        return StoreConfig.sized_for(
            n_items, k=k, n_bands=n_bands, rows_per_band=rows_per_band,
            bucket_width=4)

    # pack_codes is shape-specialized: warm the FULL (n_items, k) trace so
    # the timed build measures steady-state throughput, not XLA compile
    SketchStore(make_cfg()).add(sigs)
    store = SketchStore(make_cfg())
    t0 = time.perf_counter()
    store.add(sigs)
    t_store_build = time.perf_counter() - t0

    em("search_build_dict", t_dict_build * 1e6,
       f"items_per_s={n_items / t_dict_build:.0f}")
    em("search_build_store", t_store_build * 1e6,
       f"items_per_s={n_items / t_store_build:.0f}"
       f"|rebuilds={store.n_rebuilds}|spilled={store.n_spilled}"
       f"|load={store.table.load_factor:.2f}")

    # candidate generation (the array-ops hot path)
    t_dict_cand, ref_cands = _timed_block(
        lambda: _dict_candidates(buckets, qhashes))
    t_store_cand, rows = _timed_block(lambda: store.table.lookup(qhashes))

    # sanity: both paths propose identical candidate sets (spilled entries,
    # if any, are a conservative superset added back at query time)
    spilled = set(store.table.spilled_ids().tolist())
    for q in range(n_queries):
        got = set(rows[q][rows[q] >= 0].tolist())
        assert got <= ref_cands[q] <= got | spilled, \
            f"candidate mismatch at query {q}"

    speedup = t_dict_cand / t_store_cand
    em("search_candgen_dict", t_dict_cand * 1e6 / n_queries,
       f"qps={n_queries / t_dict_cand:.0f}")
    em("search_candgen_store", t_store_cand * 1e6 / n_queries,
       f"qps={n_queries / t_store_cand:.0f}|speedup={speedup:.1f}x")

    # end-to-end query (candidates + packed scoring + top-k)
    store.query(qsigs, top_k=10)           # warm the full query-batch trace
    t0 = time.perf_counter()
    ref_ids, ref_scores = store.query(qsigs, top_k=10)
    t_query = time.perf_counter() - t0
    em("search_query_store", t_query * 1e6 / n_queries,
       f"qps={n_queries / t_query:.0f}|n_items={n_items}")

    # fused device query path vs the legacy host fold, same store.  Queries
    # are the b=32 packed form (a bitcast of the int32 signatures) at a
    # serving-sized batch — the host walk's cost is per-query, the device
    # path's is per-dispatch, so the crossover is batch size (~1k on CPU).
    # Parity is checked on a superset batch with novel random rows appended
    # (the brute-force fallback leg), but the TIMED batch excludes them:
    # brute re-scores the whole corpus on host for both impls and would
    # otherwise swamp the LSH-path numbers being compared.  Interleaved
    # min-of-N, same convention as the obs-overhead row below — the two
    # impls flip on one store so drift hits both equally.
    from repro.kernels.dispatch import select_query_impl
    dev_impl = query_impl if query_impl not in ("auto", "host") \
        else select_query_impl()
    nq_pk = int(min(n_items, max(4 * n_queries, n_queries)))
    qsigs_pk = sigs[rng.choice(n_items, nq_pk, replace=False)]
    qwords = np.ascontiguousarray(qsigs_pk).view(np.uint32)
    novel = rng.integers(0, 1 << 20, (max(n_queries // 8, 4), k),
                         dtype=np.int32)
    qwords_par = np.ascontiguousarray(
        np.vstack([qsigs_pk, novel])).view(np.uint32)
    store.query_impl = "host"
    store.query_packed(qwords, top_k=10)           # warm host trace
    ref_pk = store.query_packed(qwords, top_k=10)
    ref_pk_par = store.query_packed(qwords_par, top_k=10)
    store.query_impl = dev_impl
    fused_par = store.query_packed(qwords_par, top_k=10)  # warm + parity
    assert np.array_equal(ref_pk_par[0], fused_par[0]), "fused ids diverge"
    assert np.array_equal(ref_pk_par[1], fused_par[1]), \
        "fused scores diverge"
    import gc
    t_host_l: list[float] = []
    t_fused_l: list[float] = []
    gc.disable()
    try:
        for _ in range(10):
            store.query_impl = "host"
            t0 = time.perf_counter()
            store.query_packed(qwords, top_k=10)
            t_host_l.append(time.perf_counter() - t0)
            store.query_impl = dev_impl
            t0 = time.perf_counter()
            store.query_packed(qwords, top_k=10)
            t_fused_l.append(time.perf_counter() - t0)
    finally:
        gc.enable()
    t_host_pk, t_fused_pk = min(t_host_l), min(t_fused_l)
    em("search_query_fused", t_fused_pk * 1e6 / nq_pk,
       f"qps={nq_pk / t_fused_pk:.0f}|impl={dev_impl}|batch={nq_pk}|"
       f"host_us={t_host_pk * 1e6 / nq_pk:.1f}|"
       f"query_fused_speedup={t_host_pk / t_fused_pk:.2f}x|"
       f"parity=exact_incl_brute")
    store.query_impl = query_impl          # the run-level knob from here on

    # observability overhead: the same queries against an identical store
    # built with the registry DISABLED (shared null handles bound at
    # construction) — the no-op fast-path claim, measured, not asserted
    # (wall-clock asserts flake on shared boxes; test_obs.py bounds the
    # per-op cost instead).  Interleaved min-of-N: run-to-run drift on a
    # shared box is bigger than the effect, so alternate the two stores
    # and take each side's minimum (see kernels/dispatch.py on why
    # non-interleaved timings mislead here)
    old_reg = obs_metrics.set_default(obs_metrics.NULL)
    try:
        store_off = SketchStore(make_cfg())
        store_off.add(sigs)
        store_off.query(qsigs, top_k=10)   # warm
    finally:
        obs_metrics.set_default(old_reg)
    import gc
    t_on_l: list[float] = []
    t_off_l: list[float] = []
    gc.disable()
    try:
        for _ in range(50):
            t0 = time.perf_counter()
            store.query(qsigs, top_k=10)
            t_on_l.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            store_off.query(qsigs, top_k=10)
            t_off_l.append(time.perf_counter() - t0)
    finally:
        gc.enable()
    t_on, t_off = min(t_on_l), min(t_off_l)
    del store_off
    em("search_obs_overhead", t_on * 1e6 / n_queries,
       f"disabled_us={t_off * 1e6 / n_queries:.1f}|"
       f"overhead_pct={(t_on - t_off) / t_off * 100.0:.2f}")

    # sharded serving plane: build + candgen+merge throughput per shard count
    # and per transport (inproc loop vs real tcp shard workers on localhost)
    # (per-shard geometry sized for its own n_items/S slice — sizing every
    # shard for the full corpus would run S tables at 1/S load and flatter
    # the sharded timings; results are geometry-independent either way)
    for s in shards:
        cfg_s = StoreConfig.sized_for(
            -(-n_items // s), k=k, n_bands=n_bands,
            rows_per_band=rows_per_band, bucket_width=4)
        # sharded queries ride the packed serving path (the fused device
        # pipeline per the run-level --query-impl; the coordinator folds
        # once and broadcasts hashes).  Parity target is the single store
        # on the HOST oracle: the timed batch against ref_pk, and an
        # untimed superset with novel brute-fallback rows against
        # ref_pk_par — every (transport, S) row re-proves fused == host
        # bit-for-bit including the fallback leg before it is timed.
        if "inproc" in transports:
            sh = ShardedSketchStore(cfg_s, n_shards=s, query_impl=query_impl)
            t0 = time.perf_counter()
            sh.add(sigs)
            t_build = time.perf_counter() - t0
            par = sh.query_packed(qwords_par, top_k=10)
            assert np.array_equal(par[0], ref_pk_par[0]) and \
                np.array_equal(par[1], ref_pk_par[1]), f"shard-brute S={s}"
            sh.query_packed(qwords, top_k=10)  # warm per-shard traces
            before = obs_metrics.default().snapshot()
            t_q, (ids, scores) = _timed_block(
                lambda: sh.query_packed(qwords, top_k=10), iters=LAT_ITERS)
            lat = _stage_quantiles(before, obs_metrics.default().snapshot(),
                                   _query_stages(s))
            # the merge contract: S shards answer exactly like one store
            assert np.array_equal(ids, ref_pk[0]), f"shard-merge ids S={s}"
            assert np.array_equal(scores, ref_pk[1]), \
                f"shard-merge scores S={s}"
            em(f"search_build_sharded_s{s}", t_build * 1e6,
               f"items_per_s={n_items / t_build:.0f}"
               f"|sizes={sh.shard_sizes().tolist()}")
            em(f"search_query_sharded_s{s}", t_q * 1e6 / nq_pk,
               f"qps={nq_pk / t_q:.0f}|n_shards={s}|merge=exact|"
               + _timing_split(sh, nq_pk), latency=lat)
        if "tcp" in transports:
            from repro.transport import (connect_sharded, shutdown_plane,
                                         spawn_workers)
            handles = spawn_workers(cfg_s, s, query_impl=query_impl)
            sh = None
            try:
                sh = connect_sharded([h.address for h in handles], cfg_s,
                                     query_impl=query_impl)
                t0 = time.perf_counter()
                sh.add(sigs)               # over the wire, ADD per shard
                t_build = time.perf_counter() - t0
                par = sh.query_packed(qwords_par, top_k=10)
                assert np.array_equal(par[0], ref_pk_par[0]) and \
                    np.array_equal(par[1], ref_pk_par[1]), f"tcp-brute S={s}"
                sh.query_packed(qwords, top_k=10)  # warm worker traces
                before = obs_metrics.default().snapshot()
                t_q, (ids, scores) = _timed_block(
                    lambda: sh.query_packed(qwords, top_k=10),
                    iters=LAT_ITERS)
                lat = _stage_quantiles(before,
                                       obs_metrics.default().snapshot(),
                                       _query_stages(s))
                # tcp answers must equal the single store bit-for-bit too
                assert np.array_equal(ids, ref_pk[0]), f"tcp-merge ids S={s}"
                assert np.array_equal(scores, ref_pk[1]), \
                    f"tcp-merge scores S={s}"
                em(f"search_build_tcp_s{s}", t_build * 1e6,
                   f"items_per_s={n_items / t_build:.0f}"
                   f"|sizes={sh.shard_sizes().tolist()}")
                em(f"search_query_tcp_s{s}", t_q * 1e6 / nq_pk,
                   f"qps={nq_pk / t_q:.0f}|n_shards={s}|merge=exact|"
                   + _timing_split(sh, nq_pk), latency=lat)
            finally:
                if sh is not None:
                    shutdown_plane(sh, handles)
                else:                      # connect failed: nothing to ack
                    for h in handles:
                        h.terminate()

    # pipelined end-to-end ingest (sign -> pack -> scatter) per depth
    if pipeline_depths:
        _bench_ingest_pipeline(em, pipeline_depths, transports,
                               ingest_docs, ingest_batch)

    # open-loop streaming axis (+ the injected-slow-shard hedge pair)
    if arrival_rates:
        from .common import smoke
        if smoke():
            # CI scale: one low rate, a short stream, and a shorter slow
            # sleep so the step stays inside its hard timeout
            # slow_prob is raised from the full run's 0.02: with ~100
            # stream rounds, 0.02 leaves the unhedged row stall-free (no
            # tail to cut) about one smoke run in seven
            _bench_stream_open_loop(
                em, transports=transports, shards=shards,
                arrival_rates=(min(arrival_rates),),
                n_docs=ingest_docs, n_stream=stream_queries or 96,
                max_batch=16, slow_prob=0.05, slow_sleep_ms=80.0,
                hedge_delay_ms=25.0)
        else:
            _bench_stream_open_loop(
                em, transports=transports, shards=shards,
                arrival_rates=arrival_rates,
                n_docs=ingest_docs, n_stream=stream_queries or 1024)

    # availability axis: kill a worker mid-traffic, unreplicated (outage)
    # vs replicated R=2 (zero failed rounds + measured recovery).  Auto:
    # on for full runs with a tcp axis, off in smoke (the CI chaos test
    # asserts the same contract; the bench exists for the numbers)
    from .common import smoke
    if availability is None:
        availability = not smoke()
    if availability and "tcp" in transports:
        if smoke():
            _bench_availability(em, n_docs=800, n_queries=16,
                                rounds=12, kill_round=4)
        else:
            _bench_availability(em, n_docs=ingest_docs, n_queries=64,
                                rounds=60, kill_round=20)

    # overload axis: open-loop arrivals past measured capacity through the
    # bounded-admission streaming front, plus the budgeted-vs-unbudgeted
    # retry storm.  Same gating as availability: full runs with tcp
    if overload is None:
        overload = not smoke()
    if overload and "tcp" in transports:
        if smoke():
            _bench_overload(em, n_docs=1_200, n_stream=160, n_storm=64,
                            rates_x=(1.0, 4.0), max_batch=16,
                            max_queue=32, query_timeout_s=5.0)
        else:
            _bench_overload(em, n_docs=8_000, n_stream=512, n_storm=128,
                            max_batch=32, max_queue=64,
                            query_timeout_s=10.0)

    return rows_out


def main(argv=None) -> None:
    import argparse

    from . import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 1 iter (CI mode; numbers not "
                         "comparable)")
    ap.add_argument("--shards", default="2,4",
                    help="comma-separated shard counts for the sharded axis")
    ap.add_argument("--transport", default="both",
                    choices=["both", "inproc", "tcp"],
                    help="which shard backends the sharded axis measures")
    ap.add_argument("--pipeline-depth", default="1,2,4",
                    help="comma-separated ingest pipeline depths "
                         "(1 = serial baseline; empty disables the axis)")
    ap.add_argument("--query-impl", default="auto",
                    choices=["auto", "jnp", "pallas", "host"],
                    help="query backend for the sharded/tcp rows (host = "
                         "legacy fold + planner walk; every row is parity-"
                         "checked against host either way)")
    ap.add_argument("--n-items", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=None)
    ap.add_argument("--stream-rates", default=None,
                    help="comma-separated offered qps for the open-loop "
                         "streaming axis (empty string disables it)")
    ap.add_argument("--stream-queries", type=int, default=None,
                    help="queries per open-loop streaming run")
    ap.add_argument("--availability", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="mid-traffic kill axis: unreplicated outage vs "
                         "replicated R=2 recovery (default: on for full "
                         "runs with a tcp axis, off in smoke)")
    ap.add_argument("--overload", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="overload axis: open-loop rates past capacity "
                         "(goodput/shed/p99 contract) + budgeted vs "
                         "unbudgeted retry storm (default: on for full "
                         "runs with a tcp axis, off in smoke)")
    args = ap.parse_args(argv)
    if args.smoke:
        common.set_smoke(True)
    kw = {}
    if args.smoke:
        kw.update(n_items=2_000, n_queries=16,
                  ingest_docs=1_000, ingest_batch=128)
    if args.n_items is not None:
        kw["n_items"] = args.n_items
    if args.n_queries is not None:
        kw["n_queries"] = args.n_queries
    kw["shards"] = tuple(int(s) for s in args.shards.split(",") if s)
    kw["transports"] = ("inproc", "tcp") if args.transport == "both" \
        else (args.transport,)
    kw["pipeline_depths"] = tuple(
        int(d) for d in args.pipeline_depth.split(",") if d)
    kw["query_impl"] = args.query_impl
    if args.stream_rates is not None:
        kw["arrival_rates"] = tuple(
            float(r) for r in args.stream_rates.split(",") if r)
    if args.stream_queries is not None:
        kw["stream_queries"] = args.stream_queries
    kw["availability"] = args.availability
    kw["overload"] = args.overload
    print("name,us_per_call,derived")
    run(**kw)


if __name__ == "__main__":
    main()
