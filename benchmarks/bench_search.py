"""SketchStore vs dict-based LSH path + the sharded serving plane.

The pre-SketchStore serving path bucketed signatures with per-item Python
``defaultdict`` loops; this benchmark keeps that path alive as the baseline
and measures the replacement at production-ish index sizes (default 100k
items): build items/s, candidate-generation queries/s (the array-ops hot path
the subsystem exists for), and end-to-end query QPS including packed scoring.

The ``--shards`` axis measures the partitioned plane (`ShardedSketchStore`):
per-S index build and end-to-end query throughput (candidate generation +
per-shard partial top-k + ``merge_topk``), asserting S-shard answers equal
the single-shard answers exactly.  The ``--transport`` axis runs the same
plane over real tcp shard workers (``repro.transport``) and records the
query wall-time split — submit/serialize (broadcast), per-shard partial
compute + gather (partial), and reduction (merge) — next to the inproc
split, so transport overhead is tracked per shard count from day one.
Rows are returned for the ``BENCH_search.json`` artifact (written by
``run.py``).

Standalone:  PYTHONPATH=src python -m benchmarks.bench_search --smoke
"""

from __future__ import annotations

import time
from collections import defaultdict

import numpy as np

from repro.core.lsh import band_hashes
from repro.store import ShardedSketchStore, SketchStore, StoreConfig

from .common import emit


# -- baseline: the pre-refactor dict path ------------------------------------

def _dict_build(hashes: np.ndarray) -> list[dict[int, list[int]]]:
    n, nb = hashes.shape
    buckets: list[dict[int, list[int]]] = [defaultdict(list)
                                           for _ in range(nb)]
    for i in range(n):
        row = hashes[i]
        for band in range(nb):
            buckets[band][int(row[band])].append(i)
    return buckets


def _dict_candidates(buckets, qhashes: np.ndarray) -> list[set[int]]:
    out = []
    for row in qhashes:
        mine: set[int] = set()
        for band, h in enumerate(row):
            mine.update(buckets[band].get(int(h), ()))
        out.append(mine)
    return out


def _timed_block(fn, iters=15):
    """Median wall time of back-to-back calls (the serving pattern), with GC
    paused — a multi-M-entry baseline dict makes every collection scan the
    whole heap, swamping both measurements."""
    import gc
    times = []
    gc.disable()
    try:
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn()
            times.append(time.perf_counter() - t0)
    finally:
        gc.enable()
    return sorted(times)[len(times) // 2], out


def _timing_split(sh, n_queries: int) -> str:
    """`last_timings` -> per-query broadcast/partial/merge derived fields."""
    t = sh.last_timings
    return "|".join(f"{key.split('_')[0]}_us="
                    f"{t.get(key, 0.0) * 1e6 / n_queries:.1f}"
                    for key in ("broadcast_s", "partial_s", "merge_s"))


def run(n_items: int = 100_000, n_queries: int = 256, k: int = 128,
        n_bands: int = 32, rows_per_band: int = 4,
        shards: tuple[int, ...] = (2, 4),
        transports: tuple[str, ...] = ("inproc", "tcp")) -> list[dict]:
    rows_out: list[dict] = []

    def em(name, us, derived):
        emit(name, us, derived)
        rows_out.append({"name": name, "us_per_call": round(us, 1),
                         "derived": derived})

    rng = np.random.default_rng(0)
    sigs = rng.integers(0, 1 << 20, (n_items, k), dtype=np.int32)
    # plant ~1% duplicate structure (clusters of <= 3) so buckets are not all
    # singletons but stay within bucket_width
    n_dup = max(n_items // 100, 2)
    picks = rng.choice(n_items, n_dup + n_dup // 2, replace=False)
    src, dup = picks[: n_dup // 2], picks[n_dup // 2:]
    sigs[dup] = sigs[np.repeat(src, 2)[: len(dup)]]
    qsigs = sigs[rng.choice(n_items, n_queries, replace=False)]
    hashes = band_hashes(sigs, n_bands, rows_per_band)
    qhashes = band_hashes(qsigs, n_bands, rows_per_band)

    # build
    t0 = time.perf_counter()
    buckets = _dict_build(hashes)
    t_dict_build = time.perf_counter() - t0

    def make_cfg():
        return StoreConfig.sized_for(
            n_items, k=k, n_bands=n_bands, rows_per_band=rows_per_band,
            bucket_width=4)

    # pack_codes is shape-specialized: warm the FULL (n_items, k) trace so
    # the timed build measures steady-state throughput, not XLA compile
    SketchStore(make_cfg()).add(sigs)
    store = SketchStore(make_cfg())
    t0 = time.perf_counter()
    store.add(sigs)
    t_store_build = time.perf_counter() - t0

    em("search_build_dict", t_dict_build * 1e6,
       f"items_per_s={n_items / t_dict_build:.0f}")
    em("search_build_store", t_store_build * 1e6,
       f"items_per_s={n_items / t_store_build:.0f}"
       f"|rebuilds={store.n_rebuilds}|spilled={store.n_spilled}"
       f"|load={store.table.load_factor:.2f}")

    # candidate generation (the array-ops hot path)
    t_dict_cand, ref_cands = _timed_block(
        lambda: _dict_candidates(buckets, qhashes))
    t_store_cand, rows = _timed_block(lambda: store.table.lookup(qhashes))

    # sanity: both paths propose identical candidate sets (spilled entries,
    # if any, are a conservative superset added back at query time)
    spilled = set(store.table.spilled_ids().tolist())
    for q in range(n_queries):
        got = set(rows[q][rows[q] >= 0].tolist())
        assert got <= ref_cands[q] <= got | spilled, \
            f"candidate mismatch at query {q}"

    speedup = t_dict_cand / t_store_cand
    em("search_candgen_dict", t_dict_cand * 1e6 / n_queries,
       f"qps={n_queries / t_dict_cand:.0f}")
    em("search_candgen_store", t_store_cand * 1e6 / n_queries,
       f"qps={n_queries / t_store_cand:.0f}|speedup={speedup:.1f}x")

    # end-to-end query (candidates + packed scoring + top-k)
    store.query(qsigs, top_k=10)           # warm the full query-batch trace
    t0 = time.perf_counter()
    ref_ids, ref_scores = store.query(qsigs, top_k=10)
    t_query = time.perf_counter() - t0
    em("search_query_store", t_query * 1e6 / n_queries,
       f"qps={n_queries / t_query:.0f}|n_items={n_items}")

    # sharded serving plane: build + candgen+merge throughput per shard count
    # and per transport (inproc loop vs real tcp shard workers on localhost)
    # (per-shard geometry sized for its own n_items/S slice — sizing every
    # shard for the full corpus would run S tables at 1/S load and flatter
    # the sharded timings; results are geometry-independent either way)
    for s in shards:
        cfg_s = StoreConfig.sized_for(
            -(-n_items // s), k=k, n_bands=n_bands,
            rows_per_band=rows_per_band, bucket_width=4)
        if "inproc" in transports:
            sh = ShardedSketchStore(cfg_s, n_shards=s)
            t0 = time.perf_counter()
            sh.add(sigs)
            t_build = time.perf_counter() - t0
            sh.query(qsigs, top_k=10)      # warm per-shard traces
            t_q, (ids, scores) = _timed_block(
                lambda: sh.query(qsigs, top_k=10), iters=5)
            # the merge contract: S shards answer exactly like one store
            assert np.array_equal(ids, ref_ids), f"shard-merge ids S={s}"
            assert np.array_equal(scores, ref_scores), \
                f"shard-merge scores S={s}"
            em(f"search_build_sharded_s{s}", t_build * 1e6,
               f"items_per_s={n_items / t_build:.0f}"
               f"|sizes={sh.shard_sizes().tolist()}")
            em(f"search_query_sharded_s{s}", t_q * 1e6 / n_queries,
               f"qps={n_queries / t_q:.0f}|n_shards={s}|merge=exact|"
               + _timing_split(sh, n_queries))
        if "tcp" in transports:
            from repro.transport import (connect_sharded, shutdown_plane,
                                         spawn_workers)
            handles = spawn_workers(cfg_s, s)
            sh = None
            try:
                sh = connect_sharded([h.address for h in handles], cfg_s)
                t0 = time.perf_counter()
                sh.add(sigs)               # over the wire, ADD per shard
                t_build = time.perf_counter() - t0
                sh.query(qsigs, top_k=10)  # warm worker-side traces
                t_q, (ids, scores) = _timed_block(
                    lambda: sh.query(qsigs, top_k=10), iters=5)
                # tcp answers must equal the single store bit-for-bit too
                assert np.array_equal(ids, ref_ids), f"tcp-merge ids S={s}"
                assert np.array_equal(scores, ref_scores), \
                    f"tcp-merge scores S={s}"
                em(f"search_build_tcp_s{s}", t_build * 1e6,
                   f"items_per_s={n_items / t_build:.0f}"
                   f"|sizes={sh.shard_sizes().tolist()}")
                em(f"search_query_tcp_s{s}", t_q * 1e6 / n_queries,
                   f"qps={n_queries / t_q:.0f}|n_shards={s}|merge=exact|"
                   + _timing_split(sh, n_queries))
            finally:
                if sh is not None:
                    shutdown_plane(sh, handles)
                else:                      # connect failed: nothing to ack
                    for h in handles:
                        h.terminate()

    return rows_out


def main(argv=None) -> None:
    import argparse

    from . import common

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 1 iter (CI mode; numbers not "
                         "comparable)")
    ap.add_argument("--shards", default="2,4",
                    help="comma-separated shard counts for the sharded axis")
    ap.add_argument("--transport", default="both",
                    choices=["both", "inproc", "tcp"],
                    help="which shard backends the sharded axis measures")
    ap.add_argument("--n-items", type=int, default=None)
    ap.add_argument("--n-queries", type=int, default=None)
    args = ap.parse_args(argv)
    if args.smoke:
        common.set_smoke(True)
    kw = {}
    if args.smoke:
        kw.update(n_items=2_000, n_queries=16)
    if args.n_items is not None:
        kw["n_items"] = args.n_items
    if args.n_queries is not None:
        kw["n_queries"] = args.n_queries
    kw["shards"] = tuple(int(s) for s in args.shards.split(",") if s)
    kw["transports"] = ("inproc", "tcp") if args.transport == "both" \
        else (args.transport,)
    print("name,us_per_call,derived")
    run(**kw)


if __name__ == "__main__":
    main()
