"""Paper Figures 4 & 5: the variance ratio Var_MH / Var_{sigma,pi}.

Fig 4: the ratio is constant in J for fixed (D, f, K) — Prop 3.5.
Fig 5: the ratio grows with K and with f (for fixed D).
"""

from __future__ import annotations

import time

from repro.core import theory

from .common import emit, smoke


def run() -> None:
    # smoke: shrink MC sample counts only — same cells, not comparable numbers
    fig4_samples = 40_000 if smoke() else 1_500_000
    fig5_samples = 15_000 if smoke() else 120_000
    # Figure 4: constant in J (D=1000, K=800 in the paper). The ratio is very
    # sensitive to E~ noise at K=800 ((K-1) amplification), so this cell uses
    # a large MC sample; the exact-enumeration version of Prop 3.5 is pinned
    # to 1e-9 in tests/test_theory.py.
    D, f, K = 1000, 200, 800
    t0 = time.perf_counter()
    ratios = []
    for a in (20, 60, 100, 140, 180):
        r = theory.variance_ratio(D, f, a, K, method="mc",
                                  n_samples=fig4_samples, seed=a)
        ratios.append(r)
    us = (time.perf_counter() - t0) * 1e6 / len(ratios)
    spread = (max(ratios) - min(ratios)) / min(ratios)
    emit(f"fig4_ratio_constant_D{D}_f{f}_K{K}", us,
         "|".join(f"J={a/f:.2f}:{r:.3f}" for a, r in
                  zip((20, 60, 100, 140, 180), ratios))
         + f"|rel_spread={spread:.3f}")

    # Figure 5: ratio vs (f, K) for D=500 and D=1000
    for D in (500, 1000):
        for f in (D // 10, D // 4, D // 2):
            row = []
            t0 = time.perf_counter()
            for K in (D // 4, D // 2, D):
                r = theory.variance_ratio(D, f, f // 2, K, method="mc",
                                          n_samples=fig5_samples, seed=f + K)
                row.append((K, r))
            us = (time.perf_counter() - t0) * 1e6 / len(row)
            emit(f"fig5_ratio_D{D}_f{f}", us,
                 "|".join(f"K={k}:{r:.3f}" for k, r in row))


if __name__ == "__main__":
    run()
