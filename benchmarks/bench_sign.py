"""Signing-path benchmark: dense int8 vs bit-packed vs jnp, sparse gather vs
window kernels, fused sign->pack, and the autotuner.

Each row is also returned as a dict so ``run.py`` can write the
machine-readable ``BENCH_sign.json`` artifact (the perf trajectory across
PRs).  The headline row is ``sparse_speedup``: the dispatchable compiled
sparse path (``windows`` on CPU — the jnp twin of the Pallas window-min
kernel; the kernel itself on TPU) against the O(B*nnz*K) jnp gather path at
the ROADMAP shape D=65536, nnz=0.01*D, K=1024, expected >= 3x.

Pallas interpret-mode timings are *correctness-path* numbers only, so
interpret kernels are timed at a tiny shape (and skipped entirely outside
smoke for the big shapes — interpreting a 65k-wide grid is pointless).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import cminhash
from repro.core.permutations import make_two_permutations
from repro.kernels import autotune, dispatch, ops

from .common import emit, smoke, time_call

ROWS: list[dict] = []


def _row(name: str, us: float, **derived) -> None:
    ROWS.append({"name": name, "us_per_call": round(us, 1), **derived})
    emit(name, us, "|".join(f"{k}={v}" for k, v in derived.items()))


def _sparse_inputs(rng, b, d, nnz):
    if b * nnz <= d:      # replace=False draws b*nnz values from [0, d)
        idx = rng.choice(d, (b, nnz), replace=False).astype(np.int32)
    else:
        idx = rng.integers(0, d, (b, nnz), np.int32)
    return jnp.asarray(np.sort(idx, axis=1))


def _bench_dense(rng) -> None:
    shapes = ([(4, 512, 64, 0.1)] if smoke()
              else [(8, 4096, 256, 0.05), (8, 16384, 1024, 0.01)])
    for b, d, k, dens in shapes:
        v = jnp.asarray((rng.random((b, d)) < dens).astype(np.int8))
        _, pi = make_two_permutations(jax.random.PRNGKey(0), d)
        tag = f"B{b}_D{d}_K{k}"
        us_ref = time_call(lambda: dispatch.signatures_dense(
            v, pi, k, impl="ref"))
        _row(f"sign_dense_ref_{tag}", us_ref,
             docs_per_s=round(b / us_ref * 1e6))
        us_auto = time_call(lambda: dispatch.signatures_dense(v, pi, k))
        _row(f"sign_dense_auto_{tag}", us_auto,
             impl=dispatch.select_dense_impl(d),
             docs_per_s=round(b / us_auto * 1e6))
        # fused sign->pack vs sign-then-pack (b-bit ingest form).
        # Interleaved min-of-N: separately-timed blocks on a shared box
        # measure scheduler bursts, not the kernels — an earlier artifact
        # recorded the fused path ~10% "slower" at the small shape from
        # exactly that (on CPU both paths dispatch IDENTICAL work: the
        # fused epilogue only exists in the Pallas kernels, and impl="ref"
        # packs via the same pack_codes either way).
        for pb in (8,):
            fuse_fn = lambda: dispatch.signatures_dense(v, pi, k, pack_b=pb)
            two_fn = lambda: ops.pack_codes(
                dispatch.signatures_dense(v, pi, k), pb)
            for fn in (fuse_fn, two_fn):
                jax.block_until_ready(fn())
            t_fuse, t_two = [], []
            import time as _time
            for _ in range(1 if smoke() else 16):
                for fn, out in ((fuse_fn, t_fuse), (two_fn, t_two)):
                    t0 = _time.perf_counter()
                    jax.block_until_ready(fn())
                    out.append(_time.perf_counter() - t0)
            _row(f"sign_pack_fused_b{pb}_{tag}", min(t_fuse) * 1e6,
                 two_step_us=round(min(t_two) * 1e6, 1))
        # interpret-mode kernels are correctness paths on CPU: time only tiny
        if d <= 1024:
            for impl in ("int8", "packed"):
                us = time_call(lambda: dispatch.signatures_dense(
                    v, pi, k, impl=impl))
                _row(f"sign_dense_{impl}_interp_{tag}", us, interpret=True)


def _bench_sparse(rng) -> None:
    if smoke():
        b, d, k = 4, 2048, 128
    else:
        b, d, k = 8, 65536, 1024          # the ROADMAP open-item shape
    nnz = max(1, int(0.01 * d))
    idx = _sparse_inputs(rng, b, d, nnz)
    _, pi = make_two_permutations(jax.random.PRNGKey(0), d)
    tag = f"B{b}_D{d}_K{k}_nnz{nnz}"

    # the fast side is whatever impl="auto" actually dispatches (windows on
    # CPU, the Pallas kernel on TPU) so the artifact tracks the real path;
    # autotune its tile first — the dispatchable path is the tuned one
    fast_impl = dispatch.select_sparse_impl()
    autotune.measure(
        "sparse_windows" if fast_impl == "windows" else "sparse_pallas",
        b, d, k, nnz=nnz, iters=1 if smoke() else 3)

    # interleaved min-of-N: this box is shared, so medians of separate
    # blocks measure scheduler bursts, not the kernels
    gather_fn = lambda: dispatch.signatures_sparse(idx, pi, k, impl="gather")
    win_fn = lambda: dispatch.signatures_sparse(idx, pi, k, impl=fast_impl)
    for fn in (gather_fn, win_fn):
        jax.block_until_ready(fn())
    t_gather, t_win = [], []
    import time as _time
    for _ in range(1 if smoke() else 16):
        for fn, out in ((gather_fn, t_gather), (win_fn, t_win)):
            t0 = _time.perf_counter()
            jax.block_until_ready(fn())
            out.append(_time.perf_counter() - t0)
    us_gather, us_win = min(t_gather) * 1e6, min(t_win) * 1e6
    speedup = us_gather / us_win
    _row(f"sign_sparse_gather_{tag}", us_gather,
         docs_per_s=round(b / us_gather * 1e6))
    _row(f"sign_sparse_{fast_impl}_{tag}", us_win,
         docs_per_s=round(b / us_win * 1e6))
    _row("sparse_speedup", us_win, speedup=round(speedup, 2),
         baseline="gather", shape=tag, impl=fast_impl)

    # the Pallas sparse kernel itself: tiny shape, interpret (correctness
    # path off-TPU; compiled path on TPU picks it via impl="auto")
    ti = _sparse_inputs(rng, 2, 512, 16)
    _, tpi = make_two_permutations(jax.random.PRNGKey(1), 512)
    us_pl = time_call(lambda: dispatch.signatures_sparse(
        ti, tpi, 64, impl="pallas"))
    _row("sign_sparse_pallas_interp_B2_D512_K64", us_pl, interpret=True)

    got = np.asarray(dispatch.signatures_sparse(idx, pi, k, impl="windows"))
    want = np.asarray(cminhash.cminhash_sparse(idx, pi, k))
    assert np.array_equal(got, want), "windows path diverged from gather"


def _bench_autotune() -> None:
    b, d, k = (4, 2048, 128) if smoke() else (8, 65536, 1024)
    nnz = max(1, d // 100)
    best = autotune.measure("sparse_windows", b, d, k, nnz=nnz,
                            iters=1 if smoke() else 3)
    _row("autotune_sparse_windows", 0.0, winner=str(best),
         cached=str(autotune.cached("sparse_windows", b, d, k, nnz=nnz)))
    idx = _sparse_inputs(np.random.default_rng(2), b, d, nnz)
    _, pi = make_two_permutations(jax.random.PRNGKey(0), d)
    us = time_call(lambda: dispatch.signatures_sparse(idx, pi, k))
    _row("sign_sparse_autotuned", us, blocks=str(best))


def run() -> list[dict]:
    ROWS.clear()
    rng = np.random.default_rng(0)
    _bench_dense(rng)
    _bench_sparse(rng)
    _bench_autotune()
    return list(ROWS)


if __name__ == "__main__":                 # python -m benchmarks.bench_sign
    import json
    import os

    rows = run()
    name = "BENCH_sign.smoke.json" if smoke() else "BENCH_sign.json"
    out = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                       name)
    with open(out, "w") as f:
        json.dump({"smoke": smoke(), "rows": rows}, f, indent=1)
    print(f"wrote {out}")
