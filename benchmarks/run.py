# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)                       # `import benchmarks` as a script
sys.path.insert(0, os.path.join(_ROOT, "src"))


def main() -> None:
    from benchmarks import (bench_dedup, bench_etilde, bench_mae, bench_ratio,
                            bench_search, bench_throughput, bench_variance)
    print("name,us_per_call,derived")
    bench_variance.run()     # Fig 6: theory vs empirical variance
    bench_etilde.run()       # Fig 2, 3: Var vs J; E~ monotone (Lemma 3.3)
    bench_ratio.run()        # Fig 4, 5: variance ratios / Prop 3.5
    bench_mae.run()          # Fig 7: MAE on text/image-statistics corpora
    bench_throughput.run()   # §5: throughput + K->2 memory
    bench_dedup.run()        # production dedup pipeline
    bench_search.run()       # SketchStore index build + query vs dict path


if __name__ == '__main__':
    main()
