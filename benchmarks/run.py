# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import sys

sys.path.insert(0, "src")


def main() -> None:
    from benchmarks import (bench_dedup, bench_etilde, bench_mae, bench_ratio,
                            bench_throughput, bench_variance)
    print("name,us_per_call,derived")
    bench_variance.run()     # Fig 6: theory vs empirical variance
    bench_etilde.run()       # Fig 2, 3: Var vs J; E~ monotone (Lemma 3.3)
    bench_ratio.run()        # Fig 4, 5: variance ratios / Prop 3.5
    bench_mae.run()          # Fig 7: MAE on text/image-statistics corpora
    bench_throughput.run()   # §5: throughput + K->2 memory
    bench_dedup.run()        # production dedup pipeline


if __name__ == '__main__':
    main()
