# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV
# and writes the machine-readable BENCH_sign.json signing-path artifact.
# ``--smoke`` (CI): 1 warmup / 1 iter / tiny shapes — exercises every script
# end-to-end without timing flakiness; numbers are not comparable.
import argparse
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)                       # `import benchmarks` as a script
sys.path.insert(0, os.path.join(_ROOT, "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="1 warmup, 1 iter, tiny shapes (CI regression mode)")
    args = ap.parse_args()
    if args.smoke:
        from benchmarks import common
        common.set_smoke(True)

    from benchmarks import (bench_dedup, bench_etilde, bench_mae, bench_ratio,
                            bench_search, bench_sign, bench_throughput,
                            bench_variance, common)
    smoke = common.smoke()
    print("name,us_per_call,derived")
    bench_variance.run(n_rep=2_000 if smoke else 60_000)  # Fig 6
    bench_etilde.run()       # Fig 2, 3: Var vs J; E~ monotone (Lemma 3.3)
    bench_ratio.run()        # Fig 4, 5: variance ratios / Prop 3.5
    bench_mae.run(**({"n_docs": 8, "n_reps": 2} if smoke else {}))  # Fig 7
    bench_throughput.run()   # §5: throughput + K->2 memory
    bench_dedup.run(n_docs=24 if smoke else 120)   # production dedup pipeline
    search_rows = bench_search.run(   # store vs dict + sharded plane
        **({"n_items": 2_000, "n_queries": 16,
            "ingest_docs": 1_000, "ingest_batch": 128} if smoke else {}))
    sign_rows = bench_sign.run()   # signing hot path (kernel dispatch)

    # smoke numbers are not comparable: never clobber the tracked artifacts
    suffix = ".smoke.json" if smoke else ".json"
    for stem, rows in (("BENCH_sign", sign_rows),
                       ("BENCH_search", search_rows)):
        out = os.path.join(_ROOT, stem + suffix)
        with open(out, "w") as f:
            json.dump({"smoke": smoke, "rows": rows}, f, indent=1)
        print(f"# wrote {out}")


if __name__ == '__main__':
    main()
