"""Paper Figures 2 & 3: Var vs J at fixed (D, f, K), and E~_D increasing in D
toward J^2 (Lemma 3.3) — exact enumeration at small D, MC at Fig-2 scale."""

from __future__ import annotations

import time

from repro.core import theory

from .common import emit, smoke


def run() -> None:
    # Figure 3: E~ monotone in D, converging to J^2 from below (exact)
    # (smoke: the f=30 exact enumeration is the expensive cell — drop it and
    # shrink the MC sample; the assertions/shape of the output stay the same)
    mc_samples = 20_000 if smoke() else 400_000
    for f in ((10,) if smoke() else (10, 30)):
        a = f // 2
        j2 = (a / f) ** 2
        t0 = time.perf_counter()
        vals = [(d, theory.etilde_exact(d, f, a))
                for d in (f, f + 5, f + 10, f + 20, f + 40)]
        us = (time.perf_counter() - t0) * 1e6 / len(vals)
        increasing = all(b[1] > a_[1] for a_, b in zip(vals, vals[1:]))
        emit(f"fig3_etilde_monotone_f{f}", us,
             "|".join(f"D={d}:{v:.5f}" for d, v in vals)
             + f"|J2={j2:.5f}|increasing={increasing}"
             + f"|below_J2={all(v < j2 for _, v in vals)}")

    # Figure 2: Var vs J for D=1000, K=500, varying f — symmetric about 0.5,
    # always below MinHash
    D, K = 1000, 500
    for f in (200, 500):
        t0 = time.perf_counter()
        row = []
        for a in (f // 10, f // 4, f // 2, 3 * f // 4, 9 * f // 10):
            v = theory.var_sigma_pi(D, f, a, K, method="mc",
                                    n_samples=mc_samples, seed=a)
            vm = theory.var_minhash(a / f, K)
            row.append((a / f, v, v < vm))
        us = (time.perf_counter() - t0) * 1e6 / len(row)
        sym = abs(row[0][1] - row[-1][1]) / row[0][1]
        emit(f"fig2_var_vs_J_D{D}_f{f}_K{K}", us,
             "|".join(f"J={j:.2f}:{v:.3e}" for j, v, _ in row)
             + f"|all_below_MH={all(b for _, _, b in row)}"
             + f"|symmetry_rel_err={sym:.3f}")


if __name__ == "__main__":
    run()
