"""End-to-end dedup pipeline benchmark: throughput + precision/recall on a
corpus with planted near-duplicates (the LLM-data production use)."""

from __future__ import annotations

import time

from repro.data.dedup import DedupConfig, dedup_corpus, dedup_metrics
from repro.data.synthetic import corpus_with_duplicates

from .common import emit


def run(n_docs: int = 120) -> None:
    docs, labels = corpus_with_duplicates(
        n_docs, vocab=20_000, doc_len=256, dup_fraction=0.3, seed=0)
    cfg = DedupConfig(d=1 << 14, k=256, n_bands=64, rows_per_band=4,
                      threshold=0.5)
    t0 = time.perf_counter()
    res = dedup_corpus(docs, cfg)
    dt = time.perf_counter() - t0
    m = dedup_metrics(res, labels)
    emit("dedup_pipeline", dt * 1e6 / n_docs,
         f"docs_per_s={n_docs / dt:.0f}|precision={m['precision']:.3f}"
         f"|recall={m['recall']:.3f}|kept={m['kept']}/{m['total']}"
         f"|candidates={res.n_candidates}")


if __name__ == "__main__":
    run()
