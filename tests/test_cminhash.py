"""Core algorithm correctness: cross-path equality, conventions, estimators."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cminhash, estimators, minhash
from repro.core.permutations import (apply_permutation_dense,
                                     circulant_shift,
                                     invert_permutation,
                                     make_two_permutations,
                                     random_permutation)
from repro.kernels import ref


def test_circulant_shift_paper_example():
    """pi = [3,1,2,4] -> pi_{->1} = [4,3,1,2], pi_{->2} = [2,4,3,1] (Sec. 2)."""
    pi = jnp.asarray([3, 1, 2, 4])
    assert list(circulant_shift(pi, 1)) == [4, 3, 1, 2]
    assert list(circulant_shift(pi, 2)) == [2, 4, 3, 1]


def test_permutation_application_convention():
    sigma = jnp.asarray([2, 0, 1], jnp.int32)   # position i -> sigma[i]
    v = jnp.asarray([[1, 0, 1]], jnp.int8)
    out = apply_permutation_dense(v, sigma)
    # v[0] -> pos 2, v[2] -> pos 1
    assert list(np.asarray(out)[0]) == [0, 1, 1]


def test_invert_permutation():
    key = jax.random.PRNGKey(0)
    p = random_permutation(key, 50)
    q = invert_permutation(p)
    assert (np.asarray(p)[np.asarray(q)] == np.arange(50)).all()


@pytest.mark.parametrize("B,D,K,dens", [(4, 64, 16, 0.3), (3, 100, 100, 0.1),
                                        (8, 777, 130, 0.5), (1, 300, 7, 0.05)])
def test_sparse_equals_dense_with_sigma(B, D, K, dens):
    rng = np.random.default_rng(0)
    v = (rng.random((B, D)) < dens).astype(np.int8)
    sigma, pi = make_two_permutations(jax.random.PRNGKey(1), D)
    nnz = max(int(v.sum(1).max()), 1)
    idx = np.full((B, nnz), -1, np.int32)
    for i in range(B):
        nz = np.where(v[i])[0]
        idx[i, :len(nz)] = nz
    s_sparse = cminhash.cminhash_sparse(jnp.asarray(idx), pi, K, sigma)
    v_perm = apply_permutation_dense(jnp.asarray(v), sigma)
    s_dense = cminhash.cminhash_dense(jnp.asarray(v), pi, K, sigma)
    s_ref = ref.cminhash_dense_ref(v_perm, pi, K)
    assert np.array_equal(np.asarray(s_sparse), np.asarray(s_ref))
    assert np.array_equal(np.asarray(s_dense), np.asarray(s_ref))


def test_sparse_k_chunk_remainder_regression():
    """k=65, k_chunk=64 must equal k_chunk=1 (no stale shifts from the scan
    grid overrun when k % k_chunk != 0)."""
    rng = np.random.default_rng(7)
    D = 128
    sigma, pi = make_two_permutations(jax.random.PRNGKey(9), D)
    idx = np.full((4, 12), -1, np.int32)
    for i in range(4):
        nz = rng.choice(D, size=rng.integers(1, 12), replace=False)
        idx[i, : len(nz)] = nz
    for sig_arg in (sigma, None):
        a = cminhash.cminhash_sparse(jnp.asarray(idx), pi, 65, sig_arg,
                                     k_chunk=64)
        b = cminhash.cminhash_sparse(jnp.asarray(idx), pi, 65, sig_arg,
                                     k_chunk=1)
        assert np.array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=20, deadline=None)
@given(st.integers(8, 200), st.data())
def test_dense_sparse_agree_property(d, data):
    """cminhash_dense on a random binary vector == cminhash_sparse on its
    padded index list, exactly, for sigma None and sigma given."""
    k = data.draw(st.integers(1, d))
    dens = data.draw(st.floats(0.0, 1.0))
    seed = data.draw(st.integers(0, 2 ** 16))
    rng = np.random.default_rng(seed)
    v = (rng.random((2, d)) < dens).astype(np.int8)
    sigma, pi = make_two_permutations(jax.random.PRNGKey(seed), d)
    nnz = max(int(v.sum(1).max()), 1)
    idx = np.full((2, nnz), -1, np.int32)
    for i in range(2):
        nz = np.where(v[i])[0]
        idx[i, : len(nz)] = nz
    for sig_arg in (None, sigma):
        s_dense = cminhash.cminhash_dense(jnp.asarray(v), pi, k, sig_arg)
        s_sparse = cminhash.cminhash_sparse(jnp.asarray(idx), pi, k, sig_arg)
        assert np.array_equal(np.asarray(s_dense), np.asarray(s_sparse)), \
            (d, k, dens, seed, sig_arg is None)


def test_k_greater_than_d_rejected():
    pi = jnp.arange(8, dtype=jnp.int32)
    with pytest.raises(ValueError):
        cminhash.cminhash_dense(jnp.ones((1, 8), jnp.int8), pi, 9)


def test_empty_vector_sentinel():
    pi = jnp.arange(16, dtype=jnp.int32)
    v = jnp.zeros((1, 16), jnp.int8)
    sig = cminhash.cminhash_dense(v, pi, 4)
    assert (np.asarray(sig) == np.iinfo(np.int32).max).all()


def test_classical_minhash_dense_sparse_agree():
    rng = np.random.default_rng(3)
    B, D, K = 5, 120, 32
    v = (rng.random((B, D)) < 0.2).astype(np.int8)
    perms = minhash.make_k_permutations(jax.random.PRNGKey(2), D, K)
    idx = np.full((B, D), -1, np.int32)
    for i in range(B):
        nz = np.where(v[i])[0]
        idx[i, :len(nz)] = nz
    s_d = minhash.minhash_dense(jnp.asarray(v), perms)
    s_s = minhash.minhash_sparse(jnp.asarray(idx), perms)
    assert np.array_equal(np.asarray(s_d), np.asarray(s_s))


@settings(max_examples=20, deadline=None)
@given(st.integers(16, 64), st.data())
def test_unbiasedness_property(d, data):
    """E[J_hat] = J over random permutations (hypothesis-driven (D,f,a))."""
    f = data.draw(st.integers(2, d))
    a = data.draw(st.integers(1, f - 1))
    k = data.draw(st.integers(1, d))
    rng = np.random.default_rng(d * 1000 + f * 10 + a)
    v = np.zeros(d, np.int8)
    w = np.zeros(d, np.int8)
    pos = rng.permutation(d)
    v[pos[:a]] = w[pos[:a]] = 1
    extra = pos[a:f]
    v[extra[: (f - a) // 2]] = 1
    w[extra[(f - a) // 2:]] = 1
    n_rep = 600
    ests = []
    for r in range(n_rep):
        key = jax.random.PRNGKey(r)
        sigma, pi = make_two_permutations(key, d)
        sv = cminhash.cminhash_dense(jnp.asarray(v[None]), pi, k, sigma)
        sw = cminhash.cminhash_dense(jnp.asarray(w[None]), pi, k, sigma)
        ests.append(float((np.asarray(sv) == np.asarray(sw)).mean()))
    j = a / f
    se = np.std(ests) / np.sqrt(n_rep) + 1e-9
    assert abs(np.mean(ests) - j) < max(5 * se, 0.02), (np.mean(ests), j)


def test_estimator_accuracy_beats_minhash_on_structured_data():
    """End-to-end MSE: C-MinHash-(sigma,pi) <= MinHash on the same pairs."""
    rng = np.random.default_rng(0)
    D, K, n_rep = 128, 64, 400
    from repro.core import theory
    x = theory.structured_location_vector(D, 32, 16)
    v = np.zeros(D, np.int8)
    w = np.zeros(D, np.int8)
    v[(x == 0)] = w[(x == 0)] = 1
    xs = np.where(x == 1)[0]
    v[xs[::2]] = 1
    w[xs[1::2]] = 1
    j = 0.5
    err_c, err_m = [], []
    for r in range(n_rep):
        key = jax.random.PRNGKey(r)
        sigma, pi = make_two_permutations(key, D)
        sv = cminhash.cminhash_dense(jnp.asarray(v[None]), pi, K, sigma)
        sw = cminhash.cminhash_dense(jnp.asarray(w[None]), pi, K, sigma)
        err_c.append((float((np.asarray(sv) == np.asarray(sw)).mean()) - j) ** 2)
        perms = minhash.make_k_permutations(key, D, K)
        mv = minhash.minhash_dense(jnp.asarray(v[None]), perms)
        mw = minhash.minhash_dense(jnp.asarray(w[None]), perms)
        err_m.append((float((np.asarray(mv) == np.asarray(mw)).mean()) - j) ** 2)
    assert np.mean(err_c) < np.mean(err_m) * 1.02, (np.mean(err_c),
                                                    np.mean(err_m))


def test_true_jaccard_helpers():
    v = jnp.asarray([[1, 1, 0, 0]], jnp.int8)
    w = jnp.asarray([[1, 0, 1, 0]], jnp.int8)
    assert float(estimators.true_jaccard_dense(v, w)[0]) == pytest.approx(1 / 3)
    assert estimators.true_jaccard_sparse(np.asarray([0, 1, -1]),
                                          np.asarray([0, 2, -1])) == 1 / 3
