"""Streaming query plane: admission queue + pipelined batch execution.

The acceptance contract: a query answered through ``StreamingQueryService``
is **bit-identical** to the same query answered alone through
``SimilaritySearchService.query_sparse`` — whatever batch it was coalesced
into, at any pipeline depth, with mixed per-query top_k, and including
rows that ride the brute-force-fallback leg.  Plus admission semantics: a
full batch flushes immediately, a lone query flushes at the deadline (no
arrival-dependent starvation), close() answers everything admitted, and a
batch's failure rejects its own tickets without killing the coalescer.

Most tests run on the in-process plane (no worker spawns); one end-to-end
test streams over real tcp workers with an injected-slow shard and hedged
reads, asserting parity AND that hedges actually fired.
"""

import time

import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.serve.search import SearchConfig, SimilaritySearchService

D, K, NB, R = 1 << 13, 64, 16, 4
NNZ = 32


def _docs(n, seed=0, lo=0, hi=D):
    rng = np.random.default_rng(seed)
    return np.sort(rng.integers(lo, hi, (n, NNZ), np.int32), axis=1)


def _service(n_shards=2, **kw):
    return SimilaritySearchService(SearchConfig(
        d=D, k=K, n_bands=NB, rows_per_band=R, n_shards=n_shards, **kw))


@pytest.fixture(scope="module")
def plane():
    """One shared inproc plane: 256 indexed docs + queries mixing indexed
    rows with novel rows (novel rows over a tiny corpus are how the global
    brute-force fallback triggers)."""
    svc = _service()
    docs = _docs(256, seed=3)
    svc.add_sparse(docs)
    q = np.concatenate([docs[:12], _docs(4, seed=7)])
    yield svc, q
    svc.close()


def _alone(svc, row, top_k):
    ids, scores = svc.query_sparse(row[None], top_k=top_k)
    return ids[0], scores[0]


def test_coalesced_equals_alone(plane):
    """Every ticket == the same query run alone, across mixed top_k and
    novel (fallback) rows, regardless of batch composition."""
    svc, q = plane
    with svc.stream(max_batch=8, max_delay_ms=5.0) as st:
        tickets = [st.submit_sparse(q[i], top_k=(3 if i % 2 else 7))
                   for i in range(len(q))]
        results = [t.result(timeout=60) for t in tickets]
    for i, (ids, scores) in enumerate(results):
        want_ids, want_scores = _alone(svc, q[i], 3 if i % 2 else 7)
        assert np.array_equal(ids, want_ids), f"ids diverge at query {i}"
        assert np.array_equal(scores, want_scores)
        assert ids.shape == (3 if i % 2 else 7,)


@pytest.mark.parametrize("s", [1, 2, 4])
def test_shard_counts_never_change_answers(s):
    """Streamed == alone at S in {1, 2, 4} (the sharded-vs-single parity
    contract extended through the admission queue)."""
    svc = _service(n_shards=s)
    try:
        docs = _docs(128, seed=s)
        svc.add_sparse(docs)
        q = np.concatenate([docs[:6], _docs(2, seed=s + 50)])
        with svc.stream(max_batch=4, max_delay_ms=2.0) as st:
            tickets = [st.submit_sparse(row, top_k=4) for row in q]
            results = [t.result(timeout=60) for t in tickets]
        for i, (ids, scores) in enumerate(results):
            want_ids, want_scores = _alone(svc, q[i], 4)
            assert np.array_equal(ids, want_ids), f"S={s} query {i}"
            assert np.array_equal(scores, want_scores)
    finally:
        svc.close()


@pytest.mark.parametrize("depth", [1, 2, 4])
def test_depth_never_changes_answers(plane, depth):
    svc, q = plane
    with svc.stream(max_batch=4, max_delay_ms=1.0, depth=depth) as st:
        tickets = [st.submit_sparse(row, top_k=5) for row in q]
        results = [t.result(timeout=60) for t in tickets]
    for i, (ids, scores) in enumerate(results):
        want_ids, want_scores = _alone(svc, q[i], 5)
        assert np.array_equal(ids, want_ids), f"depth={depth} query {i}"
        assert np.array_equal(scores, want_scores)


def test_full_batch_flushes_without_deadline(plane):
    """max_batch arrivals flush immediately — the (absurd) deadline is
    never the thing that releases them."""
    svc, q = plane
    reg = obs_metrics.default()
    full0 = reg.counter("stream.flush.full").value
    t0 = time.perf_counter()
    with svc.stream(max_batch=8, max_delay_ms=60_000.0) as st:
        tickets = [st.submit_sparse(q[i % len(q)]) for i in range(8)]
        for t in tickets:
            t.result(timeout=60)
    assert time.perf_counter() - t0 < 30          # not the 60 s deadline
    assert reg.counter("stream.flush.full").value == full0 + 1
    assert st.n_batches == 1


def test_lone_query_flushes_at_deadline(plane):
    """A single query is answered after max_delay_ms with NO further
    arrivals — deadline flush is what prevents starvation."""
    svc, q = plane
    reg = obs_metrics.default()
    dl0 = reg.counter("stream.flush.deadline").value
    with svc.stream(max_batch=64, max_delay_ms=20.0) as st:
        t = st.submit_sparse(q[0], top_k=4)
        ids, scores = t.result(timeout=60)
    assert reg.counter("stream.flush.deadline").value == dl0 + 1
    assert t.latency_s >= 0.020                   # it did wait the deadline
    want_ids, want_scores = _alone(svc, q[0], 4)
    assert np.array_equal(ids, want_ids)
    assert np.array_equal(scores, want_scores)


def test_incompatible_shape_flushes_prefix(plane):
    """A row with a different nnz can't stack with the queue in front of
    it: the prefix flushes, both still answer exactly."""
    svc, q = plane
    wide = np.sort(np.random.default_rng(9).integers(
        0, D, (NNZ * 2,), np.int32))
    reg = obs_metrics.default()
    sh0 = reg.counter("stream.flush.shape").value
    with svc.stream(max_batch=64, max_delay_ms=50.0) as st:
        a = st.submit_sparse(q[0], top_k=5)
        b = st.submit_sparse(wide, top_k=5)
        ra = a.result(timeout=60)
        rb = b.result(timeout=60)
    assert reg.counter("stream.flush.shape").value == sh0 + 1
    assert np.array_equal(ra[0], _alone(svc, q[0], 5)[0])
    assert np.array_equal(rb[0], _alone(svc, wide, 5)[0])


def test_close_flushes_everything_and_rejects_late(plane):
    svc, q = plane
    st = svc.stream(max_batch=64, max_delay_ms=60_000.0)
    tickets = [st.submit_sparse(row, top_k=3) for row in q[:5]]
    st.close()                      # no deadline ever fired: close drains
    for i, t in enumerate(tickets):
        assert t.done
        ids, _ = t.result(timeout=0)
        assert np.array_equal(ids, _alone(svc, q[i], 3)[0])
    with pytest.raises(RuntimeError, match="closed"):
        st.submit_sparse(q[0])
    st.close()                      # idempotent


def test_batch_failure_rejects_only_its_tickets():
    """Queries against an empty index fail; the rejection carries the
    service's error and the coalescer keeps serving afterwards."""
    svc = _service(n_shards=1)
    try:
        docs = _docs(64, seed=5)
        with svc.stream(max_batch=4, max_delay_ms=2.0) as st:
            bad = st.submit_sparse(docs[0], top_k=3)
            with pytest.raises(ValueError, match="empty"):
                bad.result(timeout=60)
            svc.add_sparse(docs)    # now the same stream must recover
            good = st.submit_sparse(docs[0], top_k=3)
            ids, _ = good.result(timeout=60)
        assert np.array_equal(ids, _alone(svc, docs[0], 3)[0])
    finally:
        svc.close()


def test_submit_rejects_batches():
    service = _service(n_shards=1)
    try:
        with service.stream() as st:
            with pytest.raises(ValueError, match="ONE query"):
                st.submit_sparse(_docs(2, seed=1))
    finally:
        service.close()


def test_stream_over_tcp_with_hedged_slow_shard():
    """End to end at the smallest real scale: tcp workers, one shard
    sleeping on most reads, hedged twin connections — streamed answers stay
    bit-identical to the batch reference and the hedges actually fire."""
    from repro.store.store import StoreConfig
    from repro.transport import HedgePolicy, connect_sharded, spawn_workers

    docs = _docs(200, seed=11)
    q = np.concatenate([docs[:8], _docs(3, seed=13)])
    cfg = SearchConfig(d=D, k=K, n_bands=NB, rows_per_band=R, n_shards=2,
                       transport="tcp")
    store_cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    workers = spawn_workers(store_cfg, 2, slow_shards={1: (0.8, 0.02)})
    try:
        store = connect_sharded([h.address for h in workers], store_cfg,
                                timeout=60, hedge=HedgePolicy(delay_s=0.004))
        svc = SimilaritySearchService(cfg, store=store, workers=workers)
        svc.add_sparse(docs)
        ref = svc.query_sparse(q, top_k=5)
        with svc.stream(max_batch=4, max_delay_ms=2.0) as st:
            for rep in range(4):    # several rounds so hedges get chances
                tickets = [st.submit_sparse(row, top_k=5) for row in q]
                for i, t in enumerate(tickets):
                    ids, scores = t.result(timeout=120)
                    assert np.array_equal(ids, ref[0][i]), f"query {i}"
                    assert np.array_equal(scores, ref[1][i])
        group = store.shards[0].group
        assert group.n_hedges > 0, "slow shard never triggered a hedge"
        svc.close()                 # also shuts the workers down
    finally:
        for h in workers:
            h.terminate()
