"""Coverage for serving helpers, loaders, and the roofline analysis layer."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.roofline import (cminhash_kernel_roofline, model_flops,
                                     report_markdown, roofline)
from repro.configs import get_config, reduced
from repro.core.engine import SketchConfig, SketchEngine
from repro.data.loader import PrefetchIterator
from repro.models import build
from repro.serve.decode import generate, sample_token


def test_generate_greedy_deterministic():
    cfg = reduced(get_config("llama3_2_1b"), d_model=64, vocab=128)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": np.asarray(rng.integers(0, 128, (3, 12)), np.int32)}
    a = generate(bundle, params, batch, max_new_tokens=6, temperature=0.0)
    b = generate(bundle, params, batch, max_new_tokens=6, temperature=0.0)
    assert a.shape == (3, 6)
    assert np.array_equal(a, b)


def test_sample_token_temperature():
    logits = jnp.asarray([[0.0, 10.0, 0.0]])
    greedy = sample_token(logits, jax.random.PRNGKey(0), 0.0)
    assert int(greedy[0]) == 1
    sampled = sample_token(logits, jax.random.PRNGKey(0), 1.0)
    assert sampled.shape == (1,)


def test_prefetch_iterator_order_and_stop():
    it = PrefetchIterator(iter(range(7)), depth=3)
    assert list(it) == list(range(7))


def test_sketch_engine_memory_accounting():
    eng = SketchEngine(SketchConfig(d=1024, k=64))
    assert eng.parameter_bytes == 2 * 1024 * 4
    assert SketchEngine.classical_parameter_bytes(1024, 64) == 64 * 1024 * 4
    eng0 = SketchEngine(SketchConfig(d=1024, k=64, use_sigma=False))
    assert eng0.parameter_bytes == 1024 * 4


def _fake_record(kind="train", flops=1e12, bytes_=1e11, coll=1e9):
    return {
        "arch": "x", "shape": "train_4k", "mesh": "single_pod",
        "n_chips": 256, "seq_len": 4096, "global_batch": 256, "kind": kind,
        "params": int(1e9), "active_params": int(1e9), "status": "ok",
        "compile_s": 1.0,
        "memory": {"argument_bytes": 1e9, "output_bytes": 1, "temp_bytes": 1,
                   "alias_bytes": 1, "code_bytes": 0},
        "xla_cost": {"flops": flops / 10, "bytes accessed": bytes_ / 10},
        "hlo_cost": {"flops": flops, "bytes": bytes_, "bytes_naive": bytes_,
                     "collective_bytes": coll, "collective_breakdown": {},
                     "n_collectives": 3},
    }


def test_roofline_terms_and_dominance():
    r = roofline(_fake_record(flops=1.97e14, bytes_=8.19e11, coll=5e10))
    assert r["compute_s"] == pytest.approx(1.0)
    assert r["memory_s"] == pytest.approx(1.0)
    assert r["collective_s"] == pytest.approx(1.0)
    # model flops: train = 6 * N * tokens
    assert r["model_flops"] == pytest.approx(6 * 1e9 * 256 * 4096)
    r2 = roofline(_fake_record(bytes_=1e14))
    assert r2["dominant"] == "memory"


def test_model_flops_kinds():
    rec = _fake_record()
    assert model_flops(rec) == 6 * 1e9 * 256 * 4096
    rec["kind"] = "prefill"
    assert model_flops(rec) == 2 * 1e9 * 256 * 4096
    rec["kind"] = "decode"
    assert model_flops(rec) == 2 * 1e9 * 256


def test_report_markdown_from_dir(tmp_path):
    rec = _fake_record()
    (tmp_path / "single_pod__x__train_4k.json").write_text(json.dumps(rec))
    md = report_markdown(str(tmp_path), "single_pod")
    assert "### Roofline" in md and "| x | train_4k |" in md


def test_kernel_roofline_packing_helps_memory_only():
    a = cminhash_kernel_roofline(1024, 65536, 1024, packed=False)
    b = cminhash_kernel_roofline(1024, 65536, 1024, packed=True)
    assert a["ops"] == b["ops"]
    assert b["bytes"] < a["bytes"] / 2
    assert b["arith_intensity"] > a["arith_intensity"]
