"""Autotuner: cache semantics (recommend never measures; measure caches the
winner; JSON persistence via $REPRO_AUTOTUNE_CACHE) and engine integration."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import SketchConfig, SketchEngine
from repro.kernels import autotune


@pytest.fixture(autouse=True)
def _fresh_cache(monkeypatch):
    monkeypatch.delenv(autotune.CACHE_ENV, raising=False)
    autotune.clear_cache()
    yield
    autotune.clear_cache()


def test_recommend_heuristic_on_miss():
    blocks = autotune.recommend("dense_int8", 8, 4096, 256, backend="cpu")
    assert set(blocks) == {"block_b", "block_d"}
    assert blocks["block_d"] % 32 == 0
    # clamped to the shape: tiny batch cannot get a giant batch tile
    small = autotune.recommend("dense_int8", 1, 64, 16, backend="cpu")
    assert small["block_b"] == 1
    with pytest.raises(ValueError):
        autotune.recommend("nope", 1, 1, 1, backend="cpu")


def test_measure_caches_winner():
    cands = ({"block_j": 4}, {"block_j": 8})
    best = autotune.measure("sparse_windows", 2, 256, 32, candidates=cands,
                            warmup=1, iters=1)
    assert best in [dict(c) for c in cands]
    assert autotune.cached("sparse_windows", 2, 256, 32) == best
    # recommend now returns the measured winner, not the heuristic
    assert autotune.recommend("sparse_windows", 2, 256, 32) == best
    # bucketing: a same-pow2-class shape hits the same entry
    assert autotune.cached("sparse_windows", 2, 200, 30) == best
    assert autotune.cached("sparse_windows", 2, 1024, 32) is None
    # nnz is part of the sparse key: a different density re-tunes
    assert autotune.cached("sparse_windows", 2, 256, 32, nnz=512) is None
    # measure() is sweep-on-MISS: a cached shape class returns immediately
    # (different candidate list would win if it re-swept)
    again = autotune.measure("sparse_windows", 2, 256, 32,
                             candidates=({"block_j": 2},), warmup=0, iters=1)
    assert again == best
    forced = autotune.measure("sparse_windows", 2, 256, 32, force=True,
                              candidates=({"block_j": 2},), warmup=0, iters=1)
    assert forced == {"block_j": 2}


def test_measure_guard_rejects_slow_winner(monkeypatch):
    """A default-sweep winner that cannot beat the heuristic default in the
    confirmation duel must NOT be cached — the default is, and the rejection
    is counted (regression: a cached noise artifact made every later
    recommend() slower than not tuning at all)."""
    from repro.obs import metrics as obs_metrics

    default = {"block_j": 64}
    sweeps = []

    def fake_sweep(runner, cands, warmup, iters):
        sweeps.append([dict(c) for c in cands])
        if len(sweeps) == 1:       # full sweep: a non-default "winner"
            return (1e-9, next(c for c in cands if c != default))
        return (1e-9, default)     # duel: the default is actually faster

    monkeypatch.setattr(autotune, "_sweep", fake_sweep)
    reg = obs_metrics.default()
    before = reg.counter("autotune.guard_rejects").value
    best = autotune.measure("sparse_windows", 64, 256, 32,
                            warmup=0, iters=1)
    assert best == default
    assert autotune.cached("sparse_windows", 64, 256, 32) == default
    assert reg.counter("autotune.guard_rejects").value == before + 1
    assert len(sweeps) == 2 and sorted(
        map(str, sweeps[1])) == sorted(map(str, [sweeps[0][0], default]))
    # the default rides in the sweep field even though _CANDIDATES lacks it
    assert default in sweeps[0]


def test_measure_guard_confirms_fast_winner(monkeypatch):
    """A winner that survives the duel is cached as-is, no rejection."""
    from repro.obs import metrics as obs_metrics

    winner = {"block_j": 16}

    def fake_sweep(runner, cands, warmup, iters):
        return (1e-9, winner)

    monkeypatch.setattr(autotune, "_sweep", fake_sweep)
    reg = obs_metrics.default()
    before = reg.counter("autotune.guard_rejects").value
    assert autotune.measure("sparse_windows", 64, 512, 32,
                            warmup=0, iters=1) == winner
    assert autotune.cached("sparse_windows", 64, 512, 32) == winner
    assert reg.counter("autotune.guard_rejects").value == before


def test_measure_explicit_candidates_bypass_guard(monkeypatch):
    """Explicit candidates= pins the field: no default injection, no duel —
    the caller's winner is trusted verbatim even if slower than default."""
    def boom(*a, **k):
        raise AssertionError("guard duel must not run for explicit sweeps")

    monkeypatch.setattr(autotune, "_duel", boom)
    best = autotune.measure("sparse_windows", 64, 1024, 32,
                            candidates=({"block_j": 2},), warmup=0, iters=1)
    assert best == {"block_j": 2}
    assert autotune.cached("sparse_windows", 64, 1024, 32) == {"block_j": 2}


def test_cache_persists_to_json(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    best = autotune.measure("sparse_windows", 2, 128, 16,
                            candidates=({"block_j": 4},), warmup=0, iters=1)
    assert best == {"block_j": 4}
    data = json.loads(path.read_text())
    assert any(k.startswith("sparse_windows:") for k in data)
    # a fresh process (cleared in-memory cache) reloads the file
    autotune.clear_cache()
    assert autotune.cached("sparse_windows", 2, 128, 16) == best


def test_measure_dense_kinds_tiny():
    cands = ({"block_b": 2, "block_d": 32},)
    for kind in ("dense_int8", "dense_packed"):
        best = autotune.measure(kind, 2, 64, 16, candidates=cands,
                                warmup=0, iters=1)
        assert best == {"block_b": 2, "block_d": 32}, kind


def test_engine_autotune_measure_populates_cache():
    cfg = SketchConfig(d=256, k=32, autotune_measure=True, use_kernel=True,
                       seed=0)
    eng = SketchEngine(cfg)
    idx = jnp.asarray(np.array([[3, 17, 200, -1]], np.int32))
    sig = eng.signatures_sparse(idx)
    kind = ("sparse_pallas" if jax.default_backend() == "tpu"
            else "sparse_windows")
    assert autotune.cached(kind, 1, 256, 32, nnz=idx.shape[1]) is not None
    # values unchanged vs the untuned engine
    eng2 = SketchEngine(SketchConfig(d=256, k=32, seed=0))
    assert np.array_equal(np.asarray(sig), np.asarray(
        eng2.signatures_sparse(idx)))
