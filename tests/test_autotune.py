"""Autotuner: cache semantics (recommend never measures; measure caches the
winner; JSON persistence via $REPRO_AUTOTUNE_CACHE) and engine integration."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import SketchConfig, SketchEngine
from repro.kernels import autotune


@pytest.fixture(autouse=True)
def _fresh_cache(monkeypatch):
    monkeypatch.delenv(autotune.CACHE_ENV, raising=False)
    autotune.clear_cache()
    yield
    autotune.clear_cache()


def test_recommend_heuristic_on_miss():
    blocks = autotune.recommend("dense_int8", 8, 4096, 256, backend="cpu")
    assert set(blocks) == {"block_b", "block_d"}
    assert blocks["block_d"] % 32 == 0
    # clamped to the shape: tiny batch cannot get a giant batch tile
    small = autotune.recommend("dense_int8", 1, 64, 16, backend="cpu")
    assert small["block_b"] == 1
    with pytest.raises(ValueError):
        autotune.recommend("nope", 1, 1, 1, backend="cpu")


def test_measure_caches_winner():
    cands = ({"block_j": 4}, {"block_j": 8})
    best = autotune.measure("sparse_windows", 2, 256, 32, candidates=cands,
                            warmup=1, iters=1)
    assert best in [dict(c) for c in cands]
    assert autotune.cached("sparse_windows", 2, 256, 32) == best
    # recommend now returns the measured winner, not the heuristic
    assert autotune.recommend("sparse_windows", 2, 256, 32) == best
    # bucketing: a same-pow2-class shape hits the same entry
    assert autotune.cached("sparse_windows", 2, 200, 30) == best
    assert autotune.cached("sparse_windows", 2, 1024, 32) is None
    # nnz is part of the sparse key: a different density re-tunes
    assert autotune.cached("sparse_windows", 2, 256, 32, nnz=512) is None
    # measure() is sweep-on-MISS: a cached shape class returns immediately
    # (different candidate list would win if it re-swept)
    again = autotune.measure("sparse_windows", 2, 256, 32,
                             candidates=({"block_j": 2},), warmup=0, iters=1)
    assert again == best
    forced = autotune.measure("sparse_windows", 2, 256, 32, force=True,
                              candidates=({"block_j": 2},), warmup=0, iters=1)
    assert forced == {"block_j": 2}


def test_cache_persists_to_json(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    best = autotune.measure("sparse_windows", 2, 128, 16,
                            candidates=({"block_j": 4},), warmup=0, iters=1)
    assert best == {"block_j": 4}
    data = json.loads(path.read_text())
    assert any(k.startswith("sparse_windows:") for k in data)
    # a fresh process (cleared in-memory cache) reloads the file
    autotune.clear_cache()
    assert autotune.cached("sparse_windows", 2, 128, 16) == best


def test_measure_dense_kinds_tiny():
    cands = ({"block_b": 2, "block_d": 32},)
    for kind in ("dense_int8", "dense_packed"):
        best = autotune.measure(kind, 2, 64, 16, candidates=cands,
                                warmup=0, iters=1)
        assert best == {"block_b": 2, "block_d": 32}, kind


def test_engine_autotune_measure_populates_cache():
    cfg = SketchConfig(d=256, k=32, autotune_measure=True, use_kernel=True,
                       seed=0)
    eng = SketchEngine(cfg)
    idx = jnp.asarray(np.array([[3, 17, 200, -1]], np.int32))
    sig = eng.signatures_sparse(idx)
    kind = ("sparse_pallas" if jax.default_backend() == "tpu"
            else "sparse_windows")
    assert autotune.cached(kind, 1, 256, 32, nnz=idx.shape[1]) is not None
    # values unchanged vs the untuned engine
    eng2 = SketchEngine(SketchConfig(d=256, k=32, seed=0))
    assert np.array_equal(np.asarray(sig), np.asarray(
        eng2.signatures_sparse(idx)))
