"""Wire protocol: round-trip fuzz + strict rejection of damaged frames.

The framing layer is the trust boundary of the transport plane: every
byte a worker or coordinator acts on passed through ``decode_frame`` /
``recv_message``.  Round-trips are fuzzed over message types, field mixes,
dtypes, and shapes (property-style via the hypothesis stub); the rejection
tests pin the failure taxonomy — truncated, oversized, corrupted, and
alien frames each raise their own exception, and a clean peer hangup is
distinguishable from a damaged stream.
"""

import socket
import struct

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.transport import wire
from repro.transport.wire import Message, MsgType

DTYPES = [np.bool_, np.int8, np.uint8, np.int16, np.uint16, np.int32,
          np.uint32, np.int64, np.uint64, np.float32, np.float64]


def _random_array(rng: np.random.Generator, dtype, shape):
    if dtype == np.bool_:
        return rng.integers(0, 2, shape).astype(np.bool_)
    dt = np.dtype(dtype)
    if dt.kind == "f":
        return rng.normal(size=shape).astype(dt)
    info = np.iinfo(dt)
    return rng.integers(info.min, int(info.max) + 1, shape,
                        dtype=dt, endpoint=False)


def _assert_messages_equal(a: Message, b: Message):
    assert a.type == b.type
    assert a.seq == b.seq          # request/reply pairing survives the wire
    assert set(a.fields) == set(b.fields)
    for key, val in a.fields.items():
        got = b.fields[key]
        if isinstance(val, np.ndarray):
            assert got.dtype == val.dtype, key
            assert got.shape == val.shape, key
            assert np.array_equal(val, got, equal_nan=True), key
        else:
            assert val == got, key


# -- round-trip fuzz ---------------------------------------------------------

@settings(max_examples=60)
@given(st.data())
def test_roundtrip_fuzz(data):
    """Any field mix survives encode -> decode, through bytes and sockets."""
    seed = data.draw(st.integers(0, 2**31 - 1), "seed")
    rng = np.random.default_rng(seed)
    mtype = MsgType(data.draw(st.sampled_from([int(t) for t in MsgType]),
                              "mtype"))
    fields = {}
    for fi in range(data.draw(st.integers(0, 5), "n_fields")):
        kind = data.draw(st.sampled_from(["int", "str", "arr"]), "kind")
        key = f"f{fi}_{kind}"
        if kind == "int":
            fields[key] = data.draw(
                st.integers(-(2**62), 2**62), "intval")
        elif kind == "str":
            n = data.draw(st.integers(0, 40), "slen")
            fields[key] = "".join(
                chr(data.draw(st.integers(32, 0x24F), "ch"))
                for _ in range(n))      # incl. non-ascii codepoints
        else:
            dtype = DTYPES[data.draw(st.integers(0, len(DTYPES) - 1), "dt")]
            ndim = data.draw(st.integers(0, 3), "ndim")
            shape = tuple(data.draw(st.integers(0, 5), "dim")
                          for _ in range(ndim))
            fields[key] = _random_array(rng, dtype, shape)
    msg = Message(mtype, fields,
                  seq=data.draw(st.integers(0, 2**32 - 1), "seq"))
    _assert_messages_equal(msg, wire.decode_frame(wire.message_bytes(msg)))
    a, b = socket.socketpair()
    try:
        wire.send_message(a, msg)
        _assert_messages_equal(msg, wire.recv_message(b))
    finally:
        a.close()
        b.close()


def test_roundtrip_typical_query():
    """The hot-path QUERY layout, incl. the uint64 -> 2x uint32 planes."""
    rng = np.random.default_rng(0)
    hashes = rng.integers(0, 1 << 63, (7, 16)).astype(np.uint64) * \
        np.uint64(3)                       # exercise the high bit
    lo, hi = wire.split_u64(hashes)
    assert lo.dtype == np.uint32 and hi.dtype == np.uint32
    assert np.array_equal(wire.join_u64(lo, hi), hashes)
    msg = Message(MsgType.QUERY, {
        "hash_lo": lo, "hash_hi": hi,
        "qwords": rng.integers(0, 1 << 32, (7, 8), dtype=np.uint32),
        "top_k": 10, "mode": "packed"})
    got = wire.decode_frame(wire.message_bytes(msg))
    _assert_messages_equal(msg, got)
    assert np.array_equal(
        wire.join_u64(got["hash_lo"], got["hash_hi"]), hashes)


def test_decoded_arrays_are_views():
    """Zero-copy contract: decoded arrays alias the frame buffer."""
    msg = Message(MsgType.PARTIAL,
                  {"ids": np.arange(12, dtype=np.int64).reshape(3, 4)})
    frame = wire.message_bytes(msg)
    got = wire.decode_frame(frame)
    assert got["ids"].base is not None     # a view, not a fresh allocation


# -- rejection ----------------------------------------------------------------

def _frame() -> bytes:
    return wire.message_bytes(Message(MsgType.PARTIAL, {
        "ids": np.arange(6, dtype=np.int64).reshape(2, 3),
        "scores": np.linspace(0, 1, 6, dtype=np.float32).reshape(2, 3),
        "has": np.asarray([True, False])}))


@settings(max_examples=40)
@given(st.data())
def test_truncated_frames_rejected(data):
    """Every proper prefix of a frame is rejected, never misparsed."""
    frame = _frame()
    cut = data.draw(st.integers(0, len(frame) - 1), "cut")
    with pytest.raises(wire.TruncatedFrame):
        wire.decode_frame(frame[:cut])


@settings(max_examples=40)
@given(st.data())
def test_corrupted_payload_rejected(data):
    """Any single flipped payload byte trips the checksum."""
    frame = bytearray(_frame())
    pos = data.draw(st.integers(wire.HEADER_SIZE, len(frame) - 1), "pos")
    frame[pos] ^= data.draw(st.integers(1, 255), "xor")
    with pytest.raises(wire.ChecksumError):
        wire.decode_frame(bytes(frame))


def test_oversized_frame_rejected_before_allocation():
    frame = _frame()
    with pytest.raises(wire.FrameTooLarge):
        wire.decode_frame(frame, max_payload=8)
    # the header check alone suffices — no payload needed to reject
    header = struct.pack("<2sBBIII", wire.MAGIC, wire.VERSION,
                         int(MsgType.OK), 0, wire.MAX_PAYLOAD + 1, 0)
    with pytest.raises(wire.FrameTooLarge):
        wire.decode_header(header)


def test_bad_magic_version_and_type_rejected():
    frame = bytearray(_frame())
    bad = frame.copy()
    bad[0:2] = b"XX"
    with pytest.raises(wire.ProtocolError):
        wire.decode_frame(bytes(bad))
    bad = frame.copy()
    bad[2] = 99                            # version from the future
    with pytest.raises(wire.ProtocolError):
        wire.decode_frame(bytes(bad))
    bad = frame.copy()
    bad[3] = 200                           # unknown message type
    with pytest.raises(wire.ProtocolError):
        wire.decode_frame(bytes(bad))


def test_trailing_garbage_rejected():
    with pytest.raises(wire.ProtocolError):
        wire.decode_frame(_frame() + b"\x00")


def test_unsupported_field_values_rejected_at_encode():
    with pytest.raises(wire.ProtocolError):
        wire.message_bytes(Message(MsgType.OK, {"x": 3.5}))
    with pytest.raises(wire.ProtocolError):
        wire.message_bytes(Message(MsgType.OK, {"x": [1, 2]}))
    with pytest.raises(wire.ProtocolError):
        wire.message_bytes(Message(
            MsgType.OK, {"x": np.zeros(2, dtype=np.complex64)}))


def test_malformed_but_crc_valid_payload_rejected_as_protocol_error():
    """A CRC-valid frame with absurd content (dims overflowing int64,
    non-ascii key bytes) must be a WireError, not a raw ValueError — a
    worker answers ERROR and survives instead of crashing."""
    # array field whose dims multiply past int64
    payload = struct.pack("<H", 1) + struct.pack("<B", 1) + b"x" + \
        struct.pack("<BBB2q", 2, 7, 2, 1 << 33, 1 << 33)
    frame = struct.pack("<2sBBIII", wire.MAGIC, wire.VERSION,
                        int(MsgType.OK), 0, len(payload),
                        __import__("zlib").crc32(payload))
    with pytest.raises(wire.WireError):
        wire.decode_frame(frame + payload)
    # non-ascii field-name bytes
    payload = struct.pack("<H", 1) + struct.pack("<B", 2) + b"\xff\xfe" + \
        struct.pack("<Bq", 0, 1)
    frame = struct.pack("<2sBBIII", wire.MAGIC, wire.VERSION,
                        int(MsgType.OK), 0, len(payload),
                        __import__("zlib").crc32(payload))
    with pytest.raises(wire.WireError):
        wire.decode_frame(frame + payload)


def test_socket_eof_taxonomy():
    """Clean hangup at a frame boundary vs mid-frame are distinct errors."""
    a, b = socket.socketpair()
    a.close()
    with pytest.raises(wire.ConnectionClosed):
        wire.recv_message(b)
    b.close()
    a, b = socket.socketpair()
    frame = _frame()
    a.sendall(frame[: len(frame) // 2])
    a.close()                              # died mid-frame
    with pytest.raises(wire.TruncatedFrame):
        wire.recv_message(b)
    b.close()
