"""Observability plane: exact snapshot algebra, wire-propagated traces,
STATS snapshots, dump files, and the disabled fast path.

The design contract under test mirrors ``merge_topk``'s: per-process
measurements reduce to a global view with an exact, associative,
commutative merge — S shard snapshots combined in any order or grouping
produce identical bytes.  Histogram sums are integer nanos, so this is
provable equality, not approximate.  The trace test spawns a REAL tcp
shard worker and asserts the coordinator and worker spans of one query
share a trace id (the stitched sign->shard->serve trace).
"""

import json
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.dump import MetricsDumper, check_dump

K, NB, R = 64, 16, 4


# -- histogram merge: exact, associative, commutative -------------------------

@settings(max_examples=30)
@given(st.data())
def test_hist_merge_exact_over_random_shard_splits(data):
    """Observing a stream into one histogram == splitting it across S
    'shard' histograms and merging the snapshots, in ANY order/grouping."""
    seed = data.draw(st.integers(0, 2**31 - 1), "seed")
    s = data.draw(st.integers(2, 5), "shards")
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 200))
    values = rng.uniform(0.0, 10.0, n) ** 3        # spans many buckets
    owner = rng.integers(0, s, n)

    whole = obs_metrics.Histogram("h")
    parts = [obs_metrics.Histogram("h") for _ in range(s)]
    for v, o in zip(values, owner):
        whole.observe(float(v))
        parts[int(o)].observe(float(v))
    snaps = [{"hists": {"h": p.to_snapshot()}} for p in parts]

    want = whole.to_snapshot()
    # any permutation: commutativity
    perm = rng.permutation(s)
    merged = obs_metrics.merge_snapshots(*[snaps[i] for i in perm])
    assert merged["hists"]["h"] == want
    # any grouping: associativity (left fold vs split-merge)
    cut = int(rng.integers(1, s)) if s > 1 else 1
    left = obs_metrics.merge_snapshots(*snaps[:cut])
    right = obs_metrics.merge_snapshots(*snaps[cut:])
    assert obs_metrics.merge_snapshots(left, right)["hists"]["h"] == want


def test_merge_counters_gauges_and_quantiles():
    reg_a, reg_b = obs_metrics.Registry(), obs_metrics.Registry()
    reg_a.counter("c").inc(3)
    reg_b.counter("c").inc(4)
    reg_a.gauge("g").set(10)
    reg_b.gauge("g").set(5)
    for v in (0.001, 0.002, 0.004, 0.1):
        reg_a.histogram("h").observe(v)
    merged = obs_metrics.merge_snapshots(reg_a.snapshot(), reg_b.snapshot())
    assert merged["counters"]["c"] == 7
    assert merged["gauges"]["g"] == 15          # gauges are summable levels
    h = merged["hists"]["h"]
    assert h["count"] == 4
    # bucket-resolution quantiles: ~19% relative error band
    assert obs_metrics.hist_quantile(h, 0.5) == pytest.approx(0.002, rel=0.3)
    assert obs_metrics.hist_quantile(h, 1.0) == pytest.approx(0.1, rel=0.3)
    assert obs_metrics.hist_sum(h) == pytest.approx(0.107, rel=1e-6)


def test_quantiles_interpolate_within_one_bucket():
    """Regression: when one log bucket holds all the mass, p50/p90/p99 used
    to collapse to the same bucket edge — three identical numbers carrying
    one bucket of information.  Interpolation places them at their
    fractional ranks, so they spread monotonically inside the bucket and
    stay within its edges."""
    h = obs_metrics.Histogram("h")
    h.observe_n(0.0015, 100)                 # single-bucket mass
    p50, p90, p99 = (h.quantile(q) for q in (0.5, 0.9, 0.99))
    assert p50 < p90 < p99                   # distinct, monotone
    for p in (p50, p90, p99):                # within ~one bucket of truth
        assert p == pytest.approx(0.0015, rel=0.3)
    # snapshot-form quantiles agree with the live object
    snap = h.to_snapshot()
    assert obs_metrics.hist_quantile(snap, 0.9) == pytest.approx(p90)
    # underflow bucket interpolates linearly from 0; q=0 sits at its floor
    lo = obs_metrics.Histogram("lo")
    lo.observe_n(0.0, 10)
    assert 0.0 <= lo.quantile(0.5) <= lo.quantile(0.99)
    assert h.quantile(0.0) <= p50


def test_snapshot_delta_scopes_a_window():
    reg = obs_metrics.Registry()
    reg.counter("c").inc(5)
    reg.histogram("h").observe(0.5)
    before = reg.snapshot()
    reg.counter("c").inc(2)
    reg.histogram("h").observe(0.25)
    delta = obs_metrics.snapshot_delta(before, reg.snapshot())
    assert delta["counters"] == {"c": 2}
    assert delta["hists"]["h"]["count"] == 1
    assert obs_metrics.hist_sum(delta["hists"]["h"]) == \
        pytest.approx(0.25, rel=1e-9)


# -- the disabled fast path ---------------------------------------------------

def test_disabled_registry_is_noop_and_cheap():
    """Null instruments are shared singletons, record nothing, and cost
    well under a microsecond per call — the 'observability off' contract
    (the enabled-vs-disabled wall-clock delta is tracked by the
    search_obs_overhead row in bench_search, not asserted here)."""
    reg = obs_metrics.Registry(enabled=False)
    c = reg.counter("a")
    assert c is reg.counter("b") is obs_metrics.NULL_COUNTER
    assert reg.histogram("a") is obs_metrics.NULL_HISTOGRAM
    assert reg.gauge("a") is obs_metrics.NULL_GAUGE
    c.inc(10**6)
    reg.histogram("a").observe(1.0)
    reg.gauge("a").set(5.0)
    assert reg.snapshot() == obs_metrics.empty_snapshot()

    n = 50_000
    h = reg.histogram("x")
    t0 = time.perf_counter()
    for _ in range(n):
        c.inc()
        h.observe_n(2.0, 3)
    per_op = (time.perf_counter() - t0) / (2 * n)
    assert per_op < 5e-6, f"null instrument op cost {per_op * 1e9:.0f}ns"


# -- dump files ---------------------------------------------------------------

def test_metrics_dumper_and_checker(tmp_path):
    path = str(tmp_path / "dump.jsonl")
    reg = obs_metrics.Registry()
    tr = obs_trace.Tracer(sample_rate=1.0, proc="t")
    with MetricsDumper(path, interval_s=0.05, registry=reg, tracer=tr):
        reg.counter("events").inc(3)
        reg.histogram("query.shard0.partial").observe(0.01)
        reg.histogram("query.shard1.partial").observe(0.02)
        with tr.span("op"):
            pass
        time.sleep(0.15)            # at least one periodic line
    out = check_dump(path, require_shard_hists=True)
    assert out["lines"] >= 2        # periodic + final
    assert out["spans"] == 1        # spans are incremental: exactly once
    assert out["shard_hists"] == ["query.shard0.partial",
                                  "query.shard1.partial"]


def test_dump_checker_rejects_garbage(tmp_path):
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"t": 1, "seq": 0}\n')
    with pytest.raises(ValueError, match="missing"):
        check_dump(str(bad))
    empty_hists = tmp_path / "nohists.jsonl"
    empty_hists.write_text(json.dumps(
        {"t": 1, "seq": 0, "spans": [],
         "metrics": obs_metrics.empty_snapshot()}) + "\n")
    check_dump(str(empty_hists))    # well-formed without the shard gate
    with pytest.raises(ValueError, match="per-shard"):
        check_dump(str(empty_hists), require_shard_hists=True)


def test_dump_checker_overload_families(tmp_path):
    """``--require-overload`` passes only when retry-budget, breaker, and
    a shedding surface are all wired — worker metrics folded in by an
    ``extra`` callable (per-lane relabelled STATS snapshots) count."""
    reg = obs_metrics.Registry()
    reg.gauge("transport.retry_budget.tokens").set(100.0)
    reg.gauge("transport.breaker.127.0.0.1:9000.state").set(0.0)
    partial = tmp_path / "partial.jsonl"
    partial.write_text(json.dumps(
        {"t": 1, "seq": 0, "spans": [], "metrics": reg.snapshot()}) + "\n")
    with pytest.raises(ValueError, match="shed_surface"):
        check_dump(str(partial), require_overload=True)

    # the shedding surface arrives via a worker STATS snapshot the dump's
    # ``extra`` callable folded in, not the coordinator registry
    wreg = obs_metrics.Registry()
    wreg.gauge("shard0.replica1.worker.admission.depth").set(0.0)
    wreg.counter("shard0.replica1.worker.overloaded").inc()
    full = tmp_path / "full.jsonl"
    full.write_text(json.dumps(
        {"t": 1, "seq": 0, "spans": [], "metrics": reg.snapshot(),
         "workers": {"shard0.replica1": wreg.snapshot()}}) + "\n")
    out = check_dump(str(full), require_overload=True)
    assert set(out["overload_families"]) == {"retry_budget", "breaker",
                                             "shed_surface"}


# -- wire-propagated traces + STATS snapshots (real tcp workers) --------------

def test_trace_and_stats_roundtrip_through_tcp_workers():
    """One sampled query over a 2-shard tcp plane yields ONE trace whose
    spans cover the coordinator AND both worker processes; worker STATS
    carries a parseable registry snapshot, and obs_snapshot() folds the
    plane into one view with nonzero per-shard partial histograms."""
    from repro.store import ShardedSketchStore, StoreConfig
    from repro.transport import connect_sharded, shutdown_plane, spawn_workers

    rng = np.random.default_rng(3)
    sigs = rng.integers(0, 1 << 16, (80, K), dtype=np.int32)
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    tracer = obs_trace.default()
    old_rate = tracer.sample_rate
    tracer.sample_rate = 1.0
    tracer.drain()                  # a clean ring for last_trace_id()
    handles = spawn_workers(cfg, 2)
    try:
        tcp = connect_sharded([h.address for h in handles], cfg, timeout=60)
        tcp.add(sigs)
        before = obs_metrics.default().snapshot()
        ids, _ = tcp.query(sigs[:6], top_k=3)
        assert np.array_equal(ids[:, 0], np.arange(6))   # sane answers

        tid = tracer.last_trace_id()
        assert tid is not None
        spans = tracer.for_trace(tid)
        procs = {s["proc"] for s in spans}
        assert {"shard0", "shard1"} <= procs, procs      # worker legs
        assert any(s["proc"] not in ("shard0", "shard1") for s in spans)
        assert {s["name"] for s in spans} >= \
            {"query.fold", "query.broadcast", "query.partial", "query.merge",
             "worker.query"}
        # every span of the trace shares the one id (they're from for_trace,
        # but check the worker spans' parents point into this trace too)
        by_id = {s["span"] for s in spans}
        for s in spans:
            if s["proc"].startswith("shard"):
                assert s["parent"] in by_id, "worker span not stitched"

        # per-shard partial latency histograms observed on the coordinator
        delta = obs_metrics.snapshot_delta(before,
                                           obs_metrics.default().snapshot())
        for i in range(2):
            assert delta["hists"][f"query.shard{i}.partial"]["count"] > 0

        # worker STATS carries its own registry snapshot ("obs"), tagged
        # with the shard index, and obs_snapshot() merges the plane
        for i, sh in enumerate(tcp.shards):
            st_ = sh.stats()
            assert st_["shard"] == i
            snap = json.loads(st_["obs"])
            assert set(snap) == {"counters", "gauges", "hists"}
            assert snap["hists"]["worker.handle.query"]["count"] > 0
            assert snap["counters"]["worker.bytes_in"] > 0
        plane = tcp.obs_snapshot()
        assert plane["hists"]["worker.handle.query"]["count"] >= 2
        shutdown_plane(tcp, handles)
    finally:
        tracer.sample_rate = old_rate
        for h in handles:
            h.terminate()
