"""SketchStore subsystem: packed buffer, LSH table, planner, facade."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.lsh import band_hashes, candidate_pairs
from repro.kernels import ops, ref
from repro.store import (BandedLSHTable, PackedConfig, PackedSignatureBuffer,
                         SketchStore, StoreConfig)


# -- packed codes ----------------------------------------------------------

@pytest.mark.parametrize("b", [1, 2, 4, 8, 16, 32])
def test_pack_unpack_roundtrip(b):
    rng = np.random.default_rng(b)
    sig = rng.integers(0, 1 << 20, (5, 37), dtype=np.int32)
    words = ops.pack_codes(jnp.asarray(sig), b)
    assert np.asarray(words).dtype == np.uint32
    back = np.asarray(ops.unpack_codes(words, 37, b))
    expect = sig & ((1 << b) - 1) if b < 32 else sig
    assert np.array_equal(back, expect)


@pytest.mark.parametrize("b", [1, 4, 8, 32])
def test_packed_collision_counts_vs_independent_ref(b):
    rng = np.random.default_rng(10 + b)
    k = 53
    sq = rng.integers(0, 1 << 16, (6, k), dtype=np.int32)
    sn = rng.integers(0, 1 << 16, (9, k), dtype=np.int32)
    sn[0] = sq[0]
    wq = ops.pack_codes(jnp.asarray(sq), b)
    wn = ops.pack_codes(jnp.asarray(sn), b)
    got = np.asarray(ops.packed_collision_counts(wq, wn, k, b))
    want = np.asarray(ref.packed_collision_count_ref(wq, wn, k, b))
    assert np.array_equal(got, want)
    assert got[0, 0] == k
    if b == 32:  # exact: equals raw signature collision counts
        raw = np.asarray(ops.collision_counts(jnp.asarray(sq),
                                              jnp.asarray(sn)))
        assert np.array_equal(got, raw)


# -- packed buffer ---------------------------------------------------------

def test_buffer_append_doubling_and_gather():
    cfg = PackedConfig(k=40, b=8, capacity=8)
    buf = PackedSignatureBuffer(cfg)
    rng = np.random.default_rng(0)
    sigs = rng.integers(0, 1 << 16, (100, 40), dtype=np.int32)
    for lo in range(0, 100, 13):
        ids = buf.append(sigs[lo: lo + 13])
        assert ids[0] == lo
    assert buf.size == 100
    assert buf.capacity >= 100
    assert buf.nbytes == cfg.n_words * 100 * 4      # b=8: ~4x under raw int32
    got = np.asarray(buf.codes(np.asarray([0, 57, 99])))
    assert np.array_equal(got, sigs[[0, 57, 99]] & 0xFF)


def test_buffer_snapshot_roundtrip(tmp_path):
    cfg = PackedConfig(k=17, b=4, capacity=8)
    buf = PackedSignatureBuffer(cfg)
    rng = np.random.default_rng(1)
    sigs = rng.integers(0, 1 << 12, (23, 17), dtype=np.int32)
    buf.append(sigs)
    path = str(tmp_path / "buf.npz")
    buf.save(path)
    loaded = PackedSignatureBuffer.load(path)
    assert loaded.size == 23 and loaded.cfg.k == 17 and loaded.cfg.b == 4
    assert np.array_equal(np.asarray(loaded.codes(np.arange(23))),
                          sigs & 0xF)


# -- LSH table -------------------------------------------------------------

def _dict_lookup(hashes_index, hashes_query):
    """Reference dict-based bucketing (the pre-SketchStore path)."""
    from collections import defaultdict
    nb = hashes_index.shape[1]
    buckets = [defaultdict(list) for _ in range(nb)]
    for i, row in enumerate(hashes_index):
        for band in range(nb):
            buckets[band][int(row[band])].append(i)
    out = []
    for row in hashes_query:
        mine = set()
        for band in range(nb):
            mine.update(buckets[band].get(int(row[band]), ()))
        out.append(mine)
    return out


def test_table_lookup_matches_dict_reference():
    rng = np.random.default_rng(2)
    sigs = rng.integers(0, 50, (400, 32), dtype=np.int32)   # forced collisions
    hashes = band_hashes(sigs, 8, 4)
    table = BandedLSHTable(8, n_slots=4096, bucket_width=32, max_probes=16)
    table.insert(hashes[:250], np.arange(250))
    table.insert(hashes[250:], np.arange(250, 400))
    assert table.n_spilled == 0
    want = _dict_lookup(hashes, hashes[:60])
    got = table.lookup(hashes[:60])
    for q in range(60):
        mine = set(got[q][got[q] >= 0].tolist())
        assert mine == want[q], q


def test_table_candidate_pairs_match_reference():
    rng = np.random.default_rng(3)
    sigs = rng.integers(0, 30, (150, 32), dtype=np.int32)
    hashes = band_hashes(sigs, 8, 4)
    table = BandedLSHTable(8, n_slots=2048, bucket_width=64, max_probes=16)
    table.insert(hashes, np.arange(150))
    assert table.n_spilled == 0
    got = set(map(tuple, table.candidate_pairs()))
    assert got == candidate_pairs(hashes)


def test_table_spill_and_rebuild():
    rng = np.random.default_rng(4)
    # one shared bucket per band with width 2 -> guaranteed overflow
    sigs = np.broadcast_to(rng.integers(0, 9, (1, 16), dtype=np.int32),
                           (20, 16)).copy()
    hashes = band_hashes(sigs, 4, 4)
    table = BandedLSHTable(4, n_slots=64, bucket_width=2, max_probes=4)
    table.insert(hashes, np.arange(20))
    assert table.n_spilled > 0 and table.n_spill_overflow > 0
    # spilled entries are still paired exactly
    got = set(map(tuple, table.candidate_pairs()))
    assert got == candidate_pairs(hashes)
    table.rebuild(bucket_width=32)
    assert table.n_spilled == 0
    got = set(map(tuple, table.candidate_pairs()))
    assert got == candidate_pairs(hashes)


def test_table_growth_rebuild_spill_replay_parity():
    """Insert past capacity in stages, forcing both spill modes, rebuilding
    between stages — after every stage the lookup must match the dict
    reference exactly (the replay log must renumber nothing)."""
    rng = np.random.default_rng(17)
    sigs = rng.integers(0, 25, (400, 16), dtype=np.int32)  # heavy collisions
    sigs[300:330] = sigs[0]            # oversized cluster -> overflow spills
    hashes = band_hashes(sigs, 4, 4)
    table = BandedLSHTable(4, n_slots=16, bucket_width=1, max_probes=2)
    geometries = [dict(n_slots=64), dict(bucket_width=8),
                  dict(n_slots=1024, bucket_width=64, max_probes=16)]
    n = 0
    for stage, (add, geom) in enumerate(zip((100, 150, 150), geometries)):
        table.insert(hashes[n: n + add], np.arange(n, n + add))
        n += add
        assert table.n_spilled > 0 or stage == len(geometries) - 1
        table.rebuild(**geom)
        want = _dict_lookup(hashes[:n], hashes[:30])
        got = table.lookup(hashes[:30])
        spill = table.spilled_candidates(hashes[:30])
        for q in range(30):
            mine = set(got[q][got[q] >= 0].tolist())
            mine |= set(spill[q][spill[q] >= 0].tolist())
            assert mine == want[q], (stage, q)
    # final geometry drains everything but the oversized cluster's overflow
    assert table.n_items == n
    got = set(map(tuple, table.candidate_pairs()))
    assert got == candidate_pairs(hashes[:n])


def test_spilled_candidates_dedup_and_cap():
    """A hot spilled key must not widen (Q, M) past the cap, and the capped
    row keeps the smallest matching ids (the score-tie winners)."""
    rng = np.random.default_rng(18)
    sigs = np.broadcast_to(rng.integers(0, 1 << 16, (1, 16), np.int32),
                           (40, 16)).copy()                 # one hot cluster
    sigs[30:] = rng.integers(0, 1 << 16, (10, 16), dtype=np.int32)
    hashes = band_hashes(sigs, 4, 4)
    table = BandedLSHTable(4, n_slots=64, bucket_width=2, max_probes=4)
    table.insert(hashes, np.arange(40))
    assert table.n_spill_overflow > 0
    full = table.spilled_candidates(hashes[:5])
    # dedup: an id spilled in several matching bands appears once per row
    row = full[0][full[0] >= 0]
    assert len(row) == len(np.unique(row))
    capped = table.spilled_candidates(hashes[:5], cap=3)
    assert capped.shape[1] == 3
    for q in range(5):
        want = np.sort(full[q][full[q] >= 0])[:3]
        got = capped[q][capped[q] >= 0]
        assert np.array_equal(got, want), q


def test_spill_cap_is_per_group_not_across_groups():
    """Two spilled clusters sharing a band: capping must never trade one
    group's (high-scoring) members for another group's smaller ids — the
    capped query must still match the uncapped reference exactly."""
    rng = np.random.default_rng(21)
    k, nb, r = 64, 16, 4
    a = rng.integers(0, 1 << 16, k, dtype=np.int32)
    b = rng.integers(0, 1 << 16, k, dtype=np.int32)
    b[: r] = a[: r]                       # clusters share band 0 only
    sigs = np.concatenate([np.tile(a, (6, 1)), np.tile(b, (6, 1))])
    store = SketchStore(StoreConfig(k=k, n_bands=nb, rows_per_band=r,
                                    bucket_width=1, auto_rebuild=False))
    store.add(sigs)
    assert store.n_spilled > 0
    ids, scores = store.query(sigs[[6]], top_k=3)   # query cluster B
    # reference: B's own members (score 1.0, smallest ids first)
    assert np.array_equal(ids[0], [6, 7, 8]), ids[0]
    assert np.allclose(scores[0], 1.0)
    # and sharded answers stay identical on the same data
    from repro.store import ShardedSketchStore
    sh = ShardedSketchStore(store.cfg, 2)
    sh.add(sigs)
    ids2, scores2 = sh.query(sigs[[6]], top_k=3)
    assert np.array_equal(ids, ids2)
    assert np.array_equal(scores, scores2)


def test_query_with_hot_spill_caps_width_but_keeps_top_hits():
    """End-to-end: a hot spilled duplicate cluster larger than any bucket
    still ranks its smallest ids on top (score ties break toward smaller
    ids, which is exactly what the cap retains)."""
    rng = np.random.default_rng(19)
    k, nb, r = 64, 16, 4
    sigs = np.broadcast_to(rng.integers(0, 1 << 16, (1, k), np.int32),
                           (50, k)).copy()
    store = SketchStore(StoreConfig(k=k, n_bands=nb, rows_per_band=r,
                                    bucket_width=2, auto_rebuild=False))
    store.add(sigs)
    assert store.n_spilled > 0
    ids, scores = store.query(sigs[[0]], top_k=5)
    assert np.array_equal(ids[0], np.arange(5))     # smallest ids of the tie
    assert np.allclose(scores[0], 1.0)


def test_table_probe_exhaustion_spills_then_rebuild_drains():
    rng = np.random.default_rng(5)
    sigs = rng.integers(0, 1 << 16, (120, 16), dtype=np.int32)
    hashes = band_hashes(sigs, 4, 4)
    table = BandedLSHTable(4, n_slots=32, bucket_width=4, max_probes=2)
    table.insert(hashes, np.arange(120))                # way over capacity
    assert table.n_spill_probe > 0
    table.rebuild(n_slots=1024, max_probes=16)
    assert table.n_spilled == 0
    want = _dict_lookup(hashes, hashes[:20])
    got = table.lookup(hashes[:20])
    for q in range(20):
        assert set(got[q][got[q] >= 0].tolist()) == want[q]


# -- facade ----------------------------------------------------------------

def _corpus_sigs(n=200, k=64, vals=1 << 16, seed=6):
    rng = np.random.default_rng(seed)
    sigs = rng.integers(0, vals, (n, k), dtype=np.int32)
    sigs[n // 2] = sigs[7]      # planted exact dup
    return sigs


def test_store_query_equivalent_to_pre_refactor_path():
    """b=32 store results match the reference dict-bucket + dense-score path
    (same candidates, same scores, ties broken by smaller id)."""
    k, nb, r = 64, 16, 4
    sigs = _corpus_sigs(k=k)
    store = SketchStore(StoreConfig(k=k, n_bands=nb, rows_per_band=r))
    store.add(sigs)
    q = sigs[:10]
    hashes = band_hashes(sigs, nb, r)
    per_query = _dict_lookup(hashes, band_hashes(q, nb, r))
    est = np.asarray(ops.estimated_jaccard_matrix(jnp.asarray(q),
                                                  jnp.asarray(sigs)))
    ids, scores = store.query(q, top_k=5)
    for qi in range(10):
        mine = np.asarray(sorted(per_query[qi]), np.int64)
        order = mine[np.argsort(-est[qi, mine], kind="stable")][:5]
        assert np.array_equal(ids[qi, : len(order)], order), qi
        assert np.allclose(scores[qi, : len(order)], est[qi, order])


def test_store_pregrow_sizes_ahead_of_batch_and_stays_exact():
    """A one-shot add far past the boot geometry grows the table ONCE,
    before the insert (projected-load sizing), instead of spilling the
    whole batch into a too-small table and replaying it per doubling —
    and candidate generation stays exact either way."""
    k, nb, r = 64, 16, 4
    sigs = _corpus_sigs(n=2000, k=k)
    store = SketchStore(StoreConfig(k=k, n_bands=nb, rows_per_band=r,
                                    n_slots=32, bucket_width=4))
    store.add(sigs)
    t = store.table
    # grown ahead: the batch landed at sane load, not into 32 slots
    assert store.n_rebuilds >= 1
    assert t.load_factor <= store.cfg.rebuild_load_factor
    assert t.n_slots >= len(sigs) / store.cfg.rebuild_load_factor / 2
    # probe-exhaustion spills would dominate (thousands) had the batch hit
    # 32 slots; at pre-grown load only the odd unlucky chain may spill
    assert t.n_spill_probe <= len(sigs) // 100
    got = set(map(tuple, store.candidate_pairs()))
    assert got == candidate_pairs(band_hashes(sigs, nb, r))
    # pre-grown and incrementally-grown stores answer queries identically
    staged = SketchStore(StoreConfig(k=k, n_bands=nb, rows_per_band=r,
                                     n_slots=32, bucket_width=4))
    for lo in range(0, len(sigs), 100):
        staged.add(sigs[lo: lo + 100])
    want = store.query(sigs[:8], top_k=3)
    have = staged.query(sigs[:8], top_k=3)
    assert np.array_equal(want[0], have[0])
    assert np.array_equal(want[1], have[1])


def test_store_incremental_add_auto_rebuild_stays_exact():
    k, nb, r = 64, 16, 4
    sigs = _corpus_sigs(n=500, k=k)
    store = SketchStore(StoreConfig(k=k, n_bands=nb, rows_per_band=r,
                                    n_slots=32, bucket_width=2))
    for lo in range(0, 500, 61):
        store.add(sigs[lo: lo + 61])
    assert store.n_rebuilds > 0           # tiny initial geometry forced growth
    got = set(map(tuple, store.candidate_pairs()))
    assert got == candidate_pairs(band_hashes(sigs, nb, r))
    ids, _ = store.query(sigs[:6], top_k=1)
    assert np.array_equal(ids[:, 0], np.arange(6))


def test_store_bbit_packing_degrades_gracefully():
    """b=8 store: 4x smaller, still retrieves the exact duplicate on top."""
    k, nb, r = 64, 16, 4
    sigs = _corpus_sigs(k=k)
    store = SketchStore(StoreConfig(k=k, n_bands=nb, rows_per_band=r, b=8))
    store.add(sigs)
    ids, scores = store.query(sigs[[7]], top_k=2)
    assert ids[0, 0] == 7 and scores[0, 0] == 1.0
    assert ids[0, 1] == 100                # the planted dup of row 7
    assert store.buffer.nbytes * 4 == store.size * k * 4   # 4x packed win


def test_store_duplicate_cluster_does_not_blow_up_geometry():
    """A duplicate cluster wider than any sane bucket stays spilled — the
    auto-rebuild must cap bucket_width/n_slots growth instead of doubling
    toward OOM (pairs and queries handle spilled entries exactly)."""
    k, nb, r = 64, 16, 4
    rng = np.random.default_rng(14)
    sigs = np.broadcast_to(
        rng.integers(0, 1 << 16, (1, k), dtype=np.int32), (600, k)).copy()
    store = SketchStore(StoreConfig(k=k, n_bands=nb, rows_per_band=r,
                                    n_slots=256, bucket_width=4))
    store.add(sigs)
    assert store.table.bucket_width <= store._MAX_BUCKET_WIDTH
    assert store.table.n_slots <= store._slot_cap()
    # cluster membership still exact via the spill pairing path
    got = set(map(tuple, store.candidate_pairs()))
    assert got == candidate_pairs(band_hashes(sigs, nb, r))


def test_store_snapshot_preserves_rebuild_config(tmp_path):
    cfg = StoreConfig(k=64, n_bands=16, rows_per_band=4, auto_rebuild=False,
                      rebuild_load_factor=0.55, rebuild_spill_fraction=0.2)
    store = SketchStore(cfg)
    store.add(_corpus_sigs(n=30, k=64))
    path = str(tmp_path / "s.npz")
    store.save(path)
    loaded = SketchStore.load(path)
    assert loaded.cfg.auto_rebuild is False
    assert loaded.cfg.rebuild_load_factor == 0.55
    assert loaded.cfg.rebuild_spill_fraction == 0.2


def test_store_snapshot_roundtrip(tmp_path):
    k, nb, r = 64, 16, 4
    sigs = _corpus_sigs(k=k)
    store = SketchStore(StoreConfig(k=k, n_bands=nb, rows_per_band=r, b=16))
    store.add(sigs)
    path = str(tmp_path / "store.npz")
    store.save(path)
    loaded = SketchStore.load(path)
    assert loaded.size == store.size
    ids_a, sc_a = store.query(sigs[:8], top_k=4)
    ids_b, sc_b = loaded.query(sigs[:8], top_k=4)
    assert np.array_equal(ids_a, ids_b)
    assert np.allclose(sc_a, sc_b)
    assert np.array_equal(loaded.candidate_pairs(), store.candidate_pairs())


def test_dedup_clusters_match_pre_refactor_path():
    """dedup_corpus on SketchStore reproduces the dict-path clusters exactly
    on a seeded corpus."""
    from repro.core.engine import SketchConfig, SketchEngine
    from repro.core.lsh import UnionFind
    from repro.data.dedup import DedupConfig, dedup_corpus
    from repro.data.shingle import batch_shingles
    from repro.data.synthetic import corpus_with_duplicates

    docs, _ = corpus_with_duplicates(50, vocab=4000, doc_len=100,
                                     dup_fraction=0.4, seed=12)
    cfg = DedupConfig(d=1 << 12, k=128, n_bands=32, rows_per_band=4,
                      threshold=0.5)
    res = dedup_corpus(docs, cfg)

    # reference: the pre-SketchStore pipeline (dict bucketing)
    idx = batch_shingles(docs, n=cfg.shingle_n, d=cfg.d)
    engine = SketchEngine(SketchConfig(d=cfg.d, k=cfg.k, seed=cfg.seed))
    sigs = np.asarray(engine.signatures_sparse(jnp.asarray(idx)))
    cands = candidate_pairs(band_hashes(sigs, cfg.n_bands, cfg.rows_per_band))
    uf = UnionFind(len(docs))
    for i, j in sorted(cands):
        if (sigs[i] == sigs[j]).mean() >= cfg.threshold:
            uf.union(int(i), int(j))
    ref_cluster = np.asarray([uf.find(i) for i in range(len(docs))])

    assert res.n_candidates == len(cands)
    assert np.array_equal(res.cluster_of, ref_cluster)


def test_store_empty_and_no_candidate_fallback():
    k, nb, r = 64, 16, 4
    store = SketchStore(StoreConfig(k=k, n_bands=nb, rows_per_band=r))
    ids, scores = store.query(np.zeros((2, k), np.int32), top_k=3)
    assert (ids == -1).all() and (scores == 0).all()
    sigs = _corpus_sigs(k=k)
    store.add(sigs)
    rng = np.random.default_rng(8)
    stranger = rng.integers(1 << 20, 1 << 24, (1, k), dtype=np.int32)
    ids, scores = store.query(stranger, top_k=3)
    assert (ids[0] >= 0).all()             # brute-force fallback ranked all


def test_spilled_entries_join_only_matching_queries():
    """A spilled item must appear in a query's results only when it shares a
    band bucket key with that query (the LSH contract), and must still be
    retrievable by queries that do share one."""
    k, nb, r = 64, 16, 4
    rng = np.random.default_rng(15)
    sigs = rng.integers(0, 1 << 16, (8, k), dtype=np.int32)
    sigs[1] = sigs[0]                      # width-1 bucket -> doc 1 spills
    store = SketchStore(StoreConfig(k=k, n_bands=nb, rows_per_band=r,
                                    bucket_width=1, auto_rebuild=False))
    store.add(sigs)
    assert store.n_spilled > 0
    # unrelated doc 3: the dict path would return only {3}; the spilled doc 1
    # must NOT be smuggled into its results
    ids, _ = store.query(sigs[[3]], top_k=8)
    assert 1 not in ids[0][ids[0] >= 0].tolist()
    # doc 0's query shares every bucket key with spilled doc 1
    ids, scores = store.query(sigs[[0]], top_k=2)
    assert set(ids[0].tolist()) == {0, 1} and scores[0, 1] == 1.0


def test_no_candidate_fallback_still_fires_with_spilled_entries():
    """Per-(band, key) spill matching must not mask the 'no bucket hit' test
    that triggers brute force."""
    k, nb, r = 64, 16, 4
    rng = np.random.default_rng(13)
    # identical rows overflow a width-1 bucket -> guaranteed spill
    sigs = np.broadcast_to(
        rng.integers(0, 1 << 16, (1, k), dtype=np.int32), (6, k)).copy()
    sigs[4] = rng.integers(0, 1 << 16, k, dtype=np.int32)
    sigs[5] = rng.integers(0, 1 << 16, k, dtype=np.int32)
    store = SketchStore(StoreConfig(k=k, n_bands=nb, rows_per_band=r,
                                    bucket_width=1, auto_rebuild=False))
    store.add(sigs)
    assert store.n_spilled > 0
    # query with no bucket hit anywhere: must rank the WHOLE index (ids 4, 5
    # included), not just the spilled subset
    stranger = rng.integers(1 << 20, 1 << 24, (1, k), dtype=np.int32)
    ids, _ = store.query(stranger, top_k=6)
    assert set(ids[0][ids[0] >= 0].tolist()) == set(range(6))
