"""Replicated plane: chaos failover + journal-backed recovery semantics.

The acceptance contract of the replica subsystem: with S shards x R=2
replica lanes of real spawned tcp workers, ANY single replica can be
killed mid-traffic — mid-ingest or mid-query — and the plane keeps
answering **bit-identically** to a single-store reference (zero wrong
answers, zero lost batches), while the supervisor respawns the dead
worker, replays the ingest journal, digest-verifies it against a live
peer, and restores R=2.  The resynced replica must then be able to carry
the shard ALONE (its former peer killed) and still answer bit-exactly —
parity is the proof the journal replay rebuilt content, not just counts.

The kills are NOT wall-clock races: every death is a ``FaultPlan`` event
keyed to the k-th message of a type seen by a specific lane (kill on the
4th ADD, kill on the 3rd QUERY, ...), so which worker dies at which
protocol point is a pure function of the driven traffic.  The scenario
runs TWICE on the same ``REPRO_FAULT_SEED`` and the fired-event logs must
match record-for-record — determinism is asserted, not assumed.

The in-process tests cover the coordinator-side mechanics without worker
spawns: write-ahead journal append/rollback around scatter, snapshot +
tail-replay reboot, and (shard, replica)-labelled plane observability.
"""

import os
import time

import numpy as np
import pytest

from repro.obs import metrics as obs_metrics
from repro.replica import (IngestJournal, ReplicatedSketchStore, Supervisor,
                           connect_replicated, snapshot_journal_seq,
                           spawn_replicated)
from repro.store import SketchStore, StoreConfig
from repro.transport import (FAULT_LOG_ENV, FaultEvent, FaultPlan,
                             read_fired_log, shutdown_plane)

K, NB, RPB = 64, 16, 4


def _cfg():
    return StoreConfig(k=K, n_bands=NB, rows_per_band=RPB,
                       n_slots=256, bucket_width=8)


def _corpus(n=180, k=K, seed=0, dup_pairs=3):
    rng = np.random.default_rng(seed)
    sigs = rng.integers(0, 1 << 16, (n, k), dtype=np.int32)
    for t in range(dup_pairs):
        sigs[n - 1 - t] = sigs[t]
    return sigs


def _queries(sigs, n_strangers=2, seed=1):
    """Indexed rows + strangers with no bucket hit anywhere (the global
    brute-force-fallback leg must survive failover too)."""
    rng = np.random.default_rng(seed)
    strangers = rng.integers(1 << 20, 1 << 24,
                             (n_strangers, sigs.shape[1]), dtype=np.int32)
    return np.concatenate([sigs[:10], strangers])


def _assert_parity(ref: SketchStore, store, q, top_k=5):
    want_ids, want_scores = ref.query(q, top_k=top_k)
    got_ids, got_scores = store.query(q, top_k=top_k)
    np.testing.assert_array_equal(got_ids, want_ids)
    np.testing.assert_array_equal(got_scores, want_scores)


# -- in-process: journal integration ----------------------------------------

def test_journal_write_ahead_and_reboot_replay(tmp_path):
    """Every accepted batch is journalled before it scatters; a plane
    rebooted from snapshot + journal tail answers bit-identically."""
    cfg = _cfg()
    sigs = _corpus(n=120)
    batches = np.array_split(sigs, 4)
    journal = IngestJournal(str(tmp_path / "ingest.journal"))
    ref = SketchStore(cfg)
    store = ReplicatedSketchStore(cfg, 2, journal=journal)
    for b in batches[:2]:
        ref.add(b)
        store.add(b)
    assert journal.last_seq == 1
    snap = str(tmp_path / "snap")
    store.save(snap)
    assert snapshot_journal_seq(snap) == 1
    # two more batches after the snapshot: the journal tail
    for b in batches[2:]:
        ref.add(b)
        store.add(b)
    assert journal.last_seq == 3
    # reboot from snapshot, replay the tail
    store2 = ReplicatedSketchStore.load(snap)
    store2.journal = journal
    assert store2.n_items == len(batches[0]) + len(batches[1])
    assert store2.replay_tail() == 2
    assert store2.n_items == len(sigs)
    _assert_parity(ref, store2, _queries(sigs))
    # compact: snapshot covers everything, journal empties
    snap2 = str(tmp_path / "snap2")
    assert store2.compact(snap2) == 4
    assert journal.records() == []
    journal.close()


def test_scatter_failure_rolls_back_journal_record(tmp_path):
    """A scatter that provably lands nowhere must not leave a phantom
    record — replay would diverge a resynced replica from the plane."""
    cfg = _cfg()
    journal = IngestJournal(str(tmp_path / "ingest.journal"))
    store = ReplicatedSketchStore(cfg, 2, journal=journal)
    store.add(_corpus(n=20))
    assert journal.last_seq == 0
    with pytest.raises(Exception):
        store.add(np.zeros((3, K + 1), np.int32))    # bad width: clean fail
    assert store._failed is None                     # plane still usable
    assert journal.last_seq == 0                     # record rolled back
    store.add(_corpus(n=10, seed=3))
    assert journal.last_seq == 1
    assert [r.seq for r in journal.records()] == [0, 1]
    journal.close()


# -- the chaos test: real workers, plan-scheduled kills mid-traffic ----------

def _chaos_plans(seed: int):
    """The chaos schedule, entirely FaultPlan-driven:

      - lane (0,1) dies on its 4th ADD   (mid-ingest, a non-primary)
      - lane (1,0) dies on its 3rd QUERY (mid-query, a PRIMARY)
      - lanes (0,0) and (1,1) die on their 6th ADD (the ORIGINAL
        survivors, so the resynced replicas must carry alone)

    plus seed-derived delay jitter on lane (0,0)'s first queries — the
    timing noise chaos needs, injected deterministically instead of left
    to the scheduler."""
    jitter = FaultPlan.from_seed(seed, n_events=2, horizon=3,
                                 kinds=("delay",), msg_type="query",
                                 delay_ms=15.0).events
    return {
        (0, 0): FaultPlan([FaultEvent("kill", 5, "add")] + list(jitter)),
        (0, 1): FaultPlan([FaultEvent("kill", 3, "add")]),
        (1, 0): FaultPlan([FaultEvent("kill", 2, "query")]),
        (1, 1): FaultPlan([FaultEvent("kill", 5, "add")]),
    }


def _chaos_once(tmp_path, seed: int) -> list[dict]:
    """One full chaos scenario; returns the fired-event log records
    (sorted per lane) so the caller can diff two runs."""
    os.makedirs(tmp_path, exist_ok=True)
    cfg = _cfg()
    sigs = _corpus(n=180)
    batches = np.array_split(sigs, 6)
    q = _queries(sigs)
    ref = SketchStore(cfg)
    log_path = str(tmp_path / "faults.jsonl")
    journal = IngestJournal(str(tmp_path / "ingest.journal"))
    os.environ[FAULT_LOG_ENV] = log_path
    try:
        grid = spawn_replicated(cfg, 2, 2, faults=_chaos_plans(seed))
    finally:
        os.environ.pop(FAULT_LOG_ENV, None)
    store = sup = None
    try:
        store = connect_replicated(grid, cfg, journal=journal, timeout=60)
        sup = Supervisor(store, heartbeat_timeout_s=10)

        # healthy plane: parity baseline
        for b in batches[:3]:
            ref.add(b)
            store.add(b)
        _assert_parity(ref, store, q)

        # obs provenance: worker snapshots are lane-labelled
        snap = store.obs_snapshot()
        labelled = [n for n in snap["hists"]
                    if n.startswith("shard0.replica0.")]
        assert labelled, "per-lane labelled snapshots missing"
        assert snap["hists"]["worker.handle.query"]["count"] >= 2

        # batch 3's scatter is lane (0,1)'s 4th ADD: its plan kills it
        # mid-ingest.  Writes must succeed on reduced redundancy
        # (tolerant legs), not poison the plane
        for b in batches[3:5]:
            ref.add(b)
            store.add(b)
        assert not store.shards[0].lanes[1].up
        assert store._failed is None
        _assert_parity(ref, store, q)

        # this round is lane (1,0)'s 3rd QUERY: its plan kills shard 1's
        # PRIMARY mid-query.  The read fails over to the sibling replica
        # (in-round via the failure hedge, or blocking retry) —
        # bit-identical either way, never a wrong answer
        _assert_parity(ref, store, q)
        assert not store.shards[1].lanes[0].up

        # supervisor heals: respawn (no fault plan rides along — plans
        # are per-spawn, so a respawned slot cannot crash-loop on its
        # predecessor's schedule), journal replay, digest-verified
        # rejoin, back to R=2 on every shard
        deadline = time.monotonic() + 240
        while time.monotonic() < deadline:
            sup.check_once()
            if all(l.up for rs in store.shards for l in rs.lanes):
                break
            time.sleep(0.2)
        assert all(l.up for rs in store.shards for l in rs.lanes), \
            [(l.shard, l.replica, l.why_down)
             for rs in store.shards for l in rs.lanes if not l.up]
        reg = obs_metrics.default().snapshot()["counters"]
        assert reg.get("replica.failovers", 0) >= 2
        _assert_parity(ref, store, q)

        # batch 5's scatter is the 6th ADD of BOTH original survivors:
        # their plans kill them, and the resynced replicas must carry
        # their shards alone — proof the journal replay rebuilt
        # bit-identical content, not just matching sizes
        ref.add(batches[5])
        store.add(batches[5])
        _assert_parity(ref, store, q)
        assert journal.last_seq == 5           # all six batches journalled
    finally:
        if sup is not None:
            sup.stop()
        if store is not None:
            handles = [l.handle for rs in store.shards for l in rs.lanes
                       if l.handle is not None]
            shutdown_plane(store, handles, join_timeout=15)
        else:
            for row in grid:
                for h in row:
                    h.terminate()
        journal.close()
    return read_fired_log(log_path)


def test_chaos_failover_bit_identical(tmp_path):
    """S=2 x R=2 tcp plane, every kill a FaultPlan event: answers stay
    bit-identical to the single-store reference throughout; the
    supervisor restores R=2 with digest-verified parity; the resynced
    replicas then carry the plane alone.  The scenario runs twice on the
    same seed and must inject the identical event sequence both times."""
    seed = int(os.environ.get("REPRO_FAULT_SEED", "1234"))
    fired_a = _chaos_once(tmp_path / "a", seed)
    fired_b = _chaos_once(tmp_path / "b", seed)
    # 4 kills + the seeded query jitter, identical record-for-record
    assert fired_a, "no fault events fired"
    assert sum(1 for r in fired_a if r["kind"] == "kill") == 4
    assert fired_a == fired_b, (fired_a, fired_b)


def test_all_replicas_down_is_an_error_not_a_hang(tmp_path):
    """Killing EVERY replica of a shard surfaces as an exception within
    the deadline — degraded is fine, silent wrong answers are not."""
    cfg = _cfg()
    sigs = _corpus(n=60)
    journal = IngestJournal(str(tmp_path / "ingest.journal"))
    grid = spawn_replicated(cfg, 1, 2)
    store = None
    try:
        store = connect_replicated(grid, cfg, journal=journal, timeout=30)
        store.add(sigs)
        for h in grid[0]:
            h.terminate()
        time.sleep(0.5)
        with pytest.raises(Exception):
            store.query(sigs[:4], top_k=3)
    finally:
        if store is not None:
            handles = [l.handle for rs in store.shards for l in rs.lanes
                       if l.handle is not None]
            shutdown_plane(store, handles, join_timeout=15)
        else:
            for row in grid:
                for h in row:
                    h.terminate()
        journal.close()
