"""Ingest journal durability: round-trip fuzz + torn-tail recovery.

The journal is the plane's write-ahead record of every accepted ADD batch
(``repro.replica.journal``), so its durability contract is load-bearing
for replica resync: every complete record must survive any crash exactly,
and a torn tail must be detected, reported, and truncated — never parsed.
Round-trips are fuzzed property-style (the hypothesis stub, mirroring
``test_wire.py``) over record types (raw int32 rows vs packed uint32
words), shapes including zero-row batches, and interleavings; the
torn-tail tests cut a journal at every byte offset inside its last record
and assert each prior batch is recovered bit-exactly with the torn offset
reported.
"""

import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.replica import IngestJournal, scan_journal
from repro.replica.journal import _record_frame
from repro.transport import wire


def _random_batch(rng: np.random.Generator, packed: bool, n_rows: int,
                  width: int) -> np.ndarray:
    if packed:
        return rng.integers(0, 2**32, (n_rows, width), dtype=np.uint64) \
            .astype(np.uint32)
    return rng.integers(0, 2**31, (n_rows, width), dtype=np.int64) \
        .astype(np.int32)


def _assert_record(rec, seq, gid0, packed, batch):
    assert rec.seq == seq
    assert rec.gid0 == gid0
    assert rec.packed == packed
    assert rec.batch.dtype == batch.dtype
    assert rec.batch.shape == batch.shape
    assert np.array_equal(rec.batch, batch)


# -- round-trip fuzz ---------------------------------------------------------

@settings(max_examples=40)
@given(st.data())
def test_roundtrip_fuzz(data):
    """Any append sequence reads back bit-exactly, in seq order, with the
    file reported clean — through close/reopen (durability, not caching).

    (tempfile instead of tmp_path: the hypothesis stub's @given wrapper
    takes *args, so pytest cannot inject fixtures into fuzz tests.)"""
    seed = data.draw(st.integers(0, 2**31 - 1), "seed")
    rng = np.random.default_rng(seed)
    n_records = data.draw(st.integers(0, 8), "n_records")
    tmp = tempfile.TemporaryDirectory()
    path = os.path.join(tmp.name, f"fuzz_{seed}.journal")
    appended = []
    with IngestJournal(path) as j:
        gid0 = 0
        for i in range(n_records):
            packed = bool(data.draw(st.booleans(), f"packed_{i}"))
            n_rows = data.draw(st.integers(0, 5), f"rows_{i}")
            width = data.draw(st.integers(1, 9), f"width_{i}")
            batch = _random_batch(rng, packed, n_rows, width)
            j.append(batch, packed=packed, gid0=gid0)
            appended.append((i, gid0, packed, batch))
            gid0 += n_rows
        assert j.last_seq == n_records - 1
    # a fresh scan AND a fresh journal must both see everything
    records, _, torn = scan_journal(path)
    assert torn is None
    assert len(records) == n_records
    for rec, (seq, g0, packed, batch) in zip(records, appended):
        _assert_record(rec, seq, g0, packed, batch)
    with IngestJournal(path) as j2:
        assert j2.torn_offset is None
        assert j2.last_seq == n_records - 1
        after = data.draw(st.integers(-1, max(n_records - 1, 0)), "after")
        got = j2.records(after=after)
        assert [r.seq for r in got] == [s for s, *_ in appended if s > after]
    tmp.cleanup()


# -- torn-tail recovery ------------------------------------------------------

def _build(path, n=3, seed=7):
    rng = np.random.default_rng(seed)
    batches = []
    with IngestJournal(path) as j:
        gid0 = 0
        for i in range(n):
            packed = i % 2 == 1
            batch = _random_batch(rng, packed, 2 + i, 4)
            j.append(batch, packed=packed, gid0=gid0)
            batches.append((i, gid0, packed, batch))
            gid0 += len(batch)
    return batches


def test_torn_tail_every_cut_offset(tmp_path):
    """Cut the file at EVERY byte offset inside the last record: all prior
    batches are recovered bit-exactly and the torn offset is the cut."""
    path = str(tmp_path / "torn.journal")
    batches = _build(path, n=3)
    data = open(path, "rb").read()
    records, end, _ = scan_journal(path)
    last_start = records[-1].offset
    for cut in range(last_start + 1, end):
        p = str(tmp_path / f"cut_{cut}.journal")
        with open(p, "wb") as f:
            f.write(data[:cut])
        recs, clean_end, torn = scan_journal(p)
        assert torn == last_start
        assert clean_end == last_start
        assert len(recs) == 2
        for rec, (seq, g0, packed, batch) in zip(recs, batches[:2]):
            _assert_record(rec, seq, g0, packed, batch)


def test_open_truncates_torn_tail_and_resumes(tmp_path):
    """Opening a torn journal recovers every complete batch, records the
    torn offset, truncates the garbage, and appends frame-aligned again."""
    path = str(tmp_path / "resume.journal")
    batches = _build(path, n=3)
    records, end, _ = scan_journal(path)
    cut = records[-1].offset + (records[-1].end - records[-1].offset) // 2
    with open(path, "r+b") as f:
        f.truncate(cut)
    j = IngestJournal(path)
    assert j.torn_offset == records[-1].offset
    assert j.last_seq == 1                   # seqs 0,1 survive; 2 was torn
    assert os.path.getsize(path) == records[-1].offset
    # the torn record's seq is REUSED — the batch never landed anywhere,
    # and replay must see a gapless seq sequence
    nxt = _random_batch(np.random.default_rng(1), False, 3, 4)
    j.append(nxt, packed=False, gid0=batches[2][1])
    got = j.records()
    assert [r.seq for r in got] == [0, 1, 2]
    _assert_record(got[2], 2, batches[2][1], False, nxt)
    j.close()


def test_corrupted_mid_file_stops_scan_at_corruption(tmp_path):
    """A flipped byte mid-file ends recovery there: framing past a bad
    CRC cannot be trusted, so later records are torn, not resynced."""
    path = str(tmp_path / "corrupt.journal")
    _build(path, n=3)
    records, _, _ = scan_journal(path)
    data = bytearray(open(path, "rb").read())
    flip = records[1].offset + wire.HEADER_SIZE + 2   # inside record 1
    data[flip] ^= 0xFF
    with open(path, "wb") as f:
        f.write(data)
    recs, end, torn = scan_journal(path)
    assert [r.seq for r in recs] == [0]
    assert torn == records[1].offset
    assert end == records[1].offset


def test_rollback_removes_only_last_record(tmp_path):
    path = str(tmp_path / "rb.journal")
    batches = _build(path, n=2)
    j = IngestJournal(path)
    off = j.append(np.zeros((2, 4), np.int32), packed=False, gid0=99)
    j.rollback(off)
    assert j.last_seq == 1
    assert [r.seq for r in j.records()] == [0, 1]
    # only the most recent append may be rolled back
    off2 = j.append(np.ones((1, 4), np.int32), packed=False, gid0=99)
    with pytest.raises(ValueError):
        j.rollback(off2 - 1)
    # seq space is gapless through the rollback/reappend cycle
    got = j.records()
    assert [r.seq for r in got] == [0, 1, 2]
    _assert_record(got[0], 0, batches[0][1], batches[0][2], batches[0][3])
    j.close()


def test_truncate_through_drops_snapshot_covered_prefix(tmp_path):
    """append -> snapshot -> truncate: records at or below the snapshot
    seq vanish, survivors keep their seqs and bytes, appends continue."""
    path = str(tmp_path / "trunc.journal")
    batches = _build(path, n=4)
    j = IngestJournal(path)
    assert j.truncate_through(1) == 2
    got = j.records()
    assert [r.seq for r in got] == [2, 3]
    for rec, (seq, g0, packed, batch) in zip(got, batches[2:]):
        _assert_record(rec, seq, g0, packed, batch)
    j.append(np.ones((1, 4), np.int32), packed=False, gid0=123)
    assert [r.seq for r in j.records()] == [2, 3, 4]
    assert j.truncate_through(-1) == 0       # no-op below the window
    j.close()


def test_empty_and_zero_row_batches(tmp_path):
    """A zero-row batch is a legal record (an empty ADD is a legal ADD)
    and an empty journal file opens clean at seq -1."""
    path = str(tmp_path / "empty.journal")
    with IngestJournal(path) as j:
        assert j.last_seq == -1
        assert j.records() == []
        j.append(np.zeros((0, 8), np.uint32), packed=True, gid0=0)
    records, _, torn = scan_journal(path)
    assert torn is None
    assert len(records) == 1 and records[0].batch.shape == (0, 8)


def test_record_frame_is_wire_decodable(tmp_path):
    """Journal records ARE wire frames: the transport's own decoder reads
    them, so torn-tail detection inherits the wire CRC taxonomy."""
    frame = _record_frame(5, 40, np.arange(12, dtype=np.int32).reshape(3, 4),
                          packed=False)
    msg = wire.decode_frame(frame)
    assert msg.type == wire.MsgType.ADD
    assert int(msg["seq"]) == 5 and int(msg["gid0"]) == 40
    assert np.array_equal(msg["rows"],
                          np.arange(12, dtype=np.int32).reshape(3, 4))
