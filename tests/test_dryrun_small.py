"""Integration: the real dry-run driver on real (full-size) configs.

Runs launch/dryrun.py in a subprocess (it must own the 512-device XLA flag)
for a representative subset of cells on both meshes and checks the JSON
artifacts. The full 80-cell sweep lives in EXPERIMENTS.md; this keeps CI honest.
"""

import json
import os
import subprocess
import sys

import pytest

CELLS = [
    ("llama3_2_1b", "decode_32k", "single"),
    ("hymba_1_5b", "long_500k", "single"),
    ("qwen3_moe_30b_a3b", "train_4k", "multi"),    # MoE shard_map, 512 chips
    ("seamless_m4t_medium", "decode_32k", "multi"),
]


@pytest.mark.parametrize("arch,shape,mesh", CELLS)
def test_dryrun_cell_compiles(arch, shape, mesh, tmp_path):
    out = tmp_path / "dryrun"
    env = dict(os.environ, PYTHONPATH="src")
    p = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", mesh, "--out", str(out)],
        capture_output=True, text=True, env=env, cwd=".", timeout=900)
    assert p.returncode == 0, p.stdout + p.stderr
    mesh_name = "single_pod" if mesh == "single" else "multi_pod"
    rec = json.loads((out / f"{mesh_name}__{arch}__{shape}.json").read_text())
    assert rec["status"] == "ok", rec.get("error")
    assert rec["n_chips"] == (256 if mesh == "single" else 512)
    assert rec["hlo_cost"]["flops"] > 0
    assert rec["memory"]["argument_bytes"] > 0
    if shape == "train_4k":
        assert rec["hlo_cost"]["collective_bytes"] > 0  # DP+EP collectives


def test_long_500k_skip_rule():
    from repro.configs import get_config
    from repro.configs.base import shape_by_name
    from repro.launch.specs import runnable
    long = shape_by_name("long_500k")
    ok, _ = runnable(get_config("mistral_nemo_12b"), long)
    assert not ok
    for arch in ("falcon_mamba_7b", "hymba_1_5b", "h2o_danube3_4b"):
        ok, _ = runnable(get_config(arch), long)
        assert ok, arch
