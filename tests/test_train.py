"""Training substrate: optimizer, microbatching, checkpointing, fault tolerance."""

import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import TrainConfig
from repro.data.loader import PrefetchIterator, deduped_token_batches
from repro.data.synthetic import token_batches
from repro.models import build
from repro.train import checkpoint as ckpt
from repro.train.optimizer import (adamw_update, init_opt_state, lr_schedule,
                                   global_norm)
from repro.train.train_loop import TrainLoop, make_train_step


def _tiny():
    cfg = reduced(get_config("llama3_2_1b"), d_model=64, vocab=256)
    return cfg, build(cfg)


def test_lr_schedule_shape():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(jnp.asarray(s), tc)) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(5e-4)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=0.01)  # 10% floor


def test_adamw_decreases_loss():
    cfg, bundle = _tiny()
    tc = TrainConfig(learning_rate=2e-3, warmup_steps=2, total_steps=30,
                     weight_decay=0.0)
    params = bundle.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    data = token_batches(cfg.vocab_size_real, 8, 32, seed=0)
    batch = next(data)  # overfit one batch
    step = jax.jit(make_train_step(bundle, tc))
    losses = []
    for _ in range(30):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])


def test_grad_clip():
    cfg, bundle = _tiny()
    params = bundle.init(jax.random.PRNGKey(0))
    grads = jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32) * 100, params)
    tc = TrainConfig(grad_clip=1.0)
    _, _, stats = adamw_update(params, grads, init_opt_state(params), tc)
    assert float(stats["grad_norm"]) > 1.0  # pre-clip norm reported


def test_microbatch_equals_full_batch():
    """Gradient accumulation must match the single-batch gradient step."""
    cfg, bundle = _tiny()
    cfg32 = dataclasses.replace(cfg, dtype="float32")
    bundle32 = build(cfg32)
    params = bundle32.init(jax.random.PRNGKey(0))
    data = token_batches(cfg.vocab_size_real, 8, 32, seed=1)
    batch = next(data)
    tc1 = TrainConfig(microbatches=1, learning_rate=1e-3, warmup_steps=0)
    tc4 = TrainConfig(microbatches=4, learning_rate=1e-3, warmup_steps=0)
    p1, _, m1 = jax.jit(make_train_step(bundle32, tc1))(
        params, init_opt_state(params), batch)
    p4, _, m4 = jax.jit(make_train_step(bundle32, tc4))(
        params, init_opt_state(params), batch)
    diff = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p4)))
    assert diff < 2e-5, diff


def test_checkpoint_roundtrip_and_retention():
    cfg, bundle = _tiny()
    params = bundle.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params)}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3):
            ckpt.save_checkpoint(d, s, state)
        ckpt.prune_checkpoints(d, keep=2)
        assert ckpt.committed_steps(d) == [2, 3]
        step, restored = ckpt.restore_checkpoint(d, state)
        assert step == 3
        same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)),
                            state, restored)
        assert all(jax.tree.leaves(same))


def test_checkpoint_ignores_uncommitted():
    cfg, bundle = _tiny()
    params = bundle.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_checkpoint(d, 5, {"p": params})
        os.remove(os.path.join(d, "step_00000005", "COMMIT"))
        assert ckpt.latest_step(d) is None


def test_train_loop_restart_resumes():
    cfg, bundle = _tiny()
    tc = TrainConfig(total_steps=6, checkpoint_every=2, warmup_steps=2)
    with tempfile.TemporaryDirectory() as wd:
        data = PrefetchIterator(token_batches(cfg.vocab_size_real, 4, 32))
        out = TrainLoop(bundle, tc, data, wd, log=lambda *_: None).run()
        assert len(out["losses"]) == 6
        # second run restores the final step and trains 0 steps
        data2 = PrefetchIterator(token_batches(cfg.vocab_size_real, 4, 32))
        out2 = TrainLoop(bundle, tc, data2, wd, log=lambda *_: None).run()
        assert len(out2["losses"]) == 0


def test_deduped_loader_respects_keep():
    docs = [np.full(16, i, np.int32) for i in range(10)]
    keep = np.asarray([0, 2, 4])
    it = deduped_token_batches(docs, keep, batch=2, seq=8, vocab=100, seed=0)
    batch = next(it)
    assert set(np.unique(batch["tokens"])).issubset({0, 2, 4})


def test_global_norm():
    t = {"a": jnp.ones((3,)) * 2.0, "b": jnp.zeros((4,))}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(12.0))
