"""Overload hardening: wire deadlines, admission shedding, retry budgets,
circuit breakers, streaming-front shedding, deterministic faults.

The serving invariants under pressure:

  * a request whose deadline already passed is DROPPED before any scoring
    work (the worker answers OVERLOADED/expired over an intact stream);
  * a request that makes its deadline answers bit-identically to the
    unloaded reference — deadlines shed work, they never change answers;
  * an admission-gate rejection is provably clean and retryable, and a
    retry spends from the plane's shared token budget, never firing past
    the caller's deadline;
  * the budget caps retry amplification while an unbudgeted baseline
    amplifies without bound;
  * the streaming front sheds the NEWEST arrival when its bounded queue
    fills, with a retry-after hint, and admitted work is untouched.
"""

import json
import threading
import time
import types

import numpy as np
import pytest

from repro.serve.stream import StreamConfig, StreamingQueryService
from repro.store import SketchStore, StoreConfig
from repro.transport import (CircuitBreaker, DeadlineExceeded, FaultEvent,
                             FaultPlan, Overloaded, RetryBudget,
                             ShardConnection, connect_sharded,
                             deadline_scope, read_fired_log, shutdown_plane,
                             spawn_workers)
from repro.transport.wire import DEADLINE_FIELD, Message, MsgType, deadline_us

K, NB, RPB = 64, 16, 4


def _cfg():
    return StoreConfig(k=K, n_bands=NB, rows_per_band=RPB,
                       n_slots=256, bucket_width=8)


def _corpus(n=80, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 1 << 16, (n, K), dtype=np.int32)


# -- retry budget -------------------------------------------------------------

def test_retry_budget_caps_storm_unbudgeted_amplifies():
    """100 primaries all wanting a retry: the budget grants ~ratio x
    primaries; the unbudgeted baseline grants all of them (>= 2x more) —
    the retry-storm cap in miniature."""
    b = RetryBudget(ratio=0.2, cap=5.0, floor_per_s=0.0)
    while b.try_spend():
        pass                            # drain the startup burst
    granted = 0
    for _ in range(100):
        b.note_primary()
        if b.try_spend():
            granted += 1
    assert 0 < granted <= 0.2 * 100 + 1
    u = RetryBudget(unlimited=True)
    ugranted = sum(u.try_spend() for _ in range(100))
    assert ugranted == 100
    assert ugranted >= 2 * granted
    # +1: the drain loop's terminating probe was also a denial
    assert b.n_denied == 100 - granted + 1


def test_retry_budget_floor_refills_a_quiet_plane():
    b = RetryBudget(ratio=0.0, cap=2.0, floor_per_s=50.0)
    while b.try_spend():
        pass
    assert not b.try_spend()
    time.sleep(0.05)                    # floor trickles ~2.5 tokens back
    assert b.try_spend()


# -- circuit breaker ----------------------------------------------------------

def test_circuit_breaker_state_machine():
    br = CircuitBreaker(fail_threshold=3, reset_s=0.05)
    assert br.healthy and br.allow()
    br.record_failure()
    br.record_success()                 # success resets the streak
    assert br.state == CircuitBreaker.CLOSED
    for _ in range(3):
        br.record_failure()
    assert br.state == CircuitBreaker.OPEN and not br.healthy
    assert not br.allow()               # still inside reset window
    time.sleep(0.06)
    assert br.allow()                   # half-open: single probe admitted
    assert not br.allow()               # ... and only one
    br.record_failure()                 # probe fails -> back to open
    assert br.state == CircuitBreaker.OPEN
    time.sleep(0.06)
    assert br.allow()
    br.record_success()                 # probe succeeds -> closed
    assert br.state == CircuitBreaker.CLOSED and br.healthy


# -- fault plan ---------------------------------------------------------------

def test_fault_plan_counts_per_type_and_fires_once(tmp_path):
    log = str(tmp_path / "fired.jsonl")
    plan = FaultPlan([FaultEvent("kill", 2, "add"),
                      FaultEvent("delay", 0, None, 5.0)],
                     lane="0.0", log_path=log)
    # the any-type event fires on the very first message, whatever it is
    assert [e.kind for e in plan.on_message("query")] == ["delay"]
    assert plan.on_message("add") == []          # add #0
    assert plan.on_message("add") == []          # add #1
    assert [e.kind for e in plan.on_message("add")] == ["kill"]   # add #2
    assert plan.on_message("add") == []          # each event fires ONCE
    recs = read_fired_log(log)
    assert [(r["kind"], r["on"]) for r in recs] == \
        [("delay", "query"), ("kill", "add")]
    # serialization round-trips; seeded schedules are seed-deterministic
    again = FaultPlan.decode(plan.encode())
    assert again.encode() == plan.encode()
    a = FaultPlan.from_seed(7, n_events=3, horizon=10)
    assert a.encode() == FaultPlan.from_seed(7, n_events=3, horizon=10).encode()


# -- worker: wire deadlines + admission gate ----------------------------------

def test_worker_drops_expired_answers_near_deadline_exactly():
    """An expired-on-arrival request is dropped BEFORE any scoring (the
    handle histogram never ticks); a request with a live deadline answers
    bit-identically to the reference."""
    cfg = _cfg()
    sigs = _corpus()
    ref = SketchStore(cfg)
    ref.add(sigs)
    handles = spawn_workers(cfg, 1)
    store = None
    try:
        store = connect_sharded([handles[0].address], cfg, timeout=30)
        store.add(sigs)
        conn = store.shards[0].conn
        qwords = np.zeros((1, K * cfg.b // 32), np.uint32)
        expired = Message(MsgType.BRUTE, {
            "qwords": qwords, "top_k": 3,
            DEADLINE_FIELD: deadline_us(time.time() - 5.0)})
        with pytest.raises(DeadlineExceeded):
            conn.request(expired)
        stats = dict(conn.request(Message(MsgType.STATS, {})).fields)
        assert int(stats["n_expired"]) == 1
        obs = json.loads(stats["obs"])
        assert obs["hists"].get("worker.handle.brute",
                                {}).get("count", 0) == 0, \
            "expired request was scored instead of dropped"
        # near-deadline: the wire deadline rides along and the answer is
        # exact — deadlines shed work, they never change answers
        with deadline_scope(time.time() + 30.0):
            ids, scores = store.query(sigs[:8], top_k=5)
        want_ids, want_scores = ref.query(sigs[:8], top_k=5)
        np.testing.assert_array_equal(ids, want_ids)
        np.testing.assert_array_equal(scores, want_scores)
        stats = dict(conn.request(Message(MsgType.STATS, {})).fields)
        assert int(stats["n_expired"]) == 1      # unchanged
    finally:
        if store is not None:
            shutdown_plane(store, handles, join_timeout=15)
        else:
            for h in handles:
                h.terminate()


def test_worker_admission_gate_sheds_clean_and_retryable():
    """gate_limit=0 sheds every read with a clean, retryable OVERLOADED;
    writes are not gated and the lane stays intact after shedding."""
    cfg = _cfg()
    sigs = _corpus()
    handles = spawn_workers(cfg, 1, gate_limit=0)
    store = None
    try:
        store = connect_sharded([handles[0].address], cfg, timeout=30)
        store.add(sigs)                          # writes bypass the gate
        with pytest.raises(Overloaded) as ei:
            store.query(sigs[:4], top_k=3)
        assert ei.value.retryable
        assert ei.value.retry_after_s >= 0
        conn = ShardConnection(handles[0].address, timeout=30,
                               shard=0, replica=0)
        stats = dict(conn.request(Message(MsgType.STATS, {})).fields)
        assert int(stats["gate_limit"]) == 0
        assert int(stats["n_overloaded"]) >= 1
        assert int(stats["size"]) == len(sigs)   # the ADD all landed
        store.add(_corpus(n=10, seed=3))         # lane still writable
        conn.close()
    finally:
        if store is not None:
            shutdown_plane(store, handles, join_timeout=15)
        else:
            for h in handles:
                h.terminate()


# -- streaming front ----------------------------------------------------------

class _FakeService:
    """Stand-in for SimilaritySearchService: instant sign, pluggable
    query — lets the stream tests steer overload without worker spawns."""

    packed_ingest = False

    def __init__(self, query_fn):
        self.cfg = types.SimpleNamespace(query_impl="host")
        self.store = types.SimpleNamespace(shards=[])
        self._query_fn = query_fn

    def _sign(self, rows, layout):
        return rows

    def _query(self, signed, top_k):
        return self._query_fn(signed, top_k)


def _ok_answer(signed, top_k):
    n = len(np.asarray(signed))
    return (np.zeros((n, top_k), np.int64),
            np.zeros((n, top_k), np.float32))


def _attach_budget(svc, budget):
    svc.store = types.SimpleNamespace(shards=[types.SimpleNamespace(
        group=types.SimpleNamespace(budget=budget))])


def test_stream_sheds_newest_when_queue_full():
    release = threading.Event()

    def slow(signed, top_k):
        release.wait(5.0)
        return _ok_answer(signed, top_k)

    s = StreamingQueryService(_FakeService(slow), StreamConfig(
        max_batch=1, depth=1, max_delay_ms=0.0, max_queue=2))
    try:
        admitted, shed = [], None
        for _ in range(50):
            t = s.submit_dense(np.arange(4.0))
            if t.done:                  # came back already rejected
                shed = t
                break
            admitted.append(t)
        assert shed is not None, "bounded queue never shed"
        with pytest.raises(Overloaded) as ei:
            shed.result(0)
        assert ei.value.retryable and ei.value.retry_after_s > 0
        release.set()
        for t in admitted:              # every ADMITTED query answers
            ids, scores = t.result(30)
            assert ids.shape == (s.cfg.top_k,)
    finally:
        release.set()
        s.close()


def test_stream_drops_expired_ticket_before_dispatch():
    release = threading.Event()

    def slow(signed, top_k):
        release.wait(5.0)
        return _ok_answer(signed, top_k)

    s = StreamingQueryService(_FakeService(slow), StreamConfig(
        max_batch=1, depth=1, max_delay_ms=0.0))
    try:
        t1 = s.submit_dense(np.arange(4.0))              # occupies the pipe
        t2 = s.submit_dense(np.arange(4.0), query_timeout_s=0.05)
        time.sleep(0.15)                # t2's deadline passes while queued
        release.set()
        t1.result(30)
        with pytest.raises(DeadlineExceeded):
            t2.result(30)
    finally:
        release.set()
        s.close()


def test_stream_retries_overloaded_within_budget():
    calls = []

    def flaky(signed, top_k):
        calls.append(time.monotonic())
        if len(calls) < 3:
            raise Overloaded("worker shed it", retry_after_s=0.01)
        return _ok_answer(signed, top_k)

    svc = _FakeService(flaky)
    budget = RetryBudget()
    _attach_budget(svc, budget)
    s = StreamingQueryService(svc, StreamConfig(
        max_batch=1, retries=3, query_timeout_s=30.0))
    try:
        t = s.submit_dense(np.arange(4.0))
        t.result(30)                    # retried through to the answer
        assert len(calls) == 3
        assert budget.n_spent == 2      # each retry spent one token
    finally:
        s.close()


def test_stream_never_retries_past_deadline():
    def always_shedding(signed, top_k):
        raise Overloaded("worker shed it", retry_after_s=5.0)

    svc = _FakeService(always_shedding)
    budget = RetryBudget()
    _attach_budget(svc, budget)
    s = StreamingQueryService(svc, StreamConfig(max_batch=1, retries=8))
    try:
        t = s.submit_dense(np.arange(4.0), query_timeout_s=0.3)
        t0 = time.monotonic()
        with pytest.raises(Overloaded):
            t.result(30)
        # a 5s retry-after cannot fit a 0.3s deadline: no retry fired, no
        # token burned, and the failure surfaced immediately
        assert time.monotonic() - t0 < 2.0
        assert budget.n_spent == 0
    finally:
        s.close()


def test_stream_retry_exhausted_budget_stops_retrying():
    calls = []

    def always_failing(signed, top_k):
        calls.append(1)
        raise Overloaded("worker shed it", retry_after_s=0.0)

    svc = _FakeService(always_failing)
    budget = RetryBudget(ratio=0.0, cap=0.0, floor_per_s=0.0)  # always empty
    _attach_budget(svc, budget)
    s = StreamingQueryService(svc, StreamConfig(
        max_batch=1, retries=5, query_timeout_s=30.0))
    try:
        t = s.submit_dense(np.arange(4.0))
        with pytest.raises(Overloaded):
            t.result(30)
        assert len(calls) == 1          # no budget -> primary only
        assert budget.n_denied >= 1
    finally:
        s.close()
