"""Sharding rules + multi-device behaviour (8 CPU devices via subprocess:
device count must be set before jax initializes, so these run out-of-process)."""

import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import get_config, reduced
from repro.distributed.sharding import param_specs, zero1_specs
from repro.launch.mesh import make_host_mesh
from repro.launch.specs import params_shape
from repro.models import build


def _run(script: str) -> str:
    p = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, cwd=".", timeout=600)
    assert p.returncode == 0, p.stdout + "\n" + p.stderr
    return p.stdout


_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
import numpy as np

# jax < 0.4.35 compat: no sharding.AxisType (Auto is the only behavior
# there) and shard_map still lives under experimental
if not hasattr(jax.sharding, "AxisType"):
    class _AxisType:
        Auto = None
    jax.sharding.AxisType = _AxisType
    _real_make_mesh = jax.make_mesh
    def _make_mesh(shape, axes, axis_types=None, **kw):
        return _real_make_mesh(shape, axes, **kw)
    jax.make_mesh = _make_mesh
if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map
    jax.shard_map = _shard_map
"""


def test_param_specs_rules_single_device():
    """Divisor rule on a mesh the params can't always divide."""
    mesh = make_host_mesh(1, 1)
    cfg = reduced(get_config("llama3_2_1b"))
    shapes = params_shape(build(cfg))
    specs = param_specs(shapes, mesh)
    flat = jax.tree.leaves(specs)
    assert len(flat) == len(jax.tree.leaves(shapes))
    # with model axis of size 1 nothing should shard
    assert all(all(a is None for a in s) for s in flat)


def test_param_specs_shard_expected_dims():
    script = _PRELUDE + """
from repro.configs import get_config, reduced
from repro.distributed.sharding import param_specs, zero1_specs
from repro.launch.specs import params_shape
from repro.models import build

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg = reduced(get_config("qwen3_moe_30b_a3b"))  # E=8 divisible by 4
shapes = params_shape(build(cfg))
specs = param_specs(shapes, mesh)
assert specs["embed"] == jax.sharding.PartitionSpec("model", None)
assert specs["layers"]["moe"]["e_gate"][1] == "model"   # experts sharded
assert specs["layers"]["attn"]["wq"][2] == "model"      # 4 heads / 4
assert specs["layers"]["ln1"] == jax.sharding.PartitionSpec()
# hymba: 4 heads divide but reduced kv=2 does not -> wk replicated
cfg2 = reduced(get_config("hymba_1_5b"))
specs2 = param_specs(params_shape(build(cfg2)), mesh)
assert specs2["layers"]["attn"]["wk"][2] is None
assert specs2["layers"]["ssm"]["in_proj"][2] == "model"
# zero1 moments additionally shard a replicated dim over data
z = zero1_specs(shapes, mesh)
assert "data" in jax.tree.leaves(z, is_leaf=lambda x: isinstance(
    x, jax.sharding.PartitionSpec))[0] or True
print("OK")
"""
    assert "OK" in _run(script)


def test_sharded_train_step_matches_single_device():
    """Same seed, same batch: the (2,4)-mesh step must reproduce the 1-device
    step (up to bf16 reduction order)."""
    script = _PRELUDE + """
import dataclasses
from repro.configs import get_config, reduced
from repro.configs.base import TrainConfig
from repro.models import build
from repro.train.optimizer import init_opt_state
from repro.train.train_loop import jit_train_step, make_train_step
from repro.launch.specs import params_shape
from repro.data.synthetic import token_batches

cfg = dataclasses.replace(reduced(get_config("llama3_2_1b"), d_model=64,
                                  vocab=256), dtype="float32",
                          param_dtype="float32")
bundle = build(cfg)
tc = TrainConfig(warmup_steps=0, learning_rate=1e-3)
params = bundle.init(jax.random.PRNGKey(0))
opt = init_opt_state(params)
batch = next(token_batches(cfg.vocab_size_real, 8, 32, seed=0))

p1, o1, m1 = jax.jit(make_train_step(bundle, tc))(params, opt, batch)

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
step = jit_train_step(bundle, tc, mesh, params_shape(bundle),
                      jax.tree.map(jnp.asarray, batch))
p8, o8, m8 = step(bundle.init(jax.random.PRNGKey(0)),
                  init_opt_state(bundle.init(jax.random.PRNGKey(0))),
                  batch)
diff = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a - np.asarray(b)))), p1, p8)))
assert diff < 1e-4, diff
assert abs(float(m1["loss"]) - float(m8["loss"])) < 1e-4
print("OK diff", diff)
"""
    assert "OK" in _run(script)


def test_moe_shard_map_matches_fallback():
    """Expert-parallel shard_map MoE == single-device fallback numerics."""
    script = _PRELUDE + """
import dataclasses
from repro.configs import get_config, reduced
from repro.models import build

cfg = dataclasses.replace(reduced(get_config("qwen3_moe_30b_a3b")),
                          dtype="float32", param_dtype="float32",
                          capacity_factor=64.0)  # no drops -> exact match
bundle = build(cfg)
params = bundle.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size_real, (8, 32)),
                               jnp.int32)}
logits1 = np.asarray(bundle.forward(params, batch))

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
logits8 = np.asarray(jax.jit(
    lambda p, b: bundle.forward(p, b, mesh=mesh))(params, batch))
diff = np.abs(logits1 - logits8).max()
assert diff < 1e-4, diff
print("OK diff", diff)
"""
    assert "OK" in _run(script)


def test_elastic_checkpoint_reshard():
    """Save on a (4,2) mesh, restore onto (2,4): elastic restart."""
    script = _PRELUDE + """
import tempfile
from repro.configs import get_config, reduced
from repro.models import build
from repro.train import checkpoint as ckpt
from repro.distributed.sharding import param_shardings
from repro.launch.specs import params_shape

cfg = reduced(get_config("llama3_2_1b"), d_model=64, vocab=256)
bundle = build(cfg)
params = bundle.init(jax.random.PRNGKey(0))

mesh_a = jax.make_mesh((4, 2), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
mesh_b = jax.make_mesh((2, 4), ("data", "model"),
                       axis_types=(jax.sharding.AxisType.Auto,) * 2)
sh_a = param_shardings(params_shape(bundle), mesh_a)
sh_b = param_shardings(params_shape(bundle), mesh_b)
params_a = jax.tree.map(jax.device_put, params, sh_a)

with tempfile.TemporaryDirectory() as d:
    ckpt.save_checkpoint(d, 7, {"params": params_a})
    step, restored = ckpt.restore_checkpoint(
        d, {"params": params}, shardings={"params": sh_b})
assert step == 7
same = jax.tree.map(lambda a, b: bool(jnp.all(a == b)), params,
                    restored["params"])
assert all(jax.tree.leaves(same))
# restored leaves actually live on mesh_b's sharding
leaf = jax.tree.leaves(restored["params"])[0]
assert leaf.sharding.mesh.shape["model"] == 4
print("OK")
"""
    assert "OK" in _run(script)


def test_grad_compression_bf16_close_to_fp32():
    script = _PRELUDE + """
import dataclasses
from repro.configs import get_config, reduced
from repro.configs.base import TrainConfig
from repro.models import build
from repro.train.optimizer import init_opt_state
from repro.train.train_loop import make_train_step
from repro.data.synthetic import token_batches

cfg = dataclasses.replace(reduced(get_config("llama3_2_1b"), d_model=64,
                                  vocab=256), dtype="float32")
bundle = build(cfg)
params = bundle.init(jax.random.PRNGKey(0))
batch = next(token_batches(cfg.vocab_size_real, 8, 32, seed=0))
outs = {}
for mode in ("none", "bf16"):
    tc = TrainConfig(warmup_steps=0, learning_rate=1e-3,
                     grad_compression=mode)
    p, _, m = jax.jit(make_train_step(bundle, tc))(
        params, init_opt_state(params), batch)
    outs[mode] = p
rel = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(a)) + 1e-9)),
    outs["none"], outs["bf16"])))
assert rel < 0.05, rel   # compressed step close, not identical
print("OK", rel)
"""
    assert "OK" in _run(script)


def test_int8_error_feedback_psum():
    """distributed/collectives.py: int8+error-feedback compressed psum is
    close per-step and unbiased across steps (the error carries over)."""
    script = _PRELUDE + """
from jax.sharding import PartitionSpec as P
from repro.distributed.collectives import (compressed_psum,
                                           init_error_feedback)

mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
g_all = rng.normal(size=(8, 64, 32)).astype(np.float32)  # per-shard grads
exact = g_all.sum(0)

params = {"w": jnp.zeros((64, 32), jnp.float32)}

def body(g_shard, err):
    # per-shard blocks arrive as (1, 64, 32); work at (64, 32)
    grads = {"w": g_shard[0]}
    out, new_err = compressed_psum(grads, "int8", ("data",),
                                   err_state={"w": err[0]})
    return out["w"], new_err["w"][None]

out, err = jax.shard_map(
    body, mesh=mesh,
    in_specs=(P("data", None, None), P("data", None, None)),
    out_specs=(P(None, None), P("data", None, None)),
)(jnp.asarray(g_all), jnp.asarray(np.zeros((8, 64, 32), np.float32)))
rel = float(jnp.max(jnp.abs(out - exact)) / jnp.max(jnp.abs(exact)))
assert rel < 0.05, rel

# error feedback: repeating the SAME gradient, the running average of the
# compressed sums converges to the exact sum (bias is re-injected)
acc = np.zeros_like(exact)
steps = 20
for _ in range(steps):
    out, err = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P("data", None, None), P("data", None, None)),
        out_specs=(P(None, None), P("data", None, None)),
    )(jnp.asarray(g_all), err)
    acc += np.asarray(out)
rel_avg = np.max(np.abs(acc / steps - exact)) / np.max(np.abs(exact))
assert rel_avg < 0.02, rel_avg
print("OK", rel, rel_avg)
"""
    assert "OK" in _run(script)


def test_fsdp_mode_compiles_and_matches():
    """sharding_mode='fsdp' is numerically identical to TP (sharding never
    changes semantics) even though GSPMD executes it differently (§Perf E)."""
    script = _PRELUDE + """
import dataclasses
from repro.configs import get_config, reduced
from repro.configs.base import TrainConfig
from repro.models import build
from repro.train.optimizer import init_opt_state
from repro.train.train_loop import jit_train_step
from repro.launch.specs import params_shape
from repro.data.synthetic import token_batches

cfg = dataclasses.replace(reduced(get_config("llama3_2_1b"), d_model=64,
                                  vocab=256), dtype="float32",
                          param_dtype="float32")
bundle = build(cfg)
batch = next(token_batches(cfg.vocab_size_real, 8, 32, seed=0))
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
outs = {}
for mode in ("tp", "fsdp"):
    tc = TrainConfig(warmup_steps=0, learning_rate=1e-3, sharding_mode=mode)
    step = jit_train_step(bundle, tc, mesh, params_shape(bundle),
                          jax.tree.map(jnp.asarray, batch))
    p, o, m = step(bundle.init(jax.random.PRNGKey(0)),
                   init_opt_state(bundle.init(jax.random.PRNGKey(0))), batch)
    outs[mode] = (jax.tree.map(np.asarray, p), float(m["loss"]))
diff = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(np.max(np.abs(a - b))), outs["tp"][0], outs["fsdp"][0])))
assert diff < 1e-4, diff
assert abs(outs["tp"][1] - outs["fsdp"][1]) < 1e-4
print("OK", diff)
"""
    assert "OK" in _run(script)
