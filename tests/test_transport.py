"""Transport plane: real tcp shard workers vs the in-process plane.

The acceptance contract: a tcp-backed ``ShardedSketchStore`` (worker
processes on localhost, framed wire protocol) answers **bit-identically**
to the in-process plane — and to a single ``SketchStore`` — on the same
items, for S in {1, 2, 4}, including the brute-force-fallback rows.  Plus
failure semantics: a killed worker surfaces as a client-side exception
within the fan-out timeout (never a hang), worker-side errors propagate
with their message, and snapshots round-trip both directions (tcp save ->
inproc load, inproc save -> worker snapshot boot).

These tests spawn real processes; each spawn re-imports jax, so they are
grouped to spend as few worker boots as possible.
"""

import time

import numpy as np
import pytest

from repro.store import ShardedSketchStore, SketchStore, StoreConfig
from repro.transport import (TransportError, WorkerError, connect_sharded,
                             shutdown_plane, spawn_workers)

K, NB, R = 64, 16, 4
SHARD_COUNTS = [1, 2, 4]


def _corpus(n=120, k=K, seed=0, dup_pairs=3):
    rng = np.random.default_rng(seed)
    sigs = rng.integers(0, 1 << 16, (n, k), dtype=np.int32)
    for t in range(dup_pairs):          # planted exact duplicates
        sigs[n - 1 - t] = sigs[t]
    return sigs


def _queries(sigs, n_strangers=2, seed=1):
    """Indexed rows + strangers that hit no bucket anywhere (forcing the
    global brute-force-fallback leg over the wire)."""
    rng = np.random.default_rng(seed)
    strangers = rng.integers(1 << 20, 1 << 24,
                             (n_strangers, sigs.shape[1]), dtype=np.int32)
    return np.concatenate([sigs[:10], strangers])


def _shutdown(store, handles):
    assert shutdown_plane(store, handles, join_timeout=15)
    for h in handles:
        assert not h.alive, f"worker {h.shard} survived graceful shutdown"


@pytest.mark.parametrize("s", SHARD_COUNTS)
def test_tcp_plane_bit_identical(s, tmp_path):
    """tcp == inproc == single store: ids, scores, fallback rows, stats —
    plus a snapshot written over the wire reloads in-process exactly."""
    sigs = _corpus(seed=s)
    q = _queries(sigs, seed=s + 1)
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    single = SketchStore(cfg)
    single.add(sigs)
    inproc = ShardedSketchStore(cfg, s)
    inproc.add(sigs)
    handles = spawn_workers(cfg, s)
    try:
        tcp = connect_sharded([h.address for h in handles], cfg, timeout=60)
        gids = tcp.add(sigs)
        assert np.array_equal(gids, np.arange(len(sigs)))
        for top_k in (1, 5):
            want_ids, want_scores = single.query(q, top_k=top_k)
            in_ids, in_scores = inproc.query(q, top_k=top_k)
            got_ids, got_scores = tcp.query(q, top_k=top_k)
            assert np.array_equal(want_ids, in_ids)
            assert np.array_equal(want_ids, got_ids)
            assert np.array_equal(want_scores, in_scores)
            assert np.array_equal(want_scores, got_scores)
        assert np.array_equal(tcp.shard_sizes(), inproc.shard_sizes())
        assert tcp.n_spilled == inproc.n_spilled
        # workers resolve probe_impl="auto" against THEIR backend at boot
        # and report the choice in STATS (a mixed CPU/accelerator fleet
        # serves one plane, each worker on its best probe path)
        for sh in tcp.shards:
            assert sh.stats()["probe_impl"] in ("numpy", "jnp", "pallas")
            assert sh.stats()["query_impl"] in ("jnp", "pallas", "host")
        # wall-time split is populated for the artifact row
        assert set(tcp.last_timings) == \
            {"fold_s", "broadcast_s", "partial_s", "merge_s"}
        # snapshot written worker-side, reloaded in-process: same answers
        snap = str(tmp_path / "plane")
        tcp.save(snap)
        re = ShardedSketchStore.load(snap)
        want = single.query(q, top_k=4)
        got = re.query(q, top_k=4)
        assert np.array_equal(want[0], got[0])
        assert np.array_equal(want[1], got[1])
        _shutdown(tcp, handles)
    finally:
        for h in handles:
            h.terminate()


def test_tcp_packed_path_and_snapshot_boot(tmp_path):
    """Fused packed ingest/query over the wire, then workers booted FROM an
    inproc snapshot answer identically (the resharding/boot workflow)."""
    import jax.numpy as jnp

    from repro.kernels import ops

    sigs = _corpus(seed=9)
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    words = np.asarray(ops.pack_codes(jnp.asarray(sigs), 32))
    qw = np.asarray(ops.pack_codes(jnp.asarray(_queries(sigs, seed=10)), 32))
    single = SketchStore(cfg)
    single.add_packed(words)
    want = single.query_packed(qw, top_k=6)

    inproc = ShardedSketchStore(cfg, 2, partition="hash")
    inproc.add_packed(words)
    snap = str(tmp_path / "plane")
    inproc.save(snap)

    handles = spawn_workers(None, 2, snapshot_dir=snap)
    try:
        # forgetting snapshot_dir must be rejected, not answer with
        # shard-local ids: the coordinator's (empty) gid maps don't match
        # the workers' stores
        with pytest.raises(WorkerError, match="gid map"):
            connect_sharded([h.address for h in handles], cfg, timeout=60)
        tcp = connect_sharded([h.address for h in handles],
                              snapshot_dir=snap, timeout=60)
        assert tcp.n_items == inproc.n_items
        assert tcp.partition == "hash"
        got = tcp.query_packed(qw, top_k=6)
        assert np.array_equal(want[0], got[0])
        assert np.array_equal(want[1], got[1])
        # the booted plane keeps ingesting: gids continue in arrival order
        more = _corpus(n=30, seed=11, dup_pairs=0)
        w_more = np.asarray(ops.pack_codes(jnp.asarray(more), 32))
        assert np.array_equal(tcp.add_packed(w_more),
                              np.arange(len(sigs), len(sigs) + 30))
        single.add_packed(w_more)
        inproc.add_packed(w_more)
        want2 = single.query_packed(qw, top_k=6)
        got2 = tcp.query_packed(qw, top_k=6)
        in2 = inproc.query_packed(qw, top_k=6)
        assert np.array_equal(want2[0], got2[0])
        assert np.array_equal(want2[1], got2[1])
        assert np.array_equal(want2[0], in2[0])
        _shutdown(tcp, handles)
    finally:
        for h in handles:
            h.terminate()


def test_killed_worker_raises_within_timeout():
    """A dead worker is a client-side exception, never a hang — both on the
    fan-out path and on the blocking request path."""
    sigs = _corpus(n=60, dup_pairs=0)
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    handles = spawn_workers(cfg, 2)
    try:
        tcp = connect_sharded([h.address for h in handles], cfg, timeout=5)
        tcp.add(sigs)
        tcp.query(sigs[:4], top_k=3)           # plane is healthy first
        handles[1].proc.kill()                 # SIGKILL: no goodbye frame
        handles[1].proc.join(10)
        t0 = time.monotonic()
        with pytest.raises(TransportError):
            tcp.query(sigs[:4], top_k=3)
        assert time.monotonic() - t0 < 30
        t0 = time.monotonic()
        with pytest.raises(TransportError):
            tcp.add(sigs)                      # blocking path fails too
        assert time.monotonic() - t0 < 30
    finally:
        for h in handles:
            h.terminate()


def test_killed_worker_mid_add_poisons_plane():
    """A worker killed under the ADD fan-out raises within the deadline AND
    poisons the plane: the surviving shard may have indexed its slice, so a
    retry would re-issue the same gids and double-index — the plane must
    refuse further writes and reads instead (mirrors the query-side kill
    test, which stays read-only and does NOT poison)."""
    sigs = _corpus(n=60, dup_pairs=0)
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    handles = spawn_workers(cfg, 2)
    try:
        tcp = connect_sharded([h.address for h in handles], cfg, timeout=5)
        tcp.add(sigs)                          # plane is healthy first
        handles[0].proc.kill()                 # SIGKILL: no goodbye frame
        handles[0].proc.join(10)
        t0 = time.monotonic()
        with pytest.raises(TransportError):
            tcp.add(sigs)                      # fan-out write hits the corpse
        assert time.monotonic() - t0 < 30
        with pytest.raises(RuntimeError, match="inconsistent"):
            tcp.add(sigs)                      # retry must not double-index
        with pytest.raises(RuntimeError, match="inconsistent"):
            tcp.query(sigs[:4], top_k=3)
    finally:
        for h in handles:
            h.terminate()


def test_failed_query_fanout_does_not_poison_writes():
    """Queries are read-only: a fan-out that dies mid-QUERY must not mark
    the plane inconsistent — the surviving plane still refuses nothing
    (the degraded query itself raises, as always)."""
    sigs = _corpus(n=40, dup_pairs=0)
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    handles = spawn_workers(cfg, 1)
    try:
        tcp = connect_sharded([h.address for h in handles], cfg, timeout=5)
        tcp.add(sigs)
        handles[0].proc.kill()
        handles[0].proc.join(10)
        with pytest.raises(TransportError):
            tcp.query(sigs[:4], top_k=3)
        assert tcp._failed is None             # reads never poison
    finally:
        for h in handles:
            h.terminate()


def test_stale_reply_discarded():
    """A reply left over from an abandoned request (its seq never matches)
    is skipped — the connection pairs each request with its own reply."""
    import socket
    import threading

    from repro.transport.client import ShardConnection
    from repro.transport.wire import (Message, MsgType, recv_message,
                                      send_message)

    lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lsock.bind(("127.0.0.1", 0))
    lsock.listen(1)

    def serve():
        conn, _ = lsock.accept()
        with conn:
            msg = recv_message(conn)
            send_message(conn, Message(MsgType.OK, {"n": 99}, seq=0xDEAD))
            send_message(conn, Message(MsgType.OK, {"n": 7}, seq=msg.seq))

    t = threading.Thread(target=serve, daemon=True)
    t.start()
    try:
        c = ShardConnection(lsock.getsockname(), timeout=10)
        assert int(c.request(Message(MsgType.STATS, {}))["n"]) == 7
        c.close()
        t.join(10)
    finally:
        lsock.close()


def _fake_worker(handler):
    """A scripted TCP shard 'worker' for protocol-level failure tests:
    runs ``handler(conn)`` for one accepted connection on a daemon thread.
    Returns (listener socket, thread)."""
    import socket
    import threading

    lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lsock.bind(("127.0.0.1", 0))
    lsock.listen(1)

    def serve():
        conn, _ = lsock.accept()
        with conn:
            handler(conn)

    t = threading.Thread(target=serve, daemon=True)
    t.start()
    return lsock, t


def test_one_shard_error_does_not_brick_the_group():
    """An ERROR reply from one shard raises WorkerError — and the fan-out
    group abandons the round cleanly, so the next query works instead of
    tripping the one-outstanding-request guard."""
    from repro.transport.client import (FanoutGroup, RemoteShard,
                                        ShardConnection)
    from repro.transport.wire import (Message, MsgType, recv_message,
                                      send_message)

    def ok_partial(conn, rounds=2):
        for _ in range(rounds):
            msg = recv_message(conn)
            q = msg["qwords"].shape[0]
            send_message(conn, Message(MsgType.PARTIAL, {
                "ids": np.full((q, 3), -1, np.int64),
                "scores": np.full((q, 3), -np.inf, np.float32),
                "has": np.zeros(q, bool)}, seq=msg.seq))

    def error_then_ok(conn):
        msg = recv_message(conn)
        send_message(conn, Message(MsgType.ERROR, {"error": "boom"},
                                   seq=msg.seq))
        ok_partial(conn, rounds=1)

    l0, t0 = _fake_worker(error_then_ok)
    l1, t1 = _fake_worker(lambda c: ok_partial(c, rounds=2))
    try:
        conns = [ShardConnection(l0.getsockname(), timeout=10),
                 ShardConnection(l1.getsockname(), timeout=10)]
        group = FanoutGroup(conns, timeout=10)
        shards = [RemoteShard(c, group) for c in conns]
        hashes = np.zeros((2, NB), np.uint64)
        qw = np.zeros((2, K), np.uint32)
        pend = [sh.start_query(hashes, qw, 3, "sig") for sh in shards]
        with pytest.raises(WorkerError, match="boom"):
            for p in pend:
                p.result()
        # the plane is still queryable: a fresh round completes on both
        pend = [sh.start_query(hashes, qw, 3, "sig") for sh in shards]
        for p in pend:
            part = p.result()
            assert part.ids.shape == (2, 3)
        for c in conns:
            c.close()
    finally:
        l0.close()
        l1.close()


def test_midframe_timeout_poisons_connection():
    """A reply cut mid-frame by a timeout cannot be re-synced by seq
    pairing — the connection must refuse further use, not misparse."""
    import time as _time

    from repro.transport.client import ShardConnection
    from repro.transport.wire import Message, MsgType, message_bytes, \
        recv_message

    def half_reply(conn):
        msg = recv_message(conn)
        frame = message_bytes(Message(MsgType.OK, {"n": 1}, seq=msg.seq))
        conn.sendall(frame[: len(frame) - 4])      # cut mid-frame
        _time.sleep(3)                             # past the client timeout

    lsock, _ = _fake_worker(half_reply)
    try:
        c = ShardConnection(lsock.getsockname(), timeout=1)
        with pytest.raises(TransportError):
            c.request(Message(MsgType.STATS, {}))
        assert c.broken
        with pytest.raises(WorkerError, match="unusable"):
            c.request(Message(MsgType.STATS, {}))
    finally:
        lsock.close()


def test_worker_survives_client_hangup_mid_reply():
    """A client that disconnects before reading a (large) reply must not
    kill the worker: it returns to accept and serves the next client."""
    import socket

    from repro.transport.wire import Message, MsgType, send_message

    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    handles = spawn_workers(cfg, 1)
    try:
        # raw client: request a ~1.2 MB brute partial, vanish immediately
        rude = socket.create_connection(handles[0].address, timeout=30)
        send_message(rude, Message(
            MsgType.BRUTE,
            {"qwords": np.zeros((2000, K), np.uint32), "top_k": 50}, seq=1))
        rude.close()
        # the worker must still be there for a well-behaved coordinator
        tcp = connect_sharded([handles[0].address], cfg, timeout=60)
        sigs = _corpus(n=30, dup_pairs=0)
        tcp.add(sigs)
        ids, _ = tcp.query(sigs[:3], top_k=2)
        assert np.array_equal(ids[:, 0], np.arange(3))
        assert handles[0].alive
        _shutdown(tcp, handles)
    finally:
        for h in handles:
            h.terminate()


def test_hedged_reads_bit_identical_and_win():
    """With one shard sleeping on most of its reads, hedged twin reads must
    (a) fire, (b) win some races, and (c) never change a single bit of any
    answer — the losing leg's late reply is discarded by seq, not merged."""
    from repro.transport import HedgePolicy

    sigs = _corpus(seed=21)
    q = _queries(sigs, seed=22)
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    single = SketchStore(cfg)
    single.add(sigs)
    want = single.query(q, top_k=5)
    handles = spawn_workers(cfg, 2, slow_shards={1: (0.8, 0.03)})
    try:
        tcp = connect_sharded([h.address for h in handles], cfg, timeout=60,
                              hedge=HedgePolicy(delay_s=0.005))
        tcp.add(sigs)
        for _ in range(15):
            got = tcp.query(q, top_k=5)
            assert np.array_equal(want[0], got[0])
            assert np.array_equal(want[1], got[1])
        g = tcp.shards[0].group
        assert g.n_hedges > 0, "slow shard never triggered a hedge"
        assert g.n_hedge_wins > 0, "no hedge ever beat a 30 ms stall"
        _shutdown(tcp, handles)
    finally:
        for h in handles:
            h.terminate()


def test_hedge_delay_derives_from_peer_skew():
    """The adaptive delay for a shard comes from its PEERS' reply-skew
    histograms, never its own: a stalling shard's own percentiles are
    inflated by rounds queued behind each stall, and a self-derived delay
    would grow past the stall and veto the very hedge that should cut it.
    (``FanoutGroup``'s ctor never touches sockets, so plain objects stand
    in for connections.)"""
    from repro.transport import HedgePolicy
    from repro.transport.client import FanoutGroup

    slow, fast1, fast2 = object(), object(), object()
    g = FanoutGroup([slow, fast1, fast2], hedge=HedgePolicy(),
                    hedge_conns={slow: object(), fast1: object(),
                                 fast2: object()})
    for _ in range(40):                 # peers land ~2 ms after the fastest
        g._lat_h[fast1].observe(0.002)
        g._lat_h[fast2].observe(0.002)
        g._lat_h[slow].observe(0.5)     # the slow shard skews 500 ms
    g._msgs = {slow: object(), fast1: object()}   # hedgeable this round
    d = g._hedge_delay(slow)
    assert d is not None and d < 0.05, \
        f"slow shard's own history leaked into its delay (got {d})"
    # the healthy shard's delay sees the slow peer's fat tail — that only
    # makes its hedges rarer, never wrong
    assert g._hedge_delay(fast1) is not None
    assert g._hedge_delay(fast2) is None          # not hedgeable this round
    # a single-connection group has no peers, hence no skew signal: the
    # adaptive mode never hedges it (a fixed delay_s still would)
    lone = FanoutGroup([slow], hedge=HedgePolicy(),
                       hedge_conns={slow: object()})
    lone._msgs = {slow: object()}
    for _ in range(40):
        lone._lat_h[slow].observe(0.002)
    assert lone._hedge_delay(slow) is None


def test_writes_never_hedge():
    """ADD is not idempotent: even with an immediate hedge delay, only the
    read path (QUERY/BRUTE) may re-issue on the twin connection."""
    from repro.transport import HedgePolicy

    sigs = _corpus(n=80, dup_pairs=0)
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    handles = spawn_workers(cfg, 2, slow_shards={0: (1.0, 0.02)})
    try:
        tcp = connect_sharded([h.address for h in handles], cfg, timeout=60,
                              hedge=HedgePolicy(delay_s=0.0))
        g = tcp.shards[0].group
        tcp.add(sigs)
        tcp.add(_corpus(n=40, seed=5, dup_pairs=0))
        assert g.n_hedges == 0, "a write was hedged"
        tcp.query(sigs[:4], top_k=3)           # every read stalls 20 ms:
        assert g.n_hedges > 0                  # delay-0 hedges must fire
        _shutdown(tcp, handles)
    finally:
        for h in handles:
            h.terminate()


def test_query_timeout_error_names_the_knob():
    """A fan-out deadline on the query path tells the operator WHICH
    deadline expired (``query_timeout_s``), not just that one did."""
    sigs = _corpus(n=60, dup_pairs=0)
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    handles = spawn_workers(cfg, 1, slow_shards={0: (1.0, 2.0)})
    try:
        tcp = connect_sharded([h.address for h in handles], cfg, timeout=0.5)
        tcp.add(sigs)                          # writes are never slowed
        with pytest.raises(TransportError, match="query_timeout_s"):
            tcp.query(sigs[:2], top_k=3)
    finally:
        for h in handles:
            h.terminate()


def test_worker_error_propagates_with_message():
    """A worker-side exception comes back as WorkerError carrying the
    worker's own message, and the worker keeps serving afterwards."""
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    handles = spawn_workers(cfg, 1)
    try:
        tcp = connect_sharded([h.address for h in handles], cfg, timeout=60)
        with pytest.raises(WorkerError, match="expected"):
            tcp.add(np.zeros((2, K + 1), np.int32))     # wrong K
        sigs = _corpus(n=40, dup_pairs=0)
        tcp.add(sigs)                          # connection still healthy
        ids, _ = tcp.query(sigs[:3], top_k=2)
        assert np.array_equal(ids[:, 0], np.arange(3))
        _shutdown(tcp, handles)
    finally:
        for h in handles:
            h.terminate()
