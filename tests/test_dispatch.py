"""Dispatch-layer parity sweeps: every signing path == the jnp oracle.

Covers the non-divisible shapes the tiling has to get right — b % block_b,
d % block_d, k < block_d, k % 32 — for shift_offset in {0, 1}, plus the fused
sign->pack epilogue (bit-identical to sign-then-pack_codes for every b), the
engine's config routing, and the packed store ingest path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cminhash
from repro.core.engine import SketchConfig, SketchEngine
from repro.core.permutations import make_two_permutations
from repro.kernels import dispatch, ops, ref
from repro.kernels.packfmt import PACK_BITS, pack_codes

# b % block_b != 0, d % block_d != 0, k < block_d, k % 32 != 0 all appear
SHAPES = [
    (3, 100, 37, 0.05),    # k % 32 != 0, d % block_d != 0, b % block_b != 0
    (5, 300, 300, 0.3),    # k > block_d after clamping? k % 32 != 0
    (2, 257, 129, 0.9),    # everything prime-ish
    (4, 96, 7, 0.1),       # k < block_d, tiny k
    (1, 64, 64, 0.5),      # exact fit
]
BLOCKS = {"block_b": 4, "block_d": 64}


def _inputs(b, d, dens, seed):
    rng = np.random.default_rng(seed)
    v = (rng.random((b, d)) < dens).astype(np.int8)
    nnz = max(1, int(v.sum(axis=1).max()))
    idx = np.full((b, nnz), -1, np.int32)
    for i in range(b):
        z = np.where(v[i])[0]
        idx[i, : len(z)] = z
    _, pi = make_two_permutations(jax.random.PRNGKey(seed), d)
    return jnp.asarray(v), jnp.asarray(idx), pi


@pytest.mark.parametrize("B,D,K,dens", SHAPES)
@pytest.mark.parametrize("off", [0, 1])
def test_dense_impls_match_ref(B, D, K, dens, off):
    v, _, pi = _inputs(B, D, dens, B * D + K + off)
    want = np.asarray(ref.cminhash_dense_ref(v, pi, K, shift_offset=off))
    for impl in ("int8", "packed", "ref"):
        got = dispatch.signatures_dense(v, pi, K, shift_offset=off,
                                        impl=impl, **BLOCKS)
        assert np.array_equal(np.asarray(got), want), impl


@pytest.mark.parametrize("B,D,K,dens", SHAPES)
@pytest.mark.parametrize("off", [0, 1])
def test_sparse_impls_match_ref(B, D, K, dens, off):
    v, idx, pi = _inputs(B, D, dens, B * D + K + off)
    want = np.asarray(ref.cminhash_dense_ref(v, pi, K, shift_offset=off))
    for impl, blocks in (("gather", {}),
                         ("windows", {"block_j": 4}),
                         ("pallas", {"block_b": 4, "block_j": 4})):
        got = dispatch.signatures_sparse(idx, pi, K, shift_offset=off,
                                         impl=impl, **blocks)
        assert np.array_equal(np.asarray(got), want), impl


def test_sparse_all_padding_rows():
    # rows with zero valid indices must sign to SENTINEL on every path
    _, pi = make_two_permutations(jax.random.PRNGKey(0), 128)
    idx = jnp.asarray(np.array([[-1, -1, -1], [3, -1, -1]], np.int32))
    want = np.asarray(dispatch.signatures_sparse(idx, pi, 32, impl="gather"))
    assert (want[0] == np.iinfo(np.int32).max).all()
    for impl in ("windows", "pallas"):
        got = dispatch.signatures_sparse(idx, pi, 32, impl=impl)
        assert np.array_equal(np.asarray(got), want), impl


def test_sparse_with_sigma_matches_dense():
    v, idx, pi = _inputs(4, 200, 0.1, 11)
    sigma, _ = make_two_permutations(jax.random.PRNGKey(3), 200)
    want = np.asarray(dispatch.signatures_dense(v, pi, 64, sigma, impl="ref"))
    for impl in ("gather", "windows", "pallas"):
        got = dispatch.signatures_sparse(idx, pi, 64, sigma, impl=impl)
        assert np.array_equal(np.asarray(got), want), impl


@pytest.mark.parametrize("B,D,K,dens", [(3, 100, 37, 0.05), (2, 257, 129, 0.3),
                                        (4, 96, 7, 0.1)])
@pytest.mark.parametrize("b", PACK_BITS)
def test_fused_pack_bit_identical(B, D, K, dens, b):
    v, idx, pi = _inputs(B, D, dens, B + D + K)
    sig = ref.cminhash_dense_ref(v, pi, K)
    want = np.asarray(pack_codes(sig, b))
    for impl in ("int8", "packed", "ref"):
        got = dispatch.signatures_dense(v, pi, K, impl=impl, pack_b=b,
                                        **BLOCKS)
        assert got.dtype == jnp.uint32
        assert np.array_equal(np.asarray(got), want), impl
    # sparse paths: window-min kernels fuse the same epilogue (gather packs
    # as a separate step but must agree bit-for-bit)
    for impl, blocks in (("gather", {}),
                         ("windows", {"block_j": 4}),
                         ("pallas", {"block_b": 2, "block_j": 4})):
        got = dispatch.signatures_sparse(idx, pi, K, impl=impl, pack_b=b,
                                         **blocks)
        assert got.dtype == jnp.uint32, impl
        assert np.array_equal(np.asarray(got), want), impl


def test_auto_policy():
    # CPU: compiled jnp twins; TPU: kernels, packed once D is HBM-bound
    assert dispatch.select_dense_impl(512, backend="cpu") == "ref"
    assert dispatch.select_dense_impl(512, use_kernel=False,
                                      backend="tpu") == "ref"
    assert dispatch.select_dense_impl(512, backend="tpu") == "int8"
    assert dispatch.select_dense_impl(dispatch.PACKED_MIN_D,
                                      backend="tpu") == "packed"
    assert dispatch.select_sparse_impl(backend="cpu") == "windows"
    assert dispatch.select_sparse_impl(backend="tpu") == "pallas"
    assert dispatch.select_sparse_impl(use_kernel=False,
                                       backend="tpu") == "gather"
    with pytest.raises(ValueError):
        dispatch.signatures_dense(jnp.zeros((1, 8), jnp.int8),
                                  jnp.arange(8, dtype=jnp.int32), 4,
                                  impl="nope")


def test_engine_sparse_respects_config(monkeypatch):
    """signatures_sparse must route through dispatch with the engine config
    (it used to call cminhash_sparse directly, ignoring use_kernel/blocks)."""
    calls = []
    real = dispatch.signatures_sparse

    def spy(*args, **kw):
        calls.append(kw)
        return real(*args, **kw)

    monkeypatch.setattr("repro.kernels.dispatch.signatures_sparse", spy)
    cfg = SketchConfig(d=256, k=32, use_kernel=False, block_j=4, seed=0)
    eng = SketchEngine(cfg)
    idx = jnp.asarray(np.array([[1, 5, 9, -1]], np.int32))
    sig = eng.signatures_sparse(idx)
    assert calls and calls[-1]["use_kernel"] is False
    assert calls[-1]["block_j"] == 4
    # and the values still match the direct gather formulation
    want = cminhash.cminhash_sparse(idx, eng.pi, 32, eng.sigma)
    assert np.array_equal(np.asarray(sig), np.asarray(want))

    eng2 = SketchEngine(SketchConfig(d=256, k=32, use_kernel=True, seed=0))
    sig2 = eng2.signatures_sparse(idx)
    assert calls[-1]["use_kernel"] is True
    assert np.array_equal(np.asarray(sig2), np.asarray(want))


def test_engine_sign_packed_matches_two_step():
    eng = SketchEngine(SketchConfig(d=512, k=64, seed=2))
    rng = np.random.default_rng(2)
    v = jnp.asarray((rng.random((6, 512)) < 0.1).astype(np.int8))
    sig = eng.signatures_dense(v)
    for b in PACK_BITS:
        got = eng.sign_packed(v, b)
        assert np.array_equal(np.asarray(got),
                              np.asarray(pack_codes(sig, b))), b


def test_ops_wrapper_still_dispatches():
    v, _, pi = _inputs(4, 300, 0.2, 21)
    a = ops.cminhash_signatures(v, pi, 100, use_kernel=True)
    b = ops.cminhash_signatures(v, pi, 100, use_kernel=False)
    c = ops.cminhash_signatures(v, pi, 100, block_b=4, block_d=64)
    w = ops.cminhash_signatures_packed(v, pi, 100, 8)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    assert np.array_equal(np.asarray(a), np.asarray(c))
    assert np.array_equal(np.asarray(w), np.asarray(pack_codes(a, 8)))


def test_band_mode_survives_snapshot(tmp_path):
    from repro.store import SketchStore, StoreConfig

    eng = SketchEngine(SketchConfig(d=512, k=64, seed=5))
    rng = np.random.default_rng(5)
    v = jnp.asarray((rng.random((8, 512)) < 0.1).astype(np.int8))
    cfg = StoreConfig(k=64, n_bands=16, rows_per_band=4, b=8, capacity=16)
    s = SketchStore(cfg)
    s.add_packed(np.asarray(eng.sign_packed(v, 8)))
    path = str(tmp_path / "store.npz")
    s.save(path)
    loaded = SketchStore.load(path)
    # the packed pin must survive the round-trip: raw-sig queries on a
    # packed-keyed table would silently miss every candidate
    with pytest.raises(ValueError):
        loaded.query(np.zeros((1, 64), np.int32))
    qi, _ = loaded.query_packed(np.asarray(eng.sign_packed(v[:3], 8)), 2)
    assert (qi[:, 0] >= 0).all()


def test_store_packed_ingest_interop():
    from repro.store import SketchStore, StoreConfig

    eng = SketchEngine(SketchConfig(d=512, k=64, seed=3))
    rng = np.random.default_rng(3)
    v = jnp.asarray((rng.random((24, 512)) < 0.08).astype(np.int8))
    sigs = np.asarray(eng.signatures_dense(v))

    # b=32: packed ingest interoperates exactly with the sig path
    cfg = StoreConfig(k=64, n_bands=16, rows_per_band=4, b=32, capacity=32)
    s_sig, s_pack = SketchStore(cfg), SketchStore(cfg)
    s_sig.add(sigs)
    s_pack.add_packed(np.asarray(eng.sign_packed(v, 32)))
    i1, sc1 = s_sig.query(sigs[:6], top_k=4)
    i2, sc2 = s_pack.query(sigs[:6], top_k=4)
    i3, sc3 = s_pack.query_packed(np.asarray(pack_codes(jnp.asarray(sigs[:6]),
                                                        32)), top_k=4)
    assert np.array_equal(i1, i2) and np.allclose(sc1, sc2)
    assert np.array_equal(i1, i3) and np.allclose(sc1, sc3)

    # b=8: fully-packed store (ingest + query) finds exact duplicates
    cfg8 = StoreConfig(k=64, n_bands=16, rows_per_band=4, b=8, capacity=32)
    s8 = SketchStore(cfg8)
    ids = s8.add_packed(np.asarray(eng.sign_packed(v, 8)))
    qi, qs = s8.query_packed(np.asarray(eng.sign_packed(v[:5], 8)), top_k=3)
    assert np.array_equal(qi[:, 0], ids[:5])
    assert np.allclose(qs[:, 0], 1.0)

    # word-misaligned bands must refuse loudly
    cfg_bad = StoreConfig(k=64, n_bands=32, rows_per_band=2, b=8, capacity=32)
    with pytest.raises(ValueError):
        SketchStore(cfg_bad).add_packed(
            np.asarray(eng.sign_packed(v[:2], 8)))
    # ...including when pad words make W % n_bands == 0 hold by accident
    cfg_sly = StoreConfig(k=10, n_bands=2, rows_per_band=5, b=4, capacity=8)
    with pytest.raises(ValueError):
        SketchStore(cfg_sly).add_packed(np.zeros((1, 2), np.uint32))

    # b < 32: sig-keys and packed keys differ — mixing modes must raise,
    # not silently miss candidates
    s_mix = SketchStore(cfg8)
    s_mix.add(sigs)
    with pytest.raises(ValueError):
        s_mix.add_packed(np.asarray(eng.sign_packed(v[:2], 8)))
    with pytest.raises(ValueError):
        s_mix.query_packed(np.asarray(eng.sign_packed(v[:2], 8)))
    s_mix.query(sigs[:2])              # same-mode queries still fine


def test_buffer_append_packed_matches_append():
    from repro.store.packed import PackedConfig, PackedSignatureBuffer

    rng = np.random.default_rng(4)
    sigs = rng.integers(0, 1 << 20, (10, 48), dtype=np.int32)
    for b in (8, 32):
        b1 = PackedSignatureBuffer(PackedConfig(k=48, b=b, capacity=8))
        b2 = PackedSignatureBuffer(PackedConfig(k=48, b=b, capacity=8))
        b1.append(sigs)
        b2.append_packed(np.asarray(pack_codes(jnp.asarray(sigs), b)))
        assert np.array_equal(b1.all_packed(), b2.all_packed())
    with pytest.raises(ValueError):
        b2.append_packed(np.zeros((2, 3), np.uint32))
