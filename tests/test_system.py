"""End-to-end behaviour tests for the paper's system: the full production path
(corpus -> C-MinHash dedup -> training -> checkpoint -> serving) in one go."""

import tempfile

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import TrainConfig
from repro.data.dedup import DedupConfig, dedup_corpus
from repro.data.loader import PrefetchIterator, deduped_token_batches
from repro.data.shingle import batch_shingles
from repro.data.synthetic import corpus_with_duplicates
from repro.models import build
from repro.serve.decode import generate
from repro.serve.search import SearchConfig, SimilaritySearchService
from repro.train.train_loop import TrainLoop


def test_end_to_end_dedup_train_serve():
    # 1. corpus with planted near-duplicates
    docs, labels = corpus_with_duplicates(
        80, vocab=2000, doc_len=128, dup_fraction=0.3, seed=0)

    # 2. dedup with the paper's two-permutation sketch
    res = dedup_corpus(docs, DedupConfig(d=1 << 12, k=128, n_bands=32,
                                         rows_per_band=4, threshold=0.5))
    assert len(res.keep) < len(docs)

    # 3. train a small LM on the deduped stream, with checkpointing
    cfg = reduced(get_config("llama3_2_1b"), d_model=64, vocab=2048)
    bundle = build(cfg)
    tc = TrainConfig(total_steps=8, warmup_steps=2, checkpoint_every=4,
                     learning_rate=1e-3)
    data = PrefetchIterator(deduped_token_batches(
        docs, res.keep, batch=4, seq=64, vocab=cfg.vocab_size_real))
    with tempfile.TemporaryDirectory() as wd:
        out = TrainLoop(bundle, tc, data, wd, log=lambda *_: None).run()
        assert len(out["losses"]) == 8
        assert np.isfinite(out["losses"]).all()
        params = out["params"]

    # 4. serve the trained model: batched generation
    prompts = {"tokens": np.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size_real, (4, 16)),
        np.int32)}
    toks = generate(bundle, params, prompts, max_new_tokens=8)
    assert toks.shape == (4, 8)
    assert (toks >= 0).all() and (toks < cfg.vocab_size).all()

    # 5. serve the signature index: the dedup signatures drive retrieval
    idx = batch_shingles(docs, n=3, d=1 << 12)
    svc = SimilaritySearchService(SearchConfig(d=1 << 12, k=128, n_bands=32,
                                               rows_per_band=4))
    svc.add_sparse(idx)
    ids, scores = svc.query_sparse(idx[:4], top_k=3)
    assert (ids[:, 0] == np.arange(4)).all()
    assert np.allclose(scores[:, 0], 1.0)
