"""Sharded serving plane: partition + broadcast + mergeable top-k.

The acceptance contract: ``ShardedSketchStore`` with S in {1, 2, 3, 8}
answers *exactly* like a single-shard ``SketchStore`` on the same items —
ids, scores, padding, and the empty-candidate brute-force-fallback rows —
for both partitioners and both ingest paths (raw signatures and fused
packed words).  Plus unit coverage of ``merge_topk``'s algebra.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.distributed.collectives import merge_topk
from repro.kernels import ops
from repro.store import ShardedSketchStore, SketchStore, StoreConfig

SHARD_COUNTS = [1, 2, 3, 8]
K, NB, R = 64, 16, 4


def _corpus(n=160, k=K, seed=0, dup_pairs=3):
    rng = np.random.default_rng(seed)
    sigs = rng.integers(0, 1 << 16, (n, k), dtype=np.int32)
    for t in range(dup_pairs):          # planted exact duplicates
        sigs[n - 1 - t] = sigs[t]
    return sigs


def _queries(sigs, n_strangers=2, seed=1):
    """Query batch mixing indexed rows with strangers that hit no bucket
    anywhere (forcing the global brute-force-fallback leg)."""
    rng = np.random.default_rng(seed)
    strangers = rng.integers(1 << 20, 1 << 24,
                             (n_strangers, sigs.shape[1]), dtype=np.int32)
    return np.concatenate([sigs[:12], strangers])


@pytest.mark.parametrize("s", SHARD_COUNTS)
@pytest.mark.parametrize("partition", ["round_robin", "hash"])
def test_sharded_query_matches_single_store(s, partition):
    sigs = _corpus()
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    single = SketchStore(cfg)
    single.add(sigs)
    sharded = ShardedSketchStore(cfg, s, partition=partition)
    gids = sharded.add(sigs)
    assert np.array_equal(gids, np.arange(len(sigs)))   # arrival-order ids
    q = _queries(sigs)
    want_ids, want_scores = single.query(q, top_k=5)
    got_ids, got_scores = sharded.query(q, top_k=5)
    assert np.array_equal(want_ids, got_ids)
    assert np.array_equal(want_scores, got_scores)


@pytest.mark.parametrize("s", SHARD_COUNTS)
def test_sharded_query_packed_matches_single_store(s):
    sigs = _corpus(seed=3)
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    words = np.asarray(ops.pack_codes(jnp.asarray(sigs), 32))
    qw = np.asarray(ops.pack_codes(jnp.asarray(_queries(sigs, seed=4)), 32))
    single = SketchStore(cfg)
    single.add_packed(words)
    sharded = ShardedSketchStore(cfg, s)
    sharded.add_packed(words)
    want_ids, want_scores = single.query_packed(qw, top_k=6)
    got_ids, got_scores = sharded.query_packed(qw, top_k=6)
    assert np.array_equal(want_ids, got_ids)
    assert np.array_equal(want_scores, got_scores)


@pytest.mark.parametrize("s", [2, 8])
def test_sharded_bbit_packed_store(s):
    """Fully-packed b=8 plane: sharded == single, and exact dups surface."""
    sigs = _corpus(seed=5)
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R, b=8)
    words = np.asarray(ops.pack_codes(jnp.asarray(sigs), 8))
    single = SketchStore(cfg)
    single.add_packed(words)
    sharded = ShardedSketchStore(cfg, s)
    sharded.add_packed(words)
    want = single.query_packed(words[:8], top_k=3)
    got = sharded.query_packed(words[:8], top_k=3)
    assert np.array_equal(want[0], got[0])
    assert np.array_equal(want[1], got[1])
    assert (got[0][:, 0] == np.arange(8)).all()       # self-hit on top


def test_sharded_incremental_adds_interleave():
    """Global ids stay arrival-ordered across many small batches, and the
    merged answers still match a single store fed identically."""
    sigs = _corpus(n=230, seed=6)
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R,
                      n_slots=64, bucket_width=2)   # force rebuilds too
    single = SketchStore(cfg)
    sharded = ShardedSketchStore(cfg, 3)
    for lo in range(0, len(sigs), 37):
        batch = sigs[lo: lo + 37]
        ids_a = single.add(batch)
        ids_b = sharded.add(batch)
        assert np.array_equal(ids_a, ids_b)
    q = _queries(sigs, seed=7)
    want = single.query(q, top_k=4)
    got = sharded.query(q, top_k=4)
    assert np.array_equal(want[0], got[0])
    assert np.array_equal(want[1], got[1])


def test_sharded_empty_shards_and_tiny_corpus():
    """S > N leaves shards empty; queries must still answer exactly."""
    sigs = _corpus(n=3, seed=8, dup_pairs=0)
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    single = SketchStore(cfg)
    single.add(sigs)
    sharded = ShardedSketchStore(cfg, 8)
    sharded.add(sigs)
    assert int(sharded.shard_sizes().sum()) == 3
    q = _queries(sigs[:2], n_strangers=1, seed=9)
    want = single.query(q, top_k=5)
    got = sharded.query(q, top_k=5)
    assert np.array_equal(want[0], got[0])
    assert np.array_equal(want[1], got[1])


def test_sharded_spill_stays_exact():
    """Spilled entries (width-1 buckets) must surface identically through
    the per-shard spill matching + merge."""
    rng = np.random.default_rng(15)
    sigs = rng.integers(0, 1 << 16, (10, K), dtype=np.int32)
    sigs[1] = sigs[0]                       # width-1 bucket -> spill
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R,
                      bucket_width=1, auto_rebuild=False)
    single = SketchStore(cfg)
    single.add(sigs)
    assert single.n_spilled > 0
    for s in (2, 3):
        sharded = ShardedSketchStore(cfg, s)
        sharded.add(sigs)
        want = single.query(sigs[[0, 3]], top_k=4)
        got = sharded.query(sigs[[0, 3]], top_k=4)
        assert np.array_equal(want[0], got[0]), s
        assert np.array_equal(want[1], got[1]), s


def test_sharded_guards():
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    with pytest.raises(ValueError):
        ShardedSketchStore(cfg, 0)
    with pytest.raises(ValueError):
        ShardedSketchStore(cfg, 2, partition="nope")
    sh = ShardedSketchStore(cfg, 2)
    sh.add(_corpus(n=8, dup_pairs=0))
    with pytest.raises(NotImplementedError):
        sh.candidate_pairs()               # cross-shard pairs unrepresentable
    cfg_np = StoreConfig(k=K, n_bands=NB, rows_per_band=R,
                         store_signatures=False)
    with pytest.raises(RuntimeError):
        ShardedSketchStore(cfg_np, 2).query(np.zeros((1, K), np.int32))
    # single-shard dedup path still works through the wrapper
    sh1 = ShardedSketchStore(cfg, 1)
    sh1.add(_corpus(n=20, seed=2))
    assert sh1.candidate_pairs().shape[1] == 2


def test_partial_write_poisons_plane():
    """If a later shard fails after an earlier shard indexed its slice (a
    remote-backend failure mode), the plane refuses further writes and
    reads instead of double-indexing rows on retry."""
    from repro.store import InProcessShard

    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)

    class FailingShard(InProcessShard):
        def add(self, sigs):
            raise ConnectionError("worker died mid-batch")

    sharded = ShardedSketchStore(
        cfg, backends=[InProcessShard(cfg), FailingShard(cfg)])
    sigs = _corpus(n=20, dup_pairs=0)
    with pytest.raises(ConnectionError):
        sharded.add(sigs)
    with pytest.raises(RuntimeError, match="inconsistent"):
        sharded.add(sigs)                  # a retry must not double-index
    with pytest.raises(RuntimeError, match="inconsistent"):
        sharded.query(sigs[:2], top_k=3)
    with pytest.raises(RuntimeError, match="inconsistent"):
        sharded.save("/tmp/never-written")
    # a clean failure before ANY shard wrote leaves the plane usable
    sharded2 = ShardedSketchStore(
        cfg, backends=[FailingShard(cfg), InProcessShard(cfg)])
    with pytest.raises(ConnectionError):
        sharded2.add(sigs)                 # fails at shard 0, pre-write
    ids, _ = sharded2.query(sigs[:2], top_k=3)     # not poisoned
    assert (ids == -1).all()               # empty plane, padded answers

    # a shard that PARTIALLY wrote before raising (e.dirty) poisons the
    # plane even when it is the first shard touched
    class DirtyShard(InProcessShard):
        def add(self, rows):
            self.store.add(rows[: len(rows) // 2])   # half landed
            err = ConnectionError("worker died mid-write")
            err.dirty = True
            raise err

    sharded3 = ShardedSketchStore(
        cfg, backends=[DirtyShard(cfg), InProcessShard(cfg)])
    with pytest.raises(ConnectionError):
        sharded3.add(sigs)
    with pytest.raises(RuntimeError, match="inconsistent"):
        sharded3.add(sigs)


# -- plane snapshots ---------------------------------------------------------

@pytest.mark.parametrize("partition", ["round_robin", "hash"])
def test_sharded_save_load_roundtrip(partition, tmp_path):
    """Directory snapshot (per-shard npz + manifest) restores the whole
    plane: answers, gid maps, partitioner — and ingest continues with
    arrival-order global ids as if the store never went down."""
    sigs = _corpus(n=140, seed=12)
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    sharded = ShardedSketchStore(cfg, 3, partition=partition)
    sharded.add(sigs)
    d = str(tmp_path / "plane")
    sharded.save(d)
    re = ShardedSketchStore.load(d)
    assert re.n_shards == 3
    assert re.partition == partition
    assert re.n_items == len(sigs)
    assert np.array_equal(re.shard_sizes(), sharded.shard_sizes())
    q = _queries(sigs, seed=13)
    want = sharded.query(q, top_k=5)
    got = re.query(q, top_k=5)
    assert np.array_equal(want[0], got[0])
    assert np.array_equal(want[1], got[1])
    # ingest continues: same gids and answers as the never-saved plane
    more = _corpus(n=25, seed=14, dup_pairs=0)
    assert np.array_equal(re.add(more), sharded.add(more))
    want = sharded.query(q, top_k=5)
    got = re.query(q, top_k=5)
    assert np.array_equal(want[0], got[0])
    assert np.array_equal(want[1], got[1])


def test_sharded_load_backend_count_guard(tmp_path):
    from repro.store import InProcessShard
    cfg = StoreConfig(k=K, n_bands=NB, rows_per_band=R)
    sharded = ShardedSketchStore(cfg, 2)
    sharded.add(_corpus(n=20, dup_pairs=0))
    d = str(tmp_path / "plane")
    sharded.save(d)
    with pytest.raises(ValueError):
        ShardedSketchStore.load(d, backends=[InProcessShard(cfg)])


# -- merge_topk algebra ------------------------------------------------------

def _part(scores, ids):
    return (np.asarray(scores, np.float32)[None, :],
            np.asarray(ids, np.int64)[None, :])


def test_merge_topk_order_and_ties():
    inf = np.float32(-np.inf)
    s1, i1 = _part([0.9, 0.5, inf], [4, 7, -1])
    s2, i2 = _part([0.9, 0.5], [2, 1])
    scores, ids = merge_topk([s1, s2], [i1, i2], 4)
    # ties break toward the smaller id, padding sinks to the tail
    assert ids.tolist() == [[2, 4, 1, 7]]
    assert np.allclose(scores, [[0.9, 0.9, 0.5, 0.5]])


def test_merge_topk_associative_commutative():
    rng = np.random.default_rng(11)
    parts = []
    next_id = 0
    for _ in range(4):                     # disjoint id sets, random scores
        k = rng.integers(1, 6)
        ids = np.arange(next_id, next_id + k, dtype=np.int64)
        rng.shuffle(ids)
        scores = rng.choice([0.25, 0.5, 0.75, 1.0], size=k).astype(np.float32)
        order = np.lexsort((ids, -scores))
        parts.append((scores[order][None, :], ids[order][None, :]))
        next_id += k
    flat = merge_topk([p[0] for p in parts], [p[1] for p in parts], 5)
    # pairwise tree, reversed order
    left = merge_topk([parts[3][0], parts[2][0]],
                      [parts[3][1], parts[2][1]], 5)
    right = merge_topk([parts[1][0], parts[0][0]],
                       [parts[1][1], parts[0][1]], 5)
    tree = merge_topk([left[0], right[0]], [left[1], right[1]], 5)
    assert np.array_equal(flat[1], tree[1])
    assert np.array_equal(flat[0], tree[0])


def test_merge_topk_widens_and_pads():
    s1, i1 = _part([0.5], [3])
    scores, ids = merge_topk([s1], [i1], 4)
    assert ids.tolist() == [[3, -1, -1, -1]]
    assert scores[0, 0] == np.float32(0.5)
    assert np.isneginf(scores[0, 1:]).all()
