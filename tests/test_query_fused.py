"""Fused device query path: uint32-lane fold parity vs the host uint64 fold,
probe-meta parity, device top-k scoring parity, and end-to-end store/sharded
bit-identity against the legacy host-fold reference oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lsh import band_hashes, band_hashes_packed
from repro.kernels import dispatch, ops, query_fused as qf
from repro.kernels.lsh_probe import probe_operands
from repro.store.store import SketchStore, StoreConfig
from repro.store.sharded import ShardedSketchStore


def _fold_words(words, n_bands, *, pallas, block_q=128):
    hi, lo = qf.words_to_planes(jnp.asarray(words), n_bands)
    if pallas:
        fh, fl = qf.fold_planes_pallas(hi, lo, block_q=block_q,
                                       interpret=True)
    else:
        fh, fl = qf.fold_planes_jnp(hi, lo)
    return qf.planes_to_hashes(np.asarray(fh), np.asarray(fl))


# -- fold parity (the uint32-lane emulation) ---------------------------------

@pytest.mark.parametrize("pallas", [False, True])
def test_fold_parity_words_geometry_sweep(pallas):
    """Property-style sweep: random packed words over many band geometries
    must fold bit-identically to the host uint64 polynomial fold."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        nb = int(rng.integers(1, 33))
        wpb = int(rng.integers(1, 9))          # words per band
        b = int(rng.integers(1, 9))
        words = rng.integers(0, 2**32, (b, nb * wpb), dtype=np.uint32)
        ref = band_hashes_packed(words, nb)
        got = _fold_words(words, nb, pallas=pallas,
                          block_q=int(rng.choice([1, 2, 4, 128])))
        assert (got == ref).all(), (nb, wpb, b)


@pytest.mark.parametrize("pallas", [False, True])
def test_fold_parity_signatures_negative_and_odd_rows(pallas):
    """Raw int32 signatures: negative codes sign-extend into the hi plane,
    and rows_per_band need not divide into words (the non-divisible
    corner packed banding rejects but the sig path serves)."""
    rng = np.random.default_rng(1)
    for nb, r in [(8, 3), (5, 7), (1, 13), (16, 1)]:
        sig = rng.integers(-2**31, 2**31, (6, nb * r), dtype=np.int32)
        ref = band_hashes(sig, nb, r)
        hi, lo = qf.sig_to_planes(jnp.asarray(sig), nb, r)
        if pallas:
            fh, fl = qf.fold_planes_pallas(hi, lo, block_q=4, interpret=True)
        else:
            fh, fl = qf.fold_planes_jnp(hi, lo)
        got = qf.planes_to_hashes(np.asarray(fh), np.asarray(fl))
        assert (got == ref).all(), (nb, r)


def test_fold_parity_edge_values():
    """All-zeros, all-ones, and single-bit rows hit the carry corners."""
    for words in (np.zeros((2, 8), np.uint32),
                  np.full((2, 8), 0xFFFFFFFF, np.uint32),
                  np.eye(8, dtype=np.uint32)):
        ref = band_hashes_packed(words, 4)
        assert (_fold_words(words, 4, pallas=False) == ref).all()
        assert (_fold_words(words, 4, pallas=True) == ref).all()


def test_words_to_planes_rejects_misaligned():
    with pytest.raises(ValueError):
        qf.words_to_planes(jnp.zeros((2, 7), jnp.uint32), 4)


# -- probe meta --------------------------------------------------------------

def test_meta_matches_host_probe_operands():
    rng = np.random.default_rng(2)
    words = rng.integers(0, 2**32, (9, 24), dtype=np.uint32)
    hi, lo = qf.words_to_planes(jnp.asarray(words), 8)
    fh, fl = qf.fold_planes_jnp(hi, lo)
    hashes = qf.planes_to_hashes(np.asarray(fh), np.asarray(fl))
    for n_slots in (64, 2048):
        ref = probe_operands(hashes, n_slots)
        got = np.asarray(qf.meta_from_planes(fh, fl, n_slots=n_slots))
        assert (got == ref).all(), n_slots


def test_meta_rejects_non_pow2_slots():
    hi = jnp.zeros((2, 4), jnp.uint32)
    with pytest.raises(ValueError):
        qf.meta_from_planes(hi, hi, n_slots=100)


# -- device top-k scoring ----------------------------------------------------

@pytest.mark.parametrize("b", [8, 32])
def test_score_topk_matches_planner_partial(b):
    """Random -1-padded candidate rows (dups, empties, all-pad rows) must
    score and rank bit-identically to the planner's host partial."""
    from repro.store.packed import PackedConfig, PackedSignatureBuffer
    from repro.store.planner import QueryPlanner

    rng = np.random.default_rng(3)
    k, n, q, top_k = 64, 120, 11, 5
    sigs = rng.integers(0, 40, (n, k), dtype=np.int32)
    buf = PackedSignatureBuffer(PackedConfig(k=k, b=b))
    buf.append(sigs)
    planner = QueryPlanner(buf)
    qsigs = rng.integers(0, 40, (q, k), dtype=np.int32)
    qwords = np.asarray(ops.pack_codes(jnp.asarray(qsigs), b))
    cand = rng.integers(-1, n, (q, 17), dtype=np.int64)
    cand[3] = -1                                   # no-candidate row
    cand[4, 1:] = cand[4, 0]                       # heavy duplicates
    ref = planner.partial_topk_packed(qwords, cand, top_k)
    ids, scores, has = qf.score_topk(
        jnp.asarray(cand.astype(np.int32)), buf.device_words(),
        jnp.asarray(qwords), k=k, b=b, top_k=top_k)
    assert (np.asarray(ids).astype(np.int64) == ref.ids).all()
    assert (np.asarray(scores) == ref.scores).all()
    assert (np.asarray(has) == ref.has_candidates).all()


# -- dispatch front door -----------------------------------------------------

def test_dispatch_rejects_host_and_unknown():
    rec = jnp.full((8, 4), -1, jnp.int32)
    w = jnp.zeros((1, 4), jnp.uint32)
    for bad in ("host", "nope"):
        with pytest.raises(ValueError):
            dispatch.query_fused(rec, w, w, n_bands=2, n_slots=4,
                                 max_probes=4, k=4, b=32, top_k=2, impl=bad)
    with pytest.raises(ValueError):
        dispatch.fold_hashes(w, n_bands=2, impl="host")


def test_fold_hashes_matches_host():
    rng = np.random.default_rng(4)
    words = rng.integers(0, 2**32, (5, 32), dtype=np.uint32)
    ref = band_hashes_packed(words, 8)
    assert (dispatch.fold_hashes(words, n_bands=8, impl="jnp") == ref).all()
    assert (dispatch.fold_hashes(words, n_bands=8,
                                 impl="pallas") == ref).all()


# -- end-to-end store parity -------------------------------------------------

def _parallel_stores(b, impls, *, n_slots=64, n=250, seed=5):
    rng = np.random.default_rng(seed)
    # auto_rebuild off so bucket overflow stays spilled and the fused
    # path's host spill leg is actually exercised
    cfg = StoreConfig(k=64, n_bands=16, rows_per_band=4, b=b,
                      n_slots=n_slots, bucket_width=2, capacity=64,
                      auto_rebuild=False)
    sigs = rng.integers(0, 50, (n, 64), dtype=np.int32)
    words = np.asarray(ops.pack_codes(jnp.asarray(sigs), b))
    stores = []
    for impl in impls:
        s = SketchStore(cfg, query_impl=impl)
        s.add_packed(words)
        stores.append(s)
    # stored rows (candidates), perturbed rows, novel rows (brute fallback)
    q = np.vstack([words[:16], words[16:28] ^ np.uint32(1),
                   rng.integers(0, 2**32, (6, words.shape[1]),
                                dtype=np.uint32)])
    return stores, q


@pytest.mark.parametrize("b", [8, 32])
def test_store_query_packed_fused_bit_identical(b):
    (host, j, p), q = _parallel_stores(b, ("host", "jnp", "pallas"))
    assert host.table.n_spilled > 0          # the spill host leg is exercised
    hi, hs = host.query_packed(q, top_k=5)
    for s in (j, p):
        fi, fs = s.query_packed(q, top_k=5)
        assert (hi == fi).all() and (hs == fs).all(), s.query_impl


def test_store_partial_hashed_fused_bit_identical():
    (host, fused), q = _parallel_stores(32, ("host", "jnp"))
    hashes = band_hashes_packed(q, 16)
    a = host.partial_topk_packed_hashed(hashes, q, 5)
    b_ = fused.partial_topk_packed_hashed(hashes, q, 5)
    assert (a.ids == b_.ids).all() and (a.scores == b_.scores).all()
    assert (a.has_candidates == b_.has_candidates).all()


def test_resolve_gates_fall_back_to_host():
    cfg = StoreConfig(k=64, n_bands=16, rows_per_band=4, n_slots=64,
                      bucket_width=4)
    s = SketchStore(cfg, query_impl="jnp")
    assert s._resolve_query_impl() == "host"       # empty buffer
    s.add_packed(np.zeros((3, 64), np.uint32))
    assert s._resolve_query_impl() == "jnp"
    s.query_impl = "host"
    assert s._resolve_query_impl() == "host"
    with pytest.raises(ValueError):
        SketchStore(cfg, query_impl="nope")


def test_sharded_fused_bit_identical():
    rng = np.random.default_rng(6)
    cfg = StoreConfig(k=64, n_bands=16, rows_per_band=4, n_slots=64,
                      bucket_width=4)
    words = rng.integers(0, 2**32, (240, 64), dtype=np.uint32)
    q = np.vstack([words[:12],
                   rng.integers(0, 2**32, (4, 64), dtype=np.uint32)])
    host = ShardedSketchStore(cfg, 2, query_impl="host")
    fused = ShardedSketchStore(cfg, 2, query_impl="jnp")
    host.add_packed(words)
    fused.add_packed(words)
    hi, hs = host.query_packed(q, top_k=4)
    fi, fs = fused.query_packed(q, top_k=4)
    assert (hi == fi).all() and (hs == fs).all()
    assert fused.last_timings["fold_s"] > 0.0
    for sh in fused.shards:
        assert sh.stats()["query_impl"] == "jnp"


def test_device_words_cache_tracks_mutations():
    from repro.store.packed import PackedConfig, PackedSignatureBuffer
    buf = PackedSignatureBuffer(PackedConfig(k=8, b=32))
    buf.append(np.arange(16, dtype=np.int32).reshape(2, 8))
    d1 = buf.device_words()
    assert buf.device_words() is d1              # no re-upload, no mutation
    buf.append(np.arange(8, dtype=np.int32).reshape(1, 8))
    d2 = buf.device_words()
    assert d2 is not d1 and d2.shape[0] == 3
    assert (np.asarray(d2) == buf.all_packed()).all()


def test_autotune_knows_query_kinds():
    from repro.kernels import autotune
    r = autotune.recommend("query_fold", 8, 16, 2, backend="cpu")
    assert set(r) == {"block_q"} and r["block_q"] <= 8
    r = autotune.recommend("probe_pallas", 256, 64, 8, backend="cpu")
    assert set(r) == {"block_e"}
