"""b-bit hashed-feature logistic regression (the paper's learning application)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import SketchConfig, SketchEngine
from repro.core.linear_model import (HashedLinearConfig, accuracy,
                                     fit_logistic, predict_logistic)


def _data(rng, n, d, templates, flip=0.02):
    t0, t1 = templates
    y = rng.integers(0, 2, n)
    x = np.where(y[:, None] == 0, t0, t1) ^ (rng.random((n, d)) < flip)
    return x.astype(np.int8), y.astype(np.int32)


def test_classifier_separates_jaccard_clusters():
    rng = np.random.default_rng(0)
    d, k = 1024, 128
    templates = (rng.random(d) < 0.05, rng.random(d) < 0.05)
    x_tr, y_tr = _data(rng, 256, d, templates)
    x_te, y_te = _data(rng, 128, d, templates)
    eng = SketchEngine(SketchConfig(d=d, k=k, seed=3))
    s_tr = eng.signatures_dense(jnp.asarray(x_tr))
    s_te = eng.signatures_dense(jnp.asarray(x_te))
    for b in (1, 4):
        wb = fit_logistic(s_tr, jnp.asarray(y_tr), HashedLinearConfig(b=b))
        acc = accuracy(wb, s_te, jnp.asarray(y_te), b)
        assert acc > 0.95, (b, acc)


def test_predict_probabilities_bounded():
    rng = np.random.default_rng(1)
    sigs = jnp.asarray(rng.integers(0, 100, (16, 32)), jnp.int32)
    y = jnp.asarray(rng.integers(0, 2, 16), jnp.int32)
    wb = fit_logistic(sigs, y, HashedLinearConfig(b=2, steps=50))
    p = predict_logistic(wb, sigs, 2)
    assert float(p.min()) >= 0.0 and float(p.max()) <= 1.0
