"""Validate the paper's theory module against direct simulation of the
algorithms — the repo-internal version of the paper's Section 4.1 sanity check."""

import numpy as np
import pytest

from repro.core import theory


def _split_pair(x):
    """Location vector -> a concrete (v, w) pair realizing it."""
    xs = np.where(x == theory.X)[0]
    v = (x == theory.O).copy()
    w = (x == theory.O).copy()
    v[xs[::2]] = True
    w[xs[1::2]] = True
    return v, w


def _empirical(x, K, n_rep, seed, use_sigma):
    """Vectorized Monte-Carlo of Algorithms 2/3 on a fixed location vector."""
    D = len(x)
    rng = np.random.default_rng(seed)
    v, w = _split_pair(x)
    ests = np.empty(n_rep)
    B = 20000
    for off in range(0, n_rep, B):
        n = min(B, n_rep - off)
        pis = np.argsort(rng.random((n, D)), axis=1)
        if use_sigma:
            # apply a random sigma to each replicate
            sig = np.argsort(rng.random((n, D)), axis=1)
            vp = np.zeros((n, D), bool)
            wp = np.zeros((n, D), bool)
            rows = np.arange(n)[:, None]
            vp[rows, sig[:, v]] = True
            wp[rows, sig[:, w]] = True
        else:
            vp = np.broadcast_to(v, (n, D))
            wp = np.broadcast_to(w, (n, D))
        coll = np.zeros(n)
        for k in range(1, K + 1):
            mv = np.roll(vp, -k, axis=1)
            mw = np.roll(wp, -k, axis=1)
            hv = np.where(mv, pis, 1 << 30).min(axis=1)
            hw = np.where(mw, pis, 1 << 30).min(axis=1)
            coll += hv == hw
        ests[off:off + n] = coll / K
    return ests


@pytest.mark.parametrize("D,f,a", [(16, 8, 4), (24, 12, 3), (32, 20, 10),
                                   (40, 10, 5)])
def test_etilde_exact_matches_mc(D, f, a):
    ex = theory.etilde_exact(D, f, a)
    mc = theory.etilde_mc(D, f, a, n_samples=300_000, seed=1)
    assert abs(ex - mc) < 5e-4, (ex, mc)


@pytest.mark.parametrize("D,f,a,K", [(32, 16, 8, 16), (24, 12, 6, 12)])
def test_var_sigma_pi_matches_simulation(D, f, a, K):
    x = theory.structured_location_vector(D, f, a)
    ests = _empirical(x, K, 150_000, seed=0, use_sigma=True)
    emp_mean, emp_var = ests.mean(), ests.var()
    assert abs(emp_mean - a / f) < 5e-3          # unbiasedness (Thm 3.1)
    th = theory.var_sigma_pi(D, f, a, K, method="exact")
    assert abs(emp_var - th) / th < 0.03, (emp_var, th)


@pytest.mark.parametrize("D,f,a,K", [(24, 12, 6, 12), (32, 16, 4, 24)])
def test_var_0pi_matches_simulation(D, f, a, K):
    x = theory.structured_location_vector(D, f, a)
    ests = _empirical(x, K, 150_000, seed=2, use_sigma=False)
    th = theory.var_0pi(x, K)
    assert abs(ests.mean() - a / f) < 5e-3       # unbiased regardless of sigma
    assert abs(ests.var() - th) / th < 0.03, (ests.var(), th)


def test_uniform_superiority_thm_3_4():
    """Var_{sigma,pi} < Var_MH on a grid (Theorem 3.4)."""
    K = 16
    for D in (20, 32, 44):
        for f in (6, 12, 18):
            for a in range(1, f):
                vs = theory.var_sigma_pi(D, f, a, K, method="exact")
                vm = theory.var_minhash(a / f, K)
                assert vs < vm, (D, f, a, vs, vm)


def test_symmetry_prop_3_2():
    """(D,f,a) and (D,f,f-a) give the same Var_{sigma,pi}."""
    K = 20
    for D, f in [(30, 14), (40, 21)]:
        for a in range(1, f // 2 + 1):
            v1 = theory.var_sigma_pi(D, f, a, K, method="exact")
            v2 = theory.var_sigma_pi(D, f, f - a, K, method="exact")
            assert abs(v1 - v2) < 1e-12, (D, f, a)


def test_consistent_improvement_prop_3_5():
    """The ratio Var_MH / Var_{sigma,pi} is constant in a (fixed D, f, K)."""
    D, f, K = 36, 15, 24
    ratios = [theory.variance_ratio(D, f, a, K, method="exact")
              for a in range(1, f)]
    assert max(ratios) - min(ratios) < 1e-9 * max(ratios), ratios
    assert all(r > 1 for r in ratios)


def test_etilde_monotone_in_D_lemma_3_3():
    """E~_D strictly increases in D and stays below J^2 (Lemma 3.3 + Thm 3.4)."""
    f, a = 10, 4
    j2 = (a / f) ** 2
    vals = [theory.etilde_exact(D, f, a) for D in range(f, 40)]
    diffs = np.diff(vals)
    assert (diffs > 0).all()
    assert all(v < j2 for v in vals)
    # converges toward J^2 from below
    assert j2 - vals[-1] < j2 - vals[0]


def test_corner_cases():
    assert theory.var_sigma_pi(20, 10, 0, 8) == 0.0   # J=0
    assert theory.var_sigma_pi(20, 10, 10, 8) == 0.0  # J=1
    # D == f special case: E~ = J * (a-1)/(f-1)
    assert abs(theory.etilde_exact(10, 10, 4) - 0.4 * 3 / 9) < 1e-12


def test_variance_formula_shape_matches_fig2():
    """Var is symmetric around J=0.5 and below MinHash (Figure 2 behaviour)."""
    D, f, K = 100, 50, 50
    js, ratios = [], []
    for a in (5, 15, 25, 35, 45):
        v = theory.var_sigma_pi(D, f, a, K, method="mc", n_samples=150_000)
        vm = theory.var_minhash(a / f, K)
        js.append(a / f)
        ratios.append(vm / v)
        assert v < vm
    # Prop 3.5: ratio approx constant in a even by MC
    assert max(ratios) / min(ratios) < 1.1, ratios
