"""The scan-aware HLO cost analyzer — pinned against XLA's own cost_analysis
on scan-free modules and against analytic counts with scans + collectives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo


def _cost(compiled) -> dict:
    ref = compiled.cost_analysis()
    return ref[0] if isinstance(ref, list) else ref   # older jax wraps it


def test_matches_xla_on_scan_free_module():
    def f(x, w):
        return jnp.tanh(x @ w)

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    got = hlo.analyze(c.as_text())
    ref = _cost(c)
    assert got.flops == pytest.approx(ref["flops"], rel=0.02)
    # the naive model reproduces XLA's every-op accounting
    assert got.bytes_naive == pytest.approx(ref["bytes accessed"], rel=0.1)
    assert got.collective_bytes == 0


def test_fused_bytes_ignore_elementwise_chains():
    """Elementwise work inside a scan body is free under the TPU-fusion proxy
    but piles up per trip under naive accounting. (A straight-line chain gets
    fused by XLA:CPU itself, so the scan keeps the ops distinct.)

    The premise — "naive accounting sees the body's work once per trip" —
    depends on how this XLA version lays the body out (direct ops, per-op
    kLoop fusions, or one fused call), so it is gated on *observed* HLO
    behavior, not a version check: if doubling the trip count does not grow
    naive bytes, this XLA emits the body in a form the naive model cannot
    see per-trip work in, and the naive-vs-fused contrast is untestable.
    """
    def body(y, _):
        y = jnp.tanh(y) * 1.01 + 0.1
        y = jnp.exp(y * 0.1) - 1.0
        return y, None

    def compiled(length):
        def f(x):
            y, _ = jax.lax.scan(body, x, None, length=length)
            return y
        x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
        return jax.jit(f).lower(x).compile()

    got = hlo.analyze(compiled(30).as_text())
    doubled = hlo.analyze(compiled(60).as_text())
    if doubled.bytes_naive < 1.5 * got.bytes_naive:
        pytest.skip("this XLA emits the scan body in a form whose per-trip "
                    "buffers are invisible to naive accounting")
    assert got.bytes < got.bytes_naive / 3, (got.bytes, got.bytes_naive)


def test_scan_trip_count_multiplies():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    for L in (4, 16):
        ws = jax.ShapeDtypeStruct((L, 256, 256), jnp.float32)
        c = jax.jit(f).lower(x, ws).compile()
        got = hlo.analyze(c.as_text())
        ref = _cost(c)
        assert got.flops == pytest.approx(L * ref["flops"], rel=0.05), L


def test_nested_scans_multiply():
    def inner_body(c, _):
        return jnp.tanh(c @ c), None

    def outer_body(x, _):
        y, _ = jax.lax.scan(inner_body, x, None, length=3)
        return y, None

    def f(x):
        y, _ = jax.lax.scan(outer_body, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(f).lower(x).compile()
    got = hlo.analyze(c.as_text())
    dot_flops = 2 * 64 * 64 * 64
    assert got.flops == pytest.approx(15 * dot_flops, rel=0.05)


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >1 device")
def test_collectives_exact():  # exercised in the subprocess sharding test
    pass


def test_collective_formula_in_sharded_scan(tmp_path):
    """Subprocess with 8 CPU devices: all-reduce wire bytes inside a scan must
    match the analytic ring formula exactly."""
    import subprocess
    import sys
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.analysis import hlo

kw = {"axis_types": (jax.sharding.AxisType.Auto,) * 2} \
    if hasattr(jax.sharding, "AxisType") else {}   # jax < 0.4.35
mesh = jax.make_mesh((2, 4), ("data", "model"), **kw)

def layer(x, w):
    w1, w2 = w
    return jnp.tanh(x @ w1) @ w2, None

def f(x, ws):
    y, _ = jax.lax.scan(layer, x, ws)
    return y

L, B, D, F = 6, 64, 128, 512
x = jax.ShapeDtypeStruct((B, D), jnp.float32)
ws = (jax.ShapeDtypeStruct((L, D, F), jnp.float32),
      jax.ShapeDtypeStruct((L, F, D), jnp.float32))
with mesh:
    c = jax.jit(f, in_shardings=(
        NamedSharding(mesh, P("data", None)),
        (NamedSharding(mesh, P(None, None, "model")),
         NamedSharding(mesh, P(None, "model", None))),
    )).lower(x, ws).compile()
got = hlo.analyze(c.as_text())
expected = L * 2 * (4 - 1) / 4 * (B // 2) * D * 4   # ring all-reduce / layer
assert abs(got.collective_bytes - expected) / expected < 1e-6, \
    (got.collective_bytes, expected)
exp_flops = L * 2 * (2 * (B // 2) * D * (F // 4))
assert abs(got.flops - exp_flops) / exp_flops < 0.05, (got.flops, exp_flops)
print("OK")
"""
    p = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd=".")
    assert "OK" in p.stdout, p.stdout + p.stderr
