"""Pipelined ingest == serial ingest, bit for bit, on every plane.

The ingest pipeline only changes WHEN work runs (batch N+1's signing
overlaps batch N's scatter), never what lands in the store: scatter order
equals submit order, so ids, buckets, spills — and therefore every query
answer — are identical to serial ingestion of the same batches, for any
depth, any shard count, and either transport.
"""

import numpy as np
import pytest

from repro.serve.search import (SearchConfig, SimilaritySearchService)

D, K, NB, R = 1 << 12, 64, 16, 4
BATCH = 16


def _docs(n=96, nnz=40, seed=0):
    rng = np.random.default_rng(seed)
    idx = np.sort(rng.integers(0, D, (n, nnz), np.int32), axis=1)
    idx[-5:] = idx[:5]                    # planted duplicates
    return idx


def _serial_reference(docs, top_k=5):
    """Single-shard inproc serial ingest: the one true answer."""
    svc = SimilaritySearchService(SearchConfig(
        d=D, k=K, n_bands=NB, rows_per_band=R))
    for lo in range(0, len(docs), BATCH):
        svc.add_sparse(docs[lo: lo + BATCH])
    return svc.query_sparse(docs[:20], top_k=top_k)


@pytest.mark.parametrize("transport", ["inproc", "tcp"])
@pytest.mark.parametrize("s", [1, 2, 4])
def test_pipelined_ingest_bit_identical(transport, s):
    docs = _docs(seed=s)
    want_ids, want_scores = _serial_reference(docs)
    with SimilaritySearchService(SearchConfig(
            d=D, k=K, n_bands=NB, rows_per_band=R, n_shards=s,
            transport=transport)) as svc:
        with svc.pipeline(depth=3) as pipe:
            for lo in range(0, len(docs), BATCH):
                pipe.submit(docs[lo: lo + BATCH])
        assert len(pipe) == 0             # context exit flushed everything
        assert pipe.timings["n_items"] == len(docs)
        got_ids, got_scores = svc.query_sparse(docs[:20], top_k=5)
        assert np.array_equal(want_ids, got_ids), (transport, s)
        assert np.array_equal(want_scores, got_scores), (transport, s)


def test_pipeline_depth_one_is_serial_and_deeper_is_identical():
    docs = _docs(seed=9)
    answers = []
    for depth in (1, 2, 5):
        svc = SimilaritySearchService(SearchConfig(
            d=D, k=K, n_bands=NB, rows_per_band=R, n_shards=2))
        pipe = svc.pipeline(depth=depth)
        for lo in range(0, len(docs), BATCH):
            pipe.submit(docs[lo: lo + BATCH])
            # depth bounds the signed-but-unscattered backlog at all times
            assert len(pipe) < max(depth, 2)
        pipe.flush()
        answers.append(svc.query_sparse(docs[:16], top_k=4))
    for ids, scores in answers[1:]:
        assert np.array_equal(answers[0][0], ids)
        assert np.array_equal(answers[0][1], scores)


def test_pipeline_rejects_bad_config():
    svc = SimilaritySearchService(SearchConfig(
        d=D, k=K, n_bands=NB, rows_per_band=R))
    with pytest.raises(ValueError, match="depth"):
        svc.pipeline(depth=0)
    with pytest.raises(ValueError, match="layout"):
        svc.pipeline(layout="csr")


def test_query_on_empty_index_raises_value_error():
    """Regression: this was a bare ``assert`` — gone under ``python -O``,
    leaving an empty-index query to fail somewhere deep in the store."""
    svc = SimilaritySearchService(SearchConfig(
        d=D, k=K, n_bands=NB, rows_per_band=R))
    with pytest.raises(ValueError, match="empty index"):
        svc.query_sparse(_docs(n=2))
    svc.add_sparse(_docs(n=8))            # after ingest, queries work
    ids, _ = svc.query_sparse(_docs(n=8)[:3], top_k=1)
    assert np.array_equal(ids[:, 0], np.arange(3))
