"""Device-resident LSH probe: jnp twin + Pallas kernel vs the numpy walk.

The parity contract: for any table geometry (including non-divisible slot
counts, odd bucket widths, short probe chains, heavy spill) and any query
batch (present keys, absent keys, sentinel-valued hashes), every probe
backend returns exactly the candidate rows of ``BandedLSHTable.lookup``'s
host loop — element-for-element, since all backends gather the same record
row for a hit.
"""

import numpy as np
import pytest

from repro.core.lsh import band_hashes
from repro.kernels import dispatch, lsh_probe
from repro.store import BandedLSHTable, SketchStore, StoreConfig
from repro.store.table import SENTINEL_KEY

# (n_slots, bucket_width, max_probes, n_bands): primes and non-powers on
# purpose — slot wraps, partial tiles, and truncation must all be exercised
GEOMETRIES = [
    (37, 3, 5, 5),
    (64, 2, 4, 4),
    (101, 7, 16, 8),
    (16, 1, 2, 3),       # tiny: heavy spill, most lookups miss
]


def _loaded_table(ns, w, mp, nb, n=260, seed=2):
    rng = np.random.default_rng(seed)
    sigs = rng.integers(0, 40, (n, nb * 4), dtype=np.int32)  # forced clashes
    hashes = band_hashes(sigs, nb, 4)
    hashes[5, 0] = SENTINEL_KEY          # sentinel-valued hash -> spill
    t = BandedLSHTable(nb, n_slots=ns, bucket_width=w, max_probes=mp)
    t.insert(hashes[: n // 2], np.arange(n // 2))
    t.insert(hashes[n // 2:], np.arange(n // 2, n))
    return t, hashes


@pytest.mark.parametrize("ns,w,mp,nb", GEOMETRIES)
@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_probe_parity_vs_numpy_lookup(ns, w, mp, nb, impl):
    t, hashes = _loaded_table(ns, w, mp, nb)
    qh = hashes[:70].copy()
    qh[3, 1] = SENTINEL_KEY              # sentinel query must match nothing
    rng = np.random.default_rng(9)
    qh[60:] = rng.integers(0, 1 << 60, (10, nb)).astype(np.uint64)  # absent
    want = t.lookup(qh)
    got = t.lookup(qh, impl=impl)
    assert got.shape == want.shape
    assert got.dtype == want.dtype
    assert np.array_equal(got, want)


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_probe_parity_after_rebuild(impl):
    t, hashes = _loaded_table(32, 2, 3, 4)
    assert t.n_spilled > 0
    t.rebuild(n_slots=257, bucket_width=8, max_probes=16)  # prime slots
    want = t.lookup(hashes[:40])
    got = t.lookup(hashes[:40], impl=impl)
    assert np.array_equal(got, want)


def test_probe_device_cache_invalidates_on_insert():
    """device_records must re-upload after mutation, not serve stale rows."""
    t, hashes = _loaded_table(101, 4, 8, 4, n=60)
    first = t.lookup(hashes[:10], impl="jnp")
    extra = band_hashes(
        np.random.default_rng(3).integers(0, 40, (30, 16), dtype=np.int32),
        4, 4)
    t.insert(extra, np.arange(60, 90))
    assert first.shape == (10, t.n_bands * t.bucket_width)
    want = t.lookup(np.concatenate([hashes[:10], extra[:5]]))
    got = t.lookup(np.concatenate([hashes[:10], extra[:5]]), impl="jnp")
    assert np.array_equal(got, want)       # stale upload would diverge here


@pytest.mark.parametrize("block_e", [1, 7, 64, 1024])
def test_probe_pallas_entry_tiling(block_e):
    """E % block_e != 0 must pad with invalid entries, never wrap."""
    t, hashes = _loaded_table(37, 3, 5, 5, n=90)
    meta = lsh_probe.probe_operands(hashes[:11], t.n_slots)
    import jax.numpy as jnp
    out = lsh_probe.lsh_probe_pallas(
        t.device_records(), jnp.asarray(meta), n_slots=t.n_slots,
        max_probes=t.max_probes, block_e=block_e)
    want = t.lookup(hashes[:11])
    got = np.asarray(out).reshape(11, -1)
    assert np.array_equal(got, want)


def test_probe_dispatch_guards():
    t, hashes = _loaded_table(37, 3, 5, 5, n=40)
    with pytest.raises(ValueError):
        t.lookup(hashes[:2], impl="nope")
    with pytest.raises(ValueError):
        dispatch.lsh_probe(t.device_records(), hashes[:2],
                           n_slots=t.n_slots, max_probes=t.max_probes,
                           impl="numpy")
    assert dispatch.select_probe_impl(backend="tpu") == "pallas"
    assert dispatch.select_probe_impl(backend="cpu") == "numpy"


@pytest.mark.parametrize("impl", ["jnp", "pallas"])
def test_store_query_identical_across_probe_backends(impl):
    """End-to-end: a store on a device probe answers exactly like numpy."""
    rng = np.random.default_rng(7)
    sigs = rng.integers(0, 1 << 16, (120, 64), dtype=np.int32)
    sigs[100] = sigs[3]
    cfg = StoreConfig(k=64, n_bands=16, rows_per_band=4)
    a = SketchStore(cfg)
    b = SketchStore(cfg, probe_impl=impl)
    a.add(sigs)
    b.add(sigs)
    q = np.concatenate([sigs[:8],
                        rng.integers(1 << 20, 1 << 24, (2, 64),
                                     dtype=np.int32)])
    ia, sa = a.query(q, top_k=5)
    ib, sb = b.query(q, top_k=5)
    assert np.array_equal(ia, ib)
    assert np.array_equal(sa, sb)
