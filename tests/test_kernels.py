"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.permutations import make_two_permutations
from repro.kernels import ops, ref
from repro.kernels.cminhash_kernel import cminhash_pallas
from repro.kernels.collision_kernel import collision_count_pallas


@pytest.mark.parametrize("B,D,K", [
    (1, 64, 1), (2, 64, 64), (4, 100, 37), (8, 256, 256), (3, 777, 300),
    (5, 1024, 1024), (2, 2048, 500),
])
@pytest.mark.parametrize("dens", [0.02, 0.3, 0.9])
def test_cminhash_kernel_matches_ref(B, D, K, dens):
    rng = np.random.default_rng(B * D + K)
    v = (rng.random((B, D)) < dens).astype(np.int8)
    _, pi = make_two_permutations(jax.random.PRNGKey(0), D)
    got = cminhash_pallas(jnp.asarray(v), pi, K, interpret=True)
    want = ref.cminhash_dense_ref(jnp.asarray(v), pi, K)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int32, jnp.bool_])
def test_cminhash_kernel_dtypes(dtype):
    rng = np.random.default_rng(1)
    v = (rng.random((4, 128)) < 0.3)
    _, pi = make_two_permutations(jax.random.PRNGKey(0), 128)
    got = cminhash_pallas(jnp.asarray(v).astype(dtype), pi, 32, interpret=True)
    want = ref.cminhash_dense_ref(jnp.asarray(v.astype(np.int8)), pi, 32)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_b,block_d", [(1, 128), (8, 256), (4, 512)])
def test_cminhash_kernel_block_sizes(block_b, block_d):
    rng = np.random.default_rng(2)
    v = (rng.random((6, 700)) < 0.1).astype(np.int8)
    _, pi = make_two_permutations(jax.random.PRNGKey(3), 700)
    got = cminhash_pallas(jnp.asarray(v), pi, 200, block_b=block_b,
                          block_d=block_d, interpret=True)
    want = ref.cminhash_dense_ref(jnp.asarray(v), pi, 200)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_cminhash_kernel_shift_offset_zero():
    rng = np.random.default_rng(4)
    v = (rng.random((2, 96)) < 0.25).astype(np.int8)
    _, pi = make_two_permutations(jax.random.PRNGKey(5), 96)
    got = cminhash_pallas(jnp.asarray(v), pi, 96, shift_offset=0,
                          interpret=True)
    want = ref.cminhash_dense_ref(jnp.asarray(v), pi, 96, shift_offset=0)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 6), st.integers(16, 400), st.data())
def test_cminhash_kernel_property(B, D, data):
    K = data.draw(st.integers(1, D))
    seed = data.draw(st.integers(0, 2**16))
    dens = data.draw(st.floats(0.0, 1.0))
    rng = np.random.default_rng(seed)
    v = (rng.random((B, D)) < dens).astype(np.int8)
    _, pi = make_two_permutations(jax.random.PRNGKey(seed), D)
    got = cminhash_pallas(jnp.asarray(v), pi, K, interpret=True)
    want = ref.cminhash_dense_ref(jnp.asarray(v), pi, K)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("Q,N,K", [(1, 1, 1), (64, 64, 128), (37, 53, 130),
                                   (128, 200, 64), (5, 300, 1024)])
def test_collision_kernel_matches_ref(Q, N, K):
    rng = np.random.default_rng(Q + N + K)
    sq = rng.integers(0, 37, (Q, K)).astype(np.int32)
    sn = rng.integers(0, 37, (N, K)).astype(np.int32)
    got = collision_count_pallas(jnp.asarray(sq), jnp.asarray(sn),
                                 interpret=True)
    want = ref.collision_count_ref(jnp.asarray(sq), jnp.asarray(sn))
    assert np.array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 40), st.integers(1, 40), st.integers(1, 200),
       st.integers(0, 2**16))
def test_collision_kernel_property(Q, N, K, seed):
    rng = np.random.default_rng(seed)
    sq = rng.integers(0, 11, (Q, K)).astype(np.int32)
    sn = rng.integers(0, 11, (N, K)).astype(np.int32)
    got = collision_count_pallas(jnp.asarray(sq), jnp.asarray(sn),
                                 interpret=True)
    want = ref.collision_count_ref(jnp.asarray(sq), jnp.asarray(sn))
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_ops_wrappers_roundtrip():
    rng = np.random.default_rng(9)
    B, D, K = 6, 512, 128
    v = (rng.random((B, D)) < 0.15).astype(np.int8)
    sigma, pi = make_two_permutations(jax.random.PRNGKey(7), D)
    s_k = ops.cminhash_signatures(jnp.asarray(v), pi, K, sigma,
                                  use_kernel=True)
    s_r = ops.cminhash_signatures(jnp.asarray(v), pi, K, sigma,
                                  use_kernel=False)
    assert np.array_equal(np.asarray(s_k), np.asarray(s_r))
    est = ops.estimated_jaccard_matrix(s_k, s_k)
    assert np.allclose(np.diag(np.asarray(est)), 1.0)


@pytest.mark.parametrize("B,D,K,dens,bd", [
    (2, 64, 64, 0.3, 64), (4, 256, 256, 0.1, 256), (3, 777, 300, 0.5, 64),
    (1, 300, 7, 0.05, 256), (2, 96, 96, 0.9, 64),
])
def test_packed_kernel_matches_ref(B, D, K, dens, bd):
    from repro.kernels.cminhash_packed import cminhash_packed_pallas
    rng = np.random.default_rng(B * D + K)
    v = (rng.random((B, D)) < dens).astype(np.int8)
    _, pi = make_two_permutations(jax.random.PRNGKey(0), D)
    got = cminhash_packed_pallas(jnp.asarray(v), pi, K, block_d=bd,
                                 interpret=True)
    want = ref.cminhash_dense_ref(jnp.asarray(v), pi, K)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_pack_bits_layout():
    from repro.kernels.cminhash_packed import pack_bits
    rng = np.random.default_rng(5)
    v = (rng.random((2, 70)) < 0.5).astype(np.int8)
    w = np.asarray(pack_bits(jnp.asarray(v)))
    for b in range(2):
        for pos in range(70):
            assert ((w[b, pos // 32] >> (pos % 32)) & 1) == v[b, pos]


@pytest.mark.parametrize("B,D", [(2, 70), (3, 64), (1, 257), (4, 32)])
def test_pack_bits_matches_shift_sum_formulation(B, D):
    # regression for the OR-fold rewrite: identical to the original
    # shift + jnp.sum reduction (which materialized a (B, nw, 32) intermediate)
    from repro.kernels.cminhash_packed import pack_bits
    rng = np.random.default_rng(B * D)
    v = (rng.random((B, D)) < 0.5).astype(np.int8)
    got = np.asarray(pack_bits(jnp.asarray(v)))
    nw = -(-D // 32)
    bits = np.pad((v > 0).astype(np.uint64),
                  ((0, 0), (0, nw * 32 - D))).reshape(B, nw, 32)
    want = np.sum(bits << np.arange(32, dtype=np.uint64),
                  axis=-1).astype(np.uint32)
    assert np.array_equal(got, want)


def _sparse_from_dense(v):
    nnz = max(1, int(v.sum(axis=1).max()))
    idx = np.full((v.shape[0], nnz), -1, np.int32)
    for i in range(v.shape[0]):
        z = np.where(v[i])[0]
        idx[i, : len(z)] = z
    return jnp.asarray(idx)


@pytest.mark.parametrize("B,D,K,dens", [
    (1, 64, 1, 0.1), (2, 64, 64, 0.3), (4, 100, 37, 0.05), (3, 777, 300, 0.02),
    (2, 300, 7, 0.05), (2, 96, 96, 0.9),
])
@pytest.mark.parametrize("off", [0, 1])
def test_sparse_pallas_kernel_matches_ref(B, D, K, dens, off):
    from repro.kernels.cminhash_sparse import cminhash_sparse_pallas
    rng = np.random.default_rng(B * D + K)
    v = (rng.random((B, D)) < dens).astype(np.int8)
    _, pi = make_two_permutations(jax.random.PRNGKey(0), D)
    got = cminhash_sparse_pallas(_sparse_from_dense(v), pi, K,
                                 shift_offset=off, block_b=2, block_j=8,
                                 interpret=True)
    want = ref.cminhash_dense_ref(jnp.asarray(v), pi, K, shift_offset=off)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(16, 300), st.data())
def test_sparse_windows_property(B, D, data):
    from repro.kernels.cminhash_sparse import cminhash_sparse_windows
    K = data.draw(st.integers(1, D))
    seed = data.draw(st.integers(0, 2**16))
    dens = data.draw(st.floats(0.0, 1.0))
    rng = np.random.default_rng(seed)
    v = (rng.random((B, D)) < dens).astype(np.int8)
    _, pi = make_two_permutations(jax.random.PRNGKey(seed), D)
    got = cminhash_sparse_windows(_sparse_from_dense(v), pi, K,
                                  block_j=data.draw(st.integers(1, 8)))
    want = ref.cminhash_dense_ref(jnp.asarray(v), pi, K)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 4), st.integers(32, 300), st.data())
def test_packed_kernel_property(B, D, data):
    from repro.kernels.cminhash_packed import cminhash_packed_pallas
    K = data.draw(st.integers(1, D))
    seed = data.draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    v = (rng.random((B, D)) < data.draw(st.floats(0.0, 1.0))).astype(np.int8)
    _, pi = make_two_permutations(jax.random.PRNGKey(seed), D)
    got = cminhash_packed_pallas(jnp.asarray(v), pi, K, block_d=64,
                                 interpret=True)
    want = ref.cminhash_dense_ref(jnp.asarray(v), pi, K)
    assert np.array_equal(np.asarray(got), np.asarray(want))
