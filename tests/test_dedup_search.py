"""Data pipeline (shingles, dedup) and the similarity-search service."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.lsh import UnionFind, band_hashes, candidate_pairs, \
    candidate_probability
from repro.core.bbit import bbit_collision_fraction, bbit_features, \
    lowest_b_bits
from repro.data.dedup import DedupConfig, dedup_corpus, dedup_metrics
from repro.data.shingle import batch_shingles, densify, shingle_indices
from repro.data.synthetic import corpus_with_duplicates
from repro.serve.search import SearchConfig, SimilaritySearchService

import jax.numpy as jnp


def test_shingles_deterministic_and_bounded():
    doc = np.arange(50, dtype=np.int32)
    a = shingle_indices(doc, n=3, d=1024)
    b = shingle_indices(doc, n=3, d=1024)
    assert np.array_equal(a, b)
    assert (a >= 0).all() and (a < 1024).all()
    assert len(np.unique(a)) == len(a)


def test_identical_docs_have_identical_shingles():
    doc = np.arange(30, dtype=np.int32)
    idx = batch_shingles([doc, doc.copy()], n=3, d=4096)
    assert np.array_equal(idx[0], idx[1])


def test_densify_matches_indices():
    idx = np.asarray([[3, 7, -1], [0, -1, -1]], np.int32)
    v = densify(idx, 10)
    assert v[0, 3] == 1 and v[0, 7] == 1 and v[0].sum() == 2
    assert v[1, 0] == 1 and v[1].sum() == 1


def test_dedup_end_to_end_precision_recall():
    docs, labels = corpus_with_duplicates(
        60, vocab=5000, doc_len=128, dup_fraction=0.4, seed=3)
    res = dedup_corpus(docs, DedupConfig(d=1 << 12, k=128, n_bands=32,
                                         rows_per_band=4, threshold=0.5))
    m = dedup_metrics(res, labels)
    assert m["precision"] > 0.95, m
    assert m["recall"] > 0.9, m
    assert m["kept"] < 60


def test_dedup_without_planted_dups_only_merges_truly_similar():
    """With no planted duplicates, any merge must be justified by genuinely
    high true Jaccard (Zipf-headed docs can legitimately overlap)."""
    docs, labels = corpus_with_duplicates(
        30, vocab=5000, doc_len=128, dup_fraction=0.0, seed=4)
    cfg = DedupConfig(d=1 << 12, k=128, n_bands=32, rows_per_band=4,
                      threshold=0.5)
    res = dedup_corpus(docs, cfg)
    assert len(res.keep) >= 27   # no mass false merging
    from collections import defaultdict
    clusters = defaultdict(list)
    for i, c in enumerate(res.cluster_of):
        clusters[c].append(i)
    for members in clusters.values():
        for i in members:
            for j in members:
                if i < j:
                    sa = set(shingle_indices(docs[i], n=3, d=cfg.d).tolist())
                    sb = set(shingle_indices(docs[j], n=3, d=cfg.d).tolist())
                    true_j = len(sa & sb) / len(sa | sb)
                    # estimator noise at K=128 is ~1/sqrt(K) ~ 0.09
                    assert true_j > cfg.threshold - 0.15, (i, j, true_j)


def test_lsh_s_curve_monotone():
    ps = [candidate_probability(j, 32, 4) for j in (0.1, 0.3, 0.5, 0.7, 0.9)]
    assert all(a < b for a, b in zip(ps, ps[1:]))
    assert ps[0] < 0.01 or ps[0] < ps[-1]


def test_band_hashes_group_equal_rows():
    sig = np.asarray([[1, 2, 3, 4], [1, 2, 9, 9], [1, 2, 3, 4]], np.int32)
    h = band_hashes(sig, n_bands=2, rows_per_band=2)
    assert h[0, 0] == h[1, 0] == h[2, 0]     # shared first band
    assert h[0, 1] == h[2, 1] != h[1, 1]
    pairs = candidate_pairs(h)
    assert (0, 1) in pairs and (0, 2) in pairs


def test_union_find_clusters():
    uf = UnionFind(5)
    uf.union(0, 1)
    uf.union(3, 4)
    clusters = uf.clusters()
    assert sorted(map(sorted, clusters.values())) == [[0, 1], [2], [3, 4]]


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 8))
def test_bbit_properties(b):
    rng = np.random.default_rng(b)
    sig = jnp.asarray(rng.integers(0, 1 << 20, (4, 32)), jnp.int32)
    low = lowest_b_bits(sig, b)
    assert int(jnp.max(low)) < (1 << b)
    feats = bbit_features(sig, b)
    assert feats.shape == (4, 32 * (1 << b))
    assert np.allclose(np.asarray(feats).sum(axis=1), 32)  # one-hot per hash
    # identical signatures collide at fraction 1
    assert float(bbit_collision_fraction(sig, sig, b)[0]) == 1.0


def test_search_service_self_retrieval_and_ranking():
    docs, _ = corpus_with_duplicates(40, vocab=3000, doc_len=96,
                                     dup_fraction=0.3, seed=5)
    idx = batch_shingles(docs, n=3, d=1 << 12)
    svc = SimilaritySearchService(SearchConfig(d=1 << 12, k=128, n_bands=32,
                                               rows_per_band=4))
    svc.add_sparse(idx)
    assert svc.size == 40
    ids, scores = svc.query_sparse(idx[:8], top_k=5)
    assert (ids[:, 0] == np.arange(8)).all()       # self is top hit
    assert (scores[:, 0] >= scores[:, 1]).all()    # ranked


def test_search_service_empty_bucket_fallback_is_per_query():
    """A query with no bucket hit anywhere brute-forces the index on its own;
    queries with candidates keep bucket-restricted results (the old code
    shared one aliased candidate set and only fell back when ALL queries
    missed)."""
    rng = np.random.default_rng(11)
    d = 1 << 12
    svc = SimilaritySearchService(SearchConfig(d=d, k=128, n_bands=32,
                                               rows_per_band=4))
    base = np.sort(rng.choice(d, 64, replace=False)).astype(np.int32)
    corpus = np.stack([base, base.copy()])      # two identical docs
    svc.add_sparse(corpus)
    # query 0: an indexed doc (bucket hits); query 1: disjoint support
    # (virtually surely no bucket hit)
    other = np.sort(rng.choice(
        np.setdiff1d(np.arange(d), base), 64, replace=False)).astype(np.int32)
    ids, scores = svc.query_sparse(np.stack([base, other]), top_k=2)
    assert ids[0, 0] in (0, 1) and scores[0, 0] == 1.0   # bucket path
    # fallback path returned this query's own brute-force ranking, not a
    # copy of query 0's candidates and not empty
    assert (ids[1] >= 0).all()
    assert scores[1, 0] < 0.5


def test_search_service_finds_near_duplicates():
    docs, labels = corpus_with_duplicates(40, vocab=3000, doc_len=96,
                                          dup_fraction=0.5, cluster_size=2,
                                          seed=6)
    idx = batch_shingles(docs, n=3, d=1 << 12)
    svc = SimilaritySearchService(SearchConfig(d=1 << 12, k=128, n_bands=32,
                                               rows_per_band=4))
    svc.add_sparse(idx)
    hits = 0
    total = 0
    for i in range(40):
        if labels[i] < 0:
            continue
        twins = [j for j in range(40) if labels[j] == labels[i] and j != i]
        ids, _ = svc.query_sparse(idx[i: i + 1], top_k=3)
        total += 1
        hits += any(t in ids[0] for t in twins)
    assert hits / total > 0.9, (hits, total)
