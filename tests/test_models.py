"""Per-architecture smoke tests (reduced configs) + decode/forward consistency.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation) — see tests/test_dryrun_small.py and launch/dryrun.py.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.configs.base import TrainConfig
from repro.models import build
from repro.train.optimizer import init_opt_state
from repro.train.train_loop import make_train_step

B, S = 2, 64


def _batch(cfg, rng, b=B, s=S):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size_real, (b, s)), jnp.int32)}
    if cfg.frontend == "patches":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, s // 8, cfg.d_model)), jnp.float32)
    if cfg.frontend == "frames":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, s, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    """One forward + one train step on CPU: shapes right, no NaNs."""
    cfg = reduced(get_config(arch))
    bundle = build(cfg)
    rng = np.random.default_rng(0)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)

    logits = bundle.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    tc = TrainConfig(total_steps=2, warmup_steps=1)
    step = make_train_step(bundle, tc)
    params2, opt2, metrics = jax.jit(step)(params, init_opt_state(params),
                                           batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, params2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_prefill_decode_shapes(arch):
    cfg = reduced(get_config(arch))
    bundle = build(cfg)
    rng = np.random.default_rng(1)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    logits, cache = bundle.prefill(params, batch, max_len=S + 4)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache = bundle.decode_step(params, cache, tok)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()
    assert int(cache["t"]) == S + 1


@pytest.mark.parametrize("arch,extra", [
    ("llama3_2_1b", {}),
    ("h2o_danube3_4b", {"sliding_window": 16}),      # ring buffer exercised
    ("falcon_mamba_7b", {}),
    ("hymba_1_5b", {"sliding_window": 16}),
    ("qwen3_moe_30b_a3b", {"capacity_factor": 64.0}),  # no token drops
    ("seamless_m4t_medium", {}),
])
def test_decode_matches_forward_fp32(arch, extra):
    """Teacher-forced decode must reproduce the training forward exactly
    (fp32, no capacity drops): validates caches, rings, SSM state carry."""
    cfg = dataclasses.replace(reduced(get_config(arch)), dtype="float32",
                              **extra)
    bundle = build(cfg)
    rng = np.random.default_rng(2)
    s, s0 = 40, 25
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, rng, b=2, s=s)
    full = np.asarray(bundle.forward(params, batch), np.float32)

    pbatch = dict(batch)
    pbatch["tokens"] = batch["tokens"][:, :s0]
    if "patches" in pbatch:
        pbatch["patches"] = pbatch["patches"][:, : s0 // 8]
        full = None  # patch prefix differs between lengths; skip strict check
    logits, cache = bundle.prefill(params, pbatch, max_len=s)
    if full is None:
        return
    errs = [np.abs(np.asarray(logits, np.float32) - full[:, s0 - 1]).max()]
    for t in range(s0, s):
        logits, cache = bundle.decode_step(params, cache,
                                           batch["tokens"][:, t])
        errs.append(np.abs(np.asarray(logits, np.float32) - full[:, t]).max())
    assert max(errs) < 1e-4, max(errs)


def test_moe_capacity_drops_are_bounded():
    """With tight capacity, outputs differ but stay finite (GShard drops)."""
    cfg = dataclasses.replace(reduced(get_config("qwen3_moe_30b_a3b")),
                              capacity_factor=1.0)
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    batch = _batch(cfg, rng)
    loss, metrics = bundle.loss_fn(params, batch)
    assert np.isfinite(float(loss))
    assert float(metrics["aux"]) > 0


def test_param_count_analytic_matches_actual():
    for arch in ARCH_IDS:
        cfg = reduced(get_config(arch))
        bundle = build(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        assert actual == cfg.param_count(), \
            f"{arch}: analytic {cfg.param_count()} vs actual {actual}"
