"""Minimal deterministic stand-in for ``hypothesis`` when it is not installed.

The container image does not ship hypothesis and nothing may be pip-installed,
so ``conftest.py`` registers this module as ``hypothesis`` /
``hypothesis.strategies`` if the real package is missing.  It implements just
the surface the test suite uses — ``@given``/``@settings``, ``st.integers``,
``st.floats`` and interactive ``st.data()`` — running each property
``max_examples`` times with a per-example seeded PRNG, so failures reproduce
exactly.  When the real hypothesis is present it is used untouched.
"""

from __future__ import annotations

import random
import types


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    seq = list(elements)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.getrandbits(1)))


def lists(elems: _Strategy, *, min_size: int = 0, max_size: int = 10,
          **_kw) -> _Strategy:
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elems._draw(rng) for _ in range(n)]
    return _Strategy(draw)


class _Data:
    """Interactive draw object backing ``st.data()``."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: _Strategy, label: str | None = None):
        return strategy._draw(self._rng)


def data() -> _Strategy:
    return _Strategy(lambda rng: _Data(rng))


def settings(**kwargs):
    def deco(fn):
        fn._stub_settings = kwargs
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        # no functools.wraps: pytest follows __wrapped__ when inspecting the
        # signature and would treat the strategy parameters as fixtures
        def wrapper(*args, **kwargs):
            # @settings may sit above @given (attr lands on wrapper) or below
            # it (attr lands on fn) — real hypothesis accepts both orders
            cfg = getattr(wrapper, "_stub_settings", None) or \
                getattr(fn, "_stub_settings", {})
            n = cfg.get("max_examples", 10)
            for example in range(n):
                rng = random.Random(0x5EED0000 + example)
                drawn = [s._draw(rng) for s in strategies]
                fn(*args, *drawn, **kwargs)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper
    return deco


def install() -> None:
    """Register this module as ``hypothesis`` if the real one is absent."""
    import sys
    try:
        import hypothesis  # noqa: F401  (real package wins)
        return
    except ImportError:
        pass
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "sampled_from", "booleans", "lists",
                 "data"):
        setattr(st_mod, name, globals()[name])
    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.strategies = st_mod
    hyp_mod.__stub__ = True
    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod
