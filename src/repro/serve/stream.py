"""Streaming query front end: individual queries in, device batches out.

``SimilaritySearchService`` answers pre-formed query batches; a serving
front end sees one query at a time.  ``StreamingQueryService`` bridges the
two with an admission queue: callers submit single queries and get a
``QueryTicket`` back immediately, a coalescer thread gathers compatible
queries into device-sized batches, and a batch flushes when it reaches
``max_batch`` OR its oldest query has waited ``max_delay_ms`` — whichever
comes first.  Batches then run through the same depth-parameterized overlap
``IngestPipeline`` uses for ingest: batch N+1's device sign/fold dispatches
(JAX async) while batch N's shard fan-out, scoring, and merge are in
flight, so the signing engine and the shard plane work concurrently
instead of strictly alternating.

Exactness: coalescing composes a batch out of independent per-row work —
sign, fold, probe, score, and merge are all row-independent, and a row's
brute-force-fallback decision depends only on its own candidates — so the
answer for a query is bit-identical whether it rides a coalesced batch,
any pipeline depth, or a batch of one.  Mixed per-query ``top_k`` stays
exact the same way: the batch asks the store for the max, and a prefix of
a longer ranking IS the shorter ranking (same scores, same deterministic
tie-breaks).

Batch compatibility is by (layout, row shape, dtype): a sparse plane with
fixed nnz coalesces everything into one key.  An incompatible arrival
flushes the queue in front of it (FIFO order is never reordered, so no
ticket can be starved by later arrivals).  ``pad_pow2`` pads a partial
flush up to the next power of two **by repeating the batch's first row** —
padding with real data keeps pad rows on the exact same code path (zeros
could have no candidates and drag the whole batch through the brute
fallback) while per-row independence keeps the real rows' answers
untouched; the padding's only job is to keep the set of distinct batch
shapes small so JAX recompiles O(log max_batch) times, not O(max_batch).

Overload hardening: ``max_queue`` bounds the admission queue — a full
queue sheds the NEWEST arrival (its ticket comes back already rejected
with :class:`~repro.transport.client.Overloaded` carrying a retry-after
hint), so queued work is never reordered and every *admitted* query stays
bit-identical to the serial reference.  ``query_timeout_s`` gives each
ticket an absolute deadline that propagates as the wire deadline of its
coalesced batch (the batch carries the MAX over its tickets' deadlines;
per-row independence means sharing a batch never changes an answer, only
when it lands).  Tickets whose deadline passes while still queued are
dropped at dispatch without signing.  Batch retries
(``StreamConfig.retries``) spend from the plane's shared ``RetryBudget``
— the same bucket hedges and replica failovers draw on — honor a
server's ``retry_after_s`` hint, and never fire past the batch deadline.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.transport.client import (DeadlineExceeded, Overloaded,
                                    TransportError, deadline_scope)

FLUSH_REASONS = ("full", "deadline", "shape", "close")


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    max_batch: int = 256        # flush when this many compatible queries
    max_delay_ms: float = 2.0   # ... or when the oldest waited this long
    depth: int = 2              # in-flight batches (1 = serial, 2 = overlap)
    pad_pow2: bool = True       # pad partial batches to pow2 (see module doc)
    top_k: int = 10             # default per-query top_k
    # transient-failure retries per batch query (reads are idempotent, so a
    # retry can only cost latency, never change an answer).  On a
    # replicated plane a round that dies to a killed replica typically
    # succeeds on retry — the replica set has failed over by then — so the
    # admitted queries survive the kill instead of erroring out
    retries: int = 0
    # admission bound (0 = unbounded): a full queue sheds the NEWEST
    # arrival with an already-rejected Overloaded ticket — admitted work
    # is never reordered or revoked
    max_queue: int = 0
    # default per-ticket deadline (0 = none), overridable per submit;
    # propagates as the batch's wire deadline so workers drop expired work
    query_timeout_s: float = 0.0

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1 (got {self.max_batch})")
        if self.max_delay_ms < 0:
            raise ValueError(
                f"max_delay_ms must be >= 0 (got {self.max_delay_ms})")
        if self.depth < 1:
            raise ValueError(f"depth must be >= 1 (got {self.depth})")
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0 (got {self.retries})")
        if self.max_queue < 0:
            raise ValueError(f"max_queue must be >= 0 (got {self.max_queue})")
        if self.query_timeout_s < 0:
            raise ValueError(f"query_timeout_s must be >= 0 "
                             f"(got {self.query_timeout_s})")


class QueryTicket:
    """One submitted query: resolves to ``(ids, scores)`` when its batch
    completes.  ``latency_s`` is admission-to-answer wall time."""

    def __init__(self, row: np.ndarray, layout: str, top_k: int,
                 deadline: float | None = None):
        self.row = row
        self.layout = layout
        self.top_k = top_k
        self.deadline = deadline   # absolute epoch seconds, None = no limit
        # admission-compatibility key: batches only coalesce rows the
        # signing kernel can stack into one array
        self.key = (layout, row.shape, row.dtype.str)
        self.t_submit = time.perf_counter()
        self.t_done: float | None = None
        self._ev = threading.Event()
        self._ids: np.ndarray | None = None
        self._scores: np.ndarray | None = None
        self._err: BaseException | None = None

    def _resolve(self, ids: np.ndarray, scores: np.ndarray) -> None:
        self._ids, self._scores = ids, scores
        self.t_done = time.perf_counter()
        self._ev.set()

    def _reject(self, err: BaseException) -> None:
        self._err = err
        self.t_done = time.perf_counter()
        self._ev.set()

    @property
    def done(self) -> bool:
        return self._ev.is_set()

    @property
    def latency_s(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.t_submit

    def result(self, timeout: float | None = None):
        """Block for this query's ``(ids, scores)`` (each ``(top_k,)``).

        Re-raises the batch's failure if its dispatch or drain died."""
        if not self._ev.wait(timeout):
            raise TimeoutError("query still in flight")
        if self._err is not None:
            raise self._err
        return self._ids, self._scores


class StreamingQueryService:
    """Admission queue + pipelined batch execution over one service.

    One coalescer thread owns the whole flow (admission order == dispatch
    order == drain order, so FIFO fairness and exactness need no further
    locking): it collects a compatible FIFO prefix of the queue, dispatches
    its signing asynchronously, and only materializes + fans out the oldest
    in-flight batch once ``depth`` batches are in flight — or as soon as
    the queue goes quiet, so an idle pipeline never sits on results.

    Close flushes: every admitted query is answered before ``close``
    returns (a query submitted after close is rejected immediately).
    """

    def __init__(self, service, cfg: StreamConfig | None = None):
        self.service = service
        self.cfg = cfg or StreamConfig()
        self._q: collections.deque[QueryTicket] = collections.deque()
        self._cond = threading.Condition()
        self._closed = False
        self._inflight: collections.deque = collections.deque()
        reg = obs_metrics.default()
        self._h_batch = reg.histogram("stream.batch")
        self._h_qwait = reg.histogram("stream.queue_wait")
        self._h_e2e = reg.histogram("stream.e2e")
        self._c_queries = reg.counter("stream.queries")
        self._c_retries = reg.counter("stream.retries")
        self._c_shed = reg.counter("stream.shed")
        self._c_expired = reg.counter("stream.expired")
        self._g_depth = reg.gauge("stream.queue_depth")
        self._c_flush = {r: reg.counter(f"stream.flush.{r}")
                         for r in FLUSH_REASONS}
        self.n_batches = 0
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="stream-query")
        self._thread.start()

    # -- submission ----------------------------------------------------------
    def submit_sparse(self, idx, top_k: int | None = None,
                      query_timeout_s: float | None = None) -> QueryTicket:
        """Admit one sparse query (1-D array of active indices)."""
        return self._submit(np.asarray(idx), "sparse", top_k, query_timeout_s)

    def submit_dense(self, v, top_k: int | None = None,
                     query_timeout_s: float | None = None) -> QueryTicket:
        """Admit one dense query (1-D vector of length d)."""
        return self._submit(np.asarray(v), "dense", top_k, query_timeout_s)

    def _retry_after_locked(self) -> float:
        """Server-side backoff hint for a shed ticket: roughly one drain of
        the current queue (observed e2e mean per batch x queued batches),
        floored at one coalescing window."""
        floor = self.cfg.max_delay_ms / 1e3
        if not self._h_e2e.count:
            return max(floor, 1e-3)
        batches = max(len(self._q) / self.cfg.max_batch, 1.0)
        return max(self._h_e2e.mean * batches, floor, 1e-3)

    def _submit(self, row: np.ndarray, layout: str, top_k: int | None,
                query_timeout_s: float | None = None) -> QueryTicket:
        if row.ndim != 1:
            raise ValueError(
                f"submit takes ONE query (1-D row, got shape {row.shape}); "
                "batches are what the admission queue builds")
        tmo = self.cfg.query_timeout_s if query_timeout_s is None \
            else float(query_timeout_s)
        t = QueryTicket(row, layout, int(top_k or self.cfg.top_k),
                        deadline=time.time() + tmo if tmo > 0 else None)
        with self._cond:
            if self._closed:
                raise RuntimeError("streaming service is closed")
            if self.cfg.max_queue and len(self._q) >= self.cfg.max_queue:
                # reject-newest: the ticket comes back already rejected —
                # same interface as an admitted one, so callers need one
                # code path — and the queue's FIFO admitted work stands
                self._c_shed.inc()
                t._reject(Overloaded(
                    f"streaming admission queue full "
                    f"({len(self._q)}/{self.cfg.max_queue}): query shed",
                    retry_after_s=self._retry_after_locked()))
                return t
            self._q.append(t)
            self._g_depth.set(len(self._q))
            self._cond.notify()
        return t

    # -- the coalescer thread ------------------------------------------------
    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._q and not self._closed and not self._inflight:
                    self._cond.wait()
                if not self._q and not self._inflight and self._closed:
                    return
                batch = reason = None
                deadline_pending = False
                if self._q:
                    batch, reason = self._collect_locked()
                    deadline_pending = batch is None
            if batch is not None:
                self._dispatch(batch, reason)
            with self._cond:
                has_work = bool(self._q)
            if self._inflight and (len(self._inflight) >= self.cfg.depth
                                   or not has_work or deadline_pending):
                self._drain_one()

    def _collect_locked(self):
        """With the lock held and a non-empty queue: block until the head
        batch is ready and pop it, or return ``(None, None)`` when the
        deadline is still running and there are in-flight batches whose
        drain can overlap the wait."""
        cfg = self.cfg
        deadline = self._q[0].t_submit + cfg.max_delay_ms / 1e3
        while True:
            key0 = self._q[0].key
            n = 1
            while n < len(self._q) and n < cfg.max_batch \
                    and self._q[n].key == key0:
                n += 1
            if n >= cfg.max_batch:
                reason = "full"
            elif n < len(self._q):
                reason = "shape"     # incompatible follower: flush the prefix
            elif self._closed:
                reason = "close"
            elif time.perf_counter() >= deadline:
                reason = "deadline"
            elif self._inflight:
                return None, None    # drain instead of idling out the wait
            else:
                self._cond.wait(
                    timeout=max(deadline - time.perf_counter(), 0.0))
                continue
            out = [self._q.popleft() for _ in range(n)]
            self._g_depth.set(len(self._q))
            return out, reason

    def _pad_to(self, n: int) -> int:
        if not self.cfg.pad_pow2:
            return n
        return min(1 << (n - 1).bit_length(), self.cfg.max_batch)

    def _dispatch(self, tickets: list[QueryTicket], reason: str) -> None:
        # a ticket whose deadline passed while queued is dead weight: its
        # caller is gone, so it is dropped before any signing work happens
        now = time.time()
        live = []
        for t in tickets:
            if t.deadline is not None and now >= t.deadline:
                self._c_expired.inc()
                t._reject(DeadlineExceeded(
                    "query deadline passed while queued: dropped before "
                    "dispatch"))
            else:
                live.append(t)
        self._c_flush[reason].inc()
        if not live:
            return
        tickets = live
        rows = np.stack([t.row for t in tickets])
        n_pad = self._pad_to(len(tickets)) - len(tickets)
        if n_pad:
            rows = np.concatenate(
                [rows, np.broadcast_to(rows[:1],
                                       (n_pad,) + rows.shape[1:])])
        try:
            signed = self.service._sign(rows, tickets[0].layout)  # async
        except Exception as e:
            for t in tickets:
                t._reject(e)
            return
        self._h_batch.observe(len(tickets))
        now = time.perf_counter()
        for t in tickets:
            self._h_qwait.observe(now - t.t_submit)
        self._inflight.append((signed, tickets))

    def _budget(self):
        """The plane's shared ``RetryBudget``, when the store has one (a
        remote plane routes every shard through one ``FanoutGroup`` whose
        budget is THE plane budget); an in-proc store has no transport and
        its retries stay free."""
        for sh in getattr(self.service.store, "shards", []) or []:
            b = getattr(getattr(sh, "group", None), "budget", None)
            if b is not None:
                return b
        return None

    @staticmethod
    def _batch_deadline(tickets: list[QueryTicket]) -> float | None:
        """Wire deadline for a coalesced batch: the MAX over its tickets'
        deadlines.  The batch must be allowed to finish for its most
        patient ticket; per-row independence means an earlier-deadline
        sibling still gets its exact answer when the batch lands.  Any
        ticket without a deadline makes the batch unbounded."""
        dls = [t.deadline for t in tickets]
        if any(d is None for d in dls):
            return None
        return max(dls)

    def _query_with_retry(self, svc, signed, top_k: int,
                          batch_deadline: float | None = None):
        """Run one batch query under the batch's wire deadline, retrying up
        to ``cfg.retries`` times on transient failures only.

        Transient means a ``TransportError`` (a shard round died — worker
        killed, stream cut — which a self-healing plane fixes between
        attempts) or an ``Overloaded`` rejection (provably clean, and its
        ``retry_after_s`` hint is honored before re-asking).  Every retry
        spends one token from the plane's shared ``RetryBudget`` and never
        fires past ``batch_deadline``.  ``DeadlineExceeded`` is terminal:
        the caller is gone, so re-asking is pure waste.  Any other
        exception is deterministic and re-raises immediately."""
        budget = self._budget()
        last: BaseException | None = None
        for attempt in range(self.cfg.retries + 1):
            scope = deadline_scope(batch_deadline) \
                if batch_deadline is not None else contextlib.nullcontext()
            try:
                with scope:
                    return svc._query(signed, top_k)
            except DeadlineExceeded:
                raise
            except Overloaded as e:
                last, wait = e, max(e.retry_after_s, 0.0)
            except TransportError as e:
                last, wait = e, 0.0
            if attempt >= self.cfg.retries:
                break
            if batch_deadline is not None \
                    and time.time() + wait >= batch_deadline:
                break                  # a retry could not land in time
            if budget is not None and not budget.try_spend():
                break                  # plane-wide retry budget exhausted
            if wait:
                time.sleep(wait)
            self._c_retries.inc()
        raise last

    def _drain_one(self) -> None:
        signed, tickets = self._inflight.popleft()
        svc = self.service
        try:
            if not (svc.packed_ingest and svc.cfg.query_impl != "host"):
                # legacy paths take the host batch; the fused path keeps
                # the signed words device-resident into the store's fold
                # (mirrors _traced_query)
                signed = np.asarray(signed)
            top_k = max(t.top_k for t in tickets)
            ids, scores = self._query_with_retry(
                svc, signed, top_k, self._batch_deadline(tickets))
            ids, scores = np.asarray(ids), np.asarray(scores)
        except Exception as e:
            # one batch's failure answers its own tickets and nothing else;
            # the coalescer keeps serving
            for t in tickets:
                t._reject(e)
            return
        for i, t in enumerate(tickets):
            t._resolve(ids[i, :t.top_k].copy(), scores[i, :t.top_k].copy())
            self._h_e2e.observe(t.t_done - t.t_submit)
        self._c_queries.inc(len(tickets))
        self.n_batches += 1

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        """Flush every admitted query and stop the coalescer (idempotent)."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self._thread.join()

    def __enter__(self) -> "StreamingQueryService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
