"""Batched autoregressive generation on top of the model bundles."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def sample_token(logits: Array, key: Array, temperature: float) -> Array:
    if temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1) \
        .astype(jnp.int32)


def generate(bundle, params, batch: dict, *, max_new_tokens: int,
             temperature: float = 0.0, seed: int = 0,
             mesh=None) -> np.ndarray:
    """Prefill the prompt batch and decode ``max_new_tokens`` greedily/sampled.

    Returns (B, max_new_tokens) int32. The decode loop runs as a single
    ``lax.scan`` (one compiled program, O(1) dispatch per sequence).
    """
    prompt_len = batch["tokens"].shape[1]
    logits, cache = bundle.prefill(params, batch, mesh=mesh,
                                   max_len=prompt_len + max_new_tokens)
    key = jax.random.PRNGKey(seed)
    first = sample_token(logits, key, temperature)

    def step(carry, k):
        tok, cache = carry
        logits, cache = bundle.decode_step(params, cache, tok, mesh)
        nxt = sample_token(logits, k, temperature)
        return (nxt, cache), tok

    keys = jax.random.split(key, max_new_tokens)
    (_, _), toks = jax.lax.scan(step, (first, cache), keys)
    return np.asarray(jnp.moveaxis(toks, 0, 1))
