"""Batched similarity-search service over C-MinHash signatures.

Index + query path is owned by the sharded SketchStore plane: signatures
live in b-bit packed device buffers partitioned across ``n_shards`` shards,
LSH bucketing is open-addressing array state per shard (no per-item Python
dicts), and a query batch is answered with one band-hash fold broadcast to
every shard, per-shard candidate gather + collision-kernel scoring, and a
mergeable top-k reduction (``distributed.collectives.merge_topk``).  At the
default ``n_shards=1`` the pipeline degenerates to the single-store path and
results are bit-identical to it; raising ``n_shards`` changes *where* items
live, never *what* a query answers.  At the default ``b=32`` the stored
codes are the exact signatures, so results match the unpacked reference path
bit-for-bit; ``b<32`` trades a small upward score bias (Li & Koenig, 2011)
for 32/b smaller index memory.  ``probe_impl`` picks the bucket-probe
backend ("auto": numpy host loop on CPU, device Pallas kernel on TPU).

``transport`` picks where the shards live: ``"inproc"`` (default) runs them
in this process; ``"tcp"`` spawns one shard worker process per shard on
localhost and talks the framed wire protocol (``repro.transport``) — same
answers bit-for-bit, but the index outgrows one process.  tcp services own
their workers: call ``close()`` (or use the service as a context manager)
to shut them down.

Ingest runs the fused sign->pack fast path end-to-end whenever the banding
is word-aligned (``rows_per_band % (32/b) == 0``; always true at the
default b = 32): signatures leave the kernel as b-bit packed words
(``SketchEngine.sign_packed``) and are indexed from the words directly
(``add_packed``/``query_packed``) — no (B, K) int32 batch ever forms on the
host, and at b = 32 answers are bit-identical to the raw-signature path.
``IngestPipeline`` adds double-buffering on top: batch N+1's signing is
dispatched (JAX async) while batch N scatters into the shards, so device
and host work overlap instead of strictly alternating.
"""

from __future__ import annotations

import collections
import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.core.engine import SketchConfig, SketchEngine
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.store import ShardedSketchStore, StoreConfig

TRANSPORTS = ("inproc", "tcp")


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    d: int = 1 << 16
    k: int = 256
    n_bands: int = 32
    rows_per_band: int = 8
    seed: int = 0
    b: int = 32                 # stored bits per hash (32 = exact scoring)
    n_slots: int = 2048         # initial LSH table slots per band (per shard)
    bucket_width: int = 8       # initial postings per bucket
    n_shards: int = 1           # index partitions (1 = single-store path)
    partition: str = "round_robin"   # or "hash" (see store/sharded.py)
    probe_impl: str = "auto"    # LSH probe backend: numpy | jnp | pallas
    query_impl: str = "auto"    # fused query backend: jnp | pallas | host
    transport: str = "inproc"   # shard backend: inproc | tcp (worker procs)
    query_timeout_s: float = 30.0    # fan-out deadline (tcp transport)
    hedge: bool = False         # hedged shard reads (tcp transport)
    hedge_delay_ms: float | None = None  # fixed hedge delay; None = derived
    # replication (tcp transport; see repro.replica): R workers per shard,
    # a write-ahead ingest journal, and a self-healing supervisor.  At the
    # default n_replicas=1 with no journal the classic unreplicated plane
    # is built — bit-identical to before these knobs existed.
    n_replicas: int = 1         # replica lanes per shard
    journal_dir: str | None = None   # write-ahead ingest journal directory
    supervisor: bool = True     # self-heal dead replicas (n_replicas > 1)


class SimilaritySearchService:
    def __init__(self, cfg: SearchConfig, mesh=None, *,
                 store=None, workers=None):
        """``store``/``workers`` inject a pre-built shard plane (benchmarks
        and tests spawn planes with injected-slow workers); by default the
        service builds its own per ``cfg.transport``."""
        if cfg.n_bands * cfg.rows_per_band != cfg.k:
            raise ValueError("n_bands * rows_per_band must equal k")
        if cfg.transport not in TRANSPORTS:
            raise ValueError(f"transport must be one of {TRANSPORTS} "
                             f"(got {cfg.transport!r})")
        self.cfg = cfg
        self.engine = SketchEngine(SketchConfig(d=cfg.d, k=cfg.k,
                                                seed=cfg.seed), mesh=mesh)
        store_cfg = StoreConfig(k=cfg.k, n_bands=cfg.n_bands,
                                rows_per_band=cfg.rows_per_band, b=cfg.b,
                                n_slots=cfg.n_slots,
                                bucket_width=cfg.bucket_width)
        self._workers: list = list(workers) if workers else []
        self._supervisor = None
        if store is not None:
            self.store = store
        elif cfg.transport == "tcp" and (cfg.n_replicas > 1
                                         or cfg.journal_dir is not None):
            self._build_replicated(store_cfg)
        elif cfg.transport == "tcp":
            from repro.transport import (HedgePolicy, connect_sharded,
                                         spawn_workers)
            self._workers = spawn_workers(store_cfg, cfg.n_shards,
                                          probe_impl=cfg.probe_impl,
                                          query_impl=cfg.query_impl)
            hedge = None
            if cfg.hedge:
                # hedge_delay_ms=0.0 is a valid fixed delay (hedge at
                # once), so the None check must be explicit
                hedge = HedgePolicy() if cfg.hedge_delay_ms is None \
                    else HedgePolicy(delay_s=cfg.hedge_delay_ms / 1e3)
            try:
                self.store = connect_sharded(
                    [h.address for h in self._workers], store_cfg,
                    partition=cfg.partition, query_impl=cfg.query_impl,
                    timeout=cfg.query_timeout_s, hedge=hedge)
            except BaseException:
                for h in self._workers:    # no orphan worker processes
                    h.terminate()
                raise
        else:
            self.store = ShardedSketchStore(
                store_cfg, n_shards=cfg.n_shards, partition=cfg.partition,
                probe_impl=cfg.probe_impl, query_impl=cfg.query_impl)
        self._tracer = obs_trace.default()
        reg = obs_metrics.default()
        self._h_query = reg.histogram("service.query")
        self._h_sign = reg.histogram("service.sign")

    def _build_replicated(self, store_cfg: StoreConfig) -> None:
        """The replicated tcp plane: an S x R worker grid, a write-ahead
        ingest journal, and (by default) the self-healing supervisor.
        Hedging is always armed here — the failure-triggered hedge IS the
        in-round read failover to a sibling replica — with
        ``hedge_delay_ms`` still honored as a fixed-delay override."""
        import os

        from repro.replica import (IngestJournal, Supervisor,
                                   connect_replicated, spawn_replicated)
        from repro.transport import HedgePolicy
        cfg = self.cfg
        journal = None
        if cfg.journal_dir is not None:
            journal = IngestJournal(
                os.path.join(cfg.journal_dir, "ingest.journal"))
        grid = spawn_replicated(store_cfg, cfg.n_shards,
                                max(cfg.n_replicas, 1),
                                probe_impl=cfg.probe_impl,
                                query_impl=cfg.query_impl)
        self._workers = [h for row in grid for h in row]
        hedge = True if cfg.hedge_delay_ms is None \
            else HedgePolicy(delay_s=cfg.hedge_delay_ms / 1e3)
        try:
            self.store = connect_replicated(
                grid, store_cfg, journal=journal,
                partition=cfg.partition, query_impl=cfg.query_impl,
                timeout=cfg.query_timeout_s, hedge=hedge)
        except BaseException:
            if journal is not None:
                journal.close()
            for h in self._workers:        # no orphan worker processes
                h.terminate()
            raise
        if cfg.supervisor and cfg.n_replicas > 1:
            self._supervisor = Supervisor(self.store,
                                          probe_impl=cfg.probe_impl,
                                          query_impl=cfg.query_impl)
            self._supervisor.start()

    # -- the fused fast path -----------------------------------------------
    @property
    def packed_ingest(self) -> bool:
        """Whether the fused sign->pack path serves this config (band
        boundaries fall on word boundaries; always true at b = 32)."""
        return self.cfg.rows_per_band % (32 // self.cfg.b) == 0

    def _sign(self, data, layout: str):
        """Dispatch signing for one batch (async — returns a device array,
        packed words on the fused path, raw signatures otherwise)."""
        pack_b = self.cfg.b if self.packed_ingest else None
        return self.engine.sign(jnp.asarray(data), layout=layout,
                                pack_b=pack_b)

    def _scatter(self, signed: np.ndarray) -> None:
        if self.packed_ingest:
            self.store.add_packed(signed)
        else:
            self.store.add(signed)

    # -- indexing ----------------------------------------------------------
    def add_sparse(self, idx: np.ndarray) -> None:
        self._scatter(np.asarray(self._sign(idx, "sparse")))

    def add_dense(self, v: np.ndarray) -> None:
        self._scatter(np.asarray(self._sign(v, "dense")))

    def pipeline(self, *, depth: int = 2,
                 layout: str = "sparse") -> "IngestPipeline":
        """A double-buffered ingest session over this service's store."""
        return IngestPipeline(self, depth=depth, layout=layout)

    def stream(self, **kw):
        """A streaming front end over this service: individual queries in,
        coalesced batches through the pipelined query path (see
        ``serve.stream.StreamingQueryService`` for the knobs)."""
        from repro.serve.stream import StreamConfig, StreamingQueryService
        return StreamingQueryService(self, StreamConfig(**kw))

    @property
    def size(self) -> int:
        return self.store.size

    # -- querying ----------------------------------------------------------
    def query_sparse(self, idx: np.ndarray, top_k: int = 10):
        return self._traced_query(idx, "sparse", top_k)

    def query_dense(self, v: np.ndarray, top_k: int = 10):
        return self._traced_query(v, "dense", top_k)

    def _traced_query(self, data, layout: str, top_k: int):
        """The traced front door: the root span opens here (where the
        sampling decision is made), the sign leg is its first child, and
        everything under ``_query`` — fold, broadcast, per-shard partials
        (worker-side over tcp), merge — nests beneath it, stitching one
        cross-process trace per sampled query batch."""
        t_wall = time.perf_counter()
        with self._tracer.span("query") as root:
            root.tag("n", len(data)).tag("top_k", top_k)
            t0 = time.perf_counter()
            with self._tracer.span("query.sign"):
                qsigned = self._sign(data, layout)
                if not (self.packed_ingest and self.cfg.query_impl != "host"):
                    # legacy paths want the host batch here; the fused path
                    # keeps it device-resident into the store's fold and
                    # syncs only for the shard broadcast
                    qsigned = np.asarray(qsigned)
            self._h_sign.observe(time.perf_counter() - t0)
            out = self._query(qsigned, top_k)
        self._h_query.observe(time.perf_counter() - t_wall)
        return out

    def _query(self, qsigned: np.ndarray, top_k: int):
        """Returns (ids (Q, top_k) int64 [-1 pad], scores (Q, top_k) f32).

        Queries with no bucket hit in any shard fall back to brute force
        over the whole index — independently per query (a query with
        candidates keeps its bucket-restricted ranking)."""
        if self.store.size <= 0:
            raise ValueError(
                "query on an empty index: add documents before querying "
                "(the brute-force fallback has nothing to score)")
        if self.packed_ingest:
            return self.store.query_packed(qsigned, top_k)
        return self.store.query(qsigned, top_k)

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        """Shut down shard workers (tcp transport); idempotent, inproc no-op.

        Graceful first (SHUTDOWN over the wire), then a hard terminate for
        any worker that did not exit in time.  The supervisor stops FIRST —
        otherwise it would diagnose the shutdown as a mass failure and
        respawn every worker the teardown just killed.
        """
        if self._supervisor is not None:
            self._supervisor.stop()
            self._supervisor = None
        if self._workers:
            from repro.transport import shutdown_plane
            shutdown_plane(self.store, self._workers)
            # replaced workers (supervisor respawns) may not be in the
            # original list; the store's lanes are authoritative
            for rset in getattr(self.store, "shards", []):
                for lane in getattr(rset, "lanes", []):
                    if lane.handle is not None:
                        lane.handle.terminate()
        journal = getattr(self.store, "journal", None)
        if journal is not None:
            journal.close()
        self._workers = []

    def __enter__(self) -> "SimilaritySearchService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class IngestPipeline:
    """Double-buffered ingest: sign batch N+1 while batch N scatters.

    ``submit(batch)`` dispatches JAX signing for the batch (asynchronous —
    no ``np.asarray`` sync) and enqueues the device array; once ``depth``
    batches are in flight, the oldest is drained: its words are
    materialized (waiting only for whatever device work is still
    outstanding) and scattered into the shards.  While that host-side
    scatter runs — LSH insert for in-process shards, the ADD fan-out for
    tcp shards — the younger batches' signing keeps executing in the
    background, so the signing engine never sits idle between batches.

    ``depth`` is the maximum number of signed-but-unscattered batches in
    flight: ``depth=1`` is the serial path (sign, wait, scatter —
    bit-identical answers, no overlap), ``depth=2`` is classic double
    buffering, higher depths only add device-memory pressure unless
    scatter time varies a lot between batches.  Scatter order always
    equals submit order, so for ANY depth the store state — ids, buckets,
    spills — is bit-identical to serial ingestion of the same batches.

    ``flush()`` (or leaving the context) drains everything still queued.

    The wall-time split lives in the process registry as per-batch latency
    HISTOGRAMS — ``ingest.sign`` (dispatch), ``ingest.wait`` (device sync —
    small when scatter covered the compute), ``ingest.scatter`` (store
    writes), ``ingest.wall`` — so tail behavior (one slow scatter among
    hundreds) is visible, not averaged away.  ``timings`` is a compatibility
    view over the same observations: the familiar ``{sign_s, wait_s,
    scatter_s, wall_s, n_batches, n_items}`` dict, scoped to THIS pipeline
    by registry deltas from its construction (counts are plain ints, so
    ``timings["n_items"]`` works even with the registry disabled).
    """

    _STAGES = ("sign", "wait", "scatter", "wall")

    def __init__(self, service: SimilaritySearchService, *, depth: int = 2,
                 layout: str = "sparse"):
        if depth < 1:
            raise ValueError(f"depth must be >= 1 (got {depth})")
        if layout not in ("sparse", "dense"):
            raise ValueError(f"unknown layout {layout!r}")
        self.service = service
        self.depth = depth
        self.layout = layout
        self._inflight: collections.deque = collections.deque()
        reg = obs_metrics.default()
        self._h = {s: reg.histogram(f"ingest.{s}") for s in self._STAGES}
        self._base = {s: self._h[s].sum for s in self._STAGES}
        self.n_batches = 0
        self.n_items = 0

    @property
    def timings(self) -> dict:
        """The classic accumulated split, derived from the registry
        histograms (sums since this pipeline was constructed)."""
        out = {f"{s}_s": self._h[s].sum - self._base[s]
               for s in self._STAGES}
        out["n_batches"] = self.n_batches
        out["n_items"] = self.n_items
        return out

    def __len__(self) -> int:
        return len(self._inflight)

    def submit(self, batch) -> None:
        """Sign one batch (async) and scatter whatever is due."""
        t0 = time.perf_counter()
        signed = self.service._sign(batch, self.layout)
        self._h["sign"].observe(time.perf_counter() - t0)
        self._inflight.append((signed, len(batch)))
        while len(self._inflight) >= self.depth:
            self._drain_one()
        self._h["wall"].observe(time.perf_counter() - t0)

    def _drain_one(self) -> None:
        signed, n = self._inflight.popleft()
        t0 = time.perf_counter()
        host = np.asarray(signed)          # sync: outstanding device work
        t1 = time.perf_counter()
        self.service._scatter(host)
        self._h["wait"].observe(t1 - t0)
        self._h["scatter"].observe(time.perf_counter() - t1)
        self.n_batches += 1
        self.n_items += n

    def flush(self) -> None:
        """Drain every in-flight batch (the pipeline stays usable)."""
        t0 = time.perf_counter()
        while self._inflight:
            self._drain_one()
        self._h["wall"].observe(time.perf_counter() - t0)

    def __enter__(self) -> "IngestPipeline":
        return self

    def __exit__(self, exc_type, *exc) -> None:
        if exc_type is None:               # don't mask the original error
            self.flush()
