"""Batched similarity-search service over C-MinHash signatures.

Index + query path is owned by the SketchStore subsystem: signatures live in
a b-bit packed device buffer, LSH bucketing is open-addressing array state
(no per-item Python dicts), and a query batch is answered with one vectorized
candidate gather + one collision-kernel call + batched top-k.  At the default
``b=32`` the stored codes are the exact signatures, so results match the
unpacked reference path bit-for-bit; ``b<32`` trades a small upward score
bias (Li & Koenig, 2011) for 32/b smaller index memory.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.engine import SketchConfig, SketchEngine
from repro.store import SketchStore, StoreConfig


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    d: int = 1 << 16
    k: int = 256
    n_bands: int = 32
    rows_per_band: int = 8
    seed: int = 0
    b: int = 32                 # stored bits per hash (32 = exact scoring)
    n_slots: int = 2048         # initial LSH table slots per band
    bucket_width: int = 8       # initial postings per bucket


class SimilaritySearchService:
    def __init__(self, cfg: SearchConfig, mesh=None):
        if cfg.n_bands * cfg.rows_per_band != cfg.k:
            raise ValueError("n_bands * rows_per_band must equal k")
        self.cfg = cfg
        self.engine = SketchEngine(SketchConfig(d=cfg.d, k=cfg.k,
                                                seed=cfg.seed), mesh=mesh)
        self.store = SketchStore(StoreConfig(
            k=cfg.k, n_bands=cfg.n_bands, rows_per_band=cfg.rows_per_band,
            b=cfg.b, n_slots=cfg.n_slots, bucket_width=cfg.bucket_width))

    # -- indexing ----------------------------------------------------------
    def add_sparse(self, idx: np.ndarray) -> None:
        sigs = np.asarray(self.engine.signatures_sparse(jnp.asarray(idx)))
        self.store.add(sigs)

    def add_dense(self, v: np.ndarray) -> None:
        sigs = np.asarray(self.engine.signatures_dense(jnp.asarray(v)))
        self.store.add(sigs)

    @property
    def size(self) -> int:
        return self.store.size

    # -- querying ----------------------------------------------------------
    def query_sparse(self, idx: np.ndarray, top_k: int = 10):
        sigs = np.asarray(self.engine.signatures_sparse(jnp.asarray(idx)))
        return self._query(sigs, top_k)

    def query_dense(self, v: np.ndarray, top_k: int = 10):
        sigs = np.asarray(self.engine.signatures_dense(jnp.asarray(v)))
        return self._query(sigs, top_k)

    def _query(self, qsigs: np.ndarray, top_k: int):
        """Returns (ids (Q, top_k) int64 [-1 pad], scores (Q, top_k) f32).

        Queries with no bucket hit anywhere fall back to brute force over the
        index — independently per query (a query with candidates keeps its
        bucket-restricted ranking)."""
        assert self.store.size > 0
        return self.store.query(qsigs, top_k)
