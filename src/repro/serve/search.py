"""Batched similarity-search service over C-MinHash signatures.

Index + query path is owned by the sharded SketchStore plane: signatures
live in b-bit packed device buffers partitioned across ``n_shards`` shards,
LSH bucketing is open-addressing array state per shard (no per-item Python
dicts), and a query batch is answered with one band-hash fold broadcast to
every shard, per-shard candidate gather + collision-kernel scoring, and a
mergeable top-k reduction (``distributed.collectives.merge_topk``).  At the
default ``n_shards=1`` the pipeline degenerates to the single-store path and
results are bit-identical to it; raising ``n_shards`` changes *where* items
live, never *what* a query answers.  At the default ``b=32`` the stored
codes are the exact signatures, so results match the unpacked reference path
bit-for-bit; ``b<32`` trades a small upward score bias (Li & Koenig, 2011)
for 32/b smaller index memory.  ``probe_impl`` picks the bucket-probe
backend ("auto": numpy host loop on CPU, device Pallas kernel on TPU).

``transport`` picks where the shards live: ``"inproc"`` (default) runs them
in this process; ``"tcp"`` spawns one shard worker process per shard on
localhost and talks the framed wire protocol (``repro.transport``) — same
answers bit-for-bit, but the index outgrows one process.  tcp services own
their workers: call ``close()`` (or use the service as a context manager)
to shut them down.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.engine import SketchConfig, SketchEngine
from repro.store import ShardedSketchStore, StoreConfig

TRANSPORTS = ("inproc", "tcp")


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    d: int = 1 << 16
    k: int = 256
    n_bands: int = 32
    rows_per_band: int = 8
    seed: int = 0
    b: int = 32                 # stored bits per hash (32 = exact scoring)
    n_slots: int = 2048         # initial LSH table slots per band (per shard)
    bucket_width: int = 8       # initial postings per bucket
    n_shards: int = 1           # index partitions (1 = single-store path)
    partition: str = "round_robin"   # or "hash" (see store/sharded.py)
    probe_impl: str = "auto"    # LSH probe backend: numpy | jnp | pallas
    transport: str = "inproc"   # shard backend: inproc | tcp (worker procs)


class SimilaritySearchService:
    def __init__(self, cfg: SearchConfig, mesh=None):
        if cfg.n_bands * cfg.rows_per_band != cfg.k:
            raise ValueError("n_bands * rows_per_band must equal k")
        if cfg.transport not in TRANSPORTS:
            raise ValueError(f"transport must be one of {TRANSPORTS} "
                             f"(got {cfg.transport!r})")
        self.cfg = cfg
        self.engine = SketchEngine(SketchConfig(d=cfg.d, k=cfg.k,
                                                seed=cfg.seed), mesh=mesh)
        store_cfg = StoreConfig(k=cfg.k, n_bands=cfg.n_bands,
                                rows_per_band=cfg.rows_per_band, b=cfg.b,
                                n_slots=cfg.n_slots,
                                bucket_width=cfg.bucket_width)
        self._workers: list = []
        if cfg.transport == "tcp":
            from repro.transport import connect_sharded, spawn_workers
            self._workers = spawn_workers(store_cfg, cfg.n_shards,
                                          probe_impl=cfg.probe_impl)
            try:
                self.store = connect_sharded(
                    [h.address for h in self._workers], store_cfg,
                    partition=cfg.partition)
            except BaseException:
                for h in self._workers:    # no orphan worker processes
                    h.terminate()
                raise
        else:
            self.store = ShardedSketchStore(
                store_cfg, n_shards=cfg.n_shards, partition=cfg.partition,
                probe_impl=cfg.probe_impl)

    # -- indexing ----------------------------------------------------------
    def add_sparse(self, idx: np.ndarray) -> None:
        sigs = np.asarray(self.engine.signatures_sparse(jnp.asarray(idx)))
        self.store.add(sigs)

    def add_dense(self, v: np.ndarray) -> None:
        sigs = np.asarray(self.engine.signatures_dense(jnp.asarray(v)))
        self.store.add(sigs)

    @property
    def size(self) -> int:
        return self.store.size

    # -- querying ----------------------------------------------------------
    def query_sparse(self, idx: np.ndarray, top_k: int = 10):
        sigs = np.asarray(self.engine.signatures_sparse(jnp.asarray(idx)))
        return self._query(sigs, top_k)

    def query_dense(self, v: np.ndarray, top_k: int = 10):
        sigs = np.asarray(self.engine.signatures_dense(jnp.asarray(v)))
        return self._query(sigs, top_k)

    def _query(self, qsigs: np.ndarray, top_k: int):
        """Returns (ids (Q, top_k) int64 [-1 pad], scores (Q, top_k) f32).

        Queries with no bucket hit in any shard fall back to brute force
        over the whole index — independently per query (a query with
        candidates keeps its bucket-restricted ranking)."""
        assert self.store.size > 0
        return self.store.query(qsigs, top_k)

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        """Shut down shard workers (tcp transport); idempotent, inproc no-op.

        Graceful first (SHUTDOWN over the wire), then a hard terminate for
        any worker that did not exit in time.
        """
        if self._workers:
            from repro.transport import shutdown_plane
            shutdown_plane(self.store, self._workers)
        self._workers = []

    def __enter__(self) -> "SimilaritySearchService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
