"""Batched similarity-search service over C-MinHash signatures.

Index: signatures (N, K) + banded LSH buckets. Queries are answered in batches:
bucket probing proposes candidates; the pairwise collision kernel scores the
query block against the candidate block; top-k by estimated Jaccard.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict

import jax.numpy as jnp
import numpy as np

from repro.core.engine import SketchConfig, SketchEngine
from repro.core.lsh import band_hashes
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    d: int = 1 << 16
    k: int = 256
    n_bands: int = 32
    rows_per_band: int = 8
    seed: int = 0


class SimilaritySearchService:
    def __init__(self, cfg: SearchConfig, mesh=None):
        if cfg.n_bands * cfg.rows_per_band != cfg.k:
            raise ValueError("n_bands * rows_per_band must equal k")
        self.cfg = cfg
        self.engine = SketchEngine(SketchConfig(d=cfg.d, k=cfg.k,
                                                seed=cfg.seed), mesh=mesh)
        self._sigs: np.ndarray | None = None
        self._buckets: list[dict[int, list[int]]] = [
            defaultdict(list) for _ in range(cfg.n_bands)]

    # -- indexing ----------------------------------------------------------
    def add_sparse(self, idx: np.ndarray) -> None:
        sigs = np.asarray(self.engine.signatures_sparse(jnp.asarray(idx)))
        self._append(sigs)

    def add_dense(self, v: np.ndarray) -> None:
        sigs = np.asarray(self.engine.signatures_dense(jnp.asarray(v)))
        self._append(sigs)

    def _append(self, sigs: np.ndarray) -> None:
        start = 0 if self._sigs is None else len(self._sigs)
        bands = np.asarray(band_hashes(sigs, self.cfg.n_bands,
                                       self.cfg.rows_per_band))
        for row in range(len(sigs)):
            for b in range(self.cfg.n_bands):
                self._buckets[b][int(bands[row, b])].append(start + row)
        self._sigs = sigs if self._sigs is None else \
            np.concatenate([self._sigs, sigs])

    @property
    def size(self) -> int:
        return 0 if self._sigs is None else len(self._sigs)

    # -- querying ----------------------------------------------------------
    def query_sparse(self, idx: np.ndarray, top_k: int = 10):
        sigs = np.asarray(self.engine.signatures_sparse(jnp.asarray(idx)))
        return self._query(sigs, top_k)

    def query_dense(self, v: np.ndarray, top_k: int = 10):
        sigs = np.asarray(self.engine.signatures_dense(jnp.asarray(v)))
        return self._query(sigs, top_k)

    def _query(self, qsigs: np.ndarray, top_k: int):
        """Returns (ids (Q, top_k) int64 [-1 pad], scores (Q, top_k) f32)."""
        assert self._sigs is not None and len(self._sigs) > 0
        qbands = np.asarray(band_hashes(qsigs, self.cfg.n_bands,
                                        self.cfg.rows_per_band))
        # union of candidates for the whole query batch -> one kernel call
        cand: set[int] = set()
        per_query: list[set[int]] = []
        for qi in range(len(qsigs)):
            mine: set[int] = set()
            for b in range(self.cfg.n_bands):
                mine.update(self._buckets[b].get(int(qbands[qi, b]), ()))
            per_query.append(mine)
            cand |= mine
        if not cand:  # no bucket hit anywhere: brute-force the index
            cand = set(range(self.size))
            per_query = [cand] * len(qsigs)
        cand_ids = np.asarray(sorted(cand), np.int64)
        est = np.asarray(ops.estimated_jaccard_matrix(
            jnp.asarray(qsigs), jnp.asarray(self._sigs[cand_ids])))

        ids = np.full((len(qsigs), top_k), -1, np.int64)
        scores = np.zeros((len(qsigs), top_k), np.float32)
        for qi, mine in enumerate(per_query):
            if not mine:
                continue
            mask = np.isin(cand_ids, np.asarray(sorted(mine), np.int64))
            local = np.where(mask)[0]
            order = local[np.argsort(-est[qi, local])][:top_k]
            ids[qi, : len(order)] = cand_ids[order]
            scores[qi, : len(order)] = est[qi, order]
        return ids, scores
