"""Fault-tolerant training loop: microbatch accumulation, preemption handling,
straggler monitoring, auto-restore, async checkpoints.

``build_train_step`` produces the jitted step used by both the real driver
(launch/train.py) and the multi-pod dry-run — the dry-run lowers exactly what
training runs.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import (batch_shardings, param_shardings,
                                        param_specs, zero1_specs)
from repro.train import checkpoint as ckpt
from repro.train.optimizer import OptState, adamw_update, init_opt_state

Array = jax.Array


def make_train_step(bundle, tc, mesh=None) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        loss, metrics = bundle.loss_fn(params, batch, mesh)
        return loss, metrics

    def train_step(params, opt_state: OptState, batch):
        if tc.microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(tc.microbatches, b // tc.microbatches,
                                 *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                gacc, lacc = carry
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                return (jax.tree.map(jnp.add, gacc, grads), lacc + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (grads, loss_sum), _ = jax.lax.scan(
                acc_fn, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / tc.microbatches, grads)
            loss = loss_sum / tc.microbatches
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        if tc.grad_compression == "bf16":
            # halve mantissa before the optimizer (the DP reduction inside the
            # backward pass is fused by XLA; this bounds end-to-end precision
            # identically and is measurable in the dry-run HLO byte counts)
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        params, opt_state, stats = adamw_update(params, grads, opt_state, tc)
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics.update(stats)
        return params, opt_state, metrics

    return train_step


def train_state_shardings(params_shape, tc, mesh):
    """(param_shardings, OptState shardings). ZeRO-1 shards the moments over
    ``data`` on top of the model layout; sharding_mode='fsdp' switches the
    whole layout to gathered-weights (moments colocate with params = ZeRO-3)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.sharding import fsdp_param_specs
    if getattr(tc, "sharding_mode", "tp") == "fsdp":
        specs = fsdp_param_specs(params_shape, mesh)
        p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
        mom = jax.tree.map(lambda x: x, p_shard)
    else:
        p_shard = param_shardings(params_shape, mesh)
        mom_specs = zero1_specs(params_shape, mesh) if tc.zero1 \
            else param_specs(params_shape, mesh)
        mom = jax.tree.map(lambda s: NamedSharding(mesh, s), mom_specs)
    o_shard = OptState(NamedSharding(mesh, P()), mom,
                       jax.tree.map(lambda x: x, mom))
    return p_shard, o_shard


def jit_train_step(bundle, tc, mesh, params_shape, batch_shape) -> Callable:
    """Jitted train step with explicit in/out shardings (the dry-run target)."""
    p_shard, o_shard = train_state_shardings(params_shape, tc, mesh)
    if getattr(tc, "sharding_mode", "tp") == "fsdp":
        # FSDP: the batch shards over EVERY mesh axis (weights are gathered
        # per use; leaving the model axis off the batch duplicates compute
        # 16x — measured in §Perf E)
        from jax.sharding import NamedSharding, PartitionSpec as P
        axes = tuple(mesh.axis_names)
        n_all = int(np.prod(list(mesh.shape.values())))

        def bspec(x):
            lead = axes if x.ndim and x.shape[0] % n_all == 0 else None
            return NamedSharding(mesh, P(lead, *([None] * (max(x.ndim, 1) - 1))))
        b_shard = jax.tree.map(bspec, batch_shape)
    else:
        b_shard = batch_shardings(batch_shape, mesh)
    step = make_train_step(bundle, tc, mesh)
    return jax.jit(step,
                   in_shardings=(p_shard, o_shard, b_shard),
                   out_shardings=(p_shard, o_shard, None),
                   donate_argnums=(0, 1))


@dataclasses.dataclass
class StragglerMonitor:
    """EMA step-time tracker; flags slow steps (on real fleets this feeds the
    scheduler to drain slow hosts; here it logs)."""

    alpha: float = 0.1
    threshold: float = 2.0
    ema: float | None = None
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        slow = self.ema is not None and dt > self.threshold * self.ema
        self.ema = dt if self.ema is None else \
            (1 - self.alpha) * self.ema + self.alpha * dt
        self.flagged += int(slow)
        return slow


class TrainLoop:
    """Restartable loop: restores the latest committed checkpoint, checkpoints
    periodically (async), and checkpoints immediately on SIGTERM/SIGINT."""

    def __init__(self, bundle, tc, data_iter: Iterator[dict], workdir: str,
                 mesh=None, log: Callable[[str], None] = print):
        self.bundle, self.tc, self.data = bundle, tc, data_iter
        self.workdir, self.mesh, self.log = workdir, mesh, log
        self.monitor = StragglerMonitor()
        self._stop = False

    def _install_signals(self):
        def handler(signum, frame):
            self._stop = True
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # not on main thread (tests)

    def run(self, start_params=None) -> dict:
        tc = self.tc
        params = start_params if start_params is not None else \
            self.bundle.init(jax.random.PRNGKey(tc.seed))
        opt_state = init_opt_state(params)
        state = {"params": params, "opt": opt_state}

        start = 0
        latest = ckpt.latest_step(self.workdir)
        if latest is not None:
            shardings = None
            if self.mesh is not None:
                shardings = {
                    "params": param_shardings(state["params"], self.mesh),
                    "opt": OptState(
                        None,
                        param_shardings(state["params"], self.mesh),
                        param_shardings(state["params"], self.mesh)),
                }
            start, state = ckpt.restore_checkpoint(
                self.workdir, state, shardings=shardings)
            self.log(f"[train] restored step {start} from {self.workdir}")

        step_fn = make_train_step(self.bundle, tc, self.mesh)
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        manager = ckpt.CheckpointManager(
            self.workdir, every=tc.checkpoint_every, keep=tc.keep_checkpoints)
        self._install_signals()

        params, opt_state = state["params"], state["opt"]
        history = []
        t_prev = time.perf_counter()
        for step in range(start, tc.total_steps):
            if self._stop:
                self.log(f"[train] preemption signal at step {step}; saving")
                manager.maybe_save(step, {"params": params, "opt": opt_state},
                                   force=True)
                manager.wait()
                break
            batch = next(self.data)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t_prev
            t_prev = time.perf_counter()
            if self.monitor.observe(dt):
                self.log(f"[train] straggler: step {step} took {dt:.2f}s "
                         f"(ema {self.monitor.ema:.2f}s)")
            history.append(loss)
            if (step + 1) % max(tc.total_steps // 10, 1) == 0:
                self.log(f"[train] step {step + 1}/{tc.total_steps} "
                         f"loss {loss:.4f} ({dt * 1e3:.0f} ms/step)")
            manager.maybe_save(step + 1, {"params": params, "opt": opt_state})
        else:
            manager.maybe_save(tc.total_steps,
                               {"params": params, "opt": opt_state}, force=True)
        manager.wait()
        return {"params": params, "opt": opt_state, "losses": history,
                "stragglers": self.monitor.flagged}
