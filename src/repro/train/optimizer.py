"""AdamW with warmup-cosine schedule and global-norm clipping — pure JAX.

The optimizer state is a pytree mirroring params (mu, nu in fp32), so ZeRO-1 is
purely a sharding decision (distributed/sharding.zero1_specs) — the math here is
layout-agnostic.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class OptState(NamedTuple):
    step: Array          # () int32
    mu: dict             # first moment,  fp32, mirrors params
    nu: dict             # second moment, fp32, mirrors params


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), zeros,
                    jax.tree.map(jnp.copy, zeros))


def lr_schedule(step: Array, tc) -> Array:
    """Linear warmup -> cosine decay to 10%."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
    progress = jnp.clip((step - tc.warmup_steps)
                        / jnp.maximum(tc.total_steps - tc.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * progress))
    return tc.learning_rate * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float) -> tuple[dict, Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(params, grads, state: OptState, tc) -> tuple[dict, OptState, dict]:
    """One AdamW step. Returns (new_params, new_state, stats)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if tc.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = lr_schedule(step, tc)
    b1, b2 = tc.beta1, tc.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + tc.eps) + tc.weight_decay \
            * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step, mu, nu), {"lr": lr, "grad_norm": gnorm}
