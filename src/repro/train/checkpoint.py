"""Sharded, atomic, async checkpointing with reshard-on-restore.

Layout:
    <dir>/step_000123/
        manifest.json        tree structure, shapes, dtypes, step
        t_<idx>.npy          one file per leaf (host-gathered)
        COMMIT               written last; restore ignores dirs without it

Restores place leaves onto whatever shardings the *current* mesh wants
(elastic restarts: save on one mesh, restore on another). Async saves run on a
single background thread; the next save joins the previous one.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np
from jax.tree_util import tree_flatten_with_path, tree_unflatten

from repro.distributed.sharding import _path_str


def _step_dir(base: str, step: int) -> str:
    return os.path.join(base, f"step_{step:08d}")


def save_checkpoint(base: str, step: int, tree) -> str:
    """Blocking save. Returns the committed directory."""
    os.makedirs(base, exist_ok=True)
    final = _step_dir(base, step)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves, treedef = tree_flatten_with_path(tree)
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"t_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr, allow_pickle=False)
        manifest["leaves"].append({
            "path": _path_str(path), "file": fname,
            "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "COMMIT"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def committed_steps(base: str) -> list[int]:
    if not os.path.isdir(base):
        return []
    steps = []
    for name in os.listdir(base):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(base, name, "COMMIT")):
            steps.append(int(name.split("_")[1]))
    return sorted(steps)


def latest_step(base: str) -> int | None:
    steps = committed_steps(base)
    return steps[-1] if steps else None


def restore_checkpoint(base: str, target_tree, *, step: int | None = None,
                       shardings=None):
    """Restore into the structure of ``target_tree``; ``shardings`` (same
    structure, NamedSharding leaves) re-shards onto the current mesh."""
    if step is None:
        step = latest_step(base)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {base}")
    d = _step_dir(base, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}

    leaves, treedef = tree_flatten_with_path(target_tree)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    if shardings is not None and len(shard_leaves) != len(leaves):
        raise ValueError("shardings tree does not match target tree")

    out = []
    for (path, ref), sh in zip(leaves, shard_leaves):
        entry = by_path[_path_str(path)]
        arr = np.load(os.path.join(d, entry["file"]), allow_pickle=False)
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch for {_path_str(path)}: "
                             f"{arr.shape} vs {ref.shape}")
        arr = arr.astype(ref.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.device_put(arr))
    return step, tree_unflatten(treedef, out)


def prune_checkpoints(base: str, keep: int) -> None:
    steps = committed_steps(base)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(_step_dir(base, s), ignore_errors=True)


class CheckpointManager:
    """Periodic async checkpointing with retention."""

    def __init__(self, base: str, *, every: int, keep: int = 3,
                 async_save: bool = True):
        self.base = base
        self.every = max(every, 1)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def maybe_save(self, step: int, tree, *, force: bool = False) -> bool:
        if not force and step % self.every != 0:
            return False
        self.wait()
        # Gather on the caller thread (device state is in flight otherwise).
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_checkpoint(self.base, step, host_tree)
            prune_checkpoints(self.base, self.keep)

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
        return True
