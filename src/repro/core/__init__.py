"""C-MinHash core: the paper's contribution as composable JAX modules."""

from .cminhash import cminhash_dense, cminhash_sparse, compute_signatures  # noqa: F401
from .engine import SketchConfig, SketchEngine  # noqa: F401
from .estimators import (  # noqa: F401
    jaccard_from_signatures,
    pairwise_jaccard_from_signatures,
    true_jaccard_dense,
)
from .minhash import make_k_permutations, minhash_dense, minhash_sparse  # noqa: F401
from .permutations import (  # noqa: F401
    circulant_shift,
    make_two_permutations,
    random_permutation,
)
