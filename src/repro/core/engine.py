"""SketchEngine — mesh-sharded batched C-MinHash signature computation.

The production entry point for the data pipeline: holds the paper's two
permutations, dispatches dense batches to the Pallas kernel (sharded over the
``data`` mesh axis; pi/sigma replicated — they are the whole point: two vectors,
trivially replicable even at D = 2^30) and sparse batches to the gather path.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..kernels import ops
from . import cminhash
from .permutations import make_two_permutations

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SketchConfig:
    d: int                      # universe size (shingle space)
    k: int = 1024               # signature length
    use_sigma: bool = True      # C-MinHash-(sigma,pi) vs -(0,pi)
    use_kernel: bool = True     # Pallas kernel vs jnp reference
    block_b: int = 8
    block_d: int = 256
    seed: int = 0


class SketchEngine:
    """Batched signer. ``mesh=None`` -> single device; else batch shards over 'data'
    (and 'pod' when present) with pi/sigma replicated."""

    def __init__(self, cfg: SketchConfig, mesh: jax.sharding.Mesh | None = None):
        self.cfg = cfg
        self.mesh = mesh
        key = jax.random.PRNGKey(cfg.seed)
        sigma, pi = make_two_permutations(key, cfg.d)
        self.pi = pi
        self.sigma = sigma if cfg.use_sigma else None

        if mesh is not None:
            batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            self._data_sharding = NamedSharding(mesh, P(batch_axes))
            self._rep_sharding = NamedSharding(mesh, P())
            self.pi = jax.device_put(self.pi, self._rep_sharding)
            if self.sigma is not None:
                self.sigma = jax.device_put(self.sigma, self._rep_sharding)
        else:
            self._data_sharding = None

    def signatures_dense(self, v: Array) -> Array:
        """(B, D) binary -> (B, K) int32 signatures."""
        if self._data_sharding is not None:
            v = jax.device_put(v, self._data_sharding)
        return ops.cminhash_signatures(
            v, self.pi, self.cfg.k, self.sigma,
            use_kernel=self.cfg.use_kernel,
            block_b=self.cfg.block_b, block_d=self.cfg.block_d)

    def signatures_sparse(self, idx: Array) -> Array:
        """(B, NNZ) padded index lists -> (B, K) int32 signatures."""
        if self._data_sharding is not None:
            idx = jax.device_put(idx, self._data_sharding)
        return cminhash.cminhash_sparse(idx, self.pi, self.cfg.k, self.sigma)

    @functools.cached_property
    def parameter_bytes(self) -> int:
        """Memory for the hashing parameters — the paper's headline win."""
        n = 2 if self.sigma is not None else 1
        return n * self.cfg.d * 4

    @staticmethod
    def classical_parameter_bytes(d: int, k: int) -> int:
        """What Algorithm 1 would need instead."""
        return k * d * 4
