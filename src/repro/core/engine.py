"""SketchEngine — mesh-sharded batched C-MinHash signature computation.

The production entry point for the data pipeline: holds the paper's two
permutations and routes every batch — dense or sparse — through the kernel
dispatch layer (``kernels.dispatch``: shape/backend implementation selection
plus autotuned block sizes), sharded over the ``data`` mesh axis with
pi/sigma replicated — they are the whole point: two vectors, trivially
replicable even at D = 2^30.

``sign_packed`` is the fused ingest path: signatures leave the kernel already
truncated to b bits and packed into uint32 words (``SketchStore.add_packed``
consumes them), so the (B, K) int32 form never reaches the host.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..kernels import dispatch
from ..obs import metrics as obs_metrics
from .permutations import make_two_permutations

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SketchConfig:
    d: int                      # universe size (shingle space)
    k: int = 1024               # signature length
    use_sigma: bool = True      # C-MinHash-(sigma,pi) vs -(0,pi)
    use_kernel: bool = True     # kernel dispatch vs jnp reference paths
    block_b: int | None = None  # None -> autotune cache / heuristic
    block_d: int | None = None  # (dense kernels)
    block_j: int | None = None  # (sparse kernels: nnz tile)
    autotune_measure: bool = False  # sweep-and-cache blocks on cache miss
    seed: int = 0


class SketchEngine:
    """Batched signer. ``mesh=None`` -> single device; else batch shards over 'data'
    (and 'pod' when present) with pi/sigma replicated."""

    def __init__(self, cfg: SketchConfig, mesh: jax.sharding.Mesh | None = None):
        self.cfg = cfg
        self.mesh = mesh
        key = jax.random.PRNGKey(cfg.seed)
        sigma, pi = make_two_permutations(key, cfg.d)
        self.pi = pi
        self.sigma = sigma if cfg.use_sigma else None

        if mesh is not None:
            batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
            self._data_sharding = NamedSharding(mesh, P(batch_axes))
            self._rep_sharding = NamedSharding(mesh, P())
            self.pi = jax.device_put(self.pi, self._rep_sharding)
            if self.sigma is not None:
                self.sigma = jax.device_put(self.sigma, self._rep_sharding)
        else:
            self._data_sharding = None
        # sign-call counters (dispatch counts per resolved kernel impl;
        # these count what the engine was ASKED, rows included, so
        # rows/impl ratios read straight off one snapshot)
        reg = obs_metrics.default()
        self._c_dense = reg.counter("engine.sign.dense")
        self._c_sparse = reg.counter("engine.sign.sparse")
        self._c_rows = reg.counter("engine.sign.rows")

    def signatures_dense(self, v: Array, *, pack_b: int | None = None) -> Array:
        """(B, D) binary -> (B, K) int32 signatures ((B, W) uint32 packed
        words when ``pack_b`` is set — the fused sign->pack kernel path)."""
        self._c_dense.inc()
        self._c_rows.inc(v.shape[0])
        if self._data_sharding is not None:
            v = jax.device_put(v, self._data_sharding)
        return dispatch.signatures_dense(
            v, self.pi, self.cfg.k, self.sigma,
            use_kernel=self.cfg.use_kernel, pack_b=pack_b,
            block_b=self.cfg.block_b, block_d=self.cfg.block_d,
            autotune_measure=self.cfg.autotune_measure)

    def signatures_sparse(self, idx: Array, *,
                          pack_b: int | None = None) -> Array:
        """(B, NNZ) padded index lists -> (B, K) int32 signatures ((B, W)
        uint32 packed words when ``pack_b`` is set)."""
        self._c_sparse.inc()
        self._c_rows.inc(idx.shape[0])
        if self._data_sharding is not None:
            idx = jax.device_put(idx, self._data_sharding)
        return dispatch.signatures_sparse(
            idx, self.pi, self.cfg.k, self.sigma,
            use_kernel=self.cfg.use_kernel, pack_b=pack_b,
            block_b=self.cfg.block_b, block_j=self.cfg.block_j,
            autotune_measure=self.cfg.autotune_measure)

    def sign_packed(self, data: Array, b: int, *,
                    layout: str = "dense") -> Array:
        """Fused sign->pack ingest: data -> (B, ceil(K/(32/b))) uint32 words.

        Bit-identical to ``pack_codes(signatures_*(data), b)`` but the dense
        kernels pack in their epilogue and the sparse window-min kernels
        pack inside the same compiled scan — no (B, K) int32 on the host.
        Feed the result to ``SketchStore.add_packed``.
        """
        if layout == "dense":
            return self.signatures_dense(data, pack_b=b)
        if layout == "sparse":
            return self.signatures_sparse(data, pack_b=b)
        raise ValueError(f"unknown layout {layout!r}")

    def sign(self, data: Array, *, layout: str = "sparse",
             pack_b: int | None = None) -> Array:
        """One signing front door: layout x (packed | raw) in one call.

        Returns a **device array without syncing** — JAX dispatch is
        asynchronous on every backend, so the computation runs in the
        background until someone materializes the result
        (``np.asarray``/``block_until_ready``).  That gap is what
        ``serve.search.IngestPipeline`` overlaps: batch N+1's signing
        executes while batch N's host-side scatter is still running.  Keep
        batch shapes uniform — each distinct shape compiles its own
        executable.
        """
        if pack_b is not None:
            return self.sign_packed(data, pack_b, layout=layout)
        if layout == "dense":
            return self.signatures_dense(data)
        if layout == "sparse":
            return self.signatures_sparse(data)
        raise ValueError(f"unknown layout {layout!r}")

    @functools.cached_property
    def parameter_bytes(self) -> int:
        """Memory for the hashing parameters — the paper's headline win."""
        n = 2 if self.sigma is not None else 1
        return n * self.cfg.d * 4

    @staticmethod
    def classical_parameter_bytes(d: int, k: int) -> int:
        """What Algorithm 1 would need instead."""
        return k * d * 4
