"""Random permutations and circulant shifts — the paper's two-permutation substrate.

Conventions (shared by every path in the repo, see DESIGN.md §8):
  * a permutation is an int32 array ``p`` of length D with ``p[i]`` the value at
    position ``i`` (0-based values ``0..D-1``);
  * the circulant right-shift by ``k`` is ``p_{->k}[i] = p[(i - k) mod D]``
    (Algorithm 2:  p=[3,1,2,4] -> p_{->1}=[4,3,1,2]);
  * applying a permutation ``sigma`` to a data vector moves position ``i`` to
    position ``sigma[i]``:  ``v'[sigma[i]] = v[i]``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def random_permutation(key: Array, d: int) -> Array:
    """A uniformly random permutation of [0, d) as int32."""
    return jax.random.permutation(key, d).astype(jnp.int32)


def make_two_permutations(key: Array, d: int) -> tuple[Array, Array]:
    """The paper's full parameter set: (sigma, pi). That's it — two vectors."""
    k_sigma, k_pi = jax.random.split(key)
    return random_permutation(k_sigma, d), random_permutation(k_pi, d)


def circulant_shift(p: Array, k) -> Array:
    """p_{->k}[i] = p[(i - k) mod d] == jnp.roll(p, k)."""
    return jnp.roll(p, k)


def apply_permutation_dense(v: Array, sigma: Array) -> Array:
    """v'[sigma[i]] = v[i] along the last axis of a dense vector/batch."""
    d = v.shape[-1]
    out_shape = v.shape
    flat = v.reshape(-1, d)
    out = jnp.zeros_like(flat).at[:, sigma].set(flat)
    return out.reshape(out_shape)


def apply_permutation_sparse(idx: Array, sigma: Array) -> Array:
    """New non-zero positions for sparse index lists (padding entries < 0 pass through)."""
    valid = idx >= 0
    mapped = jnp.where(valid, sigma[jnp.clip(idx, 0, sigma.shape[0] - 1)], idx)
    return mapped


def invert_permutation(p: Array) -> Array:
    """q with q[p[i]] = i."""
    d = p.shape[0]
    return jnp.zeros((d,), jnp.int32).at[p].set(jnp.arange(d, dtype=jnp.int32))
