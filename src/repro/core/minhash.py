"""Classical K-permutation MinHash (Algorithm 1) — the paper's baseline.

Deliberately kept as the paper describes it: K *independent* permutations, each of
length D.  The storage cost (K*D int32) is the pain the paper removes; we implement
it faithfully so the benchmarks can show the contrast.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .permutations import random_permutation

Array = jax.Array
SENTINEL = jnp.iinfo(jnp.int32).max


def make_k_permutations(key: Array, d: int, k: int) -> Array:
    """(K, D) int32 — the classical parameter set."""
    keys = jax.random.split(key, k)
    return jax.vmap(lambda kk: random_permutation(kk, d))(keys)


@functools.partial(jax.jit, static_argnames=())
def minhash_dense(v: Array, perms: Array) -> Array:
    """Signatures for dense binary vectors.

    v: (B, D) {0,1};  perms: (K, D).  Returns (B, K) int32,
    h_k(v) = min_{i: v_i != 0} perms[k, i]  (SENTINEL for empty vectors).
    """
    mask = v > 0  # (B, D)

    def one_perm(p):  # p: (D,)
        vals = jnp.where(mask, p[None, :], SENTINEL)
        return jnp.min(vals, axis=-1)  # (B,)

    sig = jax.lax.map(one_perm, perms)  # (K, B)
    return sig.T.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k_chunk",))
def minhash_sparse(idx: Array, perms: Array, k_chunk: int = 64) -> Array:
    """Signatures for padded sparse index lists.

    idx: (B, NNZ) int32, padding entries are negative; perms: (K, D).
    Returns (B, K) int32.
    """
    b, nnz = idx.shape
    k, d = perms.shape
    valid = idx >= 0
    safe_idx = jnp.clip(idx, 0, d - 1)

    def chunk_fn(carry, p_chunk):  # p_chunk: (k_chunk, D)
        vals = p_chunk[:, safe_idx]  # (k_chunk, B, NNZ)
        vals = jnp.where(valid[None], vals, SENTINEL)
        return carry, jnp.min(vals, axis=-1)  # (k_chunk, B)

    n_chunks = -(-k // k_chunk)
    pad_k = n_chunks * k_chunk - k
    perms_p = jnp.pad(perms, ((0, pad_k), (0, 0)))
    _, sigs = jax.lax.scan(chunk_fn, None, perms_p.reshape(n_chunks, k_chunk, d))
    sig = sigs.reshape(n_chunks * k_chunk, b)[:k]
    return sig.T.astype(jnp.int32)
