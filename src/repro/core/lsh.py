"""Banded LSH over MinHash/C-MinHash signatures (near-duplicate candidate generation).

K = n_bands * rows_per_band. Two items land in the same bucket of band j iff their
signature rows in that band agree exactly; the usual S-curve
P[candidate] = 1 - (1 - J^r)^b applies. Band hashing is a vectorized polynomial
hash in JAX; bucket grouping is host-side (it is index bookkeeping, not FLOPs).
"""

from __future__ import annotations

from collections import defaultdict

import numpy as np

_BASE = np.uint64(0x9E3779B97F4A7C15)  # Fibonacci hashing multiplier


def _poly_fold(rows: np.ndarray) -> np.ndarray:
    """(B, n_bands, R) uint64 -> (B, n_bands) uint64 polynomial-fold keys.

    The one definition of the bucket hash: ``band_hashes`` folds codes,
    ``band_hashes_packed`` folds words — sharing this keeps their b=32
    interop guarantee (identical keys) structural rather than coincidental.
    """
    with np.errstate(over="ignore"):
        h = np.zeros(rows.shape[:2], np.uint64)
        for r in range(rows.shape[2]):
            h = h * _BASE + rows[:, :, r] + np.uint64(1)
            h ^= h >> np.uint64(29)
    return h


def band_hashes(sig, n_bands: int, rows_per_band: int) -> np.ndarray:
    """(B, K) signatures -> (B, n_bands) uint64 bucket keys.

    Host-side (bucketing is index bookkeeping): vectorized polynomial fold in
    uint64 with wraparound — JAX's default int32 domain would silently truncate.
    """
    sig = np.asarray(sig)
    b, k = sig.shape
    if n_bands * rows_per_band != k:
        raise ValueError(f"K={k} != n_bands*rows_per_band={n_bands * rows_per_band}")
    return _poly_fold(sig.reshape(b, n_bands, rows_per_band).astype(np.uint64))


def band_hashes_packed(words: np.ndarray, n_bands: int) -> np.ndarray:
    """(B, W) b-bit packed uint32 words -> (B, n_bands) uint64 bucket keys.

    The packed-ingest twin of ``band_hashes``: requires band boundaries to
    fall on word boundaries (W % n_bands == 0, i.e. rows_per_band a multiple
    of 32/b) and folds each band's words with the same polynomial as
    ``band_hashes`` folds codes.  At b = 32 a word IS the (non-negative)
    signature value, so keys are identical to ``band_hashes`` on the raw
    signatures — packed and unpacked ingest interoperate exactly.  At b < 32
    keys are self-consistent (index and query must both use the packed path).
    """
    words = np.asarray(words)
    b, w = words.shape
    if w % n_bands:
        raise ValueError(
            f"W={w} not divisible by n_bands={n_bands}: rows_per_band must "
            "be a multiple of 32/b for packed banding")
    return _poly_fold(words.reshape(b, n_bands, w // n_bands).astype(np.uint64))


def candidate_pairs(bands: np.ndarray) -> set[tuple[int, int]]:
    """All (i, j) i<j sharing at least one band bucket (host-side)."""
    bands = np.asarray(bands)
    cands: set[tuple[int, int]] = set()
    for col in range(bands.shape[1]):
        buckets: dict[int, list[int]] = defaultdict(list)
        for i, h in enumerate(bands[:, col]):
            buckets[int(h)].append(i)
        for members in buckets.values():
            if len(members) > 1:
                for ai in range(len(members)):
                    for bi in range(ai + 1, len(members)):
                        cands.add((members[ai], members[bi]))
    return cands


class UnionFind:
    """Host-side union-find for duplicate clustering."""

    def __init__(self, n: int):
        self.parent = list(range(n))

    def find(self, i: int) -> int:
        while self.parent[i] != i:
            self.parent[i] = self.parent[self.parent[i]]
            i = self.parent[i]
        return i

    def union(self, i: int, j: int) -> None:
        ri, rj = self.find(i), self.find(j)
        if ri != rj:
            self.parent[max(ri, rj)] = min(ri, rj)

    def clusters(self) -> dict[int, list[int]]:
        out: dict[int, list[int]] = defaultdict(list)
        for i in range(len(self.parent)):
            out[self.find(i)].append(i)
        return dict(out)


def candidate_probability(j: float, n_bands: int, rows_per_band: int) -> float:
    """The LSH S-curve: P = 1 - (1 - J^r)^b."""
    return 1.0 - (1.0 - j ** rows_per_band) ** n_bands
