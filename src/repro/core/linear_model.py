"""Linear models on b-bit C-MinHash features — the paper's "large-scale
learning" application (Li, Shrivastava, Moore, Koenig, NIPS 2011: K = 512/1024
hashes as features; the paper's Sec. 1 motivates exactly this use).

Logistic regression over the one-hot b-bit feature map (K * 2^b dims), trained
with full-batch Adam in a single jitted scan.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .bbit import bbit_features

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class HashedLinearConfig:
    b: int = 4             # bits kept per hash
    l2: float = 1e-4
    lr: float = 0.05
    steps: int = 300


@functools.partial(jax.jit, static_argnames=("cfg",))
def fit_logistic(sigs: Array, labels: Array, cfg: HashedLinearConfig):
    """sigs: (N, K) int32 signatures; labels: (N,) in {0,1}.
    Returns (weights (K*2^b,), bias ())."""
    x = bbit_features(sigs, cfg.b)                 # (N, F)
    y = labels.astype(jnp.float32)
    f = x.shape[1]

    def loss_fn(wb):
        w, bias = wb
        logits = x @ w + bias
        ce = jnp.mean(jnp.logaddexp(0.0, logits) - y * logits)
        return ce + cfg.l2 * jnp.sum(w * w)

    grad_fn = jax.grad(loss_fn)

    def step(carry, _):
        wb, m, v, t = carry
        g = grad_fn(wb)
        t = t + 1
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + 0.1 * gg, m, g)
        v = jax.tree.map(lambda vv, gg: 0.999 * vv + 0.001 * gg * gg, v, g)
        mh = jax.tree.map(lambda mm: mm / (1 - 0.9 ** t), m)
        vh = jax.tree.map(lambda vv: vv / (1 - 0.999 ** t), v)
        wb = jax.tree.map(lambda p, mm, vv: p - cfg.lr * mm /
                          (jnp.sqrt(vv) + 1e-8), wb, mh, vh)
        return (wb, m, v, t), None

    wb0 = (jnp.zeros((f,), jnp.float32), jnp.zeros((), jnp.float32))
    zeros = jax.tree.map(jnp.zeros_like, wb0)
    (wb, _, _, _), _ = jax.lax.scan(
        step, (wb0, zeros, jax.tree.map(jnp.copy, zeros),
               jnp.zeros((), jnp.float32)), None, length=cfg.steps)
    return wb


@functools.partial(jax.jit, static_argnames=("b",))
def predict_logistic(wb, sigs: Array, b: int) -> Array:
    """Class-1 probability for each signature row."""
    w, bias = wb
    x = bbit_features(sigs, b)
    return jax.nn.sigmoid(x @ w + bias)


def accuracy(wb, sigs: Array, labels: Array, b: int) -> float:
    p = predict_logistic(wb, sigs, b)
    return float(jnp.mean((p > 0.5) == (labels > 0.5)))
