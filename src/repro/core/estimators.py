"""Jaccard estimators from signatures + ground-truth helpers (Eqs. 2, 4, 7)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@jax.jit
def jaccard_from_signatures(sig_a: Array, sig_b: Array) -> Array:
    """\\hat J = (1/K) sum_k 1{h_k(v) = h_k(w)} for matching leading shapes."""
    return jnp.mean((sig_a == sig_b).astype(jnp.float32), axis=-1)


@jax.jit
def pairwise_jaccard_from_signatures(sig_q: Array, sig_n: Array) -> Array:
    """(Q, K) x (N, K) -> (Q, N) estimated Jaccard matrix (reference path)."""
    eq = sig_q[:, None, :] == sig_n[None, :, :]
    return jnp.mean(eq.astype(jnp.float32), axis=-1)


@jax.jit
def true_jaccard_dense(v: Array, w: Array) -> Array:
    """Exact J for dense binary (..., D) pairs."""
    inter = jnp.sum((v > 0) & (w > 0), axis=-1)
    union = jnp.sum((v > 0) | (w > 0), axis=-1)
    return jnp.where(union > 0, inter / union, 0.0)


def true_jaccard_sparse(idx_a: np.ndarray, idx_b: np.ndarray) -> float:
    """Exact J for two padded sparse index lists (host-side)."""
    sa = set(int(i) for i in np.asarray(idx_a) if i >= 0)
    sb = set(int(i) for i in np.asarray(idx_b) if i >= 0)
    if not sa and not sb:
        return 0.0
    return len(sa & sb) / len(sa | sb)


def mae(estimates: np.ndarray, truth: np.ndarray) -> float:
    return float(np.mean(np.abs(np.asarray(estimates) - np.asarray(truth))))


def mse(estimates: np.ndarray, truth: np.ndarray) -> float:
    return float(np.mean((np.asarray(estimates) - np.asarray(truth)) ** 2))
