"""C-MinHash — the paper's contribution (Algorithms 2 & 3) as composable JAX ops.

Two variants:
  * ``sigma=None``  -> C-MinHash-(0,pi)   (Section 2; location-dependent variance)
  * ``sigma`` given -> C-MinHash-(sigma,pi) (Section 3; uniformly better than MinHash)

Identity used by every implementation path (dense, sparse, Pallas kernel):

    h_k(v) = min_{i : v'_i != 0} pi_{->k}(i)          (Algorithm 2/3)
           = min_{i : v'_i != 0} pi[(i - k) mod D]
           = min_{m : v'[(m + k) mod D] != 0} pi[m]   (substituting m = i - k)

so hash k is a min-reduction of the *fixed* value vector ``pi`` masked by the
circulantly rolled data vector — the gather-free form the TPU kernel tiles.
K <= D is required (as in the paper); ``shift_offset=1`` reproduces k = 1..K.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .permutations import apply_permutation_dense, apply_permutation_sparse

Array = jax.Array
SENTINEL = jnp.iinfo(jnp.int32).max


def _check(d: int, k: int) -> None:
    if k > d:
        raise ValueError(f"C-MinHash requires K <= D (got K={k}, D={d})")


@functools.partial(jax.jit, static_argnames=("k", "shift_offset"))
def cminhash_dense(v: Array, pi: Array, k: int, sigma: Array | None = None,
                   *, shift_offset: int = 1) -> Array:
    """Signatures for dense binary vectors v: (B, D) -> (B, K) int32."""
    d = v.shape[-1]
    _check(d, k)
    if sigma is not None:
        v = apply_permutation_dense(v, sigma)
    mask = (v > 0)
    # vpad[:, m + s] for s in [shift_offset, K + shift_offset)
    vpad = jnp.concatenate([mask, mask[:, : k + shift_offset]], axis=-1)

    def one_shift(s):  # s in [0, K)
        window = jax.lax.dynamic_slice_in_dim(vpad, s + shift_offset, d, axis=1)
        vals = jnp.where(window, pi[None, :], SENTINEL)
        return jnp.min(vals, axis=-1)  # (B,)

    sig = jax.lax.map(one_shift, jnp.arange(k))  # (K, B)
    return sig.T.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "shift_offset", "k_chunk"))
def cminhash_sparse(idx: Array, pi: Array, k: int, sigma: Array | None = None,
                    *, shift_offset: int = 1, k_chunk: int = 64) -> Array:
    """Signatures for padded sparse index lists (B, NNZ) -> (B, K) int32.

    h_k = min_{j valid} pi[(sigma(idx_j) - k) mod D]  — O(B * nnz * K) gathers,
    the economical path when nnz << D.
    """
    d = pi.shape[0]
    _check(d, k)
    if sigma is not None:
        idx = apply_permutation_sparse(idx, sigma)
    b, nnz = idx.shape
    valid = idx >= 0
    safe_idx = jnp.where(valid, idx, 0)

    def shifts_fn(ks):  # ks: (kc,) shift values -> (kc, B) partial signatures
        pos = (safe_idx[None, :, :] - ks[:, None, None]) % d  # (kc, B, NNZ)
        vals = jnp.where(valid[None], pi[pos], SENTINEL)
        return jnp.min(vals, axis=-1)

    # full chunks go through one scan; the k % k_chunk remainder is a single
    # smaller call, so no wasted shifts when k_chunk does not divide k
    n_full, rem = divmod(k, k_chunk)
    parts = []
    if n_full:
        ks_full = shift_offset + jnp.arange(n_full * k_chunk)
        _, sigs = jax.lax.scan(lambda c, ks: (c, shifts_fn(ks)), None,
                               ks_full.reshape(n_full, k_chunk))
        parts.append(sigs.reshape(n_full * k_chunk, b))
    if rem:
        ks_rem = shift_offset + n_full * k_chunk + jnp.arange(rem)
        parts.append(shifts_fn(ks_rem))
    sig = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
    return sig.T.astype(jnp.int32)


def compute_signatures(data: Array, pi: Array, k: int, sigma: Array | None = None,
                       *, layout: str = "dense", shift_offset: int = 1) -> Array:
    """Layout-dispatching front door used by the engine and the examples."""
    if layout == "dense":
        return cminhash_dense(data, pi, k, sigma, shift_offset=shift_offset)
    if layout == "sparse":
        return cminhash_sparse(data, pi, k, sigma, shift_offset=shift_offset)
    raise ValueError(f"unknown layout {layout!r}")
