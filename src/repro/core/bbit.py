"""b-bit minwise hashing (Li & Koenig, 2011) on top of C-MinHash signatures.

Keeps only the lowest b bits of each hash value — the storage/bandwidth trick used
for large-scale learning — and expands them into one-hot features for linear models
(`examples/train_hash_features` / the dedup verifier use this).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("b",))
def lowest_b_bits(sig: Array, b: int) -> Array:
    """(..., K) int32 signatures -> (..., K) values in [0, 2^b)."""
    return (sig & ((1 << b) - 1)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("b",))
def bbit_features(sig: Array, b: int) -> Array:
    """One-hot expansion: (B, K) -> (B, K * 2^b) float32 in {0,1}.

    The standard feature map for training linear classifiers on hashed data.
    """
    codes = lowest_b_bits(sig, b)  # (B, K)
    onehot = jax.nn.one_hot(codes, 1 << b, dtype=jnp.float32)  # (B, K, 2^b)
    return onehot.reshape(sig.shape[0], -1)


@functools.partial(jax.jit, static_argnames=("b",))
def bbit_collision_fraction(sig_a: Array, sig_b: Array, b: int) -> Array:
    """Fraction of matching b-bit codes (biased-up estimate of J; see Li & Koenig)."""
    eq = lowest_b_bits(sig_a, b) == lowest_b_bits(sig_b, b)
    return jnp.mean(eq.astype(jnp.float32), axis=-1)
