"""Exact/Monte-Carlo evaluation of the paper's theory (ground truth for all tests).

Implements:
  * Lemma 2.1   — Theta_delta from circular-adjacency set sizes;
  * Theorem 2.2 — Var[J_{0,pi}] for a *fixed* location vector (location-dependent);
  * Theorem 3.1 — Var[J_{sigma,pi}]: exact combinatorial \\tilde{E} (formula 19,
                  enumerated over (s, n1..n4), tractable for small D) and a
                  Monte-Carlo \\tilde{E} over random circular arrangements (any D);
  * Var[J_MH] = J(1-J)/K (Eq. 3), variance ratio (Prop. 3.5).

Location-vector encoding: 0 = 'O' (v_i = w_i = 1), 1 = 'x' (v_i + w_i = 1),
2 = '-' (v_i = w_i = 0).  All of this is host-side numpy: it is combinatorics,
not accelerator work.
"""

from __future__ import annotations

import numpy as np
from scipy.special import comb

SENT = np.iinfo(np.int32).max

O, X, N = 0, 1, 2  # 'O', 'x', '-'


# ---------------------------------------------------------------------------
# Location vectors and adjacency set sizes
# ---------------------------------------------------------------------------

def location_vector(v: np.ndarray, w: np.ndarray) -> np.ndarray:
    """Definition 2.1 for a single dense pair."""
    v = np.asarray(v) > 0
    w = np.asarray(w) > 0
    x = np.full(v.shape, N, np.int8)
    x[v & w] = O
    x[v ^ w] = X
    return x


def af_counts(x: np.ndarray) -> tuple[int, int]:
    a = int(np.sum(x == O))
    f = a + int(np.sum(x == X))
    return a, f


def structured_location_vector(d: int, f: int, a: int) -> np.ndarray:
    """The paper's Fig. 6 pattern: a 'O's, then (f-a) 'x's, then (d-f) '-'s."""
    return np.concatenate([
        np.full(a, O, np.int8), np.full(f - a, X, np.int8),
        np.full(d - f, N, np.int8)]).astype(np.int8)


def pair_set_sizes(x: np.ndarray, delta: int) -> dict[str, int]:
    """|L_i(delta)|, |G_i(delta)|, |H_i(delta)| of Definition 2.2 (circular)."""
    y = np.roll(x, -delta)  # y[i] = x[(i + delta) mod D]
    def cnt(A, B):
        return int(np.sum((x == A) & (y == B)))
    return {
        "l0": cnt(O, O), "l1": cnt(O, X), "l2": cnt(O, N),
        "g0": cnt(N, O), "g1": cnt(N, X), "g2": cnt(N, N),
        "h0": cnt(X, O), "h1": cnt(X, X), "h2": cnt(X, N),
    }


def theta_from_sizes(l0: float, l2: float, g0: float, g1: float,
                     a: int, f: int) -> float:
    """Lemma 2.1: E[1_s 1_t] = (|L0| + (|G0|+|L2|) J) / (f + |G0| + |G1|)."""
    j = a / f
    return (l0 + (g0 + l2) * j) / (f + g0 + g1)


# ---------------------------------------------------------------------------
# Variances
# ---------------------------------------------------------------------------

def var_minhash(j: float, k: int) -> float:
    """Eq. (3)."""
    return j * (1.0 - j) / k


def var_0pi(x: np.ndarray, k: int) -> float:
    """Theorem 2.2 for a fixed location vector (requires K <= D).

    Var = J/K + (2/K^2) sum_{delta=1}^{K-1} (K - delta) Theta_delta - J^2.
    """
    d = x.shape[0]
    if k > d:
        raise ValueError("K <= D required")
    a, f = af_counts(x)
    if a == 0:
        return 0.0
    j = a / f
    acc = 0.0
    for delta in range(1, k):
        s = pair_set_sizes(x, delta)
        acc += (k - delta) * theta_from_sizes(s["l0"], s["l2"], s["g0"], s["g1"], a, f)
    return j / k + 2.0 * acc / k**2 - j * j


def etilde_exact(d: int, f: int, a: int) -> float:
    """Theorem 3.1's \\tilde{E} by direct enumeration of formula (19).

    Enumerates (s, n1, n2, n3, n4) — the bin-occupation counts of the two-step
    circular placement in Appendix A.3 — and maps them to (l0, l2, g0, g1).
    Exact; intended for small D (cost grows ~ D^5, vectorized per s).
    """
    if not (0 <= a <= f <= d):
        raise ValueError("need 0 <= a <= f <= D")
    if a == 0 or a == f:
        # Var is 0 in these corners; E~ equals J^2 trivially for the variance formula.
        j = 0.0 if a == 0 else 1.0
        return j * j
    if d == f:
        # No '-' points: E~ = J * (a-1)/(f-1)  (proof of Thm 3.4).
        return (a / f) * ((a - 1) / (f - 1))

    j = a / f
    total = 0.0
    denom_balls = comb(d - 1, a)            # place a 'O's into D-a circular gaps
    denom_s = comb(d - a - 1, d - f - 1)    # stars-and-bars for the 'x' placement
    s_lo = max(0, d - 2 * f + a)
    for s in range(s_lo, d - f):
        c2 = d - f - s            # |C2| = |C3|
        c4 = f - a - c2           # |C4|
        if c4 < 0:
            continue
        p_s = comb(d - f, s) * comb(f - a - 1, c2 - 1) / denom_s
        if p_s == 0.0:
            continue
        n1 = np.arange(0, min(s, a) + 1)[:, None, None, None]
        n2 = np.arange(0, min(c2, a) + 1)[None, :, None, None]
        n3 = np.arange(0, min(c2, a) + 1)[None, None, :, None]
        n4 = np.arange(0, min(c4, a) + 1)[None, None, None, :]
        m = n1 + n2 + n3 + n4  # number of occupied bins = l1 + l2
        ways = (comb(s, n1) * comb(c2, n2) * comb(c2, n3) * comb(c4, n4)
                * comb(a - 1, a - m))
        l2 = n1 + n3
        l1 = n2 + n4
        g0 = n1 + n2
        g1 = c2 - n2
        l0 = a - l1 - l2
        expr = (l0 + (g0 + l2) * j) / (f + g0 + g1)
        valid = (m >= 1) & (m <= a) & (l0 >= 0)
        total += p_s * float(np.sum(np.where(valid, ways * expr, 0.0))) / denom_balls
    return total


def etilde_mc(d: int, f: int, a: int, n_samples: int = 200_000,
              seed: int = 0, chunk: int = 4096) -> float:
    """Monte-Carlo \\tilde{E}: average of Lemma 2.1's expression at delta=1 over
    uniformly random circular arrangements of the location multiset."""
    if a == 0 or a == f:
        j = 0.0 if a == 0 else 1.0
        return j * j
    rng = np.random.default_rng(seed)
    base = structured_location_vector(d, f, a)
    j = a / f
    acc = 0.0
    done = 0
    while done < n_samples:
        n = min(chunk, n_samples - done)
        order = np.argsort(rng.random((n, d)), axis=1)
        arr = base[order]                       # (n, D) random arrangements
        nxt = np.roll(arr, -1, axis=1)
        l0 = np.sum((arr == O) & (nxt == O), axis=1)
        l2 = np.sum((arr == O) & (nxt == N), axis=1)
        g0 = np.sum((arr == N) & (nxt == O), axis=1)
        g1 = np.sum((arr == N) & (nxt == X), axis=1)
        acc += float(np.sum((l0 + (g0 + l2) * j) / (f + g0 + g1)))
        done += n
    return acc / n_samples


def var_sigma_pi(d: int, f: int, a: int, k: int, *, method: str = "auto",
                 n_samples: int = 200_000, seed: int = 0) -> float:
    """Theorem 3.1: Var = J/K + (K-1) E~ / K - J^2."""
    if k > d:
        raise ValueError("K <= D required")
    if a == 0 or a == f:
        return 0.0
    if method == "auto":
        method = "exact" if d <= 48 else "mc"
    et = (etilde_exact(d, f, a) if method == "exact"
          else etilde_mc(d, f, a, n_samples=n_samples, seed=seed))
    j = a / f
    return j / k + (k - 1) * et / k - j * j


def variance_ratio(d: int, f: int, a: int, k: int, **kw) -> float:
    """Prop. 3.5's rho = Var_MH / Var_{sigma,pi} (constant in a for fixed D,f,K)."""
    j = a / f
    vs = var_sigma_pi(d, f, a, k, **kw)
    vm = var_minhash(j, k)
    return vm / vs if vs > 0 else np.inf
