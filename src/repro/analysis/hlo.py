"""Scan-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts every while-loop body ONCE, which
undercounts scanned-layer models by ~n_layers (verified in tests). This module
re-derives flops / bytes / collective wire-bytes from ``compiled.as_text()``:

  1. parse the module into computations (symbol table of op shapes per comp);
  2. build the call graph with execution multipliers — while bodies multiply by
     ``backend_config.known_trip_count`` (fallback: the loop-condition constant),
     fusions keep the flop multiplier but contribute bytes only at the call
     boundary (XLA semantics);
  3. per-computation costs: dot flops from output/contracting dims, elementwise
     flops ~ output size, bytes ~ operand+output sizes at non-fused ops,
     collective wire bytes from ring formulas with the replica-group size.

Approximations are deliberately conservative and documented in EXPERIMENTS.md;
tests pin this against cost_analysis() on scan-free modules.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4,
    "f64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)"
    r"(%?[\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_ZERO_BYTE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "custom-call",
}
_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs", "compare",
    "select", "and", "or", "xor", "floor", "ceil", "round-nearest-afz",
    "exponential-minus-one", "log-plus-one", "logistic", "cosine", "sine",
}


def _shape_sizes(type_str: str) -> tuple[float, float]:
    """(total bytes, total element count) for an HLO type string (incl tuples)."""
    bytes_ = 0.0
    elems = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1.0
        if dims:
            for d in dims.split(","):
                n *= int(d)
        bytes_ += n * _DTYPE_BYTES[dt]
        elems += n
    return bytes_, elems


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    type_str: str
    line: str
    out_bytes: float
    out_elems: float
    operands: list[str]


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    shapes: dict[str, str]          # op/param name -> type string


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.+\{$")


def _split_params(sig: str) -> list[tuple[str, str]]:
    """Split 'a: f32[2], b: (s32[], f32[3])' at top-level commas."""
    parts, depth, cur = [], 0, []
    for ch in sig:
        if ch == "(" or ch == "[":
            depth += 1
        elif ch == ")" or ch == "]":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    out = []
    for p in parts:
        if ":" in p:
            name, tp = p.split(":", 1)
            out.append((name.strip().lstrip("%"), tp.strip()))
    return out


def parse_module(text: str) -> tuple[dict[str, Computation], str]:
    """Parse compiled HLO text. Returns (computations, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        m = _COMP_HEADER.match(stripped)
        if m:
            cur = Computation(m.group(2), [], {})
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            for pname, ptype in _split_params(m.group(3)):
                cur.shapes[pname] = ptype
            continue
        if stripped == "}" or cur is None:
            continue
        om = _OP_RE.match(stripped)
        if not om:
            continue
        name, rhs = om.group(1), om.group(2)
        km = re.match(r"^(\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[\d,]*\})?)\s+"
                      r"([\w\-]+)", rhs)
        if not km:
            continue
        type_str, kind = km.group(1), km.group(2)
        ob, oe = _shape_sizes(type_str)
        operands = re.findall(r"%([\w.\-]+)", rhs.split(")", 1)[0])
        op = Op(name, kind, type_str, stripped, ob, oe, operands)
        cur.ops.append(op)
        cur.shapes[name] = type_str
    if not entry:  # newer dumps: ENTRY may be named main without marker
        entry = next((n for n in comps if n.startswith("main")),
                     next(iter(comps)))
    return comps, entry


def _trip_count(op: Op, comps: dict[str, Computation]) -> float:
    m = re.search(r'known_trip_count[\\"\':{]+n[\\"\':]+(\d+)', op.line)
    if m:
        return float(m.group(1))
    # fallback: constant in the loop condition
    cm = re.search(r"condition=%?([\w.\-]+)", op.line)
    if cm and cm.group(1) in comps:
        for cop in comps[cm.group(1)].ops:
            k = re.search(r"constant\((\d+)\)", cop.line)
            if k:
                return float(k.group(1))
    return 1.0


def _called(op: Op) -> list[tuple[str, str]]:
    """[(computation name, role)] called by this op."""
    out = []
    for attr, role in (("calls", "fusion"), ("to_apply", "apply"),
                       ("body", "body"), ("condition", "cond")):
        m = re.search(attr + r"=%?([\w.\-]+)", op.line)
        if m:
            out.append((m.group(1), role))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.line)
    if m:
        for name in re.findall(r"%?([\w.\-]+)", m.group(1)):
            out.append((name, "branch"))
    return out


def _dot_flops(op: Op, comp: Computation) -> float:
    _, out_elems = _shape_sizes(op.type_str)
    lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contract = 1.0
    if lc and op.operands:
        lhs_type = comp.shapes.get(op.operands[0], "")
        sm = _SHAPE_RE.search(lhs_type)
        if sm and sm.group(2):
            dims = [int(x) for x in sm.group(2).split(",")]
            for d in (int(x) for x in lc.group(1).split(",") if x):
                if d < len(dims):
                    contract *= dims[d]
    return 2.0 * out_elems * contract


def _collective_wire_bytes(op: Op, comp: Computation) -> float:
    """Per-device wire bytes using ring formulas and the replica-group size."""
    g = 1.0
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.line)
    if m:
        g = float(m.group(2))
    else:
        m = re.search(r"replica_groups=\{\{([^}]*)\}", op.line)
        if m:
            g = float(len(m.group(1).split(",")))
    if g <= 1:
        # collective-permute has no groups; bytes = payload
        if op.kind.startswith("collective-permute"):
            return op.out_bytes
        return 0.0
    size = op.out_bytes
    if op.kind.startswith("all-reduce"):
        return 2.0 * (g - 1.0) / g * size
    if op.kind.startswith("all-gather"):
        return (g - 1.0) / g * size            # size = gathered output
    if op.kind.startswith("reduce-scatter"):
        in_bytes = sum(_shape_sizes(comp.shapes.get(o, ""))[0]
                       for o in op.operands) or size * g
        return (g - 1.0) / g * in_bytes
    if op.kind.startswith("all-to-all"):
        return (g - 1.0) / g * size
    if op.kind.startswith("collective-permute"):
        return size
    return 0.0


# Ops that materialize buffers even under TPU-grade fusion. Elementwise chains
# fuse into their consumers on TPU; CPU HLO leaves them unfused (it wraps each
# in a single-op kLoop fusion), so charging every op / every fusion boundary
# (bytes_naive) wildly overstates HBM traffic. The fused model descends INTO
# fusion computations and charges only these; (dynamic-)slice charges 2x
# output (read slice + write), and dynamic-update-slice charges 2x the update
# operand — NOT the full buffer.
_MATERIALIZING = {
    "dot", "convolution", "scatter", "gather", "copy", "transpose",
    "concatenate", "pad", "reverse", "sort", "rng", "rng-bit-generator",
    "reduce", "reduce-window", "select-and-scatter", "cholesky",
    "triangular-solve",
}
_SLICE_OPS = {"slice", "dynamic-slice"}


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float              # perfect-fusion TPU proxy (roofline memory term)
    bytes_naive: float        # every-op operand+output (upper bound)
    collective_bytes: float
    collective_breakdown: dict[str, float]
    n_collectives: int
    top_collectives: list = dataclasses.field(default_factory=list)
    # [(wire_bytes_total, kind, mult, type_str, op_name_hint)] descending


def analyze(text: str) -> HloCost:
    comps, entry = parse_module(text)

    # execution multipliers: (flop_mult, byte_mult) accumulated per computation
    fmult: dict[str, float] = defaultdict(float)
    bmult: dict[str, float] = defaultdict(float)

    def walk(name: str, fm: float, bm: float, depth: int = 0):
        if name not in comps or depth > 64 or fm <= 0:
            return
        fmult[name] += fm
        bmult[name] += bm
        for op in comps[name].ops:
            if op.kind == "while":
                trips = _trip_count(op, comps)
                for cname, role in _called(op):
                    if role == "body":
                        walk(cname, fm * trips, bm * trips, depth + 1)
                    elif role == "cond":
                        walk(cname, fm, 0.0, depth + 1)
            else:
                for cname, role in _called(op):
                    if role == "fusion":
                        walk(cname, fm, 0.0, depth + 1)   # boundary bytes only
                    elif role == "apply":
                        # plain `call` interiors materialize for real — some
                        # XLA versions wrap scan bodies in a call, and
                        # zeroing bytes there hides every per-trip buffer
                        # from the naive model.  Non-call to_apply users
                        # (reduce/map/sort combiners) stay boundary-only:
                        # their scalar combiners never materialize.
                        walk(cname, fm, bm if op.kind == "call" else 0.0,
                             depth + 1)
                    elif role == "branch":
                        walk(cname, fm, bm, depth + 1)

    walk(entry, 1.0, 1.0)

    flops = 0.0
    bytes_naive = 0.0
    bytes_fused = 0.0
    coll = 0.0
    coll_breakdown: dict[str, float] = defaultdict(float)
    n_coll = 0
    top_colls: list = []
    for name, comp in comps.items():
        fm, bm = fmult.get(name, 0.0), bmult.get(name, 0.0)
        if fm == 0.0 and bm == 0.0:
            continue
        for op in comp.ops:
            if op.kind == "dot" or op.kind == "convolution":
                flops += fm * _dot_flops(op, comp)
            elif op.kind in _ELEMENTWISE_FLOP_OPS:
                flops += fm * op.out_elems
            elif op.kind.startswith("reduce"):
                flops += fm * sum(_shape_sizes(comp.shapes.get(o, ""))[1]
                                  for o in op.operands[:1])
            base_kind = op.kind.replace("-start", "")
            is_coll = base_kind.split(".")[0] in _COLLECTIVES and \
                not op.kind.endswith("-done")
            if is_coll:
                wb = fm * _collective_wire_bytes(op, comp)
                coll += wb
                coll_breakdown[base_kind] += wb
                n_coll += int(fm)
                hint = ""
                hm = re.search(r'op_name="([^"]*)"', op.line)
                if hm:
                    hint = hm.group(1)[-120:]
                top_colls.append((wb, base_kind, fm, op.type_str[:64], hint))
            if bm > 0 and op.kind not in _ZERO_BYTE_OPS:
                operand_bytes = sum(
                    _shape_sizes(comp.shapes.get(o, ""))[0]
                    for o in op.operands)
                bytes_naive += bm * (op.out_bytes + operand_bytes)
            # fused model uses the flop multiplier (descends into fusions)
            if fm > 0:
                if op.kind in _SLICE_OPS:
                    bytes_fused += fm * 2.0 * op.out_bytes
                elif op.kind == "dynamic-update-slice":
                    upd = _shape_sizes(
                        comp.shapes.get(op.operands[1], ""), )[0] \
                        if len(op.operands) > 1 else op.out_bytes
                    bytes_fused += fm * 2.0 * upd
                elif op.kind in _MATERIALIZING or is_coll:
                    operand_bytes = sum(
                        _shape_sizes(comp.shapes.get(o, ""))[0]
                        for o in op.operands)
                    bytes_fused += fm * (op.out_bytes + operand_bytes)

    # entry I/O: inputs are read once, outputs written once (their interior
    # consumers/producers may be fully fused)
    for op in comps[entry].ops:
        if op.kind == "parameter" or op.line.startswith("ROOT"):
            bytes_fused += op.out_bytes

    top_colls.sort(key=lambda t: -t[0])
    return HloCost(flops=flops, bytes=bytes_fused, bytes_naive=bytes_naive,
                   collective_bytes=coll,
                   collective_breakdown=dict(coll_breakdown),
                   n_collectives=n_coll, top_collectives=top_colls[:20])
