"""Three-term roofline from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch, shape, mesh) cell — all terms are per-chip seconds per step:

    compute    = HLO_FLOPs_per_chip / PEAK_FLOPS
    memory     = HLO_bytes_per_chip / HBM_BW
    collective = collective_wire_bytes_per_chip / ICI_BW

HLO numbers come from the scan-aware analyzer (analysis/hlo.py) — XLA's own
cost_analysis counts while bodies once and is reported alongside for reference.
MODEL_FLOPS follows the assignment: 6*N*D for training, 2*N*D for inference
forward passes, with N = active parameters (MoE: top-k experts only).

Hardware model (TPU v5e-like, from the assignment):
    197 TFLOP/s bf16 per chip; 819 GB/s HBM; 50 GB/s/link ICI.
We charge collectives against a single 50 GB/s link per chip (conservative: a
2D-torus ring uses both directions of one axis; using 2 links would halve the
collective term).
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link (1 link charged)
HBM_PER_CHIP = 16e9     # v5e HBM capacity


def model_flops(rec: dict) -> float:
    """Assignment definition, on the whole (global) step."""
    n = rec["active_params"]
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 6.0 * n * tokens
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n * tokens
    return 2.0 * n * rec["global_batch"]     # decode: one token per sequence


def roofline(rec: dict) -> dict:
    """Derive the three terms + bottleneck for one dry-run record."""
    hc = rec["hlo_cost"]
    chips = rec["n_chips"]
    compute_s = hc["flops"] / PEAK_FLOPS
    memory_s = hc["bytes"] / HBM_BW
    collective_s = hc["collective_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    useful = mf / (hc["flops"] * chips) if hc["flops"] else 0.0
    bound = max(terms.values())
    # fraction of the achievable roofline this step reaches if it ran exactly
    # at the dominant term (ideal overlap of the other two):
    step_ideal = mf / chips / PEAK_FLOPS   # time if compute were 100% useful
    frac = step_ideal / bound if bound > 0 else 0.0
    return {
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops": mf, "useful_flops_ratio": useful,
        "roofline_fraction": frac,
        "hbm_args_frac": rec["memory"]["argument_bytes"] / HBM_PER_CHIP,
    }


def load_records(dirpath: str, mesh: str | None = None) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if mesh and rec.get("mesh") != mesh:
            continue
        recs.append(rec)
    return recs


def _fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def report_markdown(dirpath: str, mesh: str = "single_pod") -> str:
    """Roofline table (single-pod by assignment) + dry-run status table."""
    recs = load_records(dirpath)
    lines = []

    lines.append(f"### Dry-run status ({len(recs)} cells)\n")
    lines.append("| mesh | arch | shape | status | compile | bytes/dev (args) | note |")
    lines.append("|---|---|---|---|---|---|---|")
    for r in recs:
        if r["status"] == "ok":
            note = (f"flops/dev {r['hlo_cost']['flops']:.2e}, "
                    f"coll {r['hlo_cost']['collective_bytes']:.2e} B")
            mem = f"{r['memory']['argument_bytes'] / 1e9:.2f} GB"
            comp = f"{r['compile_s']:.0f}s"
        elif r["status"] == "skipped":
            note, mem, comp = r["reason"], "-", "-"
        else:
            note, mem, comp = r.get("error", "?")[:80], "-", "-"
        lines.append(f"| {r['mesh']} | {r['arch']} | {r['shape']} | "
                     f"{r['status']} | {comp} | {mem} | {note} |")

    lines.append(f"\n### Roofline ({mesh}, per chip per step)\n")
    lines.append("| arch | shape | compute | memory | collective | dominant | "
                 "MODEL_FLOPS | useful ratio | roofline frac |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for r in recs:
        if r.get("mesh") != mesh or r["status"] != "ok":
            continue
        t = roofline(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(t['compute_s'])} | "
            f"{_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} | "
            f"**{t['dominant']}** | {t['model_flops']:.2e} | "
            f"{t['useful_flops_ratio']:.2f} | {t['roofline_fraction']:.2f} |")
    return "\n".join(lines)


VPU_OPS = 4e12   # ~VPU element-op throughput per chip (order-of-magnitude;
                 # the MXU peak does not apply to select/min workloads)


def cminhash_kernel_roofline(b: int, d: int, k: int, *, block_b: int = 8,
                             block_d: int = 256, packed: bool = False) -> dict:
    """Analytic roofline for the dense circulant-min kernel (§Perf).

    Per grid cell (Bt, Kt=Dt, Dt): band read (2*Bt*Dt bytes int8, /8 packed),
    pi read (4*Dt), out write (4*Bt*Kt, once per (i,j)); compute = 2 VPU ops
    (select+min) per (b, k, d) element.
    """
    bt, dt = block_b, block_d
    kt = dt
    nb, nk, nd = -(-b // bt), -(-k // kt), -(-d // dt)
    band = 2 * bt * dt * (1 / 8 if packed else 1)
    bytes_ = nb * nk * nd * (band + 4 * dt) + nb * nk * (4 * bt * kt)
    ops = 2.0 * b * k * d
    compute_s = ops / VPU_OPS
    memory_s = bytes_ / HBM_BW
    return {
        "ops": ops, "bytes": bytes_,
        "compute_s": compute_s, "memory_s": memory_s,
        "dominant": "compute" if compute_s >= memory_s else "memory",
        "arith_intensity": ops / bytes_,
    }


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--mesh", default="single_pod")
    args = ap.parse_args()
    print(report_markdown(args.dir, args.mesh))


if __name__ == "__main__":
    main()
