"""Compare two dry-run sweeps (baseline vs optimized) per cell — §Perf tables.

    PYTHONPATH=src python -m repro.analysis.compare \
        --baseline runs/dryrun --optimized runs/dryrun_opt --mesh single_pod
"""

from __future__ import annotations

import argparse

from .roofline import load_records, roofline, _fmt_s


def compare(base_dir: str, opt_dir: str, mesh: str) -> str:
    base = {(r["arch"], r["shape"]): r for r in load_records(base_dir, mesh)}
    opt = {(r["arch"], r["shape"]): r for r in load_records(opt_dir, mesh)}
    lines = [
        "| arch | shape | dominant | before | after | delta | term moved |",
        "|---|---|---|---|---|---|---|",
    ]
    for key in sorted(base):
        b, o = base.get(key), opt.get(key)
        if not b or not o or b["status"] != "ok" or o["status"] != "ok":
            continue
        tb, to = roofline(b), roofline(o)
        dom = tb["dominant"]
        before = tb[f"{dom}_s"]
        after = to[f"{dom}_s"]
        delta = (after - before) / before * 100 if before else 0.0
        if abs(delta) < 0.5:
            continue
        lines.append(
            f"| {key[0]} | {key[1]} | {dom} | {_fmt_s(before)} | "
            f"{_fmt_s(after)} | {delta:+.1f}% | "
            f"c {_fmt_s(tb['compute_s'])}->{_fmt_s(to['compute_s'])}, "
            f"m {_fmt_s(tb['memory_s'])}->{_fmt_s(to['memory_s'])}, "
            f"x {_fmt_s(tb['collective_s'])}->{_fmt_s(to['collective_s'])} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="runs/dryrun")
    ap.add_argument("--optimized", default="runs/dryrun_opt")
    ap.add_argument("--mesh", default="single_pod")
    args = ap.parse_args()
    print(compare(args.baseline, args.optimized, args.mesh))


if __name__ == "__main__":
    main()
