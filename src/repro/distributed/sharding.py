"""Logical-axis sharding rules: param-path regexes -> PartitionSpecs, with the
divisor rule (a dim only shards if its size divides the axis) and batch specs.

This is the single place where the Megatron-style layout lives:
vocab/heads/ff/experts/d_inner shard over ``model``; the batch shards over
``("pod","data")``; everything else is replicated. ZeRO-1 rewrites optimizer
moments to additionally shard a replicated dim over ``data``.
"""

from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import tree_flatten_with_path, tree_unflatten

Array = jax.Array

# (path regex, spec template). Templates apply to the *trailing* dims; leading
# dims (e.g. the stacked-layer L axis) are padded with None. Matched top-down,
# first hit wins.
_RULES: tuple[tuple[str, tuple], ...] = (
    (r"embed$",               ("model", None)),
    (r"lm_head$",             (None, "model")),
    (r"attn/wq$",             (None, "model", None)),
    (r"attn/wk$",             (None, "model", None)),
    (r"attn/wv$",             (None, "model", None)),
    (r"attn/wo$",             ("model", None, None)),
    (r"xattn/wq$",            (None, "model", None)),
    (r"xattn/wk$",            (None, "model", None)),
    (r"xattn/wv$",            (None, "model", None)),
    (r"xattn/wo$",            ("model", None, None)),
    (r"mlp/w_gate$",          (None, "model")),
    (r"mlp/w_up$",            (None, "model")),
    (r"mlp/w_down$",          ("model", None)),
    (r"moe/router$",          (None, None)),
    (r"moe/e_gate$",          ("model", None, None)),
    (r"moe/e_up$",            ("model", None, None)),
    (r"moe/e_down$",          ("model", None, None)),
    (r"ssm/in_proj$",         (None, "model")),
    (r"ssm/conv_w$",          (None, "model")),
    (r"ssm/conv_b$",          ("model",)),
    (r"ssm/x_proj$",          ("model", None)),
    (r"ssm/dt_proj$",         (None, "model")),
    (r"ssm/dt_bias$",         ("model",)),
    (r"ssm/a_log$",           ("model", None)),
    (r"ssm/d_skip$",          ("model",)),
    (r"ssm/out_proj$",        ("model", None)),
)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh: Mesh, ndim: int = 2) -> P:
    return P(batch_axes(mesh), *([None] * (ndim - 1)))


def _path_str(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


def _axes_size(mesh: Mesh, axes) -> int:
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    return int(np.prod([mesh.shape[a] for a in axes if a in mesh.axis_names]))


def _spec_for(path_s: str, shape: tuple[int, ...], mesh: Mesh,
              tensor_axes="model") -> P:
    """``tensor_axes`` is what the 'model' slot of the templates maps to —
    ("data","model") gives 2D tensor sharding for batch-starved decode
    (EXPERIMENTS.md §Perf D)."""
    for pat, template in _RULES:
        if re.search(pat, path_s):
            spec = [None] * (len(shape) - len(template)) + list(template)
            # divisor rule: drop axes that don't divide the dim (or trivial
            # size-1 axes — sharding there is replication with extra noise)
            out = []
            for dim, ax in zip(shape, spec):
                if ax == "model":
                    ax = tensor_axes
                n = _axes_size(mesh, ax) if ax is not None else 1
                if ax is not None and n > 1 and dim % n == 0:
                    out.append(ax)
                else:
                    out.append(None)
            return P(*out)
    return P()  # replicated (norms, biases, scalars)


def param_specs(params_shape, mesh: Mesh, *, tensor_axes="model"):
    """Pytree of PartitionSpecs mirroring a params pytree (arrays or
    ShapeDtypeStructs)."""
    leaves, treedef = tree_flatten_with_path(params_shape)
    specs = [_spec_for(_path_str(p), tuple(x.shape), mesh, tensor_axes)
             for p, x in leaves]
    return tree_unflatten(treedef, specs)


def param_shardings(params_shape, mesh: Mesh, *, tensor_axes="model"):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_shape, mesh,
                                    tensor_axes=tensor_axes))


def fsdp_param_specs(params_shape, mesh: Mesh, *, axis: str = "model",
                     min_size: int = 1 << 16):
    """FSDP/ZeRO-3 layout: shard the largest divisible dim of every big param
    over ``axis``; activations stay batch-sharded over data. GSPMD then
    all-gathers each weight at its use — for small dense models at TP=16 the
    weight all-gathers are far cheaper than TP activation all-reduces
    (EXPERIMENTS.md §Perf E)."""
    n = mesh.shape.get(axis, 1)

    def spec(path, x):
        shape = tuple(x.shape)
        if n <= 1 or int(np.prod(shape)) < min_size:
            return P()
        dims = sorted(range(len(shape)), key=lambda i: -shape[i])
        for i in dims:
            if shape[i] % n == 0 and shape[i] >= n:
                out = [None] * len(shape)
                out[i] = axis
                return P(*out)
        return P()

    leaves, treedef = tree_flatten_with_path(params_shape)
    return tree_unflatten(treedef, [spec(p, x) for p, x in leaves])


def zero1_specs(params_shape, mesh: Mesh):
    """Optimizer-moment specs: the param spec with the first shardable
    replicated dim additionally sharded over ``data`` (ZeRO-1)."""
    dsize = mesh.shape.get("data", 1)

    def upgrade(path, x):
        base = _spec_for(_path_str(path), tuple(x.shape), mesh)
        spec = list(base) + [None] * (len(x.shape) - len(base))
        for i, (dim, ax) in enumerate(zip(x.shape, spec)):
            if ax is None and dim % dsize == 0 and dim >= dsize:
                spec[i] = "data"
                break
        return P(*spec)

    leaves, treedef = tree_flatten_with_path(params_shape)
    return tree_unflatten(treedef, [upgrade(p, x) for p, x in leaves])


# Cache specs: leaves are (L, B, C, KVe, hd) / (L, B, Di, N) / (L, B, cw-1, Di)
# / (C,) / scalar t.
def cache_specs(cache_shape, mesh: Mesh, *, tensor_axes="model"):
    baxes = batch_axes(mesh)
    if tensor_axes != "model":
        baxes = ()  # 2D tensor sharding consumes the data axes

    def mdl(dim: int):
        n = _axes_size(mesh, tensor_axes)
        return tensor_axes if n > 1 and dim % n == 0 else None

    def spec(path, x):
        name = _path_str(path).split("/")[-1]
        shp = x.shape
        if name in ("k", "v", "xk", "xv"):   # (L, B, C, KVe|KV, hd)
            return P(None, _maybe_batch(shp[1], baxes, mesh), None,
                     mdl(shp[3]), None)
        if name == "h":                      # (L, B, Di, N)
            return P(None, _maybe_batch(shp[1], baxes, mesh), mdl(shp[2]),
                     None)
        if name == "conv":                   # (L, B, cw-1, Di)
            return P(None, _maybe_batch(shp[1], baxes, mesh), None,
                     mdl(shp[3]))
        return P()                           # entry_pos, t

    leaves, treedef = tree_flatten_with_path(cache_shape)
    return tree_unflatten(treedef, [spec(p, x) for p, x in leaves])


def _maybe_batch(dim: int, baxes: tuple[str, ...], mesh: Mesh):
    n = int(np.prod([mesh.shape[a] for a in baxes])) if baxes else 1
    return baxes if n > 1 and dim % n == 0 else None


def batch_shardings(batch_shape, mesh: Mesh):
    """Input batch dict: leading dim shards over ('pod','data') when divisible."""
    baxes = batch_axes(mesh)

    def spec(x):
        lead = _maybe_batch(x.shape[0], baxes, mesh) if x.ndim else None
        return NamedSharding(mesh, P(lead, *([None] * (max(x.ndim, 1) - 1))))

    return jax.tree.map(spec, batch_shape)
