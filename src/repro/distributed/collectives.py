"""Compressed data-parallel gradient reduction (distributed-optimization trick).

Wraps a per-shard gradient function in ``jax.shard_map`` so the DP all-reduce is
explicit and can run at reduced precision:
  * ``bf16``: cast -> psum -> fp32 (half the DP wire bytes);
  * ``int8``: per-tensor max-scaled int8 quantization with a persistent
    error-feedback buffer (1/4 wire bytes, unbiased in the long run).

Only the *data* axes are manual here; the model axis stays under the usual pjit
partitioner (shard_map's auto axes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


def _psum_bf16(g: Array, axes) -> Array:
    return jax.lax.psum(g.astype(jnp.bfloat16), axes).astype(jnp.float32)


def _psum_int8(g: Array, err: Array, axes) -> tuple[Array, Array]:
    gf = g.astype(jnp.float32) + err
    # shared scale across the reduction group (one extra scalar pmax) so the
    # int8 sum is exact in scale; per-shard scales would inject O(scale
    # variance) error that even error feedback only fixes in expectation
    scale = jax.lax.pmax(jnp.max(jnp.abs(gf)), axes) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale   # error feedback
    summed = jax.lax.psum(q.astype(jnp.int32), axes).astype(jnp.float32)
    return summed * scale, new_err


def compressed_psum(grads, mode: str, axes, err_state=None):
    """psum a gradient pytree over data axes with optional compression.
    Returns (grads, new_err_state)."""
    if mode == "none":
        return jax.tree.map(lambda g: jax.lax.psum(g, axes), grads), err_state
    if mode == "bf16":
        return jax.tree.map(lambda g: _psum_bf16(g, axes), grads), err_state
    if mode == "int8":
        if err_state is None:
            raise ValueError("int8 compression needs an error-feedback state")
        out = jax.tree.map(lambda g, e: _psum_int8(g, e, axes), grads, err_state)
        new_grads = jax.tree.map(lambda t: t[0], out,
                                 is_leaf=lambda t: isinstance(t, tuple))
        new_err = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        return new_grads, new_err
    raise ValueError(f"unknown grad compression {mode!r}")


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
