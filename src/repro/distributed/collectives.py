"""Cross-shard reduction ops: compressed DP gradient psum + mergeable top-k.

Gradient leg: wraps a per-shard gradient function in ``jax.shard_map`` so the
DP all-reduce is explicit and can run at reduced precision:
  * ``bf16``: cast -> psum -> fp32 (half the DP wire bytes);
  * ``int8``: per-tensor max-scaled int8 quantization with a persistent
    error-feedback buffer (1/4 wire bytes, unbiased in the long run).

Only the *data* axes are manual here; the model axis stays under the usual pjit
partitioner (shard_map's auto axes).

Serving leg: ``merge_topk`` is the sharded query plane's reduction — an
associative, commutative merge of padded per-shard top-k partials
(``store.planner.TopKPartial`` layout), so S-shard answers reduce in any
grouping (pairwise tree across hosts, or one flat concat) to exactly the
single-shard ranking.  This is the reduction the multi-host transport
(``repro.transport``) rides: the ``TopKPartial`` arrays are the literal
wire payload of a worker's PARTIAL frame, and the associativity is what
lets a coordinator merge replies in whatever order workers answer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Array = jax.Array

TOPK_NEG_INF = np.float32(-np.inf)     # partial-row score padding


def merge_topk(scores_parts, ids_parts,
               top_k: int) -> tuple[np.ndarray, np.ndarray]:
    """Merge padded top-k partials from disjoint id sets into one partial.

    Each part is ``scores (Q, k_s) float32`` (``-inf`` = padding) plus
    ``ids (Q, k_s) int64`` (``-1`` = padding), rows ordered (score desc,
    id asc) — the ``QueryPlanner`` partial layout.  Selection here uses the
    same (score desc, id asc) order, which is exactly the single-shard
    planner's stable ranking (stable argsort over ascending union ids), so

        merge(shard partials) == single-shard top-k

    bit-for-bit.  The op is associative and commutative — parts may arrive
    in any order and merge in any grouping (a pairwise tree across hosts
    gives the same result as one flat concat) — because top-k under a strict
    total order is an associative reduction when id sets are disjoint.

    Returns ``(scores (Q, top_k), ids (Q, top_k))`` in partial layout.
    """
    scores = np.concatenate([np.asarray(s, np.float32)
                             for s in scores_parts], axis=1)
    ids = np.concatenate([np.asarray(i, np.int64)
                          for i in ids_parts], axis=1)
    q, m = scores.shape
    out_s = np.full((q, top_k), TOPK_NEG_INF, np.float32)
    out_i = np.full((q, top_k), -1, np.int64)
    if m == 0:
        return out_s, out_i
    take = min(top_k, m)
    # per-row lexsort: primary -score, secondary ascending id (padding rows
    # carry -inf scores and sink to the tail on their own)
    order = np.lexsort((ids, -scores))[:, :take]
    out_s[:, :take] = np.take_along_axis(scores, order, axis=1)
    out_i[:, :take] = np.take_along_axis(ids, order, axis=1)
    out_i[out_s <= TOPK_NEG_INF] = -1       # renormalize padding ids
    return out_s, out_i


def _psum_bf16(g: Array, axes) -> Array:
    return jax.lax.psum(g.astype(jnp.bfloat16), axes).astype(jnp.float32)


def _psum_int8(g: Array, err: Array, axes) -> tuple[Array, Array]:
    gf = g.astype(jnp.float32) + err
    # shared scale across the reduction group (one extra scalar pmax) so the
    # int8 sum is exact in scale; per-shard scales would inject O(scale
    # variance) error that even error feedback only fixes in expectation
    scale = jax.lax.pmax(jnp.max(jnp.abs(gf)), axes) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale   # error feedback
    summed = jax.lax.psum(q.astype(jnp.int32), axes).astype(jnp.float32)
    return summed * scale, new_err


def compressed_psum(grads, mode: str, axes, err_state=None):
    """psum a gradient pytree over data axes with optional compression.
    Returns (grads, new_err_state)."""
    if mode == "none":
        return jax.tree.map(lambda g: jax.lax.psum(g, axes), grads), err_state
    if mode == "bf16":
        return jax.tree.map(lambda g: _psum_bf16(g, axes), grads), err_state
    if mode == "int8":
        if err_state is None:
            raise ValueError("int8 compression needs an error-feedback state")
        out = jax.tree.map(lambda g, e: _psum_int8(g, e, axes), grads, err_state)
        new_grads = jax.tree.map(lambda t: t[0], out,
                                 is_leaf=lambda t: isinstance(t, tuple))
        new_err = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        return new_grads, new_err
    raise ValueError(f"unknown grad compression {mode!r}")


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
