"""ShardedSketchStore — the partitioned serving plane over SketchStore.

Items are partitioned across S shards, each shard a full single-host
``SketchStore`` (packed buffer + LSH table + planner).  A query batch is
folded to band hashes **once**, broadcast to every shard, and each shard
answers with a mergeable ``TopKPartial`` (candidate-restricted, local ids
mapped to global); ``distributed.collectives.merge_topk`` reduces the S
partials to the global top-k.  Because the merge order is the planner's own
(score desc, id asc) ranking, S-shard answers equal the single-shard store's
answers bit-for-bit on the same items (sole exception: the spill cap's
documented trade on oversized non-tied spilled groups, see
``BandedLSHTable.spilled_candidates``) — including the brute-force fallback:
a query row brute-forces only when it has no candidate in *any* shard (the
per-shard ``has_candidates`` votes are OR-reduced before the decision), and
the fallback leg is itself a per-shard brute partial + merge.

Partitioning: ``"round_robin"`` (global id mod S — balanced for streaming
ingest) or ``"hash"`` (Fibonacci-hash of the global id — stable placement
under resharding-style workflows).  Either way global ids are assigned in
arrival order (0..N-1), identical to the single-shard store, and each shard
keeps a local->global id map.  Both partitioners append gids in ascending
order, so a shard's local rank order IS its global id order — per-shard
score-tie breaks (smaller local id first) map to smaller-global-id first,
which is what makes the merge bit-exact.

This is single-process sharding with the multi-host seams explicit: the only
cross-shard traffic is the (Q, n_bands) hash broadcast out and (Q, top_k)
partials back, and ``merge_topk`` is associative, so S hosts reducing
pairwise over the wire compute exactly what S local shards reduce in a loop.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.lsh import band_hashes, band_hashes_packed
from repro.distributed.collectives import merge_topk
from repro.kernels import ops

from ._growth import grown
from .planner import TopKPartial, finalize_topk
from .store import SketchStore, StoreConfig

_GOLD = np.uint64(0x9E3779B97F4A7C15)    # Fibonacci hashing multiplier

PARTITIONS = ("round_robin", "hash")


class ShardedSketchStore:
    """S-way partitioned SketchStore with exact global top-k.

    ``n_shards=1`` degenerates to a thin wrapper over one ``SketchStore``
    (same ids, same scores, same fallback behavior), so serving configs keep
    a single code path and raise ``n_shards`` when one host's table or
    buffer stops fitting.
    """

    def __init__(self, cfg: StoreConfig, n_shards: int = 1, *,
                 partition: str = "round_robin", probe_impl: str = "auto"):
        if n_shards <= 0:
            raise ValueError("n_shards must be positive")
        if partition not in PARTITIONS:
            raise ValueError(f"partition must be one of {PARTITIONS} "
                             f"(got {partition!r})")
        self.cfg = cfg
        self.n_shards = n_shards
        self.partition = partition
        self.shards = [SketchStore(cfg, probe_impl=probe_impl)
                       for _ in range(n_shards)]
        # local->global id map per shard (amortized-doubling append buffer)
        self._gid_buf = [np.zeros(8, np.int64) for _ in range(n_shards)]
        self._gid_len = [0] * n_shards
        self.n_items = 0

    # -- sizing ------------------------------------------------------------
    @property
    def size(self) -> int:
        return self.n_items

    @property
    def n_spilled(self) -> int:
        return sum(s.n_spilled for s in self.shards)

    def shard_sizes(self) -> np.ndarray:
        return np.asarray([s.size for s in self.shards], np.int64)

    def _gids(self, shard: int) -> np.ndarray:
        return self._gid_buf[shard][: self._gid_len[shard]]

    # -- partitioning ------------------------------------------------------
    def _shard_of(self, gids: np.ndarray) -> np.ndarray:
        if self.partition == "round_robin":
            return gids % self.n_shards
        with np.errstate(over="ignore"):
            h = gids.astype(np.uint64) * _GOLD
        return ((h >> np.uint64(33)) % np.uint64(self.n_shards)) \
            .astype(np.int64)

    def _scatter(self, batch: np.ndarray, add_one) -> np.ndarray:
        """Assign global ids, route batch rows to shards, record the maps."""
        n = len(batch)
        gids = np.arange(self.n_items, self.n_items + n, dtype=np.int64)
        owner = self._shard_of(gids)
        for s in range(self.n_shards):
            sel = np.flatnonzero(owner == s)
            if not len(sel):
                continue
            add_one(self.shards[s], batch[sel])
            need = self._gid_len[s] + len(sel)
            self._gid_buf[s] = grown(self._gid_buf[s], need)
            self._gid_buf[s][self._gid_len[s]: need] = gids[sel]
            self._gid_len[s] = need
        self.n_items += n
        return gids

    # -- writes ------------------------------------------------------------
    def add(self, sigs: np.ndarray) -> np.ndarray:
        """Partition + index a (B, K) int32 signature batch; returns the
        global ids (assigned in arrival order, same as one SketchStore)."""
        return self._scatter(np.asarray(sigs), lambda sh, rows: sh.add(rows))

    def add_packed(self, words: np.ndarray) -> np.ndarray:
        """``add`` for (B, W) uint32 fused sign->pack words."""
        return self._scatter(np.asarray(words, np.uint32),
                             lambda sh, rows: sh.add_packed(rows))

    # -- reads -------------------------------------------------------------
    def _to_global(self, shard: int, part: TopKPartial) -> TopKPartial:
        """Map a shard partial's local ids to global ids.  The gid map is
        monotone (both partitioners append ascending gids), so rows stay in
        (score desc, id asc) order — no re-sort needed before the merge."""
        gid = self._gids(shard)
        if not len(gid):              # empty shard: partial is all padding
            return part
        hit = part.ids >= 0
        ids = np.where(hit, gid[np.where(hit, part.ids, 0)], np.int64(-1))
        return TopKPartial(ids, part.scores, part.has_candidates)

    def _merged_query(self, qwords: np.ndarray, shard_cands: list,
                      top_k: int) -> tuple[np.ndarray, np.ndarray]:
        """The shared scoring core: per-shard candidate partials -> merge ->
        global brute-force leg for rows with no candidates anywhere."""
        parts = [
            self._to_global(s, st.planner.partial_topk_packed(
                qwords, shard_cands[s], top_k))
            for s, st in enumerate(self.shards)
        ]
        has_any = np.zeros(len(qwords), bool)
        for p in parts:
            has_any |= p.has_candidates
        scores, ids = merge_topk([p.scores for p in parts],
                                 [p.ids for p in parts], top_k)
        em = np.flatnonzero(~has_any)
        if len(em) and self.n_items:
            brute = [
                self._to_global(s, st.planner.brute_partial_packed(
                    qwords[em], top_k))
                for s, st in enumerate(self.shards)
            ]
            b_scores, b_ids = merge_topk([p.scores for p in brute],
                                         [p.ids for p in brute], top_k)
            scores[em] = b_scores
            ids[em] = b_ids
        return finalize_topk(TopKPartial(ids, scores, has_any))

    def query(self, qsigs: np.ndarray,
              top_k: int = 10) -> tuple[np.ndarray, np.ndarray]:
        """(Q, K) signatures -> (ids (Q, top_k) [-1 pad], scores (Q, top_k)).

        Bit-identical to single-shard ``SketchStore.query`` on the same
        items, for any shard count and either partitioner."""
        self._check_queryable("query()")
        qsigs = np.asarray(qsigs)
        hashes = band_hashes(qsigs, self.cfg.n_bands, self.cfg.rows_per_band)
        cands = [st.candidate_rows_hashed(hashes, mode="sig",
                                          spill_cap=top_k)
                 for st in self.shards]
        qwords = np.asarray(ops.pack_codes(jnp.asarray(qsigs, jnp.int32),
                                           self.cfg.b))
        return self._merged_query(qwords, cands, top_k)

    def query_packed(self, qwords: np.ndarray,
                     top_k: int = 10) -> tuple[np.ndarray, np.ndarray]:
        """``query`` for already-packed (Q, W) uint32 query words."""
        self._check_queryable("query_packed()")
        qwords = np.asarray(qwords, np.uint32)
        self.shards[0]._check_packed_banding()
        hashes = band_hashes_packed(qwords, self.cfg.n_bands)
        cands = [st.candidate_rows_hashed(hashes, mode="packed",
                                          spill_cap=top_k)
                 for st in self.shards]
        return self._merged_query(qwords, cands, top_k)

    def _check_queryable(self, op: str) -> None:
        if not self.cfg.store_signatures:
            raise RuntimeError(f"{op} needs stored signatures; this store "
                               "was built with store_signatures=False")

    def candidate_pairs(self) -> np.ndarray:
        """Dedup-path pairs — single-shard only: a partitioned index never
        co-buckets items from different shards, so cross-shard pairs would
        be silently missed.  Run dedup on a 1-shard store."""
        if self.n_shards != 1:
            raise NotImplementedError(
                "candidate_pairs() is exact only at n_shards=1 (cross-shard "
                "pairs never share a shard-local bucket); run dedup on a "
                "single-shard store")
        return self.shards[0].candidate_pairs()
