"""ShardedSketchStore — the partitioned serving plane over SketchStore.

Items are partitioned across S shards, each shard a full single-host
``SketchStore`` (packed buffer + LSH table + planner).  A query batch is
folded to band hashes **once**, broadcast to every shard, and each shard
answers with a mergeable ``TopKPartial`` (candidate-restricted, local ids
mapped to global); ``distributed.collectives.merge_topk`` reduces the S
partials to the global top-k.  Because the merge order is the planner's own
(score desc, id asc) ranking, S-shard answers equal the single-shard store's
answers bit-for-bit on the same items (sole exception: the spill cap's
documented trade on oversized non-tied spilled groups, see
``BandedLSHTable.spilled_candidates``) — including the brute-force fallback:
a query row brute-forces only when it has no candidate in *any* shard (the
per-shard ``has_candidates`` votes are OR-reduced before the decision), and
the fallback leg is itself a per-shard brute partial + merge.

Where a shard *lives* is behind the ``ShardBackend`` protocol:

  * ``InProcessShard`` — the shard's ``SketchStore`` in this process (the
    default; what PR 3 ran inline);
  * ``transport.client.RemoteShard`` — the same operations against a shard
    worker process over the framed TCP wire protocol.

The coordinator keeps only cfg + partition + gid maps and never scores
anything itself, so the two backends are interchangeable per shard and the
answers are bit-identical either way — the backend moves *where* the
per-shard legs run, never *what* they compute.  The query path is split
into ``start_query``/``start_brute`` (submit) and ``Pending.result()``
(gather) so remote shards all compute concurrently under the client's
fan-out loop; in-process shards evaluate lazily at gather time.

Partitioning: ``"round_robin"`` (global id mod S — balanced for streaming
ingest) or ``"hash"`` (Fibonacci-hash of the global id — stable placement
under resharding-style workflows).  Either way global ids are assigned in
arrival order (0..N-1), identical to the single-shard store, and each shard
keeps a local->global id map.  Both partitioners append gids in ascending
order, so a shard's local rank order IS its global id order — per-shard
score-tie breaks (smaller local id first) map to smaller-global-id first,
which is what makes the merge bit-exact.

``save``/``load`` snapshot the whole plane to a directory: one
``SketchStore`` npz per shard plus a manifest (cfg, n_shards, partition,
gid maps).  Shard workers boot from the same per-shard files
(``transport.server.spawn_workers(snapshot_dir=...)``), and ``load`` with
remote backends restores just the coordinator state.
"""

from __future__ import annotations

import json
import os
import time
from typing import Protocol

import jax.numpy as jnp
import numpy as np

from repro.core.lsh import band_hashes, band_hashes_packed
from repro.distributed.collectives import merge_topk
from repro.kernels import ops
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from ._growth import grown
from .planner import TopKPartial, finalize_topk
from .store import SketchStore, StoreConfig, check_packed_banding

_GOLD = np.uint64(0x9E3779B97F4A7C15)    # Fibonacci hashing multiplier

PARTITIONS = ("round_robin", "hash")

MANIFEST_FILE = "manifest.npz"


def shard_snapshot_path(dirpath: str, shard: int) -> str:
    """Per-shard ``SketchStore`` snapshot inside a plane snapshot dir."""
    return os.path.join(dirpath, f"shard_{shard}.npz")


def shard_partial_hist_name(shard: int) -> str:
    """Registry name of shard ``i``'s reply-latency histogram — the
    per-shard skew signal.  The transport's hedge delay derives from the
    same observation stream (``FanoutGroup`` keeps a private per-connection
    copy so co-resident planes can't pollute each other's signal); bench
    and ops tooling read the registry histograms by this name."""
    return f"query.shard{shard}.partial"


# -- the backend seam ---------------------------------------------------------

class Pending(Protocol):
    """Handle for one submitted per-shard query leg."""

    def result(self) -> TopKPartial: ...


class ShardBackend(Protocol):
    """One shard of the serving plane, wherever it lives.

    The contract mirrors what the coordinator needs and nothing more:
    writes route a partitioned batch (local ids are assigned worker-side in
    arrival order, exactly like ``SketchStore``) and are a submit/gather
    pair like queries (``start_add``) so S shards index concurrently;
    queries are a submit/gather pair so S shards can compute concurrently,
    and partials come back in local ids (the coordinator owns the gid
    maps).
    """

    def add(self, sigs: np.ndarray) -> int: ...
    def add_packed(self, words: np.ndarray) -> int: ...
    def start_add(self, batch: np.ndarray, *, packed: bool) -> Pending: ...
    def start_query(self, hashes: np.ndarray, qwords: np.ndarray,
                    top_k: int, mode: str) -> Pending: ...
    def start_brute(self, qwords: np.ndarray, top_k: int) -> Pending: ...
    def stats(self) -> dict: ...
    def save(self, path: str) -> None: ...
    def close(self) -> None: ...


class _Lazy:
    """In-process Pending: evaluate at gather time (mirrors the remote
    submit/gather split so fan-out timing buckets mean the same thing).

    ``lazy = True`` is the write path's no-work-until-read guarantee: a
    lazy ADD pending that is never gathered provably never touched its
    store (a remote pending's work runs worker-side whether or not the
    reply is read) — ``_scatter`` uses this to keep a clean first failure
    from poisoning the plane."""

    lazy = True

    def __init__(self, fn):
        self._fn = fn
        self.latency_s: float | None = None     # thunk runtime, once gathered

    def result(self) -> TopKPartial:
        t0 = time.perf_counter()
        try:
            return self._fn()
        finally:
            self.latency_s = time.perf_counter() - t0


class InProcessShard:
    """``ShardBackend`` over a local ``SketchStore`` (the classic path)."""

    def __init__(self, cfg: StoreConfig | None = None, *,
                 probe_impl: str | None = None,
                 query_impl: str | None = None,
                 store: SketchStore | None = None):
        if store is None:
            if cfg is None:
                raise ValueError("InProcessShard needs cfg or store")
            store = SketchStore(cfg, probe_impl=probe_impl or "auto",
                                query_impl=query_impl or "auto")
        else:                            # never clobber a configured store
            if probe_impl is not None:
                store.probe_impl = probe_impl
            if query_impl is not None:
                store.query_impl = query_impl
        self.store = store

    def _add(self, fn, batch) -> int:
        # tag exceptions that left the store partially mutated (append
        # landed, insert raised) so _scatter knows a retry would duplicate
        before = (self.store.size, self.store.table.n_items)
        try:
            return len(fn(batch))
        except BaseException as e:
            if (self.store.size, self.store.table.n_items) != before:
                e.dirty = True
            raise

    def add(self, sigs: np.ndarray) -> int:
        return self._add(self.store.add, sigs)

    def add_packed(self, words: np.ndarray) -> int:
        return self._add(self.store.add_packed, words)

    def start_add(self, batch: np.ndarray, *, packed: bool = False) -> _Lazy:
        # routes through self.add/add_packed (not the store directly) so
        # subclass overrides keep intercepting the write path
        fn = self.add_packed if packed else self.add
        return _Lazy(lambda: fn(batch))

    def start_query(self, hashes: np.ndarray, qwords: np.ndarray,
                    top_k: int, mode: str) -> _Lazy:
        # the store routes to the fused device pipeline or the legacy host
        # walk per its query_impl knob — bit-identical either way
        return _Lazy(lambda: self.store.partial_topk_packed_hashed(
            hashes, qwords, top_k, mode=mode))

    def start_brute(self, qwords: np.ndarray, top_k: int) -> _Lazy:
        return _Lazy(lambda: self.store.planner.brute_partial_packed(
            qwords, top_k))

    def stats(self) -> dict:
        from repro.kernels.dispatch import select_probe_impl, \
            select_query_impl
        impl = self.store.probe_impl
        if impl == "auto":                   # report what auto resolves to
            impl = select_probe_impl()
        qimpl = self.store.query_impl
        if qimpl == "auto":
            qimpl = select_query_impl()
        return {"size": self.store.size, "n_spilled": self.store.n_spilled,
                "n_rebuilds": self.store.n_rebuilds, "probe_impl": impl,
                "query_impl": qimpl}

    def save(self, path: str) -> None:
        self.store.save(path)

    def close(self) -> None:
        pass


class ShardedSketchStore:
    """S-way partitioned SketchStore with exact global top-k.

    ``n_shards=1`` degenerates to a thin wrapper over one ``SketchStore``
    (same ids, same scores, same fallback behavior), so serving configs keep
    a single code path and raise ``n_shards`` when one host's table or
    buffer stops fitting.  Pass ``backends`` (e.g. ``RemoteShard``s from
    ``transport.client``) to run the same plane over shard worker
    processes; the default builds ``InProcessShard``s.
    """

    def __init__(self, cfg: StoreConfig, n_shards: int = 1, *,
                 partition: str = "round_robin", probe_impl: str = "auto",
                 query_impl: str = "auto", backends: list | None = None):
        if backends is not None:
            if not backends:
                raise ValueError("backends must be non-empty")
            n_shards = len(backends)
        if n_shards <= 0:
            raise ValueError("n_shards must be positive")
        if partition not in PARTITIONS:
            raise ValueError(f"partition must be one of {PARTITIONS} "
                             f"(got {partition!r})")
        self.cfg = cfg
        self.n_shards = n_shards
        self.partition = partition
        # fused-query knob: shards apply it to their probe+score legs; the
        # coordinator applies it to its one broadcast fold (remote backends
        # got their own copy at spawn time — see transport.server)
        self.query_impl = query_impl
        self.shards = backends if backends is not None else [
            InProcessShard(cfg, probe_impl=probe_impl, query_impl=query_impl)
            for _ in range(n_shards)]
        # local->global id map per shard (amortized-doubling append buffer)
        self._gid_buf = [np.zeros(8, np.int64) for _ in range(n_shards)]
        self._gid_len = [0] * n_shards
        self.n_items = 0
        # wall-time split of the last query: submit/serialize (broadcast),
        # per-shard partial compute + gather (partial), reduction (merge)
        self.last_timings: dict[str, float] = {}
        # set when a partial write left coordinator/worker state divergent
        self._failed: str | None = None
        # registry handles bound once; per-shard partial-latency histograms
        # are the skew evidence load-aware rebalancing will consume
        reg = obs_metrics.default()
        self._h_fold = reg.histogram("query.fold")
        self._h_broadcast = reg.histogram("query.broadcast")
        self._h_partial = reg.histogram("query.partial")
        self._h_merge = reg.histogram("query.merge")
        self._h_query = reg.histogram("query.wall")
        self._h_shard = [reg.histogram(shard_partial_hist_name(i))
                         for i in range(n_shards)]
        self._tracer = obs_trace.default()

    # -- sizing ------------------------------------------------------------
    @property
    def size(self) -> int:
        return self.n_items

    @property
    def n_spilled(self) -> int:
        return sum(s.stats()["n_spilled"] for s in self.shards)

    def shard_sizes(self) -> np.ndarray:
        return np.asarray([s.stats()["size"] for s in self.shards], np.int64)

    def obs_snapshot(self) -> dict:
        """One merged registry snapshot for the whole plane: the
        coordinator's own registry plus every remote worker's (the ``obs``
        JSON in their STATS replies), reduced with ``merge_snapshots`` —
        the same exact associative reduction ``merge_topk`` does for
        scores.  In-process shards already share the coordinator's
        registry, so their stats carry no ``obs`` and nothing is counted
        twice.

        Every worker snapshot is merged twice: raw on the shard's FIRST
        lane only (plane-wide totals keep meaning "one lane per shard" at
        any replication factor, so dashboards and the existing assertions
        survive R>1 unchanged) and under a ``shard{i}.replica{r}.`` prefix
        for every lane (``label_snapshot``) — the provenance a failover
        investigation needs to see which replica's counters moved.
        Backends exposing ``stats_all`` (replica sets) contribute one
        labelled snapshot per live lane; plain backends are lane
        ``replica 0`` of their shard."""
        snaps = [obs_metrics.default().snapshot()]
        for i, sh in enumerate(self.shards):
            stats_all = getattr(sh, "stats_all", None)
            per_lane = stats_all() if stats_all is not None \
                else [(0, sh.stats())]
            for k, (r, stats) in enumerate(per_lane):
                blob = stats.get("obs")
                if not blob:
                    continue
                snap = json.loads(blob) if isinstance(blob, str) else blob
                if k == 0:
                    snaps.append(snap)
                snaps.append(obs_metrics.label_snapshot(
                    snap, f"shard{i}.replica{r}."))
        return obs_metrics.merge_snapshots(*snaps)

    def _gids(self, shard: int) -> np.ndarray:
        return self._gid_buf[shard][: self._gid_len[shard]]

    # -- partitioning ------------------------------------------------------
    def _shard_of(self, gids: np.ndarray) -> np.ndarray:
        if self.partition == "round_robin":
            return gids % self.n_shards
        with np.errstate(over="ignore"):
            h = gids.astype(np.uint64) * _GOLD
        return ((h >> np.uint64(33)) % np.uint64(self.n_shards)) \
            .astype(np.int64)

    def _check_consistent(self) -> None:
        if self._failed:
            raise RuntimeError(
                f"plane is inconsistent after a failed add ({self._failed}); "
                "rebuild it or reload from the last snapshot")

    def _scatter(self, batch: np.ndarray, *, packed: bool) -> np.ndarray:
        """Assign global ids, fan batch slices out to all shards, record
        the maps.

        Writes fan out like queries: every shard's slice is submitted first
        (``start_add``), then gathered — remote shards index concurrently
        over the wire instead of one blocking request per shard, which is
        what closes the tcp-vs-inproc build gap.

        A batch is all-or-nothing at the coordinator: if any shard indexed
        its slice while another failed, or a failing shard reports a
        partial write (``e.dirty``), or the fan-out broke after frames hit
        the wire (``e.unknown_outcome`` — nobody can prove which workers
        processed their slice), retrying would re-issue the same gids and
        duplicate rows — so the plane is marked inconsistent and refuses
        further writes and reads instead of silently double-indexing.  A
        failure that provably left every shard unwritten (validation
        ERROR replies, a submit-phase failure before any frame was sent,
        an in-process exception with no earlier shard evaluated) leaves
        the plane usable.
        """
        self._check_consistent()
        n = len(batch)
        gids = np.arange(self.n_items, self.n_items + n, dtype=np.int64)
        owner = self._shard_of(gids)
        # submit phase: remote backends only queue frames here (the first
        # gather drives the sockets), in-process backends build thunks — a
        # submit failure abandons the queued round before anything is sent,
        # so the plane stays usable
        pend = []
        for s in range(self.n_shards):
            sel = np.flatnonzero(owner == s)
            if len(sel):
                pend.append((s, sel,
                             self.shards[s].start_add(batch[sel],
                                                      packed=packed)))
        # gather phase: consume EVERY pending (remote slices run worker-side
        # whether or not their reply is read), then decide poisoning from
        # the full outcome set.  Lazy in-process pendings after a failure
        # are skipped — never evaluated, provably never written.
        wrote_any = False
        sure_clean = True       # every failure provably left stores unwritten
        first_err: BaseException | None = None
        for s, sel, p in pend:
            if first_err is not None and getattr(p, "lazy", False):
                continue
            try:
                added = p.result()
                wrote_any = True
                if added != len(sel):
                    raise RuntimeError(
                        f"shard {s} indexed {added} of {len(sel)} rows")
            except BaseException as e:
                if getattr(e, "dirty", False) or \
                        getattr(e, "unknown_outcome", False):
                    sure_clean = False
                if first_err is None:
                    first_err = e
                continue
            need = self._gid_len[s] + len(sel)
            self._gid_buf[s] = grown(self._gid_buf[s], need)
            self._gid_buf[s][self._gid_len[s]: need] = gids[sel]
            self._gid_len[s] = need
        if first_err is not None:
            if wrote_any or not sure_clean:
                self._failed = f"{type(first_err).__name__} mid-batch"
            raise first_err
        self.n_items += n
        return gids

    # -- writes ------------------------------------------------------------
    def add(self, sigs: np.ndarray) -> np.ndarray:
        """Partition + index a (B, K) int32 signature batch; returns the
        global ids (assigned in arrival order, same as one SketchStore)."""
        return self._scatter(np.asarray(sigs), packed=False)

    def add_packed(self, words: np.ndarray) -> np.ndarray:
        """``add`` for (B, W) uint32 fused sign->pack words."""
        return self._scatter(np.asarray(words, np.uint32), packed=True)

    # -- reads -------------------------------------------------------------
    def _to_global(self, shard: int, part: TopKPartial) -> TopKPartial:
        """Map a shard partial's local ids to global ids.  The gid map is
        monotone (both partitioners append ascending gids), so rows stay in
        (score desc, id asc) order — no re-sort needed before the merge."""
        gid = self._gids(shard)
        if not len(gid):              # empty shard: partial is all padding
            return part
        hit = part.ids >= 0
        ids = np.where(hit, gid[np.where(hit, part.ids, 0)], np.int64(-1))
        return TopKPartial(ids, part.scores, part.has_candidates)

    def _fanout(self, start, tally: dict) -> list[TopKPartial]:
        """One submit/gather round over all shards, timed into ``tally``.

        Per-shard reply latencies land in the ``query.shard{i}.partial``
        histograms: for remote backends the offset from fan-out start to
        that shard's reply frame completing, for in-process backends the
        thunk runtime — either way, how long shard i made the round wait.
        The broadcast span is ambient while legs are submitted, so remote
        workers' spans nest under it in the stitched trace.
        """
        t0 = time.perf_counter()
        with self._tracer.span("query.broadcast"):
            pend = [start(sh) for sh in self.shards]
        t1 = time.perf_counter()
        with self._tracer.span("query.partial"):
            parts = [self._to_global(s, p.result())
                     for s, p in enumerate(pend)]
        t2 = time.perf_counter()
        tally["broadcast_s"] += t1 - t0
        tally["partial_s"] += t2 - t1
        self._h_broadcast.observe(t1 - t0)
        self._h_partial.observe(t2 - t1)
        for s, p in enumerate(pend):
            lat = getattr(p, "latency_s", None)
            if lat is not None:
                self._h_shard[s].observe(lat)
        return parts

    def _merged_query(self, hashes: np.ndarray, qwords: np.ndarray,
                      top_k: int, mode: str, fold_s: float = 0.0,
                      ) -> tuple[np.ndarray, np.ndarray]:
        """The shared scoring core: per-shard candidate partials -> merge ->
        global brute-force leg for rows with no candidates anywhere.
        ``fold_s`` is the caller's already-spent band-hash fold time, folded
        into the timing split so every query stage is accounted for."""
        wall_t0 = time.perf_counter()
        tally = {"fold_s": fold_s, "broadcast_s": 0.0, "partial_s": 0.0,
                 "merge_s": 0.0}
        self._h_fold.observe(fold_s)
        parts = self._fanout(
            lambda sh: sh.start_query(hashes, qwords, top_k, mode), tally)
        has_any = np.zeros(len(qwords), bool)
        for p in parts:
            has_any |= p.has_candidates
        t0 = time.perf_counter()
        with self._tracer.span("query.merge"):
            scores, ids = merge_topk([p.scores for p in parts],
                                     [p.ids for p in parts], top_k)
        tally["merge_s"] += time.perf_counter() - t0
        em = np.flatnonzero(~has_any)
        if len(em) and self.n_items:
            brute = self._fanout(
                lambda sh: sh.start_brute(qwords[em], top_k), tally)
            t0 = time.perf_counter()
            with self._tracer.span("query.merge"):
                b_scores, b_ids = merge_topk([p.scores for p in brute],
                                             [p.ids for p in brute], top_k)
            scores[em] = b_scores
            ids[em] = b_ids
            tally["merge_s"] += time.perf_counter() - t0
        self.last_timings = tally
        self._h_merge.observe(tally["merge_s"])
        self._h_query.observe(time.perf_counter() - wall_t0)
        return finalize_topk(TopKPartial(ids, scores, has_any))

    def query(self, qsigs: np.ndarray,
              top_k: int = 10) -> tuple[np.ndarray, np.ndarray]:
        """(Q, K) signatures -> (ids (Q, top_k) [-1 pad], scores (Q, top_k)).

        Bit-identical to single-shard ``SketchStore.query`` on the same
        items, for any shard count, either partitioner, and either
        backend."""
        self._check_queryable("query()")
        qsigs = np.asarray(qsigs)
        # store.query is the root when nobody upstream opened one (a direct
        # store caller still gets one stitched trace); under the service's
        # "query" span it just nests
        with self._tracer.span("store.query"):
            t0 = time.perf_counter()
            with self._tracer.span("query.fold"):
                hashes = band_hashes(qsigs, self.cfg.n_bands,
                                     self.cfg.rows_per_band)
                qwords = np.asarray(
                    ops.pack_codes(jnp.asarray(qsigs, jnp.int32), self.cfg.b))
            return self._merged_query(hashes, qwords, top_k, "sig",
                                      fold_s=time.perf_counter() - t0)

    def query_packed(self, qwords: np.ndarray,
                     top_k: int = 10) -> tuple[np.ndarray, np.ndarray]:
        """``query`` for already-packed (Q, W) uint32 query words.

        The coordinator folds band hashes ONCE for the whole plane; per the
        ``query_impl`` knob that fold runs through the device uint32-lane
        kernel (``dispatch.fold_hashes``, bit-identical) or the host uint64
        loop.  A device-resident query batch (the fused serving path) is
        folded as-is — the one host sync is the broadcast copy the wire
        needs anyway."""
        self._check_queryable("query_packed()")
        check_packed_banding(self.cfg)
        with self._tracer.span("store.query"):
            t0 = time.perf_counter()
            with self._tracer.span("query.fold"):
                hashes = self._fold_packed(qwords)
            fold_s = time.perf_counter() - t0
            qwords = np.asarray(qwords, np.uint32)
            return self._merged_query(hashes, qwords, top_k, "packed",
                                      fold_s=fold_s)

    def _fold_packed(self, qwords) -> np.ndarray:
        impl = self.query_impl
        if impl == "auto":
            from repro.kernels.dispatch import select_query_impl
            impl = select_query_impl()
        if impl != "host":
            from repro.kernels.dispatch import fold_hashes
            return fold_hashes(qwords, n_bands=self.cfg.n_bands, impl=impl)
        return band_hashes_packed(np.asarray(qwords, np.uint32),
                                  self.cfg.n_bands)

    def _check_queryable(self, op: str) -> None:
        self._check_consistent()
        if not self.cfg.store_signatures:
            raise RuntimeError(f"{op} needs stored signatures; this store "
                               "was built with store_signatures=False")

    def candidate_pairs(self) -> np.ndarray:
        """Dedup-path pairs — single-shard only: a partitioned index never
        co-buckets items from different shards, so cross-shard pairs would
        be silently missed.  Run dedup on a 1-shard store."""
        if self.n_shards != 1:
            raise NotImplementedError(
                "candidate_pairs() is exact only at n_shards=1 (cross-shard "
                "pairs never share a shard-local bucket); run dedup on a "
                "single-shard store")
        if not isinstance(self.shards[0], InProcessShard):
            raise NotImplementedError(
                "candidate_pairs() needs the shard's table in-process; "
                "load the snapshot into an InProcessShard store for dedup")
        return self.shards[0].store.candidate_pairs()

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        """Release backend resources (sockets for remote shards)."""
        for sh in self.shards:
            sh.close()

    # -- snapshots ---------------------------------------------------------
    def save(self, dirpath: str) -> None:
        """Snapshot the plane: per-shard ``SketchStore`` npz + manifest.

        Remote backends write their shard file worker-side (same filesystem
        in the localhost deployment); the manifest (cfg, partition, gid
        maps) is always written here, since only the coordinator has it.
        """
        self._check_consistent()
        os.makedirs(dirpath, exist_ok=True)
        for i, sh in enumerate(self.shards):
            sh.save(shard_snapshot_path(dirpath, i))
        ints, thr = self.cfg.to_manifest()
        gids = {f"gids_{i}": self._gids(i) for i in range(self.n_shards)}
        np.savez(os.path.join(dirpath, MANIFEST_FILE),
                 n_shards=self.n_shards, n_items=self.n_items,
                 partition=self.partition, cfg=ints, cfg_thresholds=thr,
                 **gids)

    @classmethod
    def load(cls, dirpath: str, *, backends: list | None = None,
             probe_impl: str = "auto",
             query_impl: str = "auto") -> "ShardedSketchStore":
        """Restore a plane snapshot.

        Default: every shard is loaded into an ``InProcessShard``.  With
        ``backends`` (remote shards already booted from the same snapshot
        via ``spawn_workers(snapshot_dir=...)``), only the coordinator
        state — cfg, partition, gid maps — is restored here.
        """
        with np.load(os.path.join(dirpath, MANIFEST_FILE)) as z:
            n_shards = int(z["n_shards"])
            n_items = int(z["n_items"])
            partition = str(z["partition"])
            cfg = StoreConfig.from_manifest(z["cfg"], z["cfg_thresholds"])
            gids = [np.asarray(z[f"gids_{i}"], np.int64)
                    for i in range(n_shards)]
        if backends is None:
            backends = [
                InProcessShard(store=SketchStore.load(
                    shard_snapshot_path(dirpath, i)), probe_impl=probe_impl,
                    query_impl=query_impl)
                for i in range(n_shards)]
        elif len(backends) != n_shards:
            raise ValueError(f"snapshot has {n_shards} shards, got "
                             f"{len(backends)} backends")
        store = cls(cfg, n_shards, partition=partition, backends=backends,
                    query_impl=query_impl)
        for i, g in enumerate(gids):
            store._gid_buf[i] = grown(store._gid_buf[i], len(g))
            store._gid_buf[i][: len(g)] = g
            store._gid_len[i] = len(g)
        store.n_items = n_items
        return store
