"""Vectorized banded LSH table: fixed-capacity open-addressing bucket arrays.

Replaces the per-item ``defaultdict`` bucketing that made index build and
candidate generation O(N * n_bands) Python dict operations.  Each band is an
open-addressing array of fused bucket records:

    records (n_bands, n_slots, 2 + bucket_width)  int32

where ``records[b, s, :2]`` holds the two halves of the uint64 band hash that
owns slot ``s`` (both -1 = unused) and ``records[b, s, 2:]`` holds the posting
item ids (-1 padded).  Fusing key and postings means a query probe costs ONE
gather — key compare and candidate ids come from the same cache line, which
is what makes batched candidate generation beat dict probing by >5x.

Quadratic (triangular) probing bounded by ``max_probes`` resolves hash->slot;
inserts are batched (all B * n_bands entries probe simultaneously, one
vectorized pass per probe distance) and lookups are early-terminating gathers
with no per-item Python.  Entries that cannot be placed (probe chain
exhausted, or bucket full) go to a spill list; ``rebuild()`` reallocates at
larger geometry and replays every recorded band hash, draining the spill.

The all-ones hash value doubles as the empty-slot sentinel; entries hashing
to it (P ~ 2^-64) are routed to the spill list, so exactness is preserved.
"""

from __future__ import annotations

import numpy as np

# probe chain + empty-slot sentinel are owned by the probe-kernel module so
# the host walk and the device impls can never diverge
from repro.kernels.lsh_probe import SENTINEL_KEY, probe_offset  # noqa: F401
from repro.obs import metrics as obs_metrics

from ._growth import grown

_HASH_BUF_MIN = 64


def _halves(keys: np.ndarray) -> np.ndarray:
    """(E,) uint64 -> (E, 2) int32 bit-pattern halves (native endianness)."""
    return np.ascontiguousarray(keys).view(np.int32).reshape(-1, 2)


class BandedLSHTable:
    @staticmethod
    def _offset(t: int) -> int:
        """The shared quadratic probe chain (kernels.lsh_probe.probe_offset).
        Insert and lookup walk the same sequence, and slots are never freed,
        so stop-at-first-unused stays a correct absence test."""
        return probe_offset(t)

    def __init__(self, n_bands: int, n_slots: int = 2048,
                 bucket_width: int = 8, max_probes: int = 16):
        if n_slots <= 0 or bucket_width <= 0 or max_probes <= 0:
            raise ValueError("n_slots, bucket_width, max_probes must be > 0")
        self.n_bands = n_bands
        self.n_slots = n_slots
        self.bucket_width = bucket_width
        self.max_probes = max_probes
        # registry handles bound once per table; occupancy gauges report
        # DELTAS (new - last reported) so N tables in one process sum to a
        # process total — the same additive semantics gauge merges use
        reg = obs_metrics.default()
        self._c_spill_probe = reg.counter("table.spill.probe")
        self._c_spill_overflow = reg.counter("table.spill.overflow")
        self._h_probe_depth = reg.histogram("table.probe_depth")
        self._g_used = reg.gauge("table.used_slots")
        self._g_capacity = reg.gauge("table.capacity")
        self._rep_used = 0
        self._rep_capacity = 0
        self._alloc()
        # replay log for rebuild(): every inserted (item, band) hash
        self._hashes = np.zeros((_HASH_BUF_MIN, n_bands), np.uint64)
        self.n_items = 0

    def _alloc(self) -> None:
        nb, ns, w = self.n_bands, self.n_slots, self.bucket_width
        self._records_version = getattr(self, "_records_version", 0) + 1
        self._dev_records = None          # (version, jax array) upload cache
        self.records = np.full((nb, ns, 2 + w), -1, np.int32)
        self.counts = np.zeros((nb, ns), np.int32)
        # spill storage: amortized-doubling buffers (appends are in-place)
        self._sb_buf = np.zeros(_HASH_BUF_MIN, np.int32)
        self._sk_buf = np.zeros(_HASH_BUF_MIN, np.uint64)
        self._si_buf = np.zeros(_HASH_BUF_MIN, np.int64)
        self._spill_len = 0
        self._used_slots = 0        # incremental; avoids used.sum() scans
        self.n_spill_probe = 0      # probe chain exhausted (table too full)
        self.n_spill_overflow = 0   # bucket full (width too small)
        self._g_capacity.add(nb * ns - self._rep_capacity)
        self._rep_capacity = nb * ns
        self._g_used.add(-self._rep_used)      # fresh arrays: nothing used
        self._rep_used = 0

    @property
    def _spill_band(self) -> np.ndarray:
        return self._sb_buf[: self._spill_len]

    @property
    def _spill_key(self) -> np.ndarray:
        return self._sk_buf[: self._spill_len]

    @property
    def _spill_id(self) -> np.ndarray:
        return self._si_buf[: self._spill_len]

    # -- stats -------------------------------------------------------------
    @property
    def n_spilled(self) -> int:
        return len(self._spill_id)

    @property
    def load_factor(self) -> float:
        return self._used_slots / (self.n_bands * self.n_slots)

    def spilled_ids(self) -> np.ndarray:
        return np.unique(self._spill_id)

    # -- insert ------------------------------------------------------------
    def insert(self, hashes: np.ndarray, ids: np.ndarray) -> None:
        """Insert a batch: hashes (B, n_bands) uint64, ids (B,) item ids.

        Ids must be contiguous and append-ordered (``n_items .. n_items+B``):
        ``rebuild()`` replays the hash log with ``arange`` ids, so anything
        else would be silently renumbered on the first rebuild."""
        hashes = np.asarray(hashes, np.uint64)
        ids = np.asarray(ids, np.int64)
        b = hashes.shape[0]
        if hashes.shape != (b, self.n_bands) or ids.shape != (b,):
            raise ValueError("hashes must be (B, n_bands), ids (B,)")
        if b and not np.array_equal(
                ids, np.arange(self.n_items, self.n_items + b)):
            raise ValueError(
                f"ids must be contiguous append order "
                f"[{self.n_items}, {self.n_items + b}) — rebuild() replays "
                f"the hash log with arange ids")
        need = self.n_items + b
        self._hashes = grown(self._hashes, need)
        self._hashes[self.n_items: need] = hashes
        self.n_items = need
        self._insert(hashes, ids)

    def _insert(self, hashes: np.ndarray, ids: np.ndarray) -> None:
        """Batched probe-and-place, compacted per probe step.

        All B * n_bands entries probe simultaneously, one vectorized pass
        per probe distance — and entries that land (claim a slot or match
        their key's bucket) are dropped from the working set before the next
        pass, so pass t costs O(still-unplaced), not O(B * n_bands).  At
        sane load factors pass 0 places the vast majority of entries and
        the total work is ~1.3x one pass over the batch, which is what
        makes one-shot index builds run at memory speed instead of
        max_probes full-batch sweeps.
        """
        self._records_version += 1        # records mutate: device copy stale
        nb, ns, w = self.n_bands, self.n_slots, self.bucket_width
        b = hashes.shape[0]
        ent_band = np.tile(np.arange(nb, dtype=np.int64), b)
        ent_key = hashes.reshape(-1)
        ent_id = np.repeat(ids, nb)
        flat = self.records.reshape(nb * ns, 2 + w)        # view

        # sentinel-valued hashes -> spill; everything else enters the probe
        # loop as the compacted working set (original entry order preserved,
        # so first-wins claims and bucket append order match the
        # one-entry-at-a-time semantics)
        live = np.flatnonzero(ent_key != SENTINEL_KEY)
        band, key, eid = ent_band[live], ent_key[live], ent_id[live]
        half = _halves(key)                            # (A, 2) int32 copy
        key64 = half.view(np.int64)[:, 0]              # bit pattern as int64
        base = (key % np.uint64(ns)).astype(np.int64)

        for t in range(self.max_probes):
            if not len(band):
                break
            slot = (base + self._offset(t)) % ns
            lin = band * ns + slot
            k64 = flat[lin, :2].view(np.int64)[:, 0]   # one gather: slot keys
            # claim empty slots: first unplaced entry per slot wins (keys are
            # never the all-ones sentinel here, so k64 == -1 <=> slot unused)
            cl = np.flatnonzero(k64 == -1)
            if len(cl):
                _, first = np.unique(lin[cl], return_index=True)
                winners = cl[first]
                wb, ws = band[winners], slot[winners]
                self.records[wb, ws, 0] = half[winners, 0]
                self.records[wb, ws, 1] = half[winners, 1]
                self._used_slots += len(winners)
                # re-read: winners + same-key entries land this probe step
                k64 = flat[lin, :2].view(np.int64)[:, 0]
            match = k64 == key64
            m = np.flatnonzero(match)
            if len(m):
                m = m[np.argsort(lin[m], kind="stable")]
                ls = lin[m]
                new_grp = np.r_[True, ls[1:] != ls[:-1]]
                grp_start = np.flatnonzero(new_grp)
                rank = np.arange(len(m)) - grp_start[np.cumsum(new_grp) - 1]
                pos = self.counts[band[m], slot[m]] + rank
                fits = pos < w
                f = m[fits]
                self.records[band[f], slot[f], 2 + pos[fits]] = \
                    eid[f].astype(np.int32)
                sizes = np.diff(np.r_[grp_start, len(m)])
                gb, gs = band[m[grp_start]], slot[m[grp_start]]
                self.counts[gb, gs] = np.minimum(
                    self.counts[gb, gs] + sizes, w).astype(np.int32)
                over = m[~fits]
                if len(over):
                    self._spill(band[over], key[over], eid[over])
                    self.n_spill_overflow += len(over)
                    self._c_spill_overflow.inc(len(over))
                keep = ~match
                band, key, eid = band[keep], key[keep], eid[keep]
                half, key64, base = half[keep], key64[keep], base[keep]

        if len(band):                      # probe chain exhausted
            self._spill(band, key, eid)
            self.n_spill_probe += len(band)
            self._c_spill_probe.inc(len(band))
        sent = np.flatnonzero(ent_key == SENTINEL_KEY)
        if len(sent):
            self._spill(ent_band[sent], ent_key[sent], ent_id[sent])
            self.n_spill_probe += len(sent)
            self._c_spill_probe.inc(len(sent))
        self._g_used.add(self._used_slots - self._rep_used)
        self._rep_used = self._used_slots

    def _spill(self, band, key, eid) -> None:
        need = self._spill_len + len(eid)
        self._sb_buf = grown(self._sb_buf, need)
        self._sk_buf = grown(self._sk_buf, need)
        self._si_buf = grown(self._si_buf, need)
        s = self._spill_len
        self._sb_buf[s: need] = band
        self._sk_buf[s: need] = key
        self._si_buf[s: need] = eid
        self._spill_len = need

    # -- lookup ------------------------------------------------------------
    def _find_slots(self, band: np.ndarray, key: np.ndarray) -> np.ndarray:
        """(E,) band, (E,) key -> (E,) slot index, or -1 when absent.

        Early-terminating probe: an entry stops at its key's slot or at the
        first unused slot (key absent), so the expected gather count per
        entry is ~1/(1 - load_factor), not max_probes."""
        ns = self.n_slots
        key = np.asarray(key, np.uint64)
        half = _halves(key)
        base = (key % np.uint64(ns)).astype(np.int64)
        slot = np.full(len(key), -1, np.int64)
        active = np.flatnonzero(key != SENTINEL_KEY)
        for t in range(self.max_probes):
            if not len(active):
                break
            s = (base[active] + self._offset(t)) % ns
            rec = self.records[band[active], s]            # (A, 2+W)
            hit = (rec[:, 0] == half[active, 0]) & \
                  (rec[:, 1] == half[active, 1])
            unused = (rec[:, 0] == -1) & (rec[:, 1] == -1)
            slot[active[hit]] = s[hit]
            active = active[~hit & ~unused]    # mismatched slot: keep probing
        return slot

    def device_records(self):
        """(n_bands * n_slots, 2 + W) int32 device copy of the fused records,
        cached by mutation version — the table uploads once per build/rebuild
        and query batches probe the resident copy (kernels/lsh_probe.py)."""
        import jax.numpy as jnp       # local: table stays numpy-importable
        cached = self._dev_records
        if cached is None or cached[0] != self._records_version:
            flat = self.records.reshape(-1, 2 + self.bucket_width)
            self._dev_records = (self._records_version, jnp.asarray(flat))
        return self._dev_records[1]

    def lookup(self, hashes: np.ndarray, *, impl: str = "numpy") -> np.ndarray:
        """(Q, n_bands) band hashes -> (Q, n_bands * bucket_width) candidate
        item ids, -1 padded.  One fused record gather per probe — key compare
        and posting ids share the cache line.  The batched hot path.

        ``impl`` selects the probe backend: ``"numpy"`` is this host loop
        (the CPU-tuned reference), ``"jnp"``/``"pallas"`` run the probe leg on
        device over ``device_records()`` via ``kernels.dispatch.lsh_probe``,
        and ``"auto"`` resolves by backend (device kernel on TPU, numpy
        otherwise).  All backends return identical candidates."""
        hashes = np.asarray(hashes, np.uint64)
        if impl != "numpy":
            from repro.kernels import dispatch
            if impl == "auto":
                impl = dispatch.select_probe_impl()
            if impl != "numpy":
                return dispatch.lsh_probe(
                    self.device_records(), hashes, n_slots=self.n_slots,
                    max_probes=self.max_probes, impl=impl)
        q, nb = hashes.shape
        ns, w = self.n_slots, self.bucket_width
        key = np.ascontiguousarray(hashes.reshape(-1))
        key64 = key.view(np.int64)                 # bit pattern as int64
        band_off = np.tile(np.arange(nb, dtype=np.int64) * ns, q)
        base = (key % np.uint64(ns)).astype(np.int64)
        flat = self.records.reshape(nb * ns, 2 + w)        # view
        # probe 0 resolves ~1/(1-load) of entries: build the result
        # contiguously (no fancy scatter), then chase the rare chains.
        # the adjacent key halves of a gathered record row read as one int64
        # (-1 = unused sentinel), so each probe is one gather + two compares
        rec = flat[band_off + base]                        # (E, 2+W) gather
        k64 = rec[:, :2].view(np.int64)[:, 0]
        hit = k64 == key64
        out = np.where(hit[:, None], rec[:, 2:], np.int32(-1))
        active = np.flatnonzero(~hit & (k64 != -1) & (key != SENTINEL_KEY))
        # probe-depth histogram: depth d = entries that needed d gathers
        # (the ~1/(1-load) expectation made measurable; bucket values are
        # small ints, not seconds, but the log buckets resolve 1..max_probes)
        n_act = len(active)
        if q * nb - n_act:
            self._h_probe_depth.observe_n(1.0, q * nb - n_act)
        for t in range(1, self.max_probes):
            if not len(active):
                break
            rec = flat[band_off[active] + (base[active] + self._offset(t)) % ns]
            k64 = rec[:, :2].view(np.int64)[:, 0]
            hit = k64 == key64[active]
            out[active[hit]] = rec[hit, 2:]
            active = active[~hit & (k64 != -1)]
            if n_act - len(active):
                self._h_probe_depth.observe_n(float(t + 1),
                                              n_act - len(active))
            n_act = len(active)
        if n_act:                       # chain exhausted: counted at the cap
            self._h_probe_depth.observe_n(float(self.max_probes), n_act)
        return out.reshape(q, nb * w)

    def spilled_candidates(self, hashes: np.ndarray, *,
                           cap: int | None = None) -> np.ndarray:
        """(Q, n_bands) band hashes -> (Q, M) spilled item ids whose recorded
        (band, key) matches the query, -1 padded, unique-per-row (an id
        spilled in several matching bands appears once).  M = max unique
        matches over the batch, 0 wide when nothing matches.  Preserves the
        LSH contract for spilled entries: a returned id still shares a band
        bucket key with the query.  Rare path — the spill list is small by
        construction.

        ``cap`` bounds each matched spilled (band, key) *group* to its
        ``cap`` smallest ids, so one hot spilled key (an oversized duplicate
        cluster left spilled by the growth caps) cannot widen (Q, M) for
        every query in the batch: row width is bounded by n_bands * cap
        whatever the group sizes.  The cap is per group, never across
        groups — candidates from differently-keyed groups are never dropped
        in favor of smaller ids elsewhere, so capping only loses candidates
        *inside* an oversized group.  Query paths pass ``cap=top_k``: hot
        groups are in practice near-duplicate clusters whose members tie in
        score, ties break toward smaller ids, and the group's ``top_k``
        smallest are exactly the tie-winners.  The trade is explicit: a
        spilled group with > cap members whose scores do NOT tie can lose a
        higher-scoring larger id (and, sharded, per-shard caps keep
        per-shard smallest — the only window where S-shard and 1-shard
        answers may differ).  ``cap=None`` is exact."""
        q = len(hashes)
        if not len(self._spill_id):
            return np.zeros((q, 0), np.int64)
        rows: list[list[int]] = [[] for _ in range(q)]
        for band in np.unique(self._spill_band):
            sel = self._spill_band == band
            order = np.argsort(self._spill_key[sel], kind="stable")
            keys = self._spill_key[sel][order]
            ids = self._spill_id[sel][order]
            col = hashes[:, band]
            lo = np.searchsorted(keys, col, "left")
            hi = np.searchsorted(keys, col, "right")
            for qi in np.flatnonzero(hi > lo):
                grp = ids[lo[qi]: hi[qi]]      # one (band, key) group
                if cap is not None and len(grp) > cap:
                    grp = np.sort(grp)[:cap]
                rows[qi].extend(grp.tolist())
        uniq = [np.unique(np.asarray(r, np.int64)) for r in rows]
        m = max(len(u) for u in uniq)
        out = np.full((q, m), -1, np.int64)
        for qi, u in enumerate(uniq):
            out[qi, : len(u)] = u
        return out

    # -- candidate pairs (dedup path) --------------------------------------
    def candidate_pairs(self) -> np.ndarray:
        """(P, 2) int64 unique (i, j) i<j sharing at least one bucket.

        Equivalent to the reference dict grouping (core.lsh.candidate_pairs)
        when nothing has spilled; spilled entries are paired exactly via
        their recorded (band, key)."""
        w = self.bucket_width
        sel_b, sel_s = np.nonzero(self.counts >= 2)
        parts = []
        if len(sel_b):
            members = self.records[sel_b, sel_s, 2:]       # (M, W)
            cnt = self.counts[sel_b, sel_s]
            ii, jj = np.triu_indices(w, 1)
            valid = jj[None, :] < cnt[:, None]
            a = members[:, ii][valid].astype(np.int64)
            c = members[:, jj][valid].astype(np.int64)
            parts.append(np.stack([np.minimum(a, c), np.maximum(a, c)], 1))
        parts.extend(self._spill_pairs())
        if not parts:
            return np.zeros((0, 2), np.int64)
        return np.unique(np.concatenate(parts, axis=0), axis=0)

    def _spill_pairs(self) -> list[np.ndarray]:
        if not len(self._spill_id):
            return []
        parts = []
        # spilled entry x resident bucket members with the same (band, key)
        slot = self._find_slots(self._spill_band.astype(np.int64),
                                self._spill_key)
        found = slot >= 0
        if found.any():
            sb = self._spill_band[found]
            posts = self.records[sb, slot[found], 2:]      # (S, W)
            cnt = self.counts[sb, slot[found]]
            valid = np.arange(self.bucket_width)[None, :] < cnt[:, None]
            sid = np.repeat(self._spill_id[found], self.bucket_width)
            mid = posts.reshape(-1).astype(np.int64)
            ok = valid.reshape(-1) & (sid != mid)
            a, c = sid[ok], mid[ok]
            if len(a):
                parts.append(np.stack([np.minimum(a, c), np.maximum(a, c)], 1))
        # spilled x spilled within the same (band, key) group
        order = np.lexsort((self._spill_id, self._spill_key, self._spill_band))
        gb = self._spill_band[order]
        gk = self._spill_key[order]
        gi = self._spill_id[order]
        bound = np.r_[0, np.flatnonzero((gb[1:] != gb[:-1]) |
                                        (gk[1:] != gk[:-1])) + 1, len(gi)]
        for s, e in zip(bound[:-1], bound[1:]):   # spill groups are tiny/rare
            if e - s < 2:
                continue
            g = gi[s:e]
            ii, jj = np.triu_indices(len(g), 1)
            a, c = g[ii], g[jj]
            keep = a != c
            parts.append(np.stack([np.minimum(a, c)[keep],
                                   np.maximum(a, c)[keep]], 1))
        return parts

    # -- compaction --------------------------------------------------------
    def rebuild(self, n_slots: int | None = None,
                bucket_width: int | None = None,
                max_probes: int | None = None) -> None:
        """Reallocate at new geometry and replay every recorded hash.

        Drains the spill: every item ends up bucketed (or re-spilled if the
        new geometry is still too small)."""
        self.n_slots = n_slots or self.n_slots
        self.bucket_width = bucket_width or self.bucket_width
        self.max_probes = max_probes or self.max_probes
        self._alloc()
        if self.n_items:
            self._insert(self._hashes[: self.n_items],
                         np.arange(self.n_items, dtype=np.int64))

    # -- snapshots ---------------------------------------------------------
    @property
    def hash_log(self) -> np.ndarray:
        """(n_items, n_bands) uint64 — every inserted band hash, in id order
        (the replay log rebuild() uses; what snapshots persist)."""
        return self._hashes[: self.n_items]
