"""SketchStore: device-resident packed signature storage + vectorized LSH."""

from .packed import PackedConfig, PackedSignatureBuffer
from .planner import QueryPlanner
from .store import SketchStore, StoreConfig
from .table import BandedLSHTable

__all__ = ["PackedConfig", "PackedSignatureBuffer", "QueryPlanner",
           "SketchStore", "StoreConfig", "BandedLSHTable"]
