"""SketchStore: device-resident packed signature storage + vectorized LSH."""

from .packed import PackedConfig, PackedSignatureBuffer
from .planner import QueryPlanner, TopKPartial, finalize_topk
from .sharded import InProcessShard, ShardBackend, ShardedSketchStore
from .store import SketchStore, StoreConfig
from .table import BandedLSHTable

__all__ = ["PackedConfig", "PackedSignatureBuffer", "QueryPlanner",
           "SketchStore", "ShardedSketchStore", "StoreConfig",
           "BandedLSHTable", "TopKPartial", "finalize_topk",
           "InProcessShard", "ShardBackend"]
