"""SketchStore — packed signature storage + vectorized LSH indexing facade.

Owns the three pieces end-to-end: a ``PackedSignatureBuffer`` (b-bit columnar
signature storage), a ``BandedLSHTable`` (open-addressing bucket arrays), and
a ``QueryPlanner`` (batched candidate scoring).  ``add`` appends a signature
batch and indexes it; ``query`` answers a query batch with top-k (id, score)
pairs; ``candidate_pairs`` serves the dedup pipeline.  ``save``/``load``
snapshot the whole store to one ``.npz``.

The table auto-rebuilds (doubling) when open addressing degrades: slot load
factor above ``rebuild_load_factor``, or spilled entries above
``rebuild_spill_fraction`` of postings.  Probe-exhaustion spills double
``n_slots``; bucket-overflow spills double ``bucket_width``.
"""

from __future__ import annotations

import dataclasses
import time
import zlib

import numpy as np

from repro.core.lsh import band_hashes, band_hashes_packed
from repro.obs import metrics as obs_metrics

from .packed import PackedConfig, PackedSignatureBuffer
from .planner import QueryPlanner
from .table import BandedLSHTable


@dataclasses.dataclass(frozen=True)
class StoreConfig:
    k: int                          # signature length
    n_bands: int                    # LSH bands; k = n_bands * rows_per_band
    rows_per_band: int
    b: int = 32                     # stored bits per hash (32 = exact)
    n_slots: int = 2048             # initial open-addressing slots per band
    bucket_width: int = 8           # initial postings per bucket
    max_probes: int = 16            # quadratic-probe chain bound
    capacity: int = 1024            # initial packed-buffer item capacity
    rebuild_load_factor: float = 0.7
    rebuild_spill_fraction: float = 0.01
    auto_rebuild: bool = True
    store_signatures: bool = True   # False: index-only (candidate_pairs /
                                    # candidate_rows work, query() does not)

    def __post_init__(self):
        if self.n_bands * self.rows_per_band != self.k:
            raise ValueError("n_bands * rows_per_band must equal k")
        from repro.kernels import ops
        if self.b not in ops.PACK_BITS:
            raise ValueError(f"b must be one of {ops.PACK_BITS} (got {self.b})")

    # -- positional snapshot encoding (one definition: SketchStore npz and
    # the sharded-plane manifest must never drift apart field-by-field) ----
    def to_manifest(self) -> tuple[np.ndarray, np.ndarray]:
        """(int fields (10,) int64, threshold fields (2,) float64)."""
        ints = np.asarray([self.k, self.n_bands, self.rows_per_band, self.b,
                           self.n_slots, self.bucket_width, self.max_probes,
                           self.capacity, int(self.auto_rebuild),
                           int(self.store_signatures)], np.int64)
        thr = np.asarray([self.rebuild_load_factor,
                          self.rebuild_spill_fraction])
        return ints, thr

    @classmethod
    def from_manifest(cls, ints, thr) -> "StoreConfig":
        k, nb, r, b, ns, w, p, cap, auto, keep = (int(x) for x in ints[:10])
        load_f, spill_f = (float(x) for x in thr)
        return cls(k=k, n_bands=nb, rows_per_band=r, b=b, n_slots=ns,
                   bucket_width=w, max_probes=p, capacity=cap,
                   rebuild_load_factor=load_f, rebuild_spill_fraction=spill_f,
                   auto_rebuild=bool(auto), store_signatures=bool(keep))

    @classmethod
    def sized_for(cls, n_items: int, *, target_load: float = 0.5,
                  **kw) -> "StoreConfig":
        """Config pre-sized for a known corpus: slots for ~``target_load``
        per band (one-shot adds at load >~ 0.7 exhaust probe chains) and
        buffer capacity for ``n_items``."""
        n_slots = max(2048, 1 << int(np.ceil(
            np.log2(max(n_items, 1) / target_load))))
        kw.setdefault("n_slots", n_slots)
        kw.setdefault("capacity", max(n_items, 8))
        return cls(**kw)


def check_packed_banding(cfg: StoreConfig) -> None:
    """Packed banding needs every band to start on a word boundary.

    W % n_bands == 0 alone can pass on misaligned configs (pad words
    absorbing the mismatch), so this enforces the real invariant.  Shared by
    ``SketchStore`` and the coordinator side of ``ShardedSketchStore`` —
    with remote backends the coordinator folds the band hashes itself and
    must reject the same configs its workers would.
    """
    cpw = 32 // cfg.b
    if cfg.rows_per_band % cpw:
        raise ValueError(
            f"packed banding needs rows_per_band % (32/b) == 0 (got "
            f"rows_per_band={cfg.rows_per_band}, b={cfg.b}); "
            "use add()/query() on raw signatures instead")


class SketchStore:
    def __init__(self, cfg: StoreConfig, *, probe_impl: str = "auto",
                 query_impl: str = "auto"):
        from repro.kernels import dispatch
        if query_impl not in dispatch.QUERY_IMPLS:
            raise ValueError(f"query_impl must be one of "
                             f"{dispatch.QUERY_IMPLS} (got {query_impl!r})")
        self.cfg = cfg
        # probe backend for candidate generation (runtime knob, not
        # snapshotted): "auto" -> numpy host loop on CPU, device kernel on
        # TPU; see kernels/lsh_probe.py
        self.probe_impl = probe_impl
        # fused-query backend (runtime knob, not snapshotted): "auto" ->
        # device pipeline (Pallas on TPU, compiled jnp elsewhere), "host" ->
        # the legacy host fold + planner walk (the reference oracle); see
        # kernels/query_fused.py and _resolve_query_impl for the gates
        self.query_impl = query_impl
        self.buffer = PackedSignatureBuffer(PackedConfig(
            k=cfg.k, b=cfg.b,
            capacity=cfg.capacity if cfg.store_signatures else 1))
        self.table = BandedLSHTable(cfg.n_bands, n_slots=cfg.n_slots,
                                    bucket_width=cfg.bucket_width,
                                    max_probes=cfg.max_probes)
        self.planner = QueryPlanner(self.buffer)
        self.n_rebuilds = 0
        # at b < 32 sig-keys (band_hashes over raw signatures) and packed
        # keys (band_hashes_packed over truncated words) differ; the first
        # write pins the mode and mixing raises instead of silently missing
        self._band_mode: str | None = None

    # -- sizing ------------------------------------------------------------
    @property
    def size(self) -> int:
        return self.buffer.size if self.cfg.store_signatures \
            else self.table.n_items

    @property
    def n_spilled(self) -> int:
        return self.table.n_spilled

    def _band_keys(self, mode: str, *, write: bool) -> None:
        """Pin/check the banding key mode ('sig' or 'packed'); b = 32 keys
        are identical either way so anything goes."""
        if self.cfg.b == 32:
            return
        if self._band_mode is None:
            if write:
                self._band_mode = mode
        elif self._band_mode != mode:
            raise ValueError(
                f"this b={self.cfg.b} store was built with "
                f"{self._band_mode!r} band keys; mixing in {mode!r} keys "
                "would silently miss candidates (b < 32 truncates before "
                "hashing). Use one ingest/query mode per store.")

    # -- writes ------------------------------------------------------------
    def add(self, sigs: np.ndarray) -> np.ndarray:
        """Append + index a (B, K) int32 signature batch; returns new ids."""
        self._band_keys("sig", write=True)
        sigs = np.asarray(sigs)
        self._pregrow(len(sigs))
        if self.cfg.store_signatures:
            ids = self.buffer.append(sigs)
        else:                       # index-only: skip the packed copy
            ids = np.arange(self.table.n_items,
                            self.table.n_items + len(sigs), dtype=np.int64)
        hashes = band_hashes(sigs, self.cfg.n_bands, self.cfg.rows_per_band)
        self.table.insert(hashes, ids)
        if self.cfg.auto_rebuild:
            self._maybe_rebuild()
        return ids

    def add_packed(self, words: np.ndarray) -> np.ndarray:
        """Append + index a (B, W) uint32 packed-word batch; returns new ids.

        The fused sign->pack ingest path (``SketchEngine.sign_packed``): the
        packed words are stored verbatim and band-indexed directly from the
        words (``band_hashes_packed``) — no (B, K) int32 is ever formed.  At
        b = 32 this interoperates exactly with ``add``/``query`` (identical
        bucket keys); at b < 32 the whole store must use the packed path
        (requires rows_per_band % (32/b) == 0 so bands are word-aligned).
        """
        self._check_packed_banding()
        self._band_keys("packed", write=True)
        words = np.asarray(words, np.uint32)
        self._pregrow(len(words))
        if self.cfg.store_signatures:
            ids = self.buffer.append_packed(words)
        else:
            if words.shape[1] != self.buffer.cfg.n_words:
                raise ValueError(
                    f"expected (B, {self.buffer.cfg.n_words}) words, "
                    f"got {words.shape}")
            ids = np.arange(self.table.n_items,
                            self.table.n_items + len(words), dtype=np.int64)
        self.table.insert(band_hashes_packed(words, self.cfg.n_bands), ids)
        if self.cfg.auto_rebuild:
            self._maybe_rebuild()
        return ids

    # growth caps: beyond these the spill list is the right representation
    # (a duplicate cluster larger than any sane bucket stays spilled — pairs
    # and queries handle it exactly), so geometry cannot blow up on
    # pathological input
    _MAX_BUCKET_WIDTH = 256

    def _slot_cap(self, n_items: int | None = None) -> int:
        if n_items is None:
            n_items = self.table.n_items
        target = max(self.cfg.n_slots, 4 * max(n_items, 1))
        return 1 << (target - 1).bit_length()

    def _pregrow(self, n_new: int) -> None:
        """Grow slots geometrically ahead of the projected post-batch load.

        Reactive doubling inserts the batch into a too-small table (probe
        exhaustion spills everything), then rebuilds — replaying the batch
        it just inserted, once per doubling.  Growing to the projected size
        *before* the insert replays only the already-indexed items, once,
        and the batch lands in a table at sane load.  Final geometry is the
        same power-of-two ladder the reactive loop climbs, so the exactness
        story is unchanged (candidate sets never depend on geometry).
        """
        if not self.cfg.auto_rebuild or n_new <= 0:
            return
        t = self.table
        projected = t.n_items + n_new
        # distinct keys per band <= items, so this is the load ceiling
        need = projected / self.cfg.rebuild_load_factor
        cap = self._slot_cap(projected)
        ns = t.n_slots
        while ns < need and ns < cap:
            ns *= 2
        if ns > t.n_slots:
            self.rebuild(n_slots=min(ns, cap))

    def _maybe_rebuild(self) -> None:
        # loop: one large add can overshoot a single doubling by far.  each
        # pass grows only the dimension the failure mode points at
        for _ in range(32):
            t = self.table
            postings_cap = t.n_items * t.n_bands
            too_full = t.load_factor > self.cfg.rebuild_load_factor
            too_spilled = t.n_spilled > max(
                32, self.cfg.rebuild_spill_fraction * postings_cap)
            if not (too_full or too_spilled):
                return
            grow_w = (too_spilled and not too_full and
                      t.n_spill_overflow > t.n_spill_probe)
            if grow_w:
                if t.bucket_width >= self._MAX_BUCKET_WIDTH:
                    return                 # oversized cluster: leave it spilled
                self.rebuild(bucket_width=min(t.bucket_width * 2,
                                              self._MAX_BUCKET_WIDTH))
            else:
                if t.n_slots >= self._slot_cap():
                    return
                self.rebuild(n_slots=min(t.n_slots * 2, self._slot_cap()))

    def rebuild(self, n_slots: int | None = None,
                bucket_width: int | None = None,
                max_probes: int | None = None) -> None:
        t0 = time.perf_counter()
        self.table.rebuild(n_slots=n_slots, bucket_width=bucket_width,
                           max_probes=max_probes)
        self.n_rebuilds += 1
        reg = obs_metrics.default()
        reg.counter("store.rebuilds").inc()
        reg.histogram("store.rebuild").observe(time.perf_counter() - t0)

    # -- reads -------------------------------------------------------------
    def candidate_rows_hashed(self, hashes: np.ndarray, *, mode: str = "sig",
                              spill_cap: int | None = None) -> np.ndarray:
        """(Q, n_bands) uint64 band hashes -> (Q, C) candidate ids, -1 pad.

        The hash-level core of ``candidate_rows``/``candidate_rows_packed``
        — the sharded store folds a query batch's band hashes once and
        probes every shard with them.  ``spill_cap`` bounds per-query
        spilled matches (see ``BandedLSHTable.spilled_candidates``)."""
        self._band_keys(mode, write=False)
        cand = self.table.lookup(
            hashes, impl=self.probe_impl).astype(np.int64)
        spill = self.table.spilled_candidates(hashes, cap=spill_cap)
        if spill.shape[1]:
            cand = np.concatenate([cand, spill], axis=1)
        return cand

    def candidate_rows(self, qsigs: np.ndarray, *,
                       spill_cap: int | None = None) -> np.ndarray:
        """(Q, K) signatures -> (Q, C) candidate item ids, -1 padded.

        Includes spilled entries whose recorded (band, key) matches the
        query, so the candidate set equals the reference dict-bucket path
        even with a non-empty spill."""
        qsigs = np.asarray(qsigs)
        hashes = band_hashes(qsigs, self.cfg.n_bands, self.cfg.rows_per_band)
        return self.candidate_rows_hashed(hashes, mode="sig",
                                          spill_cap=spill_cap)

    def query(self, qsigs: np.ndarray,
              top_k: int = 10) -> tuple[np.ndarray, np.ndarray]:
        """(Q, K) signatures -> (ids (Q, top_k) [-1 pad], scores (Q, top_k)).

        Candidates (incl. per-query-matched spill, capped at top_k matches
        per hot spilled key) are scored with the packed collision op;
        results are identical to the reference dict-bucket path at b=32
        except when a single spilled (band, key) group holds more than
        top_k non-tied members — the documented spill-cap trade (see
        ``BandedLSHTable.spilled_candidates``)."""
        if not self.cfg.store_signatures:
            raise RuntimeError("query() needs stored signatures; this store "
                               "was built with store_signatures=False")
        qsigs = np.asarray(qsigs)
        return self.planner.topk(
            qsigs, self.candidate_rows(qsigs, spill_cap=top_k), top_k)

    def _check_packed_banding(self) -> None:
        check_packed_banding(self.cfg)

    def candidate_rows_packed(self, qwords: np.ndarray, *,
                              spill_cap: int | None = None) -> np.ndarray:
        """``candidate_rows`` for (Q, W) packed query words (fused path)."""
        self._check_packed_banding()
        qwords = np.asarray(qwords, np.uint32)
        hashes = band_hashes_packed(qwords, self.cfg.n_bands)
        return self.candidate_rows_hashed(hashes, mode="packed",
                                          spill_cap=spill_cap)

    # -- fused device query path -------------------------------------------
    def _resolve_query_impl(self) -> str:
        """Resolve the fused-query knob against store state.  The device
        pipeline needs: power-of-two ``n_slots`` (its slot modulo is a lane
        mask), stored signatures to score against, and a non-empty buffer
        (the score kernel gathers rows).  Anything else -> "host", the
        legacy fold + planner walk."""
        impl = self.query_impl
        if impl == "auto":
            from repro.kernels.dispatch import select_query_impl
            impl = select_query_impl()
        if impl == "host":
            return "host"
        ns = self.table.n_slots
        if (ns & (ns - 1)) or not self.cfg.store_signatures \
                or not self.buffer.size:
            return "host"
        return impl

    def _fused_partial(self, qwords, top_k: int, *, impl: str,
                       hashes: np.ndarray | None):
        """Run the fused device pipeline over resident store state and wrap
        the result as a planner partial.  ``hashes=None`` folds on device
        (single-store / shard-local); shard workers pass the coordinator's
        broadcast hashes and skip the fold.  The table's rare spilled keys
        stay a host leg, invoked only when the spill is non-empty."""
        from repro.kernels import dispatch
        from .planner import TopKPartial
        spill = None
        if self.table.n_spilled:
            spill = lambda h: self.table.spilled_candidates(h, cap=top_k)
        ids, scores, has = dispatch.query_fused(
            self.table.device_records(), self.buffer.device_words(), qwords,
            n_bands=self.cfg.n_bands, n_slots=self.table.n_slots,
            max_probes=self.table.max_probes, k=self.cfg.k, b=self.cfg.b,
            top_k=top_k, impl=impl, hashes=hashes, spill_lookup=spill)
        return TopKPartial.from_device(ids, scores, has)

    def partial_topk_packed_hashed(self, hashes: np.ndarray, qwords, top_k: int,
                                   *, mode: str = "packed"):
        """Per-shard candidate partial from pre-folded band hashes: device
        probe + score when the query knob resolves to a device backend, the
        legacy host walk otherwise.  The single rewiring point both shard
        worker kinds call (``InProcessShard`` and the tcp worker)."""
        impl = self._resolve_query_impl()
        if impl == "host":
            qwords = np.asarray(qwords, np.uint32)
            return self.planner.partial_topk_packed(
                qwords, self.candidate_rows_hashed(hashes, mode=mode,
                                                   spill_cap=top_k), top_k)
        self._band_keys(mode, write=False)
        return self._fused_partial(qwords, top_k, impl=impl, hashes=hashes)

    def query_packed(self, qwords,
                     top_k: int = 10) -> tuple[np.ndarray, np.ndarray]:
        """``query`` for already-packed (Q, W) uint32 query words — the
        serving twin of ``add_packed``; at b = 32 results are identical to
        ``query`` on the raw signatures.

        When the query knob resolves to a device backend the whole pipeline
        (uint32-lane fold -> probe -> score) runs fused on device
        (``kernels.dispatch.query_fused``, bit-identical to the host path);
        the brute-force fallback for rows with no candidates anywhere stays
        a host leg either way (it is global in the sharded plane)."""
        if not self.cfg.store_signatures:
            raise RuntimeError("query_packed() needs stored signatures; this "
                               "store was built with store_signatures=False")
        impl = self._resolve_query_impl()
        if impl == "host":
            qwords = np.asarray(qwords, np.uint32)
            return self.planner.topk_packed(
                qwords, self.candidate_rows_packed(qwords, spill_cap=top_k),
                top_k)
        from .planner import finalize_topk
        self._check_packed_banding()
        self._band_keys("packed", write=False)
        part = self._fused_partial(qwords, top_k, impl=impl, hashes=None)
        em = np.flatnonzero(~part.has_candidates)
        if len(em):
            qnp = np.asarray(qwords, np.uint32)
            brute = self.planner.brute_partial_packed(qnp[em], top_k)
            part.ids[em] = brute.ids
            part.scores[em] = brute.scores
        return finalize_topk(part)

    def candidate_pairs(self) -> np.ndarray:
        """(P, 2) int64 unique (i, j), i < j, sharing >= 1 band bucket."""
        return self.table.candidate_pairs()

    def digest(self) -> dict:
        """Content digest of the signature buffer: ``{size, crc, indexed}``.

        ``crc`` is the CRC-32 of the packed rows in insertion order, so two
        stores hold bit-identical signatures iff their digests match —
        regardless of table geometry (slot count, spills), which replay or
        snapshot boot may legitimately reproduce differently.  This is the
        parity check a resynced replica must pass against a live peer
        before rejoining the fan-out (``repro.replica.supervisor``)."""
        rows = np.ascontiguousarray(self.buffer.all_packed())
        return {"size": int(self.size),
                "crc": int(zlib.crc32(rows.tobytes()) & 0xFFFFFFFF),
                "indexed": int(self.table.n_items)}

    # -- snapshots ---------------------------------------------------------
    _BAND_MODES = (None, "sig", "packed")   # snapshot encoding of _band_mode

    def save(self, path: str) -> None:
        # snapshot the LIVE table geometry, not the boot values, so load
        # rebuilds at the grown size instead of replaying every doubling
        live = dataclasses.replace(
            self.cfg, n_slots=self.table.n_slots,
            bucket_width=self.table.bucket_width,
            max_probes=self.table.max_probes)
        ints, thr = live.to_manifest()
        np.savez(path,
                 words=np.asarray(self.buffer.all_packed()),
                 cfg=np.concatenate([ints, np.asarray(
                     [self._BAND_MODES.index(self._band_mode)], np.int64)]),
                 cfg_thresholds=thr,
                 table_hashes=self.table.hash_log)

    @classmethod
    def load(cls, path: str) -> "SketchStore":
        with np.load(path) as z:
            store = cls(StoreConfig.from_manifest(z["cfg"],
                                                  z["cfg_thresholds"]))
            # pre-band-mode snapshots (10-int cfg) load with mode unset
            mode = [int(x) for x in z["cfg"][10:]]
            store._band_mode = cls._BAND_MODES[mode[0]] if mode else None
            store.buffer = PackedSignatureBuffer.from_rows(
                store.buffer.cfg, z["words"])
            store.planner = QueryPlanner(store.buffer)
            hashes = z["table_hashes"]
            if len(hashes):
                store.table.insert(
                    hashes, np.arange(len(hashes), dtype=np.int64))
        return store
