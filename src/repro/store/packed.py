"""b-bit packed signature buffer (SketchStore storage layer).

Signatures are stored columnar: ``words`` has shape ``(n_words, capacity)``
uint32, word-lane major, so each of the ``ceil(K / (32/b))`` packed word lanes
is contiguous across items.  The array is host-authoritative (in-place numpy
appends, O(1) amortized with capacity doubling); ``gather`` hands row-major
packed blocks to the jit'd scoring ops, which stage them on device per call.
``save``/``load`` snapshot to ``.npz``.

b-bit packing (Li & Koenig, 2011) cuts signature storage 32/b x versus raw
int32 rows — the difference between an index that fits in HBM and one that
does not at 10^8+ items.  b = 32 stores the exact signatures (bitcast).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from ._growth import grown

_MIN_CAPACITY = 8


@dataclasses.dataclass(frozen=True)
class PackedConfig:
    k: int                      # codes per signature
    b: int = 32                 # bits per stored code (1,2,4,8,16,32)
    capacity: int = 1024        # initial item capacity

    def __post_init__(self):
        if self.b not in ops.PACK_BITS:
            raise ValueError(f"b must be one of {ops.PACK_BITS} (got {self.b})")
        if self.k <= 0:
            raise ValueError("k must be positive")

    @property
    def codes_per_word(self) -> int:
        return 32 // self.b

    @property
    def n_words(self) -> int:
        return -(-self.k // self.codes_per_word)


class PackedSignatureBuffer:
    """Append-only packed store for (N, K) int32 signatures.

    The authoritative word array lives host-side (numpy) so appends are
    in-place O(batch); ``gather``/``all_packed`` hand rows to the jit'd
    scoring ops, which stage them onto the device per call.  (An eager jnp
    buffer would copy the entire capacity on every ``.at[].set`` append —
    quadratic ingestion.)"""

    def __init__(self, cfg: PackedConfig):
        self.cfg = cfg
        cap = max(_MIN_CAPACITY, cfg.capacity)
        self._words = np.zeros((cfg.n_words, cap), np.uint32)
        self._size = 0
        # mutation counter gating the resident device copy (device_words);
        # same pattern as BandedLSHTable.device_records
        self._version = 0
        self._device: tuple[int, jnp.ndarray] | None = None

    # -- sizing ------------------------------------------------------------
    @property
    def size(self) -> int:
        return self._size

    @property
    def capacity(self) -> int:
        return self._words.shape[1]

    @property
    def nbytes(self) -> int:
        """Packed bytes actually holding data (the 32/b storage win)."""
        return self.cfg.n_words * self._size * 4

    def _grow_to(self, need: int) -> None:
        self._words = grown(self._words, need, axis=1)

    # -- writes ------------------------------------------------------------
    def append(self, sigs) -> np.ndarray:
        """Pack and append a (B, K) int32 signature batch; returns new ids."""
        sigs = jnp.asarray(sigs, jnp.int32)
        if sigs.ndim != 2 or sigs.shape[1] != self.cfg.k:
            raise ValueError(f"expected (B, {self.cfg.k}), got {sigs.shape}")
        b = sigs.shape[0]
        self._grow_to(self._size + b)
        packed = np.asarray(ops.pack_codes(sigs, self.cfg.b))  # (B, W)
        self._words[:, self._size: self._size + b] = packed.T
        ids = np.arange(self._size, self._size + b, dtype=np.int64)
        self._size += b
        self._version += 1
        return ids

    def append_packed(self, words) -> np.ndarray:
        """Append an already-packed (B, W) uint32 word batch (the fused
        sign->pack ingest path: no (B, K) int32 ever exists host-side);
        returns new ids.  Bit-identical storage to ``append(sigs)`` when
        ``words == pack_codes(sigs, b)``."""
        words = np.asarray(words, np.uint32)
        if words.ndim != 2 or words.shape[1] != self.cfg.n_words:
            raise ValueError(
                f"expected (B, {self.cfg.n_words}) packed words, "
                f"got {words.shape}")
        b = words.shape[0]
        self._grow_to(self._size + b)
        self._words[:, self._size: self._size + b] = words.T
        ids = np.arange(self._size, self._size + b, dtype=np.int64)
        self._size += b
        self._version += 1
        return ids

    # -- reads -------------------------------------------------------------
    def gather(self, ids) -> np.ndarray:
        """(C,) ids -> (C, W) uint32 packed rows for the scoring kernel."""
        ids = np.asarray(ids, np.int64)
        return np.ascontiguousarray(self._words[:, ids].T)

    def all_packed(self) -> np.ndarray:
        """(size, W) packed rows for every stored item."""
        return np.ascontiguousarray(self._words[:, : self._size].T)

    def device_words(self) -> jnp.ndarray:
        """(size, W) packed rows resident on device, re-uploaded only after
        a mutation (the fused query path scores every query batch against
        this one cached copy instead of gathering + staging per call)."""
        if self._device is None or self._device[0] != self._version:
            self._device = (self._version, jnp.asarray(self.all_packed()))
        return self._device[1]

    def codes(self, ids) -> jnp.ndarray:
        """(C,) ids -> (C, K) int32 unpacked b-bit codes."""
        return ops.unpack_codes(jnp.asarray(self.gather(ids)),
                                self.cfg.k, self.cfg.b)

    # -- snapshots ---------------------------------------------------------
    @classmethod
    def from_rows(cls, cfg: PackedConfig, rows) -> "PackedSignatureBuffer":
        """Rebuild a buffer from (N, W) row-major packed words (the
        ``gather``/``all_packed`` layout — what snapshots store)."""
        rows = np.asarray(rows, np.uint32)
        n = rows.shape[0]
        buf = cls(cfg)
        buf._grow_to(n)
        buf._words[:, :n] = rows.T
        buf._size = n
        buf._version += 1
        return buf

    def save(self, path: str) -> None:
        np.savez(path, words=self.all_packed(), k=self.cfg.k, b=self.cfg.b)

    @classmethod
    def load(cls, path: str) -> "PackedSignatureBuffer":
        with np.load(path) as z:
            words = z["words"]                         # (N, W) rows
            cfg = PackedConfig(k=int(z["k"]), b=int(z["b"]),
                               capacity=max(_MIN_CAPACITY, len(words)))
        return cls.from_rows(cfg, words)
