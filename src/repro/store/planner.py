"""Batched query planner: candidates -> dedupe -> one scoring call -> top-k.

The planner turns ragged per-query candidate lists (-1 padded rows from
``BandedLSHTable.lookup``) into a single dense scoring problem: the batch's
candidate union is gathered once from the packed buffer, scored against all
queries in one collision-kernel call, and each query then selects top-k from
its own candidate subset via a searchsorted-built mask — no per-query Python
in the scored path.

Queries whose candidate row is empty fall back to brute force over the whole
index *independently* (each such row scores everything; rows with candidates
are unaffected).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .packed import PackedSignatureBuffer

NEG_INF = np.float32(-np.inf)


def dedupe_union(cand_rows: np.ndarray) -> np.ndarray:
    """(Q, C) -1-padded candidate ids -> sorted unique union (U,) int64."""
    flat = cand_rows.reshape(-1)
    return np.unique(flat[flat >= 0]).astype(np.int64)


def candidate_mask(cand_rows: np.ndarray,
                   union_ids: np.ndarray) -> np.ndarray:
    """(Q, U) bool: union column u is a candidate of query q."""
    q = cand_rows.shape[0]
    mask = np.zeros((q, len(union_ids)), bool)
    rows, cols = np.nonzero(cand_rows >= 0)
    pos = np.searchsorted(union_ids, cand_rows[rows, cols])
    mask[rows, pos] = True
    return mask


class QueryPlanner:
    def __init__(self, buffer: PackedSignatureBuffer):
        self.buffer = buffer

    def topk(self, qsigs: np.ndarray, cand_rows: np.ndarray,
             top_k: int) -> tuple[np.ndarray, np.ndarray]:
        """Score and rank candidates.

        qsigs: (Q, K) int32 query signatures (packed on the fly).
        cand_rows: (Q, C) int64 candidate ids per query, -1 padded.
        Returns (ids (Q, top_k) int64 [-1 pad], scores (Q, top_k) float32).
        """
        qwords = np.asarray(ops.pack_codes(jnp.asarray(qsigs, jnp.int32),
                                           self.buffer.cfg.b))
        return self.topk_packed(qwords, cand_rows, top_k)

    def topk_packed(self, qwords: np.ndarray, cand_rows: np.ndarray,
                    top_k: int) -> tuple[np.ndarray, np.ndarray]:
        """``topk`` for already-packed (Q, W) uint32 query words (the fused
        sign->pack serving path — no (Q, K) int32 is ever formed)."""
        n = self.buffer.size
        q = qwords.shape[0]
        ids = np.full((q, top_k), -1, np.int64)
        scores = np.zeros((q, top_k), np.float32)
        if n == 0:
            return ids, scores
        empty = ~(cand_rows >= 0).any(axis=1)
        ne = np.flatnonzero(~empty)
        if len(ne):
            rows = cand_rows[ne]
            union_ids = dedupe_union(rows)
            ids[ne], scores[ne] = self._rank(
                qwords[ne], union_ids, candidate_mask(rows, union_ids), top_k)
        em = np.flatnonzero(empty)
        if len(em):
            # brute force only the no-candidate rows over the whole index —
            # independently per row, without widening the scored union of
            # the rows that do have candidates (mask=None: every column
            # counts, no (Q', N) bool allocation)
            union_ids = np.arange(n, dtype=np.int64)
            ids[em], scores[em] = self._rank(qwords[em], union_ids, None,
                                             top_k)
        return ids, scores

    def _rank(self, qwords: np.ndarray, union_ids: np.ndarray,
              mask: np.ndarray | None,
              top_k: int) -> tuple[np.ndarray, np.ndarray]:
        """Score (Q', U) and select top-k per row from the masked columns
        (mask=None: all columns are candidates)."""
        cfg = self.buffer.cfg
        q = qwords.shape[0]
        est = np.asarray(ops.packed_estimated_jaccard_matrix(
            jnp.asarray(qwords), self.buffer.gather(union_ids),
            cfg.k, cfg.b))  # (Q', U)
        scored = est if mask is None else np.where(mask, est, NEG_INF)
        kk = min(top_k, scored.shape[1])
        # stable sort + ascending union_ids => ties broken by smaller id,
        # matching the reference dict-path ranking exactly
        order = np.argsort(-scored, axis=1, kind="stable")[:, :kk]
        row = np.arange(q)[:, None]
        top_scores = scored[row, order]
        hit = top_scores > NEG_INF
        ids = np.full((q, top_k), -1, np.int64)
        scores = np.zeros((q, top_k), np.float32)
        ids[:, :kk] = np.where(hit, union_ids[order], -1)
        scores[:, :kk] = np.where(hit, top_scores, 0.0).astype(np.float32)
        return ids, scores
