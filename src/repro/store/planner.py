"""Batched query planner: candidates -> dedupe -> one scoring call -> top-k.

The planner turns ragged per-query candidate lists (-1 padded rows from
``BandedLSHTable.lookup``) into a single dense scoring problem: the batch's
candidate union is gathered once from the packed buffer, scored against all
queries in one collision-kernel call, and each query then selects top-k from
its own candidate subset via a searchsorted-built mask — no per-query Python
in the scored path.

Results come out as **mergeable partials** (``TopKPartial``): padded
(Q, top_k) score/id pairs ordered by (score desc, id asc), with ``NEG_INF``
score / ``-1`` id padding.  Partials from disjoint id sets merge exactly with
``distributed.collectives.merge_topk`` — the single-shard ``topk_packed`` and
the S-shard ``ShardedSketchStore.query_packed`` share this one scoring core,
the sharded path just merges more partials.

Queries whose candidate row is empty fall back to brute force over the whole
index *independently* (each such row scores everything; rows with candidates
are unaffected).  In the sharded plane that fallback decision is global — a
shard never brute-forces on its own — so ``partial_topk_packed`` reports
per-row candidate presence instead of deciding locally.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .packed import PackedSignatureBuffer

NEG_INF = np.float32(-np.inf)


def dedupe_union(cand_rows: np.ndarray) -> np.ndarray:
    """(Q, C) -1-padded candidate ids -> sorted unique union (U,) int64."""
    flat = cand_rows.reshape(-1)
    return np.unique(flat[flat >= 0]).astype(np.int64)


def candidate_mask(cand_rows: np.ndarray,
                   union_ids: np.ndarray) -> np.ndarray:
    """(Q, U) bool: union column u is a candidate of query q."""
    q = cand_rows.shape[0]
    mask = np.zeros((q, len(union_ids)), bool)
    rows, cols = np.nonzero(cand_rows >= 0)
    pos = np.searchsorted(union_ids, cand_rows[rows, cols])
    mask[rows, pos] = True
    return mask


@dataclasses.dataclass
class TopKPartial:
    """A mergeable top-k fragment: one shard's (or one leg's) ranked slice.

    Rows are ordered (score desc, id asc) and padded with ``NEG_INF`` score /
    ``-1`` id, the exact layout ``distributed.collectives.merge_topk``
    consumes.  ``has_candidates`` records which query rows had >= 1 LSH
    candidate *in this fragment* — the global brute-force-fallback decision
    ORs these across shards instead of letting any shard decide locally.
    """

    ids: np.ndarray               # (Q, top_k) int64, -1 padded
    scores: np.ndarray            # (Q, top_k) float32, NEG_INF padded
    has_candidates: np.ndarray    # (Q,) bool

    @classmethod
    def from_device(cls, ids, scores, has) -> "TopKPartial":
        """Partial from the fused device query path's host-transferred
        triple (``kernels.dispatch.query_fused``) — same layout contract as
        ``partial_topk_packed``, normalized to the planner's dtypes and made
        writable (``topk_packed``'s brute-fallback leg assigns into rows)."""
        return cls(np.array(ids, np.int64),
                   np.array(scores, np.float32),
                   np.array(has, bool))


def finalize_topk(part: TopKPartial) -> tuple[np.ndarray, np.ndarray]:
    """Partial -> the public (ids [-1 pad], scores [0.0 pad]) contract."""
    hit = part.scores > NEG_INF
    ids = np.where(hit, part.ids, np.int64(-1))
    scores = np.where(hit, part.scores, np.float32(0.0)).astype(np.float32)
    return ids, scores


class QueryPlanner:
    def __init__(self, buffer: PackedSignatureBuffer):
        self.buffer = buffer

    def topk(self, qsigs: np.ndarray, cand_rows: np.ndarray,
             top_k: int) -> tuple[np.ndarray, np.ndarray]:
        """Score and rank candidates.

        qsigs: (Q, K) int32 query signatures (packed on the fly).
        cand_rows: (Q, C) int64 candidate ids per query, -1 padded.
        Returns (ids (Q, top_k) int64 [-1 pad], scores (Q, top_k) float32).
        """
        qwords = np.asarray(ops.pack_codes(jnp.asarray(qsigs, jnp.int32),
                                           self.buffer.cfg.b))
        return self.topk_packed(qwords, cand_rows, top_k)

    def topk_packed(self, qwords: np.ndarray, cand_rows: np.ndarray,
                    top_k: int) -> tuple[np.ndarray, np.ndarray]:
        """``topk`` for already-packed (Q, W) uint32 query words (the fused
        sign->pack serving path — no (Q, K) int32 is ever formed).

        The single-shard composition of the partial API: candidate-leg
        partial, then the brute-force leg for rows with no candidates
        anywhere.  ``ShardedSketchStore`` runs the same two legs per shard
        and merges."""
        part = self.partial_topk_packed(qwords, cand_rows, top_k)
        if self.buffer.size:
            em = np.flatnonzero(~part.has_candidates)
            if len(em):
                # brute force only the no-candidate rows over the whole
                # index — independently per row, without widening the scored
                # union of the rows that do have candidates
                brute = self.brute_partial_packed(qwords[em], top_k)
                part.ids[em] = brute.ids
                part.scores[em] = brute.scores
        return finalize_topk(part)

    # -- mergeable partials (the sharded serving plane's scoring core) ------
    def partial_topk_packed(self, qwords: np.ndarray, cand_rows: np.ndarray,
                            top_k: int) -> TopKPartial:
        """Candidate-restricted partial: rows without candidates stay fully
        padded (NO local brute-force fallback — that decision is global)."""
        q = qwords.shape[0]
        ids = np.full((q, top_k), -1, np.int64)
        scores = np.full((q, top_k), NEG_INF, np.float32)
        has = np.asarray(cand_rows >= 0).any(axis=1) if cand_rows.size \
            else np.zeros(q, bool)
        ne = np.flatnonzero(has)
        if len(ne) and self.buffer.size:
            rows = cand_rows[ne]
            union_ids = dedupe_union(rows)
            ids[ne], scores[ne] = self._rank(
                qwords[ne], union_ids, candidate_mask(rows, union_ids), top_k)
        return TopKPartial(ids, scores, has)

    def brute_partial_packed(self, qwords: np.ndarray,
                             top_k: int) -> TopKPartial:
        """Brute-force partial: every stored item scored for every row
        (mask=None: no (Q, N) bool allocation).  ``has_candidates`` is False
        throughout — this leg never votes on the fallback decision.

        The query rows are padded to the next power of two (repeating row 0)
        before scoring: the scoring kernel specializes on the row count, and
        the fallback count is whatever subset of a batch had no candidates —
        without padding every new count pays a fresh trace/compile against
        the full-index column shape (seconds of tail latency, per worker).
        Scoring is row-independent, so the pad rows' results are sliced off
        without touching the real rows."""
        q = qwords.shape[0]
        ids = np.full((q, top_k), -1, np.int64)
        scores = np.full((q, top_k), NEG_INF, np.float32)
        if self.buffer.size and q:
            union_ids = np.arange(self.buffer.size, dtype=np.int64)
            n_pad = (1 << (q - 1).bit_length()) - q
            qp = qwords if not n_pad else np.concatenate(
                [qwords, np.broadcast_to(qwords[:1],
                                         (n_pad,) + qwords.shape[1:])])
            ids_p, scores_p = self._rank(qp, union_ids, None, top_k)
            ids, scores = ids_p[:q], scores_p[:q]
        return TopKPartial(ids, scores, np.zeros(q, bool))

    def _rank(self, qwords: np.ndarray, union_ids: np.ndarray,
              mask: np.ndarray | None,
              top_k: int) -> tuple[np.ndarray, np.ndarray]:
        """Score (Q', U) and select top-k per row from the masked columns
        (mask=None: all columns are candidates).  Returns partial-layout
        rows: (score desc, id asc), NEG_INF/-1 padded."""
        cfg = self.buffer.cfg
        q = qwords.shape[0]
        est = np.asarray(ops.packed_estimated_jaccard_matrix(
            jnp.asarray(qwords), self.buffer.gather(union_ids),
            cfg.k, cfg.b))  # (Q', U)
        scored = est if mask is None else np.where(mask, est, NEG_INF)
        kk = min(top_k, scored.shape[1])
        # stable sort + ascending union_ids => ties broken by smaller id,
        # matching the reference dict-path ranking exactly (and making the
        # partial's order identical to merge_topk's (score desc, id asc))
        order = np.argsort(-scored, axis=1, kind="stable")[:, :kk]
        row = np.arange(q)[:, None]
        top_scores = scored[row, order]
        hit = top_scores > NEG_INF
        ids = np.full((q, top_k), -1, np.int64)
        scores = np.full((q, top_k), NEG_INF, np.float32)
        ids[:, :kk] = np.where(hit, union_ids[order], -1)
        scores[:, :kk] = np.where(hit, top_scores,
                                  NEG_INF).astype(np.float32)
        return ids, scores
