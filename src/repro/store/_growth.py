"""Shared amortized-doubling growth for the store's numpy buffers."""

from __future__ import annotations

import numpy as np


def grown(arr: np.ndarray, need: int, axis: int = 0) -> np.ndarray:
    """Return ``arr`` if it already has ``need`` capacity along ``axis``,
    else a doubled-capacity reallocation with the old contents copied in
    (tail stays zero)."""
    cap = arr.shape[axis]
    if cap >= need:
        return arr
    while cap < need:
        cap *= 2
    shape = list(arr.shape)
    shape[axis] = cap
    out = np.zeros(shape, arr.dtype)
    sl = [slice(None)] * arr.ndim
    sl[axis] = slice(0, arr.shape[axis])
    out[tuple(sl)] = arr
    return out
