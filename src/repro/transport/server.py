"""Shard worker process: one ``SketchStore`` behind a framed TCP socket.

A worker is the remote half of the ``ShardBackend`` split: it owns exactly
the state an ``InProcessShard`` owns (one ``SketchStore``) and serves the
same operations over the wire protocol — ADD batches, the QUERY hash
broadcast (candidates + ``partial_topk_packed``), the BRUTE fallback leg,
STATS, SNAPSHOT, and a graceful SHUTDOWN.  All ranking code is the store's
own; the worker adds no scoring logic, which is what keeps tcp answers
bit-identical to the in-process plane.

Workers are ``multiprocessing``-spawnable (the entry point takes only
picklable arguments) and boot either empty from a ``StoreConfig`` or from a
per-shard snapshot written by ``ShardedSketchStore.save``.  The bound
address travels back to the parent over a one-shot pipe so workers can bind
port 0 and never race over port numbers.

Failure semantics: a handler exception is caught and answered with an ERROR
frame (the connection stays up); a protocol-level decode failure (bad
checksum, truncated frame) also gets an ERROR frame but then drops the
connection, since the stream can no longer be trusted to be in sync.  EOF
from the client returns the worker to ``accept`` — a coordinator can
reconnect.  Only SHUTDOWN (acked first) exits the process.
"""

from __future__ import annotations

import multiprocessing
import os
import socket
import traceback

import numpy as np

from repro.store.sharded import shard_snapshot_path
from repro.store.store import SketchStore, StoreConfig

from . import wire
from .wire import Message, MsgType


def _handle(store: SketchStore, msg: Message) -> tuple[Message, bool]:
    """One request -> (reply, keep_serving)."""
    f = msg.fields
    if msg.type == MsgType.ADD:
        # a failed ADD must report whether it mutated the store: the
        # coordinator keeps a retry safe only when the batch provably did
        # not land (otherwise it poisons the plane instead of duplicating)
        before = (store.size, store.table.n_items)
        try:
            if "rows" in f:
                n = len(store.add(np.asarray(f["rows"], np.int32)))
            elif "words" in f:
                n = len(store.add_packed(np.asarray(f["words"], np.uint32)))
            else:
                raise wire.ProtocolError("ADD needs 'rows' or 'words'")
        except Exception as e:
            if (store.size, store.table.n_items) != before:
                e.add_dirty = True
            raise
        return Message(MsgType.OK, {"n": n}), True
    if msg.type == MsgType.QUERY:
        hashes = wire.join_u64(f["hash_lo"], f["hash_hi"])
        top_k = int(f["top_k"])
        cands = store.candidate_rows_hashed(hashes, mode=f["mode"],
                                            spill_cap=top_k)
        part = store.planner.partial_topk_packed(
            np.asarray(f["qwords"], np.uint32), cands, top_k)
        return Message(MsgType.PARTIAL,
                       {"ids": part.ids, "scores": part.scores,
                        "has": part.has_candidates}), True
    if msg.type == MsgType.BRUTE:
        part = store.planner.brute_partial_packed(
            np.asarray(f["qwords"], np.uint32), int(f["top_k"]))
        return Message(MsgType.PARTIAL,
                       {"ids": part.ids, "scores": part.scores,
                        "has": part.has_candidates}), True
    if msg.type == MsgType.STATS:
        return Message(MsgType.OK, {"size": store.size,
                                    "n_spilled": store.n_spilled,
                                    "n_rebuilds": store.n_rebuilds,
                                    "probe_impl": store.probe_impl,
                                    "pid": os.getpid()}), True
    if msg.type == MsgType.SNAPSHOT:
        store.save(f["path"])
        return Message(MsgType.OK, {}), True
    if msg.type == MsgType.SHUTDOWN:
        return Message(MsgType.OK, {}), False
    raise wire.ProtocolError(f"unexpected message type {msg.type!r}")


def _serve_conn(store: SketchStore, conn: socket.socket) -> bool:
    """Serve one coordinator connection.  Returns False when SHUTDOWN."""
    conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    while True:
        try:
            msg = wire.recv_message(conn)
        except wire.ConnectionClosed:
            return True                          # client went away: re-accept
        except wire.WireError as e:              # stream out of sync: drop it
            try:
                wire.send_message(conn, Message(
                    MsgType.ERROR, {"error": f"{type(e).__name__}: {e}"}))
            except OSError:
                pass
            return True
        try:
            reply, keep = _handle(store, msg)
        except Exception as e:                   # worker-side op failure
            reply, keep = Message(MsgType.ERROR, {
                "error": f"{type(e).__name__}: {e}",
                "dirty": int(getattr(e, "add_dirty", False)),
                "traceback": traceback.format_exc(limit=8)}), True
        reply.seq = msg.seq                      # pair reply to its request
        try:
            wire.send_message(conn, reply)
        except OSError:
            return keep    # client vanished before reading: back to accept
        if not keep:
            return False


def run_worker(ready_conn, cfg: StoreConfig | None, snapshot: str | None,
               probe_impl: str, host: str, port: int) -> None:
    """Worker entry point (spawn target — all arguments picklable).

    Boots a ``SketchStore`` (empty from ``cfg``, or from ``snapshot``),
    binds ``(host, port)`` (port 0 = ephemeral), reports the bound address
    through ``ready_conn``, and serves until SHUTDOWN.

    ``probe_impl="auto"`` is resolved HERE, against this worker's own jax
    backend — not the coordinator's — so a mixed CPU/accelerator fleet
    serves one plane with each worker on its best probe path (Pallas on
    its accelerator hosts, the numpy walk on CPU hosts).  The resolved
    backend is reported in STATS (``probe_impl``).
    """
    if probe_impl == "auto":
        from repro.kernels.dispatch import select_probe_impl
        probe_impl = select_probe_impl()
    if snapshot is not None:
        store = SketchStore.load(snapshot)
        store.probe_impl = probe_impl
    else:
        if cfg is None:
            raise ValueError("worker needs a StoreConfig or a snapshot")
        store = SketchStore(cfg, probe_impl=probe_impl)
    lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    try:
        lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        lsock.bind((host, port))
        lsock.listen(4)
        ready_conn.send(lsock.getsockname())
        ready_conn.close()
        while True:
            conn, _ = lsock.accept()
            with conn:
                if not _serve_conn(store, conn):
                    return
    finally:
        lsock.close()


class WorkerHandle:
    """A spawned shard worker: its process and its bound address."""

    def __init__(self, proc, address: tuple[str, int], shard: int):
        self.proc = proc
        self.address = address
        self.shard = shard

    @property
    def alive(self) -> bool:
        return self.proc.is_alive()

    def join(self, timeout: float | None = None) -> None:
        self.proc.join(timeout)

    def terminate(self) -> None:
        """Hard stop (the graceful path is a client-side SHUTDOWN)."""
        if self.proc.is_alive():
            self.proc.terminate()
        self.proc.join(5)

    def __repr__(self) -> str:
        state = "alive" if self.alive else "dead"
        return f"WorkerHandle(shard={self.shard}, " \
               f"addr={self.address[0]}:{self.address[1]}, {state})"


def spawn_workers(cfg: StoreConfig | None, n_shards: int, *,
                  snapshot_dir: str | None = None, probe_impl: str = "auto",
                  host: str = "127.0.0.1",
                  start_timeout: float = 120.0) -> list[WorkerHandle]:
    """Spawn ``n_shards`` shard workers on localhost; returns their handles.

    Workers start in parallel (the dominant cost is each spawn re-importing
    jax) and each reports its ephemeral port back before this returns.  With
    ``snapshot_dir``, worker ``i`` boots from ``shard_{i}.npz`` inside it
    (the ``ShardedSketchStore.save`` layout) instead of empty from ``cfg``.
    """
    ctx = multiprocessing.get_context("spawn")
    started = []
    try:
        for i in range(n_shards):
            snap = shard_snapshot_path(snapshot_dir, i) \
                if snapshot_dir is not None else None
            parent, child = ctx.Pipe(duplex=False)
            proc = ctx.Process(
                target=run_worker,
                args=(child, cfg, snap, probe_impl, host, 0),
                daemon=True, name=f"shard-worker-{i}")
            proc.start()
            child.close()
            started.append((proc, parent, i))
        handles = []
        for proc, parent, i in started:
            if not parent.poll(start_timeout):
                if not proc.is_alive():
                    raise RuntimeError(
                        f"shard worker {i} exited (code {proc.exitcode}) "
                        "before reporting its address")
                raise TimeoutError(
                    f"shard worker {i} did not report its address within "
                    f"{start_timeout:.0f}s")
            try:
                handles.append(WorkerHandle(proc, tuple(parent.recv()), i))
            except EOFError as e:
                proc.join(5)
                raise RuntimeError(
                    f"shard worker {i} died during startup "
                    f"(exitcode {proc.exitcode})") from e
            parent.close()
        return handles
    except Exception:
        for proc, _, _ in started:
            if proc.is_alive():
                proc.terminate()
        raise
