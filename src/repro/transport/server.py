"""Shard worker process: one ``SketchStore`` behind a framed TCP socket.

A worker is the remote half of the ``ShardBackend`` split: it owns exactly
the state an ``InProcessShard`` owns (one ``SketchStore``) and serves the
same operations over the wire protocol — ADD batches, the QUERY hash
broadcast (candidates + ``partial_topk_packed``), the BRUTE fallback leg,
STATS, SNAPSHOT, and a graceful SHUTDOWN.  All ranking code is the store's
own; the worker adds no scoring logic, which is what keeps tcp answers
bit-identical to the in-process plane.

Workers are ``multiprocessing``-spawnable (the entry point takes only
picklable arguments) and boot either empty from a ``StoreConfig`` or from a
per-shard snapshot written by ``ShardedSketchStore.save``.  The bound
address travels back to the parent over a one-shot pipe so workers can bind
port 0 and never race over port numbers.

Connections are served one thread each, so a coordinator may hold more than
one connection to the same worker — which is what makes hedged queries
(``client.HedgePolicy``) work: a hedge re-issue on the second connection is
accepted and answered even while the primary connection is stalled.  The
``SketchStore`` itself is not thread-safe, so actual request *handling* is
serialized behind one worker-wide lock; the concurrency buys bypass of
head-of-line stalls that happen outside the store (socket backlog, a
dropped reply, the injected-slowness sleep below), which is exactly the
class of stall hedging targets.

Failure semantics: a handler exception is caught and answered with an ERROR
frame (the connection stays up); a protocol-level decode failure (bad
checksum, truncated frame) also gets an ERROR frame but then drops the
connection, since the stream can no longer be trusted to be in sync.  EOF
from the client returns the worker to ``accept`` — a coordinator can
reconnect.  Only SHUTDOWN (acked first) exits the process.

``spawn_workers(slow_shards=...)`` injects probabilistic latency into a
worker's QUERY/BRUTE handling (a pre-handle sleep) — the reproducible
"one slow shard" scenario the hedging benchmarks and CI smoke use to
demonstrate tail-latency cuts without relying on a noisy host.
"""

from __future__ import annotations

import json
import multiprocessing
import os
import random
import select
import socket
import threading
import time
import traceback

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.store.sharded import shard_snapshot_path
from repro.store.store import SketchStore, StoreConfig

from . import wire
from .faults import KILL_EXIT_CODE, FaultPlan
from .wire import Message, MsgType

GATE_LIMIT_ENV = "REPRO_GATE_LIMIT"
DEFAULT_GATE_LIMIT = 64

# overload control gates READS only: an OVERLOADED write leg would surface
# as a failed scatter round — poisoning the unreplicated plane and downing
# the lane on a replicated one — so writes keep their existing backpressure
# (the bounded ingest pipeline + the poison taxonomy) and the gate protects
# the latency-sensitive read path, where shedding is cheap and clean
_GATED_TYPES = (MsgType.QUERY, MsgType.BRUTE)


class AdmissionGate:
    """Bounded-inflight admission for a worker's read path.

    ``limit`` caps requests admitted concurrently (executing + waiting on
    the exec lock across all connection threads).  At the cap the worker
    answers ``OVERLOADED`` instead of queueing — the queue that would have
    formed here is unbounded memory and head-of-line latency with no one
    left to read the answer; an explicit reject is retryable within the
    caller's budget and deadline.
    """

    def __init__(self, limit: int):
        self.limit = int(limit)
        self._n = 0
        self._lock = threading.Lock()
        reg = obs_metrics.default()
        self._depth_g = reg.gauge("worker.admission.depth")
        reg.gauge("worker.admission.limit").set(self.limit)
        self.n_overloaded = reg.counter("worker.overloaded")
        self.n_expired = reg.counter("worker.expired")

    @property
    def depth(self) -> int:
        return self._n

    def try_enter(self) -> bool:
        with self._lock:
            if self._n >= self.limit:
                return False
            self._n += 1
            self._depth_g.set(self._n)
            return True

    def leave(self) -> None:
        with self._lock:
            self._n -= 1
            self._depth_g.set(self._n)


def _overloaded_reply(reason: str, retry_after_us: int,
                      gate: "AdmissionGate | None") -> Message:
    f = {"reason": reason, "retry_after_us": int(retry_after_us)}
    if gate is not None:
        f["gate_depth"] = gate.depth
        f["gate_limit"] = gate.limit
    return Message(MsgType.OVERLOADED, f)


def _handle(store: SketchStore, msg: Message,
            shard: int = -1, replica: int = 0,
            gate: "AdmissionGate | None" = None) -> tuple[Message, bool]:
    """One request -> (reply, keep_serving)."""
    f = msg.fields
    if msg.type == MsgType.ADD:
        # a failed ADD must report whether it mutated the store: the
        # coordinator keeps a retry safe only when the batch provably did
        # not land (otherwise it poisons the plane instead of duplicating)
        before = (store.size, store.table.n_items)
        try:
            if "rows" in f:
                n = len(store.add(np.asarray(f["rows"], np.int32)))
            elif "words" in f:
                n = len(store.add_packed(np.asarray(f["words"], np.uint32)))
            else:
                raise wire.ProtocolError("ADD needs 'rows' or 'words'")
        except Exception as e:
            if (store.size, store.table.n_items) != before:
                e.add_dirty = True
            raise
        return Message(MsgType.OK, {"n": n}), True
    if msg.type == MsgType.QUERY:
        hashes = wire.join_u64(f["hash_lo"], f["hash_hi"])
        # the store routes to the fused device pipeline or the legacy host
        # walk per its query_impl knob — bit-identical either way
        part = store.partial_topk_packed_hashed(
            hashes, np.asarray(f["qwords"], np.uint32), int(f["top_k"]),
            mode=f["mode"])
        return Message(MsgType.PARTIAL,
                       {"ids": part.ids, "scores": part.scores,
                        "has": part.has_candidates}), True
    if msg.type == MsgType.BRUTE:
        part = store.planner.brute_partial_packed(
            np.asarray(f["qwords"], np.uint32), int(f["top_k"]))
        return Message(MsgType.PARTIAL,
                       {"ids": part.ids, "scores": part.scores,
                        "has": part.has_candidates}), True
    if msg.type == MsgType.STATS:
        # ``obs`` is this worker's full registry snapshot (store/table/
        # kernel instrumentation plus the worker.* transport metrics) as a
        # JSON string — the coordinator merges these across shards with
        # ``obs.metrics.merge_snapshots`` exactly like ``merge_topk``
        return Message(MsgType.OK, {"size": store.size,
                                    "n_spilled": store.n_spilled,
                                    "n_rebuilds": store.n_rebuilds,
                                    "probe_impl": store.probe_impl,
                                    "query_impl": store.query_impl,
                                    "pid": os.getpid(),
                                    "shard": int(shard),
                                    "replica": int(replica),
                                    "gate_limit": gate.limit if gate else -1,
                                    "gate_depth": gate.depth if gate else 0,
                                    "n_overloaded":
                                        gate.n_overloaded.value if gate else 0,
                                    "n_expired":
                                        gate.n_expired.value if gate else 0,
                                    "obs": json.dumps(
                                        obs_metrics.default().snapshot())
                                    }), True
    if msg.type == MsgType.DIGEST:
        # signature-buffer content digest — the resync parity check a
        # respawned replica must pass against a live peer before rejoining
        return Message(MsgType.OK, store.digest()), True
    if msg.type == MsgType.SNAPSHOT:
        store.save(f["path"])
        return Message(MsgType.OK, {}), True
    if msg.type == MsgType.SHUTDOWN:
        return Message(MsgType.OK, {}), False
    raise wire.ProtocolError(f"unexpected message type {msg.type!r}")


def _serve_conn(store: SketchStore, conn: socket.socket,
                shard: int = -1, *,
                exec_lock: threading.Lock | None = None,
                slow: tuple[float, float] | None = None,
                replica: int = 0,
                gate: AdmissionGate | None = None,
                faults: FaultPlan | None = None) -> bool:
    """Serve one coordinator connection.  Returns False when SHUTDOWN.

    ``exec_lock`` serializes handler execution across this worker's
    connection threads (the store is single-threaded code).  ``slow`` is
    ``(prob, sleep_s)`` injected latency: each QUERY/BRUTE independently
    sleeps ``sleep_s`` with probability ``prob`` *before* taking the lock,
    so a hedged re-issue of the same request gets a fresh draw and can
    overtake a sleeping primary.

    ``gate`` bounds read inflight (reject with OVERLOADED at the cap);
    expired-deadline reads are dropped before computing.  ``faults`` is
    the worker's deterministic fault schedule, consulted pre-handle —
    a plan ``kill`` dies before mutating the store, a ``drop`` closes the
    connection without a reply, a ``truncate`` sends a half frame (the
    peer sees a corrupt stream, not a clean hangup).
    """
    conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    if exec_lock is None:
        exec_lock = threading.Lock()
    rng = random.Random()
    reg = obs_metrics.default()
    tracer = obs_trace.default()
    bytes_in = reg.counter("worker.bytes_in")
    bytes_out = reg.counter("worker.bytes_out")
    errors = reg.counter("worker.errors")
    wire_errors = reg.counter("worker.wire_errors")
    backlog = reg.counter("worker.backlog")
    faults_fired = reg.counter("worker.faults_fired")
    handle_h = {t: reg.histogram(f"worker.handle.{t.name.lower()}")
                for t in MsgType}
    while True:
        try:
            msg = wire.recv_message(conn, meter=bytes_in.inc)
        except wire.ConnectionClosed:
            return True                          # client went away: re-accept
        except wire.WireError as e:              # stream out of sync: drop it
            wire_errors.inc()
            try:
                wire.send_message(conn, Message(
                    MsgType.ERROR, {"error": f"{type(e).__name__}: {e}"}),
                    meter=bytes_out.inc)
            except OSError:
                pass
            return True
        if faults is not None:
            for ev in faults.on_message(msg.type.name.lower()):
                faults_fired.inc()
                if ev.kind == "delay":
                    FaultPlan.sleep(ev)
                elif ev.kind == "drop":
                    return True                  # EOF mid-round, no reply
                elif ev.kind == "truncate":
                    frame = wire.message_bytes(Message(
                        MsgType.ERROR, {"error": "injected truncation"},
                        seq=msg.seq))
                    try:                         # half a frame, then hangup
                        conn.sendall(frame[:max(wire.HEADER_SIZE + 1,
                                                len(frame) // 2)])
                    except OSError:
                        pass
                    return True
                elif ev.kind == "kill":
                    # fired-event log already fsynced by on_message; die
                    # before handling so the store never half-mutates
                    os._exit(KILL_EXIT_CODE)
        # a request carrying trace fields joins the coordinator's trace:
        # the worker's legs nest under the span whose id rode the frame
        ctx = None
        if wire.TRACE_ID_FIELD in msg.fields:
            ctx = obs_trace.TraceCtx(int(msg.fields[wire.TRACE_ID_FIELD]),
                                     int(msg.fields[wire.TRACE_PARENT_FIELD]))
        admitted = False
        if gate is not None and msg.type in _GATED_TYPES:
            dl = msg.fields.get(wire.DEADLINE_FIELD)
            if dl is not None and time.time() * 1e6 > int(dl):
                # caller's deadline already passed: computing the answer
                # is pure waste — drop before scoring, tell the caller why
                gate.n_expired.inc()
                reply = _overloaded_reply("expired", 0, gate)
                reply.seq = msg.seq
                try:
                    wire.send_message(conn, reply, meter=bytes_out.inc)
                except OSError:
                    return True
                continue
            if not gate.try_enter():
                gate.n_overloaded.inc()
                # back off roughly one queue drain: mean read handle time
                # x current depth (2ms floor when the worker is cold)
                h = handle_h[MsgType.QUERY]
                per = h.mean if h.count else 2e-3
                reply = _overloaded_reply(
                    "admission", int(max(per, 2e-3) * gate.depth * 1e6),
                    gate)
                reply.seq = msg.seq
                try:
                    wire.send_message(conn, reply, meter=bytes_out.inc)
                except OSError:
                    return True
                continue
            admitted = True
        if slow is not None and msg.type in (MsgType.QUERY, MsgType.BRUTE) \
                and rng.random() < slow[0]:
            time.sleep(slow[1])
        t0 = time.perf_counter()
        try:
            # with no ctx (and the worker tracer's sample rate of 0) this
            # returns the shared no-op span — untraced requests pay nothing
            with tracer.span(f"worker.{msg.type.name.lower()}", parent=ctx):
                with exec_lock:
                    reply, keep = _handle(store, msg, shard, replica, gate)
        except Exception as e:                   # worker-side op failure
            errors.inc()
            reply, keep = Message(MsgType.ERROR, {
                "error": f"{type(e).__name__}: {e}",
                "dirty": int(getattr(e, "add_dirty", False)),
                "traceback": traceback.format_exc(limit=8)}), True
        finally:
            if admitted:
                gate.leave()
        handle_h[msg.type].observe(time.perf_counter() - t0)
        if ctx is not None:
            spans = tracer.drain()
            if spans:               # reply carries this worker's spans home
                reply.fields[wire.TRACE_SPANS_FIELD] = json.dumps(spans)
        reply.seq = msg.seq                      # pair reply to its request
        try:
            wire.send_message(conn, reply, meter=bytes_out.inc)
        except OSError:
            return keep    # client vanished before reading: back to accept
        if not keep:
            return False
        # queue-depth proxy for a serial connection: another request
        # already readable the moment we finish one means the coordinator
        # is ahead of us — each such observation is one backlogged request
        try:
            if select.select([conn], [], [], 0)[0]:
                backlog.inc()
        except OSError:
            pass


def run_worker(ready_conn, cfg: StoreConfig | None, snapshot: str | None,
               probe_impl: str, host: str, port: int,
               shard: int = -1, query_impl: str = "auto",
               slow: tuple[float, float] | None = None,
               replica: int = 0, gate_limit: int | None = None,
               fault_spec: str | None = None) -> None:
    """Worker entry point (spawn target — all arguments picklable).

    Boots a ``SketchStore`` (empty from ``cfg``, or from ``snapshot``),
    binds ``(host, port)`` (port 0 = ephemeral), reports the bound address
    through ``ready_conn``, and serves until SHUTDOWN.  Each accepted
    connection gets its own serving thread (see ``_serve_conn`` for the
    locking discipline); ``slow`` injects probabilistic read latency.

    ``probe_impl="auto"`` and ``query_impl="auto"`` are resolved HERE,
    against this worker's own jax backend — not the coordinator's — so a
    mixed CPU/accelerator fleet serves one plane with each worker on its
    best path (Pallas on its accelerator hosts, compiled-jnp / the numpy
    walk on CPU hosts).  The resolved backends are reported in STATS
    (``probe_impl`` / ``query_impl``).

    ``gate_limit`` bounds admitted read inflight (``REPRO_GATE_LIMIT`` env
    overrides when None; default ``DEFAULT_GATE_LIMIT``; <= 0 keeps the
    gate but admits nothing — the always-shed worker the overload tests
    use).  ``fault_spec`` is a ``FaultPlan.encode()`` JSON schedule
    (``REPRO_FAULTS`` env keyed ``"<shard>.<replica>"`` when None).
    """
    lane = f"{shard}.{replica}"
    if fault_spec is not None:
        faults = FaultPlan.decode(fault_spec, lane=lane)
    else:
        faults = FaultPlan.from_env(lane)
    if gate_limit is None:
        gate_limit = int(os.environ.get(GATE_LIMIT_ENV, DEFAULT_GATE_LIMIT))
    # the worker gets its own tracer labelled with its shard index, so a
    # stitched trace says which process each span ran in; sample rate stays
    # 0 — worker spans only open under a wire-propagated parent, inheriting
    # the coordinator's sampling decision
    proc = f"shard{shard}" if shard >= 0 else f"worker-pid{os.getpid()}"
    if shard >= 0 and replica > 0:       # R-way lanes get distinct proc tags
        proc = f"shard{shard}r{replica}"
    obs_trace.set_default(obs_trace.Tracer(proc=proc))
    if probe_impl == "auto":
        from repro.kernels.dispatch import select_probe_impl
        probe_impl = select_probe_impl()
    if query_impl == "auto":
        from repro.kernels.dispatch import select_query_impl
        query_impl = select_query_impl()
    if snapshot is not None:
        store = SketchStore.load(snapshot)
        store.probe_impl = probe_impl
        store.query_impl = query_impl
    else:
        if cfg is None:
            raise ValueError("worker needs a StoreConfig or a snapshot")
        store = SketchStore(cfg, probe_impl=probe_impl,
                            query_impl=query_impl)
    lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    try:
        lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        lsock.bind((host, port))
        lsock.listen(8)
        ready_conn.send(lsock.getsockname())
        ready_conn.close()
        stop = threading.Event()
        exec_lock = threading.Lock()
        gate = AdmissionGate(gate_limit)

        def _serve(conn: socket.socket) -> None:
            try:
                with conn:
                    if not _serve_conn(store, conn, shard,
                                       exec_lock=exec_lock, slow=slow,
                                       replica=replica, gate=gate,
                                       faults=faults):
                        stop.set()
            except ConnectionResetError:
                # normal for a hedge twin: the coordinator closes it with an
                # unread stale reply still buffered, which surfaces as RST
                pass
            except Exception:
                # a crashed serving thread must not take the worker down:
                # the coordinator sees the dropped connection and reacts
                # (mark_broken / TransportError); other connections live on
                traceback.print_exc()

        threads: list[threading.Thread] = []
        lsock.settimeout(0.25)       # bounded accept so SHUTDOWN is noticed
        while not stop.is_set():
            try:
                conn, _ = lsock.accept()
            except socket.timeout:
                continue
            t = threading.Thread(target=_serve, args=(conn,), daemon=True,
                                 name=f"serve-shard{shard}")
            t.start()
            threads.append(t)
        for t in threads:
            t.join(5)
    finally:
        lsock.close()


class WorkerHandle:
    """A spawned shard worker: its process and its bound address."""

    def __init__(self, proc, address: tuple[str, int], shard: int,
                 replica: int = 0):
        self.proc = proc
        self.address = address
        self.shard = shard
        self.replica = replica

    @property
    def alive(self) -> bool:
        return self.proc.is_alive()

    def join(self, timeout: float | None = None) -> None:
        self.proc.join(timeout)

    def terminate(self) -> None:
        """Hard stop (the graceful path is a client-side SHUTDOWN)."""
        if self.proc.is_alive():
            self.proc.terminate()
        self.proc.join(5)

    def __repr__(self) -> str:
        state = "alive" if self.alive else "dead"
        return f"WorkerHandle(shard={self.shard}, replica={self.replica}, " \
               f"addr={self.address[0]}:{self.address[1]}, {state})"


def spawn_workers(cfg: StoreConfig | None, n_workers: int, *,
                  snapshot_dir: str | None = None, probe_impl: str = "auto",
                  query_impl: str = "auto", host: str = "127.0.0.1",
                  start_timeout: float = 120.0,
                  slow_shards: dict[int, tuple[float, float]] | None = None,
                  shards: list[int] | None = None,
                  replicas: list[int] | None = None,
                  gate_limit: int | None = None,
                  faults: dict[int, "FaultPlan | str"] | None = None,
                  ) -> list[WorkerHandle]:
    """Spawn ``n_workers`` shard workers on localhost; returns their handles.

    Workers start in parallel (the dominant cost is each spawn re-importing
    jax) and each reports its ephemeral port back before this returns.  With
    ``snapshot_dir``, worker ``i`` boots from ``shard_{shards[i]}.npz``
    inside it (the ``ShardedSketchStore.save`` layout) instead of empty from
    ``cfg``.

    ``shards``/``replicas`` give each worker its explicit (shard, replica)
    assignment — a replicated plane spawns R workers per shard index
    (``repro.replica``).  The default is the classic unreplicated layout:
    worker ``i`` IS shard ``i``, replica 0.

    ``slow_shards`` maps WORKER index -> ``(prob, sleep_s)`` injected read
    latency (the hedging benchmarks' reproducible slow-shard scenario; for
    the default layout worker index == shard index).

    ``gate_limit`` sets every worker's read admission cap (None = env /
    default).  ``faults`` maps WORKER index -> ``FaultPlan`` (or its
    ``encode()`` JSON) — the deterministic chaos schedule; workers with no
    entry also pick up ``REPRO_FAULTS`` env keyed by lane.
    """
    if shards is None:
        shards = list(range(n_workers))
    if replicas is None:
        replicas = [0] * n_workers
    if len(shards) != n_workers or len(replicas) != n_workers:
        raise ValueError("shards/replicas must have one entry per worker")
    ctx = multiprocessing.get_context("spawn")
    started = []
    try:
        for i in range(n_workers):
            snap = shard_snapshot_path(snapshot_dir, shards[i]) \
                if snapshot_dir is not None else None
            parent, child = ctx.Pipe(duplex=False)
            plan = faults.get(i) if faults else None
            if isinstance(plan, FaultPlan):
                plan = plan.encode()
            proc = ctx.Process(
                target=run_worker,
                args=(child, cfg, snap, probe_impl, host, 0, shards[i],
                      query_impl,
                      slow_shards.get(i) if slow_shards else None,
                      replicas[i], gate_limit, plan),
                daemon=True, name=f"shard-worker-{shards[i]}r{replicas[i]}")
            proc.start()
            child.close()
            started.append((proc, parent, i))
        handles = []
        for proc, parent, i in started:
            if not parent.poll(start_timeout):
                if not proc.is_alive():
                    raise RuntimeError(
                        f"shard worker {i} exited (code {proc.exitcode}) "
                        "before reporting its address")
                raise TimeoutError(
                    f"shard worker {i} did not report its address within "
                    f"{start_timeout:.0f}s")
            try:
                handles.append(WorkerHandle(proc, tuple(parent.recv()),
                                            shards[i], replicas[i]))
            except EOFError as e:
                proc.join(5)
                raise RuntimeError(
                    f"shard worker {i} died during startup "
                    f"(exitcode {proc.exitcode})") from e
            parent.close()
        return handles
    except Exception:
        for proc, _, _ in started:
            if proc.is_alive():
                proc.terminate()
        raise
