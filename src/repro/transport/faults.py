"""Deterministic fault injection for the transport plane.

Chaos coverage is only trustworthy when the same scenario runs twice and
injects the same faults at the same protocol points.  Wall-clock-raced
``handle.terminate()`` calls (what the chaos test and the availability
bench used before this module) kill a worker *somewhere* near the intended
message — which replica dies mid-frame vs between frames differs run to
run, so a latent recovery bug can hide behind scheduling luck.

A ``FaultPlan`` is a fixed schedule of :class:`FaultEvent`\\ s, each fired
on the ``at``-th message of a given type seen by the installed peer:

    ``delay``     sleep ``delay_ms`` before handling the message
    ``drop``      close the connection without replying (mid-round EOF)
    ``truncate``  send a deliberately short frame, then close (the peer
                  sees ``TruncatedFrame`` — a corrupt stream, not a hangup)
    ``kill``      hard-exit the worker process before handling (the
                  sharpest chaos primitive: death mid-protocol, not at a
                  test-chosen wall-clock instant)

Counting is per message type (``at=2, msg_type="add"`` fires on the third
ADD regardless of interleaved QUERY/STATS traffic), so the schedule is a
pure function of the protocol conversation — if the driving workload is
deterministic, the injected-event sequence is too, and the fired-event log
proves it: every fired event appends one JSON line to ``log_path``
(flushed + fsynced *before* the fault acts, so even a ``kill`` leaves its
record).  Run the scenario twice with the same plan and diff the logs.

Plans serialize to JSON (``encode``/``decode``) so they cross the
``spawn_workers`` process boundary and can ride environment variables:
``REPRO_FAULTS`` holds a ``{"<shard>.<replica>": spec}`` map applied by
``transport.server.run_worker``; ``REPRO_FAULT_LOG`` points the fired-event
log somewhere the test can read.  Client-side injection (coordinator
perspective: delay or drop *outgoing* requests) goes through
``install_client_plan`` and is consulted by ``transport.client``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time

FAULTS_ENV = "REPRO_FAULTS"
FAULT_LOG_ENV = "REPRO_FAULT_LOG"

KINDS = ("delay", "drop", "truncate", "kill")

# exit code of a plan-killed worker — distinguishes an injected death from
# a genuine crash in test/bench triage
KILL_EXIT_CODE = 57


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """Fire ``kind`` on the ``at``-th (0-based) message of ``msg_type``.

    ``msg_type`` is a lowercase ``MsgType`` name ("add", "query", ...) or
    ``None`` to count every message.  ``delay_ms`` only matters for
    ``kind="delay"``.
    """

    kind: str
    at: int
    msg_type: str | None = None
    delay_ms: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.at < 0:
            raise ValueError(f"fault 'at' must be >= 0, got {self.at}")


class FaultPlan:
    """A fixed, per-peer schedule of fault events plus its fired log."""

    def __init__(self, events, *, lane: str = "", log_path: str | None = None):
        self.events = [e if isinstance(e, FaultEvent) else FaultEvent(**e)
                       for e in events]
        self.lane = lane
        self.log_path = log_path or os.environ.get(FAULT_LOG_ENV) or None
        self._lock = threading.Lock()
        self._seen: dict = {}              # msg_type name (or "") -> count
        self._pending = list(self.events)
        self.fired: list[dict] = []        # in-process record of fired events

    # -- serialization --------------------------------------------------------

    def encode(self) -> str:
        return json.dumps([dataclasses.asdict(e) for e in self.events])

    @classmethod
    def decode(cls, spec: str, *, lane: str = "",
               log_path: str | None = None) -> "FaultPlan":
        return cls(json.loads(spec), lane=lane, log_path=log_path)

    @classmethod
    def from_env(cls, lane: str) -> "FaultPlan | None":
        """Plan for ``lane`` (``"<shard>.<replica>"``) from ``REPRO_FAULTS``,
        or None when the env carries nothing for it."""
        raw = os.environ.get(FAULTS_ENV)
        if not raw:
            return None
        spec = json.loads(raw).get(lane)
        if not spec:
            return None
        if not isinstance(spec, str):
            spec = json.dumps(spec)
        return cls.decode(spec, lane=lane)

    @classmethod
    def from_seed(cls, seed: int, *, n_events: int, horizon: int,
                  kinds=("delay", "drop"), msg_type: str | None = "query",
                  delay_ms: float = 50.0, lane: str = "",
                  log_path: str | None = None) -> "FaultPlan":
        """A seed-deterministic random schedule: ``n_events`` events drawn
        without replacement from message indices ``[0, horizon)``.  Same
        seed -> same schedule, always."""
        import numpy as np
        rng = np.random.default_rng(seed)
        ats = sorted(int(a) for a in
                     rng.choice(horizon, size=min(n_events, horizon),
                                replace=False))
        picks = rng.integers(0, len(kinds), size=len(ats))
        events = [FaultEvent(kind=kinds[int(k)], at=a, msg_type=msg_type,
                             delay_ms=delay_ms) for a, k in zip(ats, picks)]
        return cls(events, lane=lane, log_path=log_path)

    # -- matching + firing ----------------------------------------------------

    def on_message(self, msg_type_name: str) -> list[FaultEvent]:
        """Record one observed message; return the events it fires (each
        event fires exactly once).  Thread-safe: counts are shared across
        the worker's connection threads so the schedule tracks the peer's
        whole conversation, not one socket's."""
        fired: list[FaultEvent] = []
        with self._lock:
            n_typed = self._seen.get(msg_type_name, 0)
            n_any = self._seen.get("", 0)
            self._seen[msg_type_name] = n_typed + 1
            self._seen[""] = n_any + 1
            still: list[FaultEvent] = []
            for ev in self._pending:
                n = n_any if ev.msg_type is None else n_typed
                if (ev.msg_type in (None, msg_type_name)) and n == ev.at:
                    fired.append(ev)
                else:
                    still.append(ev)
            self._pending = still
            for ev in fired:
                self._log_locked(ev, msg_type_name)
        return fired

    def _log_locked(self, ev: FaultEvent, msg_type_name: str) -> None:
        rec = {"lane": self.lane, "kind": ev.kind, "at": ev.at,
               "msg_type": ev.msg_type, "on": msg_type_name,
               "n_fired": len(self.fired)}
        self.fired.append(rec)
        if not self.log_path:
            return
        line = json.dumps(rec, sort_keys=True) + "\n"
        # O_APPEND + one write per line keeps concurrent lanes' records
        # intact; flush+fsync BEFORE the fault acts so a kill can't eat
        # its own evidence
        fd = os.open(self.log_path, os.O_WRONLY | os.O_APPEND | os.O_CREAT,
                     0o644)
        try:
            os.write(fd, line.encode())
            os.fsync(fd)
        finally:
            os.close(fd)

    @staticmethod
    def sleep(ev: FaultEvent) -> None:
        time.sleep(ev.delay_ms / 1e3)


def read_fired_log(path: str) -> list[dict]:
    """Parse a fired-event log.  Returns records sorted by (lane, order of
    firing within the lane) — the cross-lane interleaving in the file is
    scheduler-dependent, the per-lane sequences are the deterministic
    artifact."""
    recs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                recs.append(json.loads(line))
    recs.sort(key=lambda r: (r.get("lane", ""), r.get("n_fired", 0)))
    return recs


def faults_env_value(plans: dict) -> str:
    """``{"<shard>.<replica>": FaultPlan | spec}`` -> REPRO_FAULTS value."""
    return json.dumps({lane: (p.encode() if isinstance(p, FaultPlan) else p)
                       for lane, p in plans.items()})


# -- client-side plan ---------------------------------------------------------

_client_plan: FaultPlan | None = None
_client_lock = threading.Lock()


def install_client_plan(plan: FaultPlan | None) -> None:
    """Install (or clear) the process-wide coordinator-side plan.  Only
    ``delay`` and ``drop`` act on the client: ``drop`` closes the lane's
    socket before the send, so the coordinator exercises its own
    mid-round failure paths on a deterministic schedule."""
    global _client_plan
    with _client_lock:
        _client_plan = plan


def client_events(msg_type_name: str) -> list[FaultEvent]:
    plan = _client_plan
    if plan is None:
        return []
    return plan.on_message(msg_type_name)
