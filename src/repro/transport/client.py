"""Coordinator side of the shard transport: connections, fan-out, backends.

``ShardConnection`` is one framed TCP connection to a shard worker with a
blocking request/reply path (used for ADD, STATS, SNAPSHOT, SHUTDOWN).  The
query hot path instead goes through ``FanoutGroup``: the coordinator submits
one QUERY (or BRUTE) frame per worker, and the group drives every socket
with a ``selectors`` event loop — nonblocking gather-writes out, incremental
frame reassembly in — so all S workers compute their partials concurrently
and replies are drained in whatever order they land.  One wall-clock
deadline covers the whole fan-out: when it expires the group raises
``TransportTimeout`` naming the shards still pending, and a worker that dies
mid-flight (connection reset / EOF / ERROR frame) surfaces as
``WorkerError`` — a failed query is always an exception, never a hang.

``RemoteShard`` adapts one worker to the ``ShardBackend`` protocol
(``store.sharded``), so ``ShardedSketchStore`` runs identically over
in-process shards and tcp workers; ``connect_sharded`` builds the store for
a worker address list, optionally restoring coordinator state (gid maps,
partition) from a ``ShardedSketchStore.save`` snapshot directory.

Hedging (``HedgePolicy``): with one slow shard, the fan-out wall clock is
that shard's latency — its p99 becomes the query p99.  When a policy is
set, the group holds a second connection per shard and, if a shard's reply
hasn't landed by a skew-derived hedge delay, re-issues the *same* read
request on the twin connection; the first good reply wins and the loser is
settled by the existing machinery (a late duplicate reply is discarded by
seq pairing; a leg cut mid-frame is poisoned).  Only idempotent reads
(QUERY/BRUTE) are ever hedged — writes keep exactly-once semantics.  The
hedge delay for a shard derives from its PEERS' reply-skew histograms
(how much later than each round's fastest reply everyone else lands), and
the timer arms when the round's first reply arrives: skew — not absolute
latency — is what hedging can actually fix, it is immune to
coordinator-side pauses that delay a whole round together, and excluding
the shard's own history keeps a stalling shard (whose
queued-behind-the-stall rounds inflate its own percentiles) from vetoing
its own hedges.  A lane whose request was abandoned — the twin when its
hedge lost, the PRIMARY when a hedge won its slot — still has that request
in flight on its socket and is reconnected in place before its next use:
without this, one stalled read blacks out the primary lane for the whole
stall and every round issued meanwhile must win a fresh hedge race to
survive.  Hedging cannot change results: both legs ask the same worker the
same deterministic question, so whichever reply wins is bit-identical.
"""

from __future__ import annotations

import contextlib
import dataclasses
import selectors
import socket
import threading
import time

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.store.planner import TopKPartial
from repro.store.sharded import ShardedSketchStore

from . import faults as faults_mod
from . import wire
from .wire import Message, MsgType


class TransportError(RuntimeError):
    """Base for coordinator-visible transport failures."""


class WorkerError(TransportError):
    """A worker answered with ERROR, died, or broke the stream."""


class TransportTimeout(TransportError):
    """The fan-out deadline expired with replies still pending."""


class Overloaded(WorkerError):
    """The worker (or the streaming front) rejected the request instead of
    queueing it.  Provably clean: the request was NOT executed (an
    OVERLOADED reply arrives over an intact stream), so a retry within the
    caller's budget and deadline is always safe — this error never carries
    ``dirty`` or ``unknown_outcome``.  ``retry_after_s`` is the server's
    backoff hint (roughly one queue drain)."""

    def __init__(self, msg: str, *, retry_after_s: float = 0.0):
        super().__init__(msg)
        self.retry_after_s = float(retry_after_s)
        self.retryable = True


class DeadlineExceeded(TransportError):
    """The request's absolute deadline passed — either before sending
    (checked coordinator-side) or on arrival at the worker, which dropped
    the work before computing.  Not retryable: the caller is gone."""


# -- ambient wire deadline ----------------------------------------------------
# The ``ShardBackend`` protocol (add/start_query/...) has no deadline
# parameter, and growing one through every layer would churn each backend
# for a field only the transport consumes.  Like the trace context, the
# deadline is ambient: callers wrap the query in ``deadline_scope`` and the
# remote backends stamp ``wire.DEADLINE_FIELD`` onto each outgoing request.

_ambient = threading.local()


def current_deadline() -> float | None:
    """Absolute deadline (unix seconds) of the enclosing scope, or None."""
    return getattr(_ambient, "deadline", None)


@contextlib.contextmanager
def deadline_scope(abs_deadline_s: float | None):
    """Set the ambient absolute deadline for this thread.  Scopes nest;
    an inner scope can only tighten (the effective deadline is the min)."""
    prev = current_deadline()
    eff = abs_deadline_s
    if eff is not None and prev is not None:
        eff = min(eff, prev)
    _ambient.deadline = eff if eff is not None else prev
    try:
        yield
    finally:
        _ambient.deadline = prev


def attach_deadline(fields: dict) -> dict:
    """Stamp the ambient deadline (if any) onto outgoing request fields."""
    dl = current_deadline()
    if dl is not None:
        fields[wire.DEADLINE_FIELD] = wire.deadline_us(dl)
    return fields


def check_deadline(what: str = "request") -> None:
    """Raise ``DeadlineExceeded`` when the ambient deadline already passed
    — don't put a frame on the wire for an answer nobody will read."""
    dl = current_deadline()
    if dl is not None and time.time() > dl:
        raise DeadlineExceeded(
            f"{what} deadline passed {time.time() - dl:.3f}s ago "
            "before the request was sent")


class RetryBudget:
    """Token bucket capping retry traffic as a fraction of primary traffic.

    Every primary request deposits ``ratio`` tokens; every retry — a hedge
    (timer- or failure-triggered), a replica-failover re-ask, or a
    ``StreamConfig.retries`` re-dispatch — spends one.  ``cap`` bounds the
    burst; ``floor_per_s`` trickles tokens in regardless of traffic so a
    quiet plane can still retry (without it, the first failure after an
    idle stretch on an empty bucket would be unretryable forever).

    One budget is shared across ALL retry sources of a plane (built in
    ``connect_sharded`` / ``connect_replicated``): under a brownout the
    sources compete for the same bounded pool, so total retry traffic
    stays <= ``ratio`` x primary + the floor instead of each layer
    amplifying independently — the retry-storm cap.

    ``unlimited=True`` disables the cap (the bench's "unbudgeted baseline"
    and a pre-PR-10 escape hatch).
    """

    def __init__(self, *, ratio: float = 0.2, cap: float = 100.0,
                 floor_per_s: float = 1.0, unlimited: bool = False):
        self.ratio = float(ratio)
        self.cap = float(cap)
        self.floor_per_s = float(floor_per_s)
        self.unlimited = bool(unlimited)
        self._tokens = self.cap
        self._last = time.monotonic()
        self._lock = threading.Lock()
        reg = obs_metrics.default()
        self._g_tokens = reg.gauge("transport.retry_budget.tokens")
        self._g_tokens.set(self._tokens)
        self._m_spent = reg.counter("transport.retry_budget.spent")
        self._m_exhausted = reg.counter("transport.retry_budget.exhausted")
        self.n_primaries = 0
        self.n_spent = 0
        self.n_denied = 0

    def _refill_locked(self) -> None:
        now = time.monotonic()
        self._tokens = min(self.cap,
                           self._tokens + self.floor_per_s *
                           (now - self._last))
        self._last = now

    @property
    def tokens(self) -> float:
        return self._tokens

    def note_primary(self, n: int = 1) -> None:
        """Deposit for ``n`` primary requests (``ratio`` tokens each)."""
        with self._lock:
            self.n_primaries += n
            self._refill_locked()
            self._tokens = min(self.cap, self._tokens + self.ratio * n)
            self._g_tokens.set(self._tokens)

    def try_spend(self, n: int = 1) -> bool:
        """Take ``n`` tokens for a retry; False (and the retry must not
        happen) when the budget is exhausted."""
        if self.unlimited:
            self.n_spent += n
            self._m_spent.inc(n)
            return True
        with self._lock:
            self._refill_locked()
            if self._tokens >= n:
                self._tokens -= n
                self.n_spent += n
                self._m_spent.inc(n)
                self._g_tokens.set(self._tokens)
                return True
            self.n_denied += n
            self._m_exhausted.inc(n)
            return False


class CircuitBreaker:
    """Per-lane circuit breaker: closed -> open after ``fail_threshold``
    consecutive stream-level failures -> half-open probe after ``reset_s``.

    Failures that count are lane-health events — broken streams, timeouts
    (``mark_broken`` / ``note_timeout``) — not application ERROR replies,
    which arrive over an intact stream and say nothing about the lane.  A
    flapping replica's lane opens and is *skipped* by replica failover and
    primary selection until its half-open probe succeeds, so each flap
    costs one probe instead of one full lane-timeout per read.
    """

    CLOSED, OPEN, HALF_OPEN = 0, 1, 2
    _STATE_NAMES = {0: "closed", 1: "open", 2: "half-open"}

    def __init__(self, *, fail_threshold: int = 5, reset_s: float = 2.0,
                 name: str = ""):
        self.fail_threshold = int(fail_threshold)
        self.reset_s = float(reset_s)
        self.state = self.CLOSED
        self.failures = 0
        self._opened_t = 0.0
        self._probe_t = 0.0
        self._probing = False
        self._lock = threading.Lock()
        reg = obs_metrics.default()
        self._g_state = reg.gauge(f"transport.breaker.{name}.state") \
            if name else None
        self._m_opens = reg.counter("transport.breaker.opens")
        if self._g_state is not None:
            self._g_state.set(self.CLOSED)

    def _set_state(self, s: int) -> None:
        self.state = s
        if self._g_state is not None:
            self._g_state.set(s)

    @property
    def state_name(self) -> str:
        return self._STATE_NAMES[self.state]

    @property
    def healthy(self) -> bool:
        """Non-consuming: True only when fully closed (ordering hint for
        primary selection; ``allow`` is the send-time decision)."""
        return self.state == self.CLOSED

    def allow(self) -> bool:
        """May a request be sent on this lane now?  In half-open state only
        one probe is admitted at a time (a stuck probe is recycled after
        ``reset_s`` so a lost outcome cannot wedge the lane shut)."""
        with self._lock:
            if self.state == self.CLOSED:
                return True
            now = time.monotonic()
            if self.state == self.OPEN:
                if now - self._opened_t < self.reset_s:
                    return False
                self._set_state(self.HALF_OPEN)
                self._probing = False
            if self._probing and now - self._probe_t < self.reset_s:
                return False
            self._probing = True
            self._probe_t = now
            return True

    def record_success(self) -> None:
        with self._lock:
            self.failures = 0
            self._probing = False
            if self.state != self.CLOSED:
                self._set_state(self.CLOSED)

    def record_failure(self) -> None:
        with self._lock:
            self.failures += 1
            tripped = (self.state == self.HALF_OPEN
                       or (self.state == self.CLOSED
                           and self.failures >= self.fail_threshold))
            if tripped:
                self._set_state(self.OPEN)
                self._opened_t = time.monotonic()
                self._probing = False
                self._m_opens.inc()


def _partial_from(msg: Message) -> TopKPartial:
    return TopKPartial(np.asarray(msg["ids"], np.int64),
                       np.asarray(msg["scores"], np.float32),
                       np.asarray(msg["has"], bool))


@dataclasses.dataclass(frozen=True)
class HedgePolicy:
    """When and how to re-issue a slow shard's read on its twin connection.

    With ``delay_s`` unset, the hedge delay for a shard is
    ``multiplier * q(quantile)`` of its PEER connections' observed reply
    SKEW — lateness relative to each round's fastest reply — clamped to
    ``[min_delay_s, max_delay_s]``, with the timer armed when the current
    round's first reply lands.  No hedge fires until ``min_samples`` peer
    skews have been observed (an unwarmed plane has no signal to derive a
    delay from), and single-shard groups never hedge adaptively (no peers,
    no skew).  ``delay_s`` (seconds) short-circuits all of that: a fixed
    delay from round start, active from the first request (``0.0`` is
    valid and hedges immediately — a stress setting).
    """

    delay_s: float | None = None
    quantile: float = 0.9
    multiplier: float = 2.0
    min_delay_s: float = 0.0005
    max_delay_s: float = 1.0
    min_samples: int = 32


class ShardConnection:
    """One framed connection to a shard worker (blocking request/reply).

    Every request gets a fresh sequence number and only the reply echoing
    it is accepted; replies with older seqs are stale leftovers of a failed
    fan-out (the worker answered after the coordinator stopped waiting) and
    are discarded, so one failed broadcast cannot desynchronize the
    connection for every later request.
    """

    def __init__(self, address: tuple[str, int], *, timeout: float = 30.0,
                 max_payload: int = wire.MAX_PAYLOAD,
                 deadline_name: str = "timeout",
                 shard: int = -1, replica: int = 0):
        self.address = tuple(address)
        self.timeout = timeout
        self.deadline_name = deadline_name   # which knob set the deadline
        self.max_payload = max_payload
        # (shard, replica) lane labels: every WorkerError/TransportTimeout
        # raised for this connection names the exact lane (``_name``), and
        # the lane-labelled counters below let failover tooling tell WHICH
        # replica of WHICH shard is timing out / going stale — an R-way
        # plane is unoperable when all its lanes alias one counter series
        self.shard = shard
        self.replica = replica
        self._seq = 0
        self.broken: str | None = None     # why this conn is unusable
        # registry handles are bound once at construction (the disabled
        # registry hands out shared no-ops, so a disabled plane pays zero
        # lookup cost per request); per-connection plain tallies feed the
        # which-shard/which-seq error text
        reg = obs_metrics.default()
        self._m_stale = reg.counter("transport.client.stale_replies")
        self._m_timeout = reg.counter("transport.client.timeouts")
        self._m_bytes_out = reg.counter("transport.client.bytes_out")
        self._m_bytes_in = reg.counter("transport.client.bytes_in")
        lane = f".shard{shard}.replica{replica}" if shard >= 0 else ""
        self._m_stale_lane = reg.counter(
            f"transport.client.stale_replies{lane}") if lane else None
        self._m_timeout_lane = reg.counter(
            f"transport.client.timeouts{lane}") if lane else None
        self.n_stale = 0                   # stale replies discarded here
        self.n_timeouts = 0
        self.last_stale_seq: int | None = None
        # per-lane breaker: stream-level failures below feed it; replica
        # failover and primary selection consult it (state rides the
        # lane-labelled gauge so a dump shows WHICH lane is open)
        bname = f"shard{shard}.replica{replica}" if shard >= 0 else ""
        self.breaker = CircuitBreaker(name=bname)
        try:
            self.sock = socket.create_connection(self.address,
                                                 timeout=timeout)
        except OSError as e:
            raise WorkerError(f"cannot connect to worker at "
                              f"{address[0]}:{address[1]}: {e}") from e
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)

    def next_seq(self) -> int:
        # seq 0 is reserved for connection-level worker errors (a decode
        # failure the worker cannot attribute to any request)
        self._seq = (self._seq + 1) & 0xFFFFFFFF or 1
        return self._seq

    def mark_broken(self, why: str) -> None:
        """Poison the connection (framing no longer trustworthy)."""
        self.broken = why
        self.breaker.record_failure()
        self.close()

    def check_usable(self) -> None:
        if self.broken:
            raise WorkerError(
                f"worker {self._name} connection unusable: {self.broken}")

    def note_stale(self, seq: int) -> None:
        """Record one discarded stale reply (registry + which-seq tally)."""
        self.n_stale += 1
        self.last_stale_seq = seq
        self._m_stale.inc()
        if self._m_stale_lane is not None:
            self._m_stale_lane.inc()

    def note_timeout(self) -> None:
        """Record one deadline expiry against this lane (aggregate + the
        (shard, replica)-labelled series failover logs correlate with)."""
        self.n_timeouts += 1
        self.breaker.record_failure()
        self._m_timeout.inc()
        if self._m_timeout_lane is not None:
            self._m_timeout_lane.inc()

    def _stale_note(self) -> str:
        if not self.n_stale:
            return ""
        return (f"; {self.n_stale} stale repl"
                f"{'y' if self.n_stale == 1 else 'ies'} discarded on this "
                f"connection (last stale seq={self.last_stale_seq})")

    def request(self, msg: Message) -> Message:
        """Send one frame, read its reply (raises on ERROR replies)."""
        self.check_usable()
        check_deadline(msg.type.name)
        # deterministic client-side faults (coordinator perspective): a
        # plan "drop" severs this lane's socket pre-send, so the failure
        # paths below run on a reproducible schedule
        for ev in faults_mod.client_events(msg.type.name.lower()):
            if ev.kind == "delay":
                faults_mod.FaultPlan.sleep(ev)
            else:        # sever the stream; the send below fails in-path
                try:
                    self.sock.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
        msg.seq = self.next_seq()
        try:
            wire.send_message(self.sock, msg, meter=self._m_bytes_out.inc)
            while True:
                reply = wire.recv_message(self.sock,
                                          max_payload=self.max_payload,
                                          meter=self._m_bytes_in.inc)
                if reply.seq == msg.seq:
                    break
                if reply.type == MsgType.ERROR and reply.seq == 0:
                    break      # connection-level worker error: surface it
                # stale reply from an abandoned fan-out: drop and re-read
                self.note_stale(reply.seq)
        except socket.timeout as e:
            # the frame may have been cut mid-send or mid-read; seq pairing
            # only recovers frame-aligned streams, so poison the connection
            self.note_timeout()
            self.mark_broken(f"timed out mid-{msg.type.name} seq={msg.seq}")
            raise TransportTimeout(
                f"worker {self._name} timed out after {self.timeout}s "
                f"({self.deadline_name}) "
                f"({msg.type.name} seq={msg.seq}{self._stale_note()})") from e
        except (wire.WireError, OSError) as e:
            self.mark_broken(f"stream failed during {msg.type.name} "
                             f"seq={msg.seq}: {type(e).__name__}")
            raise WorkerError(
                f"worker {self._name} failed during {msg.type.name} "
                f"seq={msg.seq}: {type(e).__name__}: {e}"
                f"{self._stale_note()}") from e
        return self._check(reply)

    def _check(self, reply: Message) -> Message:
        # a reply may carry the worker's finished trace spans next to the
        # echoed seq — fold them into this process's tracer so coordinator
        # and worker legs stitch into one trace
        blob = reply.fields.get(wire.TRACE_SPANS_FIELD)
        if blob:
            obs_trace.default().absorb_json(blob)
        if reply.type == MsgType.OVERLOADED:
            # the stream is intact and the worker provably did not execute
            # the request — lane-healthy for the breaker, clean to retry
            self.breaker.record_success()
            reason = reply.fields.get("reason", "admission")
            if reason == "expired":
                raise DeadlineExceeded(
                    f"worker {self._name} dropped the request: its "
                    f"deadline passed before computing (seq={reply.seq})")
            raise Overloaded(
                f"worker {self._name} shed the request at its admission "
                f"gate (depth {reply.fields.get('gate_depth', '?')}/"
                f"{reply.fields.get('gate_limit', '?')}, seq={reply.seq})",
                retry_after_s=int(reply.fields.get("retry_after_us", 0))
                / 1e6)
        if reply.type == MsgType.ERROR:
            err = WorkerError(f"worker {self._name}: {reply['error']} "
                              f"(seq={reply.seq}{self._stale_note()})")
            # worker says the failed op mutated its store (ADD landed
            # partially): the coordinator must not treat a retry as safe
            err.dirty = bool(reply.fields.get("dirty", 0))
            # an ERROR reply arrives over an intact stream, so unless the
            # worker said dirty, the failed op provably did NOT mutate its
            # store — which is why this error carries no ``unknown_outcome``
            # flag and a clean validation failure never poisons the plane
            # (the write-path decision in ``ShardedSketchStore._scatter``
            # keys off dirty/unknown_outcome)
            raise err
        self.breaker.record_success()
        return reply

    def reconnect(self) -> None:
        """Replace the socket in place: same worker, fresh stream, fresh
        seq space, ``broken`` cleared.  Object identity is preserved so
        every holder of this connection (``RemoteShard``, fan-out maps,
        skew histograms) sees the fresh lane without rebinding.  Used by
        the fan-out's dirty-lane hygiene: a lane abandoned mid-request
        still has a worker thread serving a question nobody will read —
        possibly sitting in the very stall that was hedged around — and
        reusing it would queue the next request behind exactly the
        latency hedging exists to cut."""
        self.close()
        try:
            self.sock = socket.create_connection(self.address,
                                                 timeout=self.timeout)
        except OSError as e:
            raise WorkerError(f"cannot reconnect to worker at "
                              f"{self.address[0]}:{self.address[1]}: "
                              f"{e}") from e
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._seq = 0
        self.broken = None

    @property
    def _name(self) -> str:
        if self.shard >= 0:
            return (f"shard {self.shard} replica {self.replica} at "
                    f"{self.address[0]}:{self.address[1]}")
        return f"{self.address[0]}:{self.address[1]}"

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


class _Pending:
    """Handle for one in-flight fan-out request.

    ``decode`` turns the reply message into the caller's value (a partial
    for QUERY/BRUTE, a row count for ADD).  ``reset_on_error`` is the query
    path's behavior: a failed take abandons the sibling replies so the next
    round starts clean.  The write path passes ``False`` — it consumes
    every pending of the round itself, because the poison decision needs
    ALL per-shard outcomes, not just the first failure.
    """

    lazy = False          # remote work runs whether or not result() is read

    def __init__(self, group: "FanoutGroup", conn: ShardConnection, *,
                 decode=_partial_from, reset_on_error: bool = True):
        self._group = group
        self._conn = conn
        self._decode = decode
        self._reset_on_error = reset_on_error

    def result(self):
        self._group.flush()
        return self._decode(self._group.take(
            self._conn, reset_on_error=self._reset_on_error))

    @property
    def latency_s(self) -> float | None:
        """Seconds from fan-out start to this shard's reply landing — the
        per-shard skew signal (None until the reply has arrived)."""
        return self._group._reply_lat.get(self._conn)


class FanoutGroup:
    """Nonblocking broadcast/gather over a set of shard connections.

    ``submit`` queues one outgoing frame per connection; the first
    ``result()``/``flush()`` drives every socket through one ``selectors``
    loop under a single deadline.  Sockets are nonblocking only inside the
    loop, so the blocking request path stays usable between fan-outs.

    With a ``HedgePolicy`` and per-shard twin connections (``hedge_conns``),
    a submitted request marked ``hedgeable`` may be re-issued on the twin
    when its reply is late (see the module docstring for the semantics).
    """

    def __init__(self, conns: list[ShardConnection], *,
                 timeout: float = 30.0, hedge: HedgePolicy | None = None,
                 hedge_conns: dict[ShardConnection, ShardConnection]
                 | None = None,
                 deadline_name: str = "timeout",
                 budget: RetryBudget | None = None):
        self.conns = list(conns)
        self.timeout = timeout
        self.hedge = hedge
        # the plane-wide retry budget: submits deposit, hedges (timer- and
        # failure-triggered) spend; replica failover and stream retries
        # share this same bucket (see RetryBudget)
        self.budget = budget if budget is not None else RetryBudget()
        self._twin = dict(hedge_conns or {})
        self._deadline_name = deadline_name
        self._out: dict[ShardConnection, list] = {}     # pending send buffers
        self._out_total: dict[ShardConnection, int] = {}
        self._in: dict[ShardConnection, bytearray] = {}
        self._want: dict[ShardConnection, int] = {}     # expected reply seq
        self._replies: dict[ShardConnection, Message] = {}
        self._msgs: dict[ShardConnection, Message] = {}  # hedgeable, per round
        # legs that may fail WITHOUT killing the round (replicated writes:
        # one dead replica degrades redundancy, the sibling legs complete);
        # a tolerant leg's failure is parked here and surfaced at take()
        self._tolerant: set[ShardConnection] = set()
        self._leg_errors: dict[ShardConnection, BaseException] = {}
        self._round_error: BaseException | None = None  # why the round died
        reg = obs_metrics.default()
        self._m_timeout = reg.counter("transport.client.timeouts")
        self._m_bytes_out = reg.counter("transport.client.bytes_out")
        self._m_bytes_in = reg.counter("transport.client.bytes_in")
        self._m_hedges = reg.counter("transport.client.hedges")
        self._m_hedge_wins = reg.counter("transport.client.hedge_wins")
        self._m_redials = reg.counter("transport.client.lane_redials")
        # lanes (twin OR primary) whose last request was abandoned
        # mid-flight: the worker is still serving that request on the
        # socket, so the lane is reconnected before its next use (see
        # _redial).  Primaries go dirty when a hedge wins their slot;
        # twins when their hedge loses or the round dies under them.
        self._dirty: set[ShardConnection] = set()
        self._h_round = reg.histogram("transport.client.fanout")
        self._round_t0 = 0.0               # when the current round started
        self._reply_lat: dict[ShardConnection, float] = {}
        # private per-shard reply-SKEW histograms — each unhedged round
        # records how much later than the round's fastest reply each shard
        # landed.  Owned by THIS group (not the registry) so another plane
        # in the same process cannot pollute the signal the hedge delay is
        # derived from; absolute latencies live in the registry's
        # ``query.shard<i>.partial`` instead
        self._lat_h = {c: obs_metrics.Histogram(f"fanout.skew.{i}")
                       for i, c in enumerate(self.conns)}
        self.n_hedges = 0                  # hedges fired (plain tallies)
        self.n_hedge_wins = 0              # hedges whose reply won the slot
        self.n_redials = 0                 # abandoned lanes reconnected

    def submit(self, conn: ShardConnection, msg: Message, *,
               decode=_partial_from, reset_on_error: bool = True,
               hedgeable: bool = False, tolerate: bool = False,
               keep_round_on_error: bool = False) -> _Pending:
        """Queue one outgoing frame.  ``tolerate`` marks the leg as allowed
        to fail without killing the round (its failure is surfaced at its
        own ``take`` instead — replicated writes use this so one dead
        replica costs redundancy, not the plane).  ``keep_round_on_error``
        makes a submit-phase failure clean up only THIS leg's slots, so a
        replica set can retry the submit on a sibling lane without
        abandoning everything already queued this round."""
        if conn in self._out or conn in self._replies:
            raise TransportError("one outstanding fan-out request per shard")
        if not self._out and not self._replies:
            self._round_error = None      # a fresh round: forget old failures
            self._reply_lat.clear()
            self._msgs.clear()
            self._tolerant.clear()
            self._leg_errors.clear()
        check_deadline(msg.type.name)
        for ev in faults_mod.client_events(msg.type.name.lower()):
            if ev.kind == "delay":
                faults_mod.FaultPlan.sleep(ev)
            else:                  # sever the lane pre-send (deterministic)
                try:
                    conn.sock.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
        self.budget.note_primary()
        try:
            # a dirty lane (its last request was abandoned to a hedged win
            # or a dead round) is reconnected before carrying new traffic;
            # see _redial for why reuse would defeat the hedge
            if conn in self._dirty and not self._redial(conn):
                raise WorkerError(
                    f"worker {conn._name} unreachable while redialing a "
                    "lane with an abandoned request in flight")
            conn.check_usable()
            msg.seq = conn.next_seq()
            self._want[conn] = msg.seq
            self._out[conn] = [memoryview(b) if not isinstance(b, memoryview)
                               else b for b in wire.encode_message(msg)]
            self._out_total[conn] = sum(b.nbytes for b in self._out[conn])
            self._in[conn] = bytearray()
            # only idempotent reads are ever eligible: the write path never
            # passes hedgeable=True, so a retry can't double-index a batch
            if hedgeable and self.hedge is not None \
                    and self._twin.get(conn) is not None:
                self._msgs[conn] = msg
            if tolerate:
                self._tolerant.add(conn)
        except BaseException:
            if keep_round_on_error:
                # drop only this leg; siblings already queued stay live
                self._out.pop(conn, None)
                self._out_total.pop(conn, None)
                self._in.pop(conn, None)
                self._want.pop(conn, None)
                self._msgs.pop(conn, None)
            else:
                self.reset()  # abandon siblings already queued this round
            raise
        return _Pending(self, conn, decode=decode,
                        reset_on_error=reset_on_error)

    def take(self, conn: ShardConnection, *,
             reset_on_error: bool = True) -> Message:
        leg_err = self._leg_errors.pop(conn, None)
        if leg_err is not None:
            # this tolerant leg failed mid-round while its siblings went on
            # to complete; after its frame hit the wire nobody can prove
            # whether the worker processed the request
            err = WorkerError(
                f"worker {conn._name} failed mid-fan-out: "
                f"{type(leg_err).__name__}: {leg_err}")
            err.unknown_outcome = True
            raise err from leg_err
        if conn not in self._replies:
            if self._round_error is None:
                raise TransportError(
                    f"no reply pending for worker {conn._name} "
                    "(already taken, or never submitted this round)")
            # this pending's round already died in flush() (stream break /
            # timeout): every sibling surfaces the same failure instead of
            # a bare KeyError — and nobody can tell whether the worker
            # processed the request before the stream broke
            err = WorkerError(
                f"worker {conn._name}: fan-out round failed before its "
                f"reply was read ({type(self._round_error).__name__}: "
                f"{self._round_error})")
            err.unknown_outcome = True
            raise err from self._round_error
        try:
            return conn._check(self._replies.pop(conn))
        except WorkerError:
            if reset_on_error:
                # the round is abandoned: drop sibling replies so the next
                # round starts clean instead of tripping the outstanding
                # guard (the write path instead consumes every reply)
                self.reset()
            raise

    def reset(self) -> None:
        """Drop every in-flight slot of the current (failed) round."""
        self._out.clear()
        self._out_total.clear()
        self._in.clear()
        self._replies.clear()
        self._msgs.clear()
        self._tolerant.clear()
        self._leg_errors.clear()

    # -- membership (replica failover rewires lanes between rounds) ----------
    def set_twin(self, primary: ShardConnection,
                 twin: ShardConnection | None) -> None:
        """Point ``primary``'s hedge twin at ``twin`` (None removes it).
        A replicated plane wires each shard's twin to ANOTHER replica's
        connection, so a hedge is a failover to a different machine —
        bit-identical replies either way, since replicas hold the same
        rows (writes fan out to all lanes before any later read)."""
        if twin is None:
            self._twin.pop(primary, None)
        else:
            self._twin[primary] = twin

    def adopt_conn(self, conn: ShardConnection) -> None:
        """Add a connection to the group (a resynced replica rejoining):
        it gets a skew histogram and the blocking-mode restore in flush."""
        if conn not in self.conns:
            self.conns.append(conn)
            self._lat_h[conn] = obs_metrics.Histogram(
                f"fanout.skew.{len(self.conns) - 1}")

    def retire_conn(self, conn: ShardConnection) -> None:
        """Remove a connection from the group (its lane went down); twin
        mappings through it are dropped — callers re-wire via set_twin."""
        if conn in self.conns:
            self.conns.remove(conn)
        self._lat_h.pop(conn, None)
        self._dirty.discard(conn)
        self._twin.pop(conn, None)
        for p, t in list(self._twin.items()):
            if t is conn:
                del self._twin[p]

    def ensure_clean(self, conn: ShardConnection) -> None:
        """Make a lane usable for a blocking request: redial it if it was
        poisoned or abandoned mid-request (the read-failover path calls
        this before re-asking a sibling replica).  Raises ``WorkerError``
        when the worker is unreachable."""
        if conn.broken or conn in self._dirty:
            if not self._redial(conn):
                raise WorkerError(
                    f"worker {conn._name} unreachable while redialing")

    def _hedge_delay(self, conn: ShardConnection) -> float | None:
        """Seconds until ``conn``'s request may hedge, or None (never)."""
        p = self.hedge
        if p is None or conn not in self._msgs:
            return None                  # no policy / not a hedgeable read
        if p.delay_s is not None:
            return max(float(p.delay_s), 0.0)
        # the delay derives from reply SKEW — how much later than its
        # round's first reply each shard lands — and only from the PEER
        # connections' skew, never conn's own.  Absolute latencies are the
        # wrong signal twice over: a coordinator-side pause (GC, a compile,
        # a scheduler hiccup) delays every reply of a round together and
        # would inflate an absolute-latency percentile into a delay that
        # never fires, and a stalling shard queues the rounds behind each
        # stall on its own socket, so its own history grows until it vetoes
        # its own hedges.  Peer skew is immune to both.  (Single-shard
        # groups have no peers, hence no skew signal: adaptive mode never
        # hedges them — set delay_s to hedge a lone shard.)
        hists = [h for c, h in self._lat_h.items() if c is not conn]
        total = sum(h.count for h in hists)
        if not hists or total < p.min_samples:
            return None                  # no skew signal yet: don't guess
        counts = [sum(h.counts[i] for h in hists)
                  for i in range(len(hists[0].counts))]
        lat = obs_metrics._quantile_from_counts(counts, total, p.quantile)
        return min(max(p.multiplier * lat, p.min_delay_s), p.max_delay_s)

    def _redial(self, conn: ShardConnection) -> bool:
        """Reconnect an abandoned lane in place; False when the worker is
        unreachable right now.

        A lane whose last request was abandoned (its hedge race was lost,
        or a hedge won its slot) still has that request in flight: the
        worker's thread for the socket is executing it — and may be
        sitting in the very stall that was hedged around — so the lane's
        next request would queue behind exactly the latency hedging
        exists to cut.  This matters most for PRIMARIES: without the
        redial, one stalled read blacks the primary lane out for the full
        stall, every round issued meanwhile must hedge to survive, and
        each of those hedges gives the twin lane its own chance to stall
        — the tail failure becomes a correlated burst.  Reconnecting the
        abandoned lane ends the blackout at the first hedged win.  A lane
        cut mid-frame (poisoned) is also recovered here: the fresh stream
        starts frame-aligned with a fresh seq space."""
        try:
            conn.reconnect()
        except TransportError:
            return False              # worker unreachable: lane stays dirty
        self._dirty.discard(conn)
        self.n_redials += 1
        self._m_redials.inc()
        return True

    # -- the event loop ------------------------------------------------------
    def flush(self) -> None:
        """Drive all submitted requests to completion or raise.  A failed
        fan-out clears every in-flight slot (including replies that did
        land), so the group stays usable after the exception surfaces —
        except connections whose request frame was cut mid-send, which are
        poisoned (``ShardConnection.broken``) and raise on further use."""
        try:
            self._flush()
        except BaseException as e:
            # after frames hit the wire nobody can prove which workers
            # processed their request — writes must treat this as a
            # maybe-wrote failure (``unknown_outcome``), and siblings of the
            # dead round re-raise it from take()
            e.unknown_outcome = True
            self._round_error = e
            self._replies.clear()
            raise

    def _flush(self) -> None:
        pending = set(self._out)
        if not pending:
            return
        self._round_t0 = time.perf_counter()
        # the caller's absolute deadline (if any) can only tighten the
        # round's wall clock — a round that cannot answer in time should
        # fail at the deadline, not keep S workers busy for the full knob
        budget_s = self.timeout
        amb = current_deadline()
        if amb is not None:
            budget_s = min(budget_s, max(amb - time.time(), 0.0))
        deadline = time.monotonic() + budget_s
        # hedge bookkeeping, all per-round: when a shard's request hedges,
        # ``owner`` maps the fired twin leg back to its primary and
        # ``fired`` the primary to its twin — two legs, one reply slot
        owner: dict[ShardConnection, ShardConnection] = {}
        fired: dict[ShardConnection, ShardConnection] = {}
        hedge_at: dict[ShardConnection, float] = {}
        unhedged_done: dict[ShardConnection, float] = {}
        # a FIXED delay arms at round start; the adaptive (skew-derived)
        # delay arms when the round's FIRST reply lands — "this shard is
        # late relative to its peers" only exists once a peer has answered,
        # and a round-start timer would misfire on every coordinator-side
        # pause that delays the whole round together
        if self.hedge is not None and self.hedge.delay_s is not None:
            now = time.monotonic()
            for conn in pending:
                d = self._hedge_delay(conn)
                if d is not None:
                    hedge_at[conn] = now + d
        sel = selectors.DefaultSelector()

        def _cleanup_leg(conn: ShardConnection) -> None:
            try:
                sel.unregister(conn.sock)
            except (KeyError, ValueError):
                pass
            pending.discard(conn)
            self._out.pop(conn, None)
            self._out_total.pop(conn, None)
            self._in.pop(conn, None)

        def _settle_loser(loser: ShardConnection, why: str) -> None:
            # the other leg won this slot: a loser cut mid-frame can no
            # longer be framed and is poisoned; fully-sent-nothing-read
            # stays usable — its late reply is a frame-aligned stale the
            # seq pairing discards on the connection's next use
            left = sum(b.nbytes for b in self._out.get(loser, []))
            if 0 < left < self._out_total.get(loser, 0):
                loser.mark_broken(f"request frame cut mid-send by {why}")
            elif len(self._in.get(loser, b"")) and not left:
                loser.mark_broken(f"reply frame partially consumed by {why}")
            _cleanup_leg(loser)

        def _fire_hedge(primary: ShardConnection) -> bool:
            twin = self._twin.get(primary)
            msg = self._msgs.get(primary)
            if twin is None or msg is None:
                return False
            # every hedge — timer-fired tail cut or failure-triggered
            # failover — is retry traffic and draws from the shared budget;
            # an exhausted budget means the hedge simply does not fire (the
            # primary leg keeps its chance, or the round fails and the
            # caller's budgeted retry path takes over)
            if not self.budget.try_spend():
                return False
            if (twin.broken or twin in self._dirty) \
                    and not self._redial(twin):
                return False          # worker unreachable: no hedge now
            # same request, re-encoded under the twin's own seq space; the
            # worker serves both connections, so whichever leg's reply
            # lands first carries the identical deterministic answer
            msg.seq = twin.next_seq()
            self._want[twin] = msg.seq
            self._out[twin] = [b if isinstance(b, memoryview)
                               else memoryview(b)
                               for b in wire.encode_message(msg)]
            self._out_total[twin] = sum(b.nbytes for b in self._out[twin])
            self._in[twin] = bytearray()
            twin.sock.setblocking(False)
            sel.register(twin.sock, selectors.EVENT_WRITE, twin)
            pending.add(twin)
            owner[twin] = primary
            fired[primary] = twin
            self.n_hedges += 1
            self._m_hedges.inc()
            return True

        def _leg_failed(conn: ShardConnection, err: BaseException) -> bool:
            """One leg's stream broke mid-round.  True when the slot
            survives on the other leg (possibly a hedge fired right now) —
            the failed leg is poisoned and retired; False when the failure
            is terminal and the round must die."""
            primary = owner.get(conn)
            if primary is not None:          # the hedge leg died: drop it
                conn.mark_broken(
                    f"hedge leg failed: {type(err).__name__}")
                _cleanup_leg(conn)
                return primary in pending or primary in self._replies
            twin = fired.get(conn)
            live = twin is not None and twin in pending
            if not live and fired.get(conn) is None:
                live = _fire_hedge(conn)     # failure-triggered hedge
            if not live:
                return False
            conn.mark_broken(
                f"stream failed mid-fan-out: {type(err).__name__}")
            _cleanup_leg(conn)
            return True

        try:
            for conn in pending:
                conn.sock.setblocking(False)
                sel.register(conn.sock, selectors.EVENT_WRITE, conn)
            while pending:
                now = time.monotonic()
                budget = deadline - now
                if budget <= 0:
                    waiting = {owner.get(c, c) for c in pending}
                    if waiting and waiting <= self._tolerant:
                        # every leg still pending opted into per-leg
                        # failure: time each out individually (lane down,
                        # outcome unknown) and let the round complete on
                        # the replies that DID land
                        for c in sorted(waiting, key=id):
                            c.note_timeout()
                            e = TransportTimeout(
                                f"worker {c._name} timed out after "
                                f"{self.timeout}s ({self._deadline_name}) "
                                f"(seq={self._want.get(c)})")
                            self._leg_errors[c] = e
                            c.mark_broken("timed out mid-fan-out")
                            _cleanup_leg(c)
                        for c in list(pending):   # stray hedge legs
                            _cleanup_leg(c)
                        continue
                    self._m_timeout.inc()
                    for c in waiting:
                        if c._m_timeout_lane is not None:
                            c._m_timeout_lane.inc()
                    names = sorted(f"{c._name} (seq={self._want.get(c)})"
                                   for c in waiting)
                    raise TransportTimeout(
                        f"fan-out timed out after {self.timeout}s "
                        f"({self._deadline_name}) waiting on "
                        f"{len(names)} shard(s): {', '.join(names)}")
                for c in [c for c, t in hedge_at.items()
                          if t <= now and c in pending and c not in fired]:
                    if not _fire_hedge(c):
                        hedge_at.pop(c, None)      # twin unusable: give up
                nxt = min((t for c, t in hedge_at.items()
                           if c in pending and c not in fired),
                          default=None)
                if nxt is not None:
                    budget = min(budget, max(nxt - now, 0.0) + 1e-4)
                for key, _ in sel.select(budget):
                    conn = key.data
                    if conn not in pending:
                        continue
                    try:
                        if self._out[conn]:
                            self._pump_send(sel, conn)
                        else:
                            self._pump_recv(sel, conn)
                    # WorkerError covers EOF mid-reply (the worker process
                    # died cleanly) — a leg failure like any stream break,
                    # so a killed replica's read fails over in-round via
                    # the failure-triggered hedge instead of killing the
                    # whole round
                    except (wire.WireError, WorkerError) as e:
                        if not _leg_failed(conn, e):
                            if conn in self._tolerant:
                                self._leg_errors[conn] = e
                                conn.mark_broken(
                                    f"stream failed mid-fan-out: "
                                    f"{type(e).__name__}")
                                _cleanup_leg(conn)
                                continue
                            if isinstance(e, WorkerError):
                                raise
                            raise WorkerError(
                                f"worker {conn._name} broke the stream: "
                                f"{type(e).__name__}: {e}") from e
                        continue
                    except OSError as e:
                        if not _leg_failed(conn, e):
                            if conn in self._tolerant:
                                self._leg_errors[conn] = e
                                conn.mark_broken(
                                    f"connection failed mid-fan-out: "
                                    f"{type(e).__name__}")
                                _cleanup_leg(conn)
                                continue
                            raise WorkerError(
                                f"worker {conn._name} connection failed: "
                                f"{e}") from e
                        continue
                    if conn in self._replies:
                        _cleanup_leg(conn)
                        primary = owner.get(conn)
                        if primary is not None:      # the hedge leg won
                            self._replies[primary] = self._replies.pop(conn)
                            self._reply_lat[primary] = \
                                self._reply_lat.pop(conn)
                            self.n_hedge_wins += 1
                            self._m_hedge_wins.inc()
                            if primary in pending:
                                # the primary's abandoned request is still
                                # being served (likely mid-stall): retire
                                # the whole lane so the NEXT round starts
                                # on a fresh one instead of queueing behind
                                # the remainder of the stall
                                self._dirty.add(primary)
                                _settle_loser(primary, "a hedged win")
                        else:
                            # only unhedged primary wins feed the skew
                            # signal (collected here, skews recorded once
                            # the round completes): a hedged win's latency
                            # includes the hedge delay and would inflate
                            # future delays
                            lat = self._reply_lat.get(conn)
                            if lat is not None and conn in self._lat_h:
                                unhedged_done[conn] = lat
                            twin = fired.get(conn)
                            if twin is not None and twin in pending:
                                # the worker is still serving the abandoned
                                # hedge on this lane: redial before reuse
                                self._dirty.add(twin)
                                _settle_loser(twin, "the primary winning")
                        if self.hedge is not None \
                                and self.hedge.delay_s is None \
                                and not hedge_at:
                            # first reply of the round landed: arm the
                            # skew timers for everyone still pending
                            now = time.monotonic()
                            for c in pending:
                                if c in owner:       # hedge legs never hedge
                                    continue
                                d = self._hedge_delay(c)
                                if d is not None:
                                    hedge_at[c] = now + d
            self._h_round.observe(time.perf_counter() - self._round_t0)
            if len(unhedged_done) > 1:
                # skew = lateness vs the round's fastest unhedged reply;
                # the 1us floor keeps the fastest shard's "zero" inside
                # the histogram's bucket range
                base = min(unhedged_done.values())
                for c, lat in unhedged_done.items():
                    self._lat_h[c].observe(max(lat - base, 1e-6))
        finally:
            # hedge legs still pending when the round ends (it died, or the
            # primary won) have abandoned requests in flight server-side
            self._dirty.update(c for c in pending if c in owner)
            sel.close()
            for conn in self.conns + list(self._twin.values()):
                try:
                    conn.sock.setblocking(True)
                    conn.sock.settimeout(conn.timeout)
                except OSError:
                    pass
            # a frame cut mid-send or mid-read leaves the stream unframed —
            # seq pairing only recovers frame-ALIGNED leftovers, so such
            # connections are poisoned instead of misparsing later frames.
            # (fully-unsent and fully-sent requests both stay in sync: the
            # worker either never sees the request or answers a reply the
            # seq discard handles.)
            for conn, bufs in self._out.items():
                left = sum(b.nbytes for b in bufs)
                if 0 < left < self._out_total.get(conn, 0):
                    conn.mark_broken(
                        "request frame cut mid-send by a failed fan-out")
            for conn in pending:
                if len(self._in.get(conn, b"")) and not self._out.get(conn):
                    conn.mark_broken(
                        "reply frame partially consumed by a failed fan-out")
            # a failed fan-out leaves no half-tracked state behind
            self._out.clear()
            self._out_total.clear()
            self._in.clear()

    def _pump_send(self, sel, conn: ShardConnection) -> None:
        bufs = self._out[conn]
        while bufs:
            try:
                sent = conn.sock.send(bufs[0])
            except BlockingIOError:
                return
            self._m_bytes_out.inc(sent)
            if sent < bufs[0].nbytes:
                bufs[0] = bufs[0].cast("B")[sent:]
                return
            bufs.pop(0)
        sel.modify(conn.sock, selectors.EVENT_READ, conn)

    def _pump_recv(self, sel, conn: ShardConnection) -> None:
        buf = self._in[conn]
        while True:
            try:
                chunk = conn.sock.recv(1 << 16)
            except BlockingIOError:
                return
            if not chunk:
                raise WorkerError(
                    f"worker {conn._name} closed the connection mid-query "
                    "(worker process died?)")
            self._m_bytes_in.inc(len(chunk))
            buf += chunk
            if self._try_complete(conn):
                return

    def _try_complete(self, conn: ShardConnection) -> bool:
        buf = self._in[conn]
        while True:
            if len(buf) < wire.HEADER_SIZE:
                return False
            mtype, seq, length, _ = wire.decode_header(
                bytes(buf[: wire.HEADER_SIZE]), max_payload=conn.max_payload)
            end = wire.HEADER_SIZE + length
            if len(buf) < end:
                return False
            if seq != self._want[conn] and \
                    not (mtype == MsgType.ERROR and seq == 0):
                conn.note_stale(seq)
                del buf[:end]      # stale reply from an abandoned fan-out
                continue
            if len(buf) > end:
                raise wire.ProtocolError("unexpected bytes after reply frame")
            # full frame validation (crc, payload decode) is wire's job —
            # one definition shared with the blocking path
            self._replies[conn] = wire.decode_frame(
                memoryview(buf)[:end], max_payload=conn.max_payload)
            self._reply_lat[conn] = time.perf_counter() - self._round_t0
            return True

    def close(self) -> None:
        for conn in self.conns:
            conn.close()
        for conn in self._twin.values():
            conn.close()


class RemoteShard:
    """``ShardBackend`` over one shard worker (see ``store.sharded``)."""

    def __init__(self, conn: ShardConnection, group: FanoutGroup,
                 hedge_conn: ShardConnection | None = None):
        self.conn = conn
        self.group = group
        self.hedge_conn = hedge_conn

    @staticmethod
    def _traced(fields: dict) -> dict:
        """Attach the ambient trace context (if any) and the ambient
        deadline as wire fields, so the worker's spans join the
        coordinator's trace and expired work can be dropped server-side.
        Reading the ambient stacks here is what keeps the ``ShardBackend``
        protocol unchanged."""
        ctx = obs_trace.current()
        if ctx is not None:
            fields[wire.TRACE_ID_FIELD] = ctx.trace_id
            fields[wire.TRACE_PARENT_FIELD] = ctx.span_id
        return attach_deadline(fields)

    # -- writes (blocking request/reply) ------------------------------------
    def add(self, sigs: np.ndarray) -> int:
        return int(self.conn.request(Message(
            MsgType.ADD, self._traced(
                {"rows": np.ascontiguousarray(sigs, np.int32)})))["n"])

    def add_packed(self, words: np.ndarray) -> int:
        return int(self.conn.request(Message(
            MsgType.ADD, self._traced(
                {"words": np.ascontiguousarray(words, np.uint32)})))["n"])

    # -- the write fan-out ---------------------------------------------------
    def start_add(self, batch: np.ndarray, *, packed: bool = False) -> _Pending:
        """Submit this shard's ADD slice; all shards index concurrently.

        ``reset_on_error=False``: the coordinator's scatter consumes every
        pending of the round — the partial-write poison decision needs all
        per-shard outcomes, not just the first failure.
        """
        field = {"words": np.ascontiguousarray(batch, np.uint32)} if packed \
            else {"rows": np.ascontiguousarray(batch, np.int32)}
        return self.group.submit(self.conn,
                                 Message(MsgType.ADD, self._traced(field)),
                                 decode=lambda m: int(m["n"]),
                                 reset_on_error=False)

    # -- the query fan-out ---------------------------------------------------
    # both reads are hedgeable: re-asking the same worker the same
    # deterministic question is idempotent, so a duplicate can only cost
    # compute, never change an answer or the store
    def start_query(self, hashes: np.ndarray, qwords: np.ndarray,
                    top_k: int, mode: str) -> _Pending:
        lo, hi = wire.split_u64(hashes)
        return self.group.submit(self.conn, Message(MsgType.QUERY, self._traced({
            "hash_lo": lo, "hash_hi": hi,
            "qwords": np.ascontiguousarray(qwords, np.uint32),
            "top_k": int(top_k), "mode": mode})), hedgeable=True)

    def start_brute(self, qwords: np.ndarray, top_k: int) -> _Pending:
        return self.group.submit(self.conn, Message(MsgType.BRUTE, self._traced({
            "qwords": np.ascontiguousarray(qwords, np.uint32),
            "top_k": int(top_k)})), hedgeable=True)

    # -- control -------------------------------------------------------------
    def stats(self) -> dict:
        return dict(self.conn.request(Message(MsgType.STATS, {})).fields)

    def save(self, path: str) -> None:
        self.conn.request(Message(MsgType.SNAPSHOT, {"path": str(path)}))

    def shutdown(self) -> None:
        """Graceful worker exit (acked before the process leaves serve)."""
        self.conn.request(Message(MsgType.SHUTDOWN, {}))
        self.close()

    def close(self) -> None:
        self.conn.close()
        if self.hedge_conn is not None:
            self.hedge_conn.close()


def shutdown_plane(store, handles, *, join_timeout: float = 10.0) -> bool:
    """Stop a shard plane: graceful SHUTDOWN per remote shard, close the
    store's backends, reap worker processes.  The one definition of the
    teardown order (service close, benchmarks, and tests all use it).

    Joins only wait when every shutdown was acked (a hung worker should
    not stall the caller); ``terminate`` no-ops on cleanly-exited workers.
    Safe on inproc planes (no shutdown legs, no handles).  Returns whether
    every remote shard acked its SHUTDOWN.
    """
    clean = True
    for sh in getattr(store, "shards", []):
        if hasattr(sh, "shutdown"):
            try:
                sh.shutdown()
            except Exception:
                clean = False          # worker already dead or unreachable
    store.close()
    for h in handles:
        if clean:
            h.join(join_timeout)
        h.terminate()
    return clean


def connect_sharded(addresses, cfg=None, *, snapshot_dir: str | None = None,
                    partition: str = "round_robin", query_impl: str = "auto",
                    timeout: float = 30.0,
                    hedge: "HedgePolicy | bool | None" = None,
                    budget: RetryBudget | None = None,
                    ) -> ShardedSketchStore:
    """Build a tcp-backed ``ShardedSketchStore`` over worker ``addresses``.

    Fresh plane: pass the workers' ``StoreConfig`` as ``cfg``.  Snapshot
    boot: pass the ``ShardedSketchStore.save`` directory the workers were
    spawned from — coordinator state (cfg, partition, gid maps) is restored
    from its manifest and must describe ``len(addresses)`` shards.

    ``query_impl`` steers only the COORDINATOR's one broadcast band-hash
    fold; each worker's probe/score legs follow the knob it was spawned
    with (``spawn_workers(query_impl=...)``).

    ``timeout`` is the effective query deadline — ``SearchConfig`` plumbs
    it here as ``query_timeout_s``, and ``TransportTimeout`` messages name
    it.  ``hedge`` enables hedged reads: a ``HedgePolicy`` (or ``True``
    for the defaults) opens a second connection per worker for the group's
    late-reply re-issues.  ``budget`` is the plane's shared ``RetryBudget``
    (None builds the default) — hedges, failovers, and stream retries all
    draw from it.
    """
    if hedge is True:
        hedge = HedgePolicy()
    elif hedge is False:
        hedge = None
    conns: list[ShardConnection] = []
    twins: dict[ShardConnection, ShardConnection] = {}
    try:
        for i, a in enumerate(addresses):
            conns.append(ShardConnection(a, timeout=timeout,
                                         deadline_name="query_timeout_s",
                                         shard=i))
        if hedge is not None:
            for c in conns:
                twins[c] = ShardConnection(c.address, timeout=timeout,
                                           deadline_name="query_timeout_s",
                                           shard=c.shard)
        group = FanoutGroup(conns, timeout=timeout, hedge=hedge,
                            hedge_conns=twins,
                            deadline_name="query_timeout_s",
                            budget=budget)
        backends = [RemoteShard(c, group, hedge_conn=twins.get(c))
                    for c in conns]
        if snapshot_dir is not None:
            store = ShardedSketchStore.load(snapshot_dir, backends=backends,
                                            query_impl=query_impl)
        elif cfg is None:
            raise ValueError("connect_sharded needs cfg or snapshot_dir")
        else:
            store = ShardedSketchStore(cfg, len(backends),
                                       partition=partition,
                                       query_impl=query_impl,
                                       backends=backends)
        # the coordinator's gid maps and the workers' stores must describe
        # the same items — a coordinator connected without its snapshot (or
        # to the wrong workers) would otherwise return shard-LOCAL ids as
        # global answers with no error
        for i, b in enumerate(backends):
            size, want = int(b.stats()["size"]), store._gid_len[i]
            if size != want:
                raise WorkerError(
                    f"worker {conns[i]._name} holds {size} items but "
                    f"the coordinator's gid map has {want} — wrong "
                    "snapshot_dir (or none) for these workers?")
        return store
    except BaseException:
        for c in conns + list(twins.values()):  # no fd leak on failure
            c.close()
        raise
