"""Multi-host transport plane for the sharded serving store.

The in-process ``ShardedSketchStore`` loop already computes with the
multi-host seams explicit: one ``(Q, n_bands)`` band-hash broadcast out to
every shard, one ``TopKPartial`` back per shard, reduced by the associative
``distributed.collectives.merge_topk``.  This package turns those seams into
an actual cross-process transport:

  * ``wire``    — versioned, length-prefixed binary framing with zero-copy
                  numpy (de)serialization and checksummed frames;
  * ``server``  — a shard worker process hosting one ``SketchStore`` and
                  serving framed requests over a TCP socket;
  * ``client``  — the coordinator side: per-worker connections, a
                  nonblocking fan-out/gather group, and the ``RemoteShard``
                  backend that plugs workers into ``ShardedSketchStore``.

Because every worker runs the exact same candidate + partial-top-k code as
the in-process backend and the merge is associative, tcp-backed answers are
bit-identical to the in-process plane on the same items.
"""

from .client import (FanoutGroup, HedgePolicy, RemoteShard, ShardConnection,
                     TransportError, TransportTimeout, WorkerError,
                     connect_sharded, shutdown_plane)
from .server import WorkerHandle, spawn_workers

__all__ = ["FanoutGroup", "HedgePolicy", "RemoteShard", "ShardConnection",
           "TransportError", "TransportTimeout", "WorkerError",
           "connect_sharded", "shutdown_plane", "WorkerHandle",
           "spawn_workers"]
