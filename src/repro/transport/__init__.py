"""Multi-host transport plane for the sharded serving store.

The in-process ``ShardedSketchStore`` loop already computes with the
multi-host seams explicit: one ``(Q, n_bands)`` band-hash broadcast out to
every shard, one ``TopKPartial`` back per shard, reduced by the associative
``distributed.collectives.merge_topk``.  This package turns those seams into
an actual cross-process transport:

  * ``wire``    — versioned, length-prefixed binary framing with zero-copy
                  numpy (de)serialization and checksummed frames;
  * ``server``  — a shard worker process hosting one ``SketchStore`` and
                  serving framed requests over a TCP socket;
  * ``client``  — the coordinator side: per-worker connections, a
                  nonblocking fan-out/gather group, and the ``RemoteShard``
                  backend that plugs workers into ``ShardedSketchStore``.

Because every worker runs the exact same candidate + partial-top-k code as
the in-process backend and the merge is associative, tcp-backed answers are
bit-identical to the in-process plane on the same items.

Overload hardening rides the same seams: requests carry an absolute wire
deadline (``deadline_scope``), workers shed behind a bounded admission
gate with retryable ``Overloaded`` replies, the coordinator spends every
hedge/failover/retry from one plane-wide ``RetryBudget`` behind per-lane
``CircuitBreaker``\\ s, and ``faults`` provides the deterministic
fault-injection plane the chaos tests and availability bench drive.
"""

from .client import (CircuitBreaker, DeadlineExceeded, FanoutGroup,
                     HedgePolicy, Overloaded, RemoteShard, RetryBudget,
                     ShardConnection, TransportError, TransportTimeout,
                     WorkerError, connect_sharded, current_deadline,
                     deadline_scope, shutdown_plane)
from .faults import (FAULT_LOG_ENV, FAULTS_ENV, KILL_EXIT_CODE, FaultEvent,
                     FaultPlan, faults_env_value, install_client_plan,
                     read_fired_log)
from .server import AdmissionGate, WorkerHandle, spawn_workers

__all__ = ["CircuitBreaker", "DeadlineExceeded", "FanoutGroup",
           "HedgePolicy", "Overloaded", "RemoteShard", "RetryBudget",
           "ShardConnection", "TransportError", "TransportTimeout",
           "WorkerError", "connect_sharded", "current_deadline",
           "deadline_scope", "shutdown_plane",
           "FAULT_LOG_ENV", "FAULTS_ENV", "KILL_EXIT_CODE", "FaultEvent",
           "FaultPlan", "faults_env_value", "install_client_plan",
           "read_fired_log", "AdmissionGate", "WorkerHandle",
           "spawn_workers"]
