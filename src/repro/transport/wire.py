"""Binary wire protocol for the shard transport plane.

Frames are length-prefixed, versioned, and checksummed:

    offset  size  field
    0       2     magic  b"CM"
    2       1     protocol version (= 1)
    3       1     message type (``MsgType``)
    4       4     sequence number, uint32 LE (replies echo the request's)
    8       4     payload length, uint32 LE
    12      4     CRC-32 of the payload, uint32 LE
    16      len   payload

The sequence number is what keeps a connection usable after a *failed*
fan-out: a timed-out broadcast can leave a healthy worker's reply sitting
unread in the socket, and without pairing, the next request would consume
that stale frame as its own answer.  Workers echo the request's seq into
the reply, and the client discards replies whose seq is not the one it is
waiting on.

The payload is a flat field table: ``n_fields`` uint16, then per field a
length-prefixed ascii key, a one-byte tag, and a tagged value — int64
scalars, utf-8 strings, or ndarrays (dtype code, ndim, int64 dims, raw
C-order bytes).  Serialization is zero-copy on both sides of the hot path:
``encode_message`` returns the header plus the arrays' own memoryviews (no
concatenated blob is built — ``send_message`` gather-writes them), and
``decode_payload`` returns ``np.frombuffer`` views into the received buffer.

Decoding is strict: short reads raise ``TruncatedFrame``, payloads larger
than ``max_payload`` raise ``FrameTooLarge`` *before* any allocation, CRC
mismatches raise ``ChecksumError``, and unknown magic/version/tag bytes
raise ``ProtocolError``.  A clean EOF at a frame boundary is the distinct
``ConnectionClosed`` (how a peer hangup differs from a corrupt stream).

The ``QUERY`` broadcast carries the uint64 band hashes as two uint32 planes
(``split_u64``/``join_u64``) so every array lane on the hot frame is <= 32
bits — the layout device-side consumers (and the packed store itself) use —
and reassembly is an explicit, tested step instead of a dtype cast.
"""

from __future__ import annotations

import dataclasses
import enum
import struct
import zlib

import numpy as np

MAGIC = b"CM"
VERSION = 1
# magic, version, msg type, seq, payload len, payload crc
_HEADER = struct.Struct("<2sBBIII")
HEADER_SIZE = _HEADER.size

MAX_PAYLOAD = 1 << 30                   # 1 GiB hard ceiling per frame

# Trace-context field names (``repro.obs.trace``).  The header is frozen at
# 16 bytes, so trace ids ride as ordinary payload fields — underscore-
# prefixed to stay clear of operation fields, ignored by peers that do not
# know them (decode returns a plain dict; handlers read specific keys).
# Requests carry the trace id + parent span id; replies carry the worker's
# finished spans as a JSON string next to the echoed seq.
TRACE_ID_FIELD = "_tr"          # request: int, the 63-bit trace id
TRACE_PARENT_FIELD = "_trp"     # request: int, the coordinator's span id
TRACE_SPANS_FIELD = "_trs"      # reply: str, JSON list of worker span dicts

# Deadline field (overload control).  Same frozen-header constraint as the
# trace fields: the absolute deadline rides as an underscore-prefixed payload
# field — int64 microseconds since the unix epoch (``time.time() * 1e6``;
# workers are same-host or NTP-disciplined, and deadline checks only need
# millisecond-grade agreement).  Workers drop expired read work *before*
# computing and answer ``OVERLOADED`` with ``reason="expired"``.
DEADLINE_FIELD = "_dl"          # request: int, absolute deadline (us epoch)


def deadline_us(abs_deadline_s: float) -> int:
    """Absolute deadline in seconds-since-epoch -> the wire's int64 us."""
    return int(abs_deadline_s * 1e6)


class MsgType(enum.IntEnum):
    ADD = 1          # rows=(B,K) i32 sigs  OR  words=(B,W) u32 packed
    QUERY = 2        # hash_lo/hash_hi=(Q,NB) u32, qwords=(Q,W) u32,
                     # top_k, mode ("sig"|"packed")
    BRUTE = 3        # qwords=(Q,W) u32, top_k — the global fallback leg
    PARTIAL = 4      # reply: ids=(Q,k) i64, scores=(Q,k) f32, has=(Q,) bool
    STATS = 5        # request worker counters
    OK = 6           # generic reply (ADD count, STATS counters, acks)
    SNAPSHOT = 7     # path — worker saves its SketchStore there
    SHUTDOWN = 8     # graceful worker exit (acked with OK first)
    ERROR = 9        # reply: error=str — worker-side exception text
    DIGEST = 10      # content digest of the worker's signature buffer
                     # (replica resync parity check — see replica.supervisor)
    OVERLOADED = 11  # reply: reason ("admission"|"expired"), retry_after_us,
                     # gate_depth, gate_limit — the worker did NOT execute
                     # the request (provably clean: safe to retry within
                     # budget; never poisons the plane)


class WireError(Exception):
    """Base for protocol-level failures."""


class ConnectionClosed(WireError):
    """Peer closed the stream cleanly at a frame boundary."""


class TruncatedFrame(WireError):
    """Stream ended (or buffer ran out) mid-frame."""


class ChecksumError(WireError):
    """Payload CRC-32 does not match the header."""


class FrameTooLarge(WireError):
    """Declared payload length exceeds the receiver's limit."""


class ProtocolError(WireError):
    """Bad magic, unsupported version, or malformed payload."""


# -- field encoding -----------------------------------------------------------

_TAG_INT = 0
_TAG_STR = 1
_TAG_ARR = 2

_DTYPES = (np.bool_, np.int8, np.uint8, np.int16, np.uint16, np.int32,
           np.uint32, np.int64, np.uint64, np.float32, np.float64)
_DTYPE_CODE = {np.dtype(d): i for i, d in enumerate(_DTYPES)}
_CODE_DTYPE = {i: np.dtype(d) for i, d in enumerate(_DTYPES)}


@dataclasses.dataclass
class Message:
    type: MsgType
    fields: dict
    seq: int = 0                  # request/reply pairing (uint32, echoed)

    def __getitem__(self, key):
        return self.fields[key]


def _array_view(arr: np.ndarray) -> memoryview:
    """Flat byte view of a (C-contiguified) array — the zero-copy leg of
    encoding: the frame references the array's own buffer.  Goes through a
    1-D uint8 reinterpret (not ``memoryview.cast``, which rejects 0-d and
    empty shapes)."""
    return memoryview(np.ascontiguousarray(arr).reshape(-1).view(np.uint8))


def encode_payload(fields: dict) -> list:
    """Field dict -> list of buffers (metadata chunks + raw array views)."""
    bufs: list = []
    meta = bytearray(struct.pack("<H", len(fields)))
    for key, val in fields.items():
        kb = key.encode("ascii")
        if len(kb) > 255:
            raise ProtocolError(f"field name too long: {key!r}")
        meta += struct.pack("<B", len(kb)) + kb
        if isinstance(val, (bool, int, np.integer)):
            meta += struct.pack("<Bq", _TAG_INT, int(val))
        elif isinstance(val, str):
            sb = val.encode("utf-8")
            meta += struct.pack("<BI", _TAG_STR, len(sb)) + sb
        elif isinstance(val, np.ndarray):
            if val.dtype not in _DTYPE_CODE:
                raise ProtocolError(f"unsupported array dtype {val.dtype}")
            meta += struct.pack(f"<BBB{val.ndim}q", _TAG_ARR,
                                _DTYPE_CODE[val.dtype], val.ndim, *val.shape)
            bufs.append(bytes(meta))
            meta = bytearray()
            bufs.append(_array_view(val))
        else:
            raise ProtocolError(f"unsupported field type {type(val)!r} "
                                f"for {key!r}")
    if meta:
        bufs.append(bytes(meta))
    return bufs


def encode_message(msg: Message) -> list:
    """Message -> [header, *payload buffers] ready for a gather-write."""
    payload = encode_payload(msg.fields)
    length = sum(b.nbytes if isinstance(b, memoryview) else len(b)
                 for b in payload)
    if length > MAX_PAYLOAD:
        raise FrameTooLarge(f"payload {length} exceeds MAX_PAYLOAD")
    crc = 0
    for b in payload:
        crc = zlib.crc32(b, crc)
    header = _HEADER.pack(MAGIC, VERSION, int(msg.type),
                          msg.seq & 0xFFFFFFFF, length, crc & 0xFFFFFFFF)
    return [header, *payload]


def message_bytes(msg: Message) -> bytes:
    """One contiguous frame (test/convenience path; copies)."""
    return b"".join(bytes(b) for b in encode_message(msg))


def decode_header(header: bytes, *, max_payload: int = MAX_PAYLOAD
                  ) -> tuple[MsgType, int, int, int]:
    """16-byte header -> (msg type, seq, payload length, expected crc)."""
    if len(header) < HEADER_SIZE:
        raise TruncatedFrame(f"header: got {len(header)} of {HEADER_SIZE} "
                             "bytes")
    magic, version, mtype, seq, length, crc = \
        _HEADER.unpack(header[:HEADER_SIZE])
    if magic != MAGIC:
        raise ProtocolError(f"bad magic {magic!r}")
    if version != VERSION:
        raise ProtocolError(f"unsupported protocol version {version}")
    if length > max_payload:
        raise FrameTooLarge(f"payload {length} exceeds limit {max_payload}")
    try:
        mt = MsgType(mtype)
    except ValueError as e:
        raise ProtocolError(f"unknown message type {mtype}") from e
    return mt, seq, length, crc


def decode_payload(payload) -> dict:
    """Payload buffer -> field dict.  Arrays come back as ``np.frombuffer``
    views into ``payload`` (zero-copy, read-only)."""
    buf = memoryview(payload).cast("B")
    fields: dict = {}
    try:
        (n_fields,) = struct.unpack_from("<H", buf, 0)
        off = 2
        for _ in range(n_fields):
            (klen,) = struct.unpack_from("<B", buf, off)
            off += 1
            key = bytes(buf[off: off + klen]).decode("ascii")
            off += klen
            (tag,) = struct.unpack_from("<B", buf, off)
            off += 1
            if tag == _TAG_INT:
                (fields[key],) = struct.unpack_from("<q", buf, off)
                off += 8
            elif tag == _TAG_STR:
                (slen,) = struct.unpack_from("<I", buf, off)
                off += 4
                if off + slen > len(buf):
                    raise TruncatedFrame("string field overruns payload")
                fields[key] = bytes(buf[off: off + slen]).decode("utf-8")
                off += slen
            elif tag == _TAG_ARR:
                code, ndim = struct.unpack_from("<BB", buf, off)
                off += 2
                if code not in _CODE_DTYPE:
                    raise ProtocolError(f"unknown dtype code {code}")
                shape = struct.unpack_from(f"<{ndim}q", buf, off)
                off += 8 * ndim
                if any(d < 0 for d in shape):
                    raise ProtocolError(f"negative dim in shape {shape}")
                dt = _CODE_DTYPE[code]
                nbytes = dt.itemsize
                for d in shape:        # python ints: no int64 overflow wrap
                    nbytes *= d
                if off + nbytes > len(buf):
                    raise TruncatedFrame("array field overruns payload")
                fields[key] = np.frombuffer(
                    buf[off: off + nbytes], dtype=dt).reshape(shape)
                off += nbytes
            else:
                raise ProtocolError(f"unknown field tag {tag}")
        if off != len(buf):
            raise ProtocolError(f"{len(buf) - off} trailing payload bytes")
    except WireError:
        raise
    except struct.error as e:                  # ran off the end of the meta
        raise TruncatedFrame(str(e)) from e
    except Exception as e:
        # a CRC-valid but malformed payload (bad utf-8/ascii, absurd shape)
        # must surface as a protocol failure the server/client error paths
        # understand — never crash a worker with a raw ValueError
        raise ProtocolError(
            f"malformed payload: {type(e).__name__}: {e}") from e
    return fields


def decode_frame(frame, *, max_payload: int = MAX_PAYLOAD) -> Message:
    """One contiguous frame -> Message (header + crc + payload checks)."""
    frame = memoryview(frame).cast("B")
    mtype, seq, length, crc = decode_header(bytes(frame[:HEADER_SIZE]),
                                            max_payload=max_payload)
    payload = frame[HEADER_SIZE:]
    if len(payload) < length:
        raise TruncatedFrame(f"payload: got {len(payload)} of {length} bytes")
    if len(payload) > length:
        raise ProtocolError(f"{len(payload) - length} bytes past frame end")
    if zlib.crc32(payload) & 0xFFFFFFFF != crc:
        raise ChecksumError("payload CRC mismatch")
    return Message(mtype, decode_payload(payload), seq)


# -- socket framing -----------------------------------------------------------

def read_exact(sock, n: int) -> bytearray:
    """Read exactly n bytes; ConnectionClosed on clean EOF before byte 0,
    TruncatedFrame on EOF mid-read."""
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if not buf:
                raise ConnectionClosed("peer closed the connection")
            raise TruncatedFrame(f"stream ended at byte {len(buf)} of {n}")
        buf += chunk
    return buf


def recv_message(sock, *, max_payload: int = MAX_PAYLOAD,
                 meter=None) -> Message:
    """Blocking read of one frame from a socket.  ``meter``, if given, is
    called with the frame's total byte count (bytes-in accounting)."""
    header = read_exact(sock, HEADER_SIZE)
    mtype, seq, length, crc = decode_header(bytes(header),
                                            max_payload=max_payload)
    payload = read_exact(sock, length) if length else bytearray()
    if zlib.crc32(payload) & 0xFFFFFFFF != crc:
        raise ChecksumError("payload CRC mismatch")
    if meter is not None:
        meter(HEADER_SIZE + length)
    return Message(mtype, decode_payload(payload), seq)


def send_message(sock, msg: Message, *, meter=None) -> None:
    """Gather-write one frame (no concatenated payload copy).  ``meter``,
    if given, is called with the frame's total byte count."""
    bufs = [memoryview(b) if not isinstance(b, memoryview) else b
            for b in encode_message(msg)]
    if meter is not None:
        meter(sum(b.nbytes for b in bufs))
    sendmsg = getattr(sock, "sendmsg", None)
    if sendmsg is None:                        # exotic socket: join + sendall
        sock.sendall(b"".join(bytes(b) for b in bufs))
        return
    while bufs:
        sent = sendmsg(bufs)
        while bufs and sent >= bufs[0].nbytes:
            sent -= bufs[0].nbytes
            bufs.pop(0)
        if bufs and sent:
            bufs[0] = bufs[0].cast("B")[sent:]


# -- uint64 band hashes as two uint32 planes ---------------------------------

def split_u64(h: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(…,) uint64 -> (lo, hi) uint32 planes (the QUERY broadcast layout)."""
    h = np.asarray(h, np.uint64)
    lo = (h & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (h >> np.uint64(32)).astype(np.uint32)
    return lo, hi


def join_u64(lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
    """Inverse of ``split_u64``."""
    return (np.asarray(hi, np.uint64) << np.uint64(32)) | \
        np.asarray(lo, np.uint64)
