"""Decoder-only LM assembly: scan-over-layers forward, prefill, and decode for
every decoder family (dense / moe / ssm / hybrid / vlm).

Layer parameters are stacked along a leading L axis so ``lax.scan`` keeps the
HLO size independent of depth; each block is optionally wrapped in
``jax.checkpoint`` (cfg.remat). Caches are dicts of stacked per-layer tensors so
decode is also a single scan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .layers import (apply_rope, attention_block, decode_attention, init_attention,
                     init_mlp, mlp_block, normal_init, project_kv, qkv_project,
                     rmsnorm)
from .moe import init_moe, moe_block
from .ssm import init_ssm, ssm_block, ssm_decode_step

Array = jax.Array


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg):
    return jnp.dtype(cfg.param_dtype)


def has_attention(cfg) -> bool:
    return cfg.family != "ssm"


def has_ssm(cfg) -> bool:
    return cfg.family in ("ssm", "hybrid")


def kv_eff_heads(cfg, tp: int) -> int:
    """Decode-cache KV head count: replicate KV heads up to the TP degree when
    that enables clean sharding (DESIGN.md §5)."""
    kv, h = cfg.n_kv_heads, cfg.n_heads
    if kv % tp == 0:
        return kv
    if tp % kv == 0 and h % tp == 0:
        return tp
    return kv


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_layer(key: Array, cfg) -> dict:
    dt = _pdtype(cfg)
    ks = jax.random.split(key, 6)
    p: dict = {"ln1": jnp.ones((cfg.d_model,), dt)}
    if has_attention(cfg):
        p["attn"] = init_attention(ks[0], cfg, dt)
    if has_ssm(cfg):
        p["ssm"] = init_ssm(ks[1], cfg, dt)
    if cfg.family == "moe":
        p["moe"] = init_moe(ks[2], cfg, dt)
        p["ln2"] = jnp.ones((cfg.d_model,), dt)
    elif cfg.d_ff > 0:
        p["mlp"] = init_mlp(ks[3], cfg, dt)
        p["ln2"] = jnp.ones((cfg.d_model,), dt)
    return p


def init_params(key: Array, cfg) -> dict:
    dt = _pdtype(cfg)
    k_embed, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    params = {
        "embed": normal_init(k_embed, (cfg.vocab_size, cfg.d_model), 0.02, dt),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = normal_init(
            k_head, (cfg.d_model, cfg.vocab_size), cfg.d_model ** -0.5, dt)
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def block_forward(lp: dict, x: Array, positions: Array, cfg, mesh) -> tuple[Array, Array]:
    """One layer, full-sequence. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    xn = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    delta = jnp.zeros_like(x)
    if has_attention(cfg):
        delta = delta + attention_block(lp["attn"], xn, positions, cfg)
    if has_ssm(cfg):
        y, _, _ = ssm_block(lp["ssm"], xn, cfg)
        delta = delta + y
    x = x + delta
    if cfg.family == "moe":
        y, aux = moe_block(lp["moe"], rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg, mesh)
        x = x + y
    elif cfg.d_ff > 0:
        x = x + mlp_block(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps))
    return x, aux


def _maybe_remat(fn, cfg):
    if cfg.remat == "block":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


# ---------------------------------------------------------------------------
# Forward (training) — tokens (B, S) [+ optional prefix embeddings] -> logits
# ---------------------------------------------------------------------------

def forward(params: dict, tokens: Array, cfg, mesh=None,
            prefix_embeddings: Array | None = None) -> tuple[Array, Array]:
    """Returns (logits (B, S, V), aux_loss scalar)."""
    dt = _dtype(cfg)
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    if prefix_embeddings is not None:  # VLM/multimodal stub: overwrite prefix
        p = prefix_embeddings.shape[1]
        x = jnp.concatenate([prefix_embeddings.astype(dt), x[:, p:]], axis=1)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    body = _maybe_remat(
        lambda xx, lp: block_forward(lp, xx, positions, cfg, mesh), cfg)
    x, auxes = jax.lax.scan(lambda xx, lp: body(xx, lp), x, params["layers"])

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(dt)
    return logits, jnp.sum(auxes)


def lm_loss(logits: Array, targets: Array, mask: Array) -> Array:
    """Next-token CE (caller supplies aligned targets/mask), fp32."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def cache_len(cfg, max_len: int) -> int:
    return min(cfg.sliding_window, max_len) if cfg.sliding_window > 0 else max_len


def init_cache(cfg, batch: int, max_len: int, *, tp: int = 1) -> dict:
    """Decode cache pytree (zeros/empty). max_len includes prompt + generation."""
    dt = _dtype(cfg)
    l = cfg.n_layers
    cache: dict = {"t": jnp.zeros((), jnp.int32)}
    if has_attention(cfg):
        kve = kv_eff_heads(cfg, tp)
        c = cache_len(cfg, max_len)
        cache["k"] = jnp.zeros((l, batch, c, kve, cfg.head_dim), dt)
        cache["v"] = jnp.zeros((l, batch, c, kve, cfg.head_dim), dt)
        cache["entry_pos"] = jnp.full((c,), -1, jnp.int32)
    if has_ssm(cfg):
        cache["h"] = jnp.zeros((l, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
        cache["conv"] = jnp.zeros((l, batch, cfg.ssm_conv - 1, cfg.d_inner), dt)
    return cache


def _repeat_kv_to(k: Array, kve: int) -> Array:
    """(..., KV, hd) -> (..., KVe, hd) by replication (KVe % KV == 0)."""
    kv = k.shape[-2]
    if kv == kve:
        return k
    return jnp.repeat(k, kve // kv, axis=-2)


# ---------------------------------------------------------------------------
# Prefill — run the prompt, build a decode-ready cache
# ---------------------------------------------------------------------------

def prefill(params: dict, tokens: Array, cfg, mesh=None, *, tp: int = 1,
            max_len: int | None = None,
            prefix_embeddings: Array | None = None) -> tuple[Array, dict]:
    """Returns (last-position logits (B, V), cache)."""
    dt = _dtype(cfg)
    b, s = tokens.shape
    max_len = max_len or s
    c = cache_len(cfg, max_len)
    kve = kv_eff_heads(cfg, tp) if has_attention(cfg) else 0
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    if prefix_embeddings is not None:
        p = prefix_embeddings.shape[1]
        x = jnp.concatenate([prefix_embeddings.astype(dt), x[:, p:]], axis=1)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(xx, lp):
        entries = {}
        xn = rmsnorm(xx, lp["ln1"], cfg.norm_eps)
        delta = jnp.zeros_like(xx)
        if has_attention(cfg):
            delta = delta + attention_block(lp["attn"], xn, positions, cfg)
            k, v = project_kv(lp["attn"], xn, positions, cfg)
            k = apply_rope(k, positions, cfg.rope_theta)
            k, v = _repeat_kv_to(k, kve), _repeat_kv_to(v, kve)
            if s >= c:  # keep the last C entries at ring slots pos % C
                slots = (s - c + jnp.arange(c)) % c
                entries["k"] = jnp.zeros((b, c, kve, cfg.head_dim), dt
                                         ).at[:, slots].set(k[:, -c:])
                entries["v"] = jnp.zeros((b, c, kve, cfg.head_dim), dt
                                         ).at[:, slots].set(v[:, -c:])
            else:
                pad = ((0, 0), (0, c - s), (0, 0), (0, 0))
                entries["k"] = jnp.pad(k, pad)
                entries["v"] = jnp.pad(v, pad)
        if has_ssm(cfg):
            y, h_fin, conv_tail = ssm_block(lp["ssm"], xn, cfg)
            delta = delta + y
            entries["h"] = h_fin
            entries["conv"] = conv_tail
        xx = xx + delta
        if cfg.family == "moe":
            y, _ = moe_block(lp["moe"], rmsnorm(xx, lp["ln2"], cfg.norm_eps),
                             cfg, mesh)
            xx = xx + y
        elif cfg.d_ff > 0:
            xx = xx + mlp_block(lp["mlp"], rmsnorm(xx, lp["ln2"], cfg.norm_eps))
        return xx, entries

    body = _maybe_remat(body, cfg)
    x, layer_entries = jax.lax.scan(body, x, params["layers"])

    x_last = rmsnorm(x[:, -1], params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x_last @ head.astype(dt)

    cache = dict(layer_entries)
    cache["t"] = jnp.asarray(s, jnp.int32)
    if has_attention(cfg):
        pos0 = jnp.arange(c)
        if s >= c:
            slots = (s - c + jnp.arange(c)) % c
            entry_pos = jnp.zeros((c,), jnp.int32).at[slots].set(
                jnp.arange(s - c, s))
        else:
            entry_pos = jnp.where(pos0 < s, pos0, -1).astype(jnp.int32)
        cache["entry_pos"] = entry_pos
    return logits, cache


# ---------------------------------------------------------------------------
# Decode — one token against the cache
# ---------------------------------------------------------------------------

def decode_step(params: dict, cache: dict, token: Array, cfg,
                mesh=None) -> tuple[Array, dict]:
    """token: (B,) int32. Returns (logits (B, V), updated cache)."""
    dt = _dtype(cfg)
    b = token.shape[0]
    t = cache["t"]
    x = jnp.take(params["embed"], token, axis=0).astype(dt)  # (B, D)

    attn = has_attention(cfg)
    ssm = has_ssm(cfg)
    if attn:
        c = cache["k"].shape[2]
        slot = t % c
        entry_pos = cache["entry_pos"].at[slot].set(t)
    pos_b = jnp.broadcast_to(t, (b, 1))

    xs: dict = {"lp": params["layers"]}
    if attn:
        xs["k"] = cache["k"]
        xs["v"] = cache["v"]
    if ssm:
        xs["h"] = cache["h"]
        xs["conv"] = cache["conv"]

    def body(xx, layer):
        lp = layer["lp"]
        entries = {}
        xn = rmsnorm(xx, lp["ln1"], cfg.norm_eps)
        delta = jnp.zeros_like(xx)
        if attn:
            ap = lp["attn"]
            q, k_new, v_new = qkv_project(ap, xn, cfg)
            q = apply_rope(q[:, None], pos_b, cfg.rope_theta)[:, 0]
            k_new = apply_rope(k_new[:, None], pos_b, cfg.rope_theta)[:, 0]
            kve = layer["k"].shape[-2]
            k_cache = layer["k"].at[:, slot].set(_repeat_kv_to(k_new, kve))
            v_cache = layer["v"].at[:, slot].set(_repeat_kv_to(v_new, kve))
            out = decode_attention(q, k_cache, v_cache, entry_pos, t,
                                   window=cfg.sliding_window)
            delta = delta + jnp.einsum("bhk,hkd->bd", out, ap["wo"].astype(dt))
            entries["k"], entries["v"] = k_cache, v_cache
        if ssm:
            y, h_new, conv_new = ssm_decode_step(lp["ssm"], xn, layer["h"],
                                                 layer["conv"], cfg)
            delta = delta + y
            entries["h"], entries["conv"] = h_new, conv_new
        xx = xx + delta
        if cfg.family == "moe":
            y, _ = moe_block(lp["moe"],
                             rmsnorm(xx, lp["ln2"], cfg.norm_eps)[:, None],
                             cfg, mesh)
            xx = xx + y[:, 0]
        elif cfg.d_ff > 0:
            xx = xx + mlp_block(lp["mlp"], rmsnorm(xx, lp["ln2"], cfg.norm_eps))
        return xx, entries

    x, new_entries = jax.lax.scan(body, x, xs)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(dt)

    new_cache = dict(cache)
    new_cache.update(new_entries)
    new_cache["t"] = t + 1
    if attn:
        new_cache["entry_pos"] = entry_pos
    return logits, new_cache
