"""Top-k MoE with shard_map expert parallelism.

Experts shard over the ``model`` mesh axis. Routing (a small matmul + top_k) runs
in plain pjit-land; the expert FFN runs inside ``jax.shard_map``: every model
shard applies its local experts to the local data-shard's tokens at a fixed
capacity, and shard outputs are combined with a single ``psum`` over ``model`` —
the same wire cost as a Megatron MLP all-reduce, with no data-dependent
collectives for XLA to guess at (DESIGN.md §5). Over-capacity tokens are dropped
(GShard semantics); the router aux loss encourages balance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .layers import normal_init

Array = jax.Array


def init_moe(key: Array, cfg, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    down_scale = f ** -0.5 / np.sqrt(2 * cfg.n_layers)
    return {
        "router": normal_init(ks[0], (d, e), scale, jnp.float32),
        "e_gate": normal_init(ks[1], (e, d, f), scale, dtype),
        "e_up": normal_init(ks[2], (e, d, f), scale, dtype),
        "e_down": normal_init(ks[3], (e, f, d), down_scale, dtype),
    }


def _capacity(n_tokens: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(np.ceil(n_tokens * top_k / n_experts * factor))
    return max(8, -(-c // 8) * 8)


def _expert_ffn(xf: Array, idx: Array, gates: Array, wg: Array, wu: Array,
                wd: Array, *, e_offset, n_experts_total: int,
                capacity: int) -> Array:
    """Apply local experts to local tokens at fixed capacity.

    xf: (T, D); idx: (T, k) global expert ids; gates: (T, k); wg/wu: (El, D, F);
    wd: (El, F, D); e_offset: first global id owned locally. Returns (T, D).
    """
    t, k = idx.shape
    el = wg.shape[0]
    d = xf.shape[-1]
    dtype = xf.dtype

    lid = idx.reshape(-1) - e_offset                      # (T*k,) local ids
    valid = (lid >= 0) & (lid < el)
    lid_safe = jnp.where(valid, lid, 0)

    onehot = jax.nn.one_hot(jnp.where(valid, lid, el), el, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                  # position within expert
    pos = jnp.take_along_axis(pos, lid_safe[:, None], axis=1)[:, 0]
    keep = valid & (pos < capacity)

    slot = jnp.where(keep, lid_safe * capacity + pos, el * capacity)  # drop idx
    token_of = jnp.repeat(jnp.arange(t), k)

    buf = jnp.zeros((el * capacity, d), dtype)
    buf = buf.at[slot].add(xf[token_of], mode="drop")
    buf = buf.reshape(el, capacity, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg.astype(dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, wu.astype(dtype))
    out = jnp.einsum("ecf,efd->ecd", h, wd.astype(dtype))
    out = out.reshape(el * capacity, d)

    contrib = jnp.where(keep, gates.reshape(-1), 0.0).astype(dtype)
    y = jnp.zeros((t, d), dtype)
    y = y.at[token_of].add(out[jnp.clip(slot, 0, el * capacity - 1)]
                           * contrib[:, None])
    return y


def _route(xf: Array, router_w: Array, e: int, k: int):
    """Router: top-k gates + load-balance aux. Pure per-token math — safe to
    run per shard (no cross-token state)."""
    logits = (xf @ router_w.astype(xf.dtype)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                            # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    assign = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)
    aux = e * jnp.mean(jnp.mean(assign, axis=0) * jnp.mean(probs, axis=0))
    return gates, idx, aux


def moe_block(p: dict, x: Array, cfg, mesh=None) -> tuple[Array, Array]:
    """x: (B, S, D) -> (y: (B, S, D), aux_loss scalar).

    Expert-parallel path: routing runs INSIDE the shard_map (top_k on the
    local token shard — the partitioner otherwise all-gathers the full (T, E)
    probs), and tokens cross the shard boundary sharded over ``model`` on the
    feature dim with an explicit in-body all_gather. Its transpose is a
    reduce-scatter at (T_loc, D/tp) — without this, the backward all-reduces
    the pre-scatter (T_loc*k, D) cotangent, ~15x more wire (measured in
    EXPERIMENTS.md §Perf).
    """
    b, s, d = x.shape
    dtype = x.dtype
    e, k = cfg.n_experts, cfg.top_k
    xf = x.reshape(b * s, d)

    tp = mesh.shape["model"] if mesh is not None and "model" in \
        getattr(mesh, "axis_names", ()) else 1
    use_ep = tp > 1 and e % tp == 0 and d % tp == 0
    if use_ep:
        el = e // tp
        batch_axes = tuple(a for a in mesh.axis_names if a != "model")
        n_data = int(np.prod([mesh.shape[a] for a in batch_axes]))
        n_mesh = int(np.prod(list(mesh.shape.values())))
        cap = _capacity(b * s // n_data, e, k, cfg.capacity_factor)
        from jax.sharding import PartitionSpec as P

        def body(x_shard, router_w, wg, wu, wd):
            xl = jax.lax.all_gather(x_shard, "model", axis=1, tiled=True)
            gl, il, aux = _route(xl, router_w, e, k)
            off = jax.lax.axis_index("model") * el
            y = _expert_ffn(xl, il, gl.astype(dtype), wg, wu, wd,
                            e_offset=off, n_experts_total=e, capacity=cap)
            y = jax.lax.psum(y, "model")
            aux = jax.lax.psum(aux, tuple(mesh.axis_names)) / n_mesh
            return y, aux

        if hasattr(jax, "shard_map"):
            shard_map = jax.shard_map
        else:                          # jax < 0.4.35 spells it experimental
            from jax.experimental.shard_map import shard_map
        y, aux = shard_map(
            body, mesh=mesh,
            in_specs=(P(batch_axes, "model"), P(None, None),
                      P("model", None, None), P("model", None, None),
                      P("model", None, None)),
            out_specs=(P(batch_axes, None), P()),
        )(xf, p["router"],
          p["e_gate"].astype(dtype), p["e_up"].astype(dtype),
          p["e_down"].astype(dtype))
    else:
        gates, idx, aux = _route(xf, p["router"], e, k)
        cap = _capacity(b * s, e, k, cfg.capacity_factor)
        y = _expert_ffn(xf, idx, gates.astype(dtype), p["e_gate"], p["e_up"],
                        p["e_down"], e_offset=0, n_experts_total=e,
                        capacity=cap)
    return y.reshape(b, s, d), aux
