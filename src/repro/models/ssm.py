"""Mamba-1 selective SSM block — chunked associative scan (train/prefill) and an
O(1)-state decode step.

TPU adaptation: instead of a fused recurrent kernel (CUDA) or materializing the
full (B, S, d_inner, N) scan tensor (OOM at 4k+ sequence), we scan over sequence
chunks of ``cfg.ssm_chunk``; within a chunk an associative scan runs in fp32 over
(decay, increment) pairs. Live memory is O(B * chunk * d_inner * N) and the chunk
loop is remat-friendly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .layers import normal_init

Array = jax.Array


def init_ssm(key: Array, cfg, dtype) -> dict:
    d, di, n, r, cw = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
                       cfg.ssm_conv)
    ks = jax.random.split(key, 6)
    a_log = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
    dt_init = float(np.log(np.expm1(0.01)))
    return {
        "in_proj": normal_init(ks[0], (d, 2 * di), d ** -0.5, dtype),
        "conv_w": normal_init(ks[1], (cw, di), cw ** -0.5, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": normal_init(ks[2], (di, r + 2 * n), di ** -0.5, dtype),
        "dt_proj": normal_init(ks[3], (r, di), r ** -0.5, dtype),
        "dt_bias": jnp.full((di,), dt_init, dtype),
        "a_log": jnp.broadcast_to(a_log, (di, n)).astype(jnp.float32) + 0.0,
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": normal_init(ks[4], (di, d),
                                di ** -0.5 / np.sqrt(2 * cfg.n_layers), dtype),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv along S. x: (B, S, Di), w: (cw, Di)."""
    cw = w.shape[0]
    out = x * w[-1]
    for i in range(1, cw):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[-1 - i]
    return out + b


def _ssm_inner(dt: Array, a: Array, bmat: Array, cmat: Array, xs: Array,
               h0: Array, chunk: int, scan_dtype) -> tuple[Array, Array]:
    """The selective scan, chunked along S.

    dt: (B,S,Di) fp32; a: (Di,N) fp32; bmat/cmat: (B,S,N); xs: (B,S,Di);
    h0: (B,Di,N) fp32. Returns (y: (B,S,Di) fp32, h_final).

    The 4D (B,Q,Di,N) decay/increment tensors are built INSIDE the chunk body
    (§Perf: building them at full S materializes n_levels full-sequence copies
    through the associative scan); the state carry stays fp32, the in-chunk
    scan runs in ``scan_dtype``.
    """
    b, s, di = dt.shape
    n = a.shape[-1]
    q = min(chunk, s)
    n_chunks = -(-s // q)
    pad = n_chunks * q - s
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(x):  # (B, S', ...) -> (nc, B, q, ...)
        return jnp.moveaxis(x.reshape(b, n_chunks, q, *x.shape[2:]), 1, 0)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h, inp):
        dtc, bc, cc, xc = inp        # (B,q,Di), (B,q,N), (B,q,N), (B,q,Di)
        decay = jnp.exp(dtc[..., None] * a).astype(scan_dtype)   # (B,q,Di,N)
        bx = (dtc[..., None] * bc[:, :, None, :].astype(jnp.float32)
              * xc[..., None].astype(jnp.float32)).astype(scan_dtype)
        a_cum, inner = jax.lax.associative_scan(combine, (decay, bx), axis=1)
        h_t = (a_cum.astype(jnp.float32) * h[:, None]
               + inner.astype(jnp.float32))                      # (B,q,Di,N)
        y = jnp.einsum("bqdn,bqn->bqd", h_t, cc.astype(jnp.float32))
        return h_t[:, -1], y

    h_final, ys = jax.lax.scan(
        chunk_step, h0, (to_chunks(dt), to_chunks(bmat), to_chunks(cmat),
                         to_chunks(xs)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, n_chunks * q, di)[:, :s]
    return y, h_final


def ssm_block(p: dict, x: Array, cfg, h0: Array | None = None,
              conv_init: Array | None = None) -> tuple[Array, Array, Array]:
    """x: (B, S, D) -> (y: (B, S, D), h_final: (B, Di, N), conv_tail).

    ``h0``/``conv_init`` allow stateful chunked prefill; None means zeros.
    """
    dtype = x.dtype
    bsz, s, _ = x.shape
    di, n, r, cw = cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv

    xz = x @ p["in_proj"].astype(dtype)
    xs, z = jnp.split(xz, 2, axis=-1)                # (B, S, Di) each
    if conv_init is not None:
        xs_ext = jnp.concatenate([conv_init.astype(dtype), xs], axis=1)
        xs_conv = _causal_conv(xs_ext, p["conv_w"].astype(dtype),
                               p["conv_b"].astype(dtype))[:, cw - 1:]
    else:
        xs_conv = _causal_conv(xs, p["conv_w"].astype(dtype),
                               p["conv_b"].astype(dtype))
    conv_tail = xs[:, -(cw - 1):] if s >= cw - 1 else jnp.pad(
        xs, ((0, 0), (cw - 1 - s, 0), (0, 0)))
    xs_conv = jax.nn.silu(xs_conv)

    proj = xs_conv @ p["x_proj"].astype(dtype)       # (B, S, r + 2N)
    dt_raw, bmat, cmat = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        (dt_raw @ p["dt_proj"].astype(dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))          # (B, S, Di) fp32
    a = -jnp.exp(p["a_log"].astype(jnp.float32))     # (Di, N)

    h0 = jnp.zeros((bsz, di, n), jnp.float32) if h0 is None else h0
    y, h_final = _ssm_inner(dt, a, bmat, cmat, xs_conv, h0, cfg.ssm_chunk,
                            jnp.dtype(cfg.ssm_scan_dtype))
    y = y + xs_conv.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = (y.astype(dtype) * jax.nn.silu(z))
    return y @ p["out_proj"].astype(dtype), h_final, conv_tail


def ssm_decode_step(p: dict, x: Array, h: Array, conv_state: Array,
                    cfg) -> tuple[Array, Array, Array]:
    """One token. x: (B, D); h: (B, Di, N) fp32; conv_state: (B, cw-1, Di).

    Returns (y: (B, D), h', conv_state').
    """
    dtype = x.dtype
    n, r = cfg.ssm_state, cfg.dt_rank

    xz = x @ p["in_proj"].astype(dtype)
    xs, z = jnp.split(xz, 2, axis=-1)                # (B, Di)
    window = jnp.concatenate([conv_state.astype(dtype), xs[:, None]], axis=1)
    xc = jnp.einsum("bcd,cd->bd", window, p["conv_w"].astype(dtype)) \
        + p["conv_b"].astype(dtype)
    xc = jax.nn.silu(xc)
    conv_state_new = window[:, 1:].astype(conv_state.dtype)

    proj = xc @ p["x_proj"].astype(dtype)
    dt_raw, bvec, cvec = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        (dt_raw @ p["dt_proj"].astype(dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))          # (B, Di)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt[..., None] * a)               # (B, Di, N)
    h_new = decay * h + (dt[..., None] * bvec.astype(jnp.float32)[:, None, :]
                         * xc.astype(jnp.float32)[..., None])
    y = jnp.einsum("bdn,bn->bd", h_new, cvec.astype(jnp.float32))
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(dtype) * jax.nn.silu(z)
    return y @ p["out_proj"].astype(dtype), h_new, conv_state_new
