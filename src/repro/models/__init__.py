"""Model zoo: layers + family assemblies for the 10 assigned architectures."""

from .registry import ModelBundle, build  # noqa: F401
