"""Shared neural net layers: RMSNorm, RoPE, GQA attention (full/SWA, chunked), SwiGLU.

All layers are pure functions over param pytrees (plain dicts of jnp arrays).
Parameters are stored in ``param_dtype`` (fp32) and cast to the compute dtype at
use; attention softmax and normalization statistics stay in fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def normal_init(key: Array, shape: tuple[int, ...], scale: float,
                dtype) -> Array:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, weight: Array, eps: float) -> Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * rms).astype(dtype) * weight.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (rotate-half convention, fp32 internals)
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(hd, theta))          # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return rotated.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (training/prefill: chunked over query blocks with a sliding KV
# window; decode: single-token against a cache)
# ---------------------------------------------------------------------------

def _expand_kv(k: Array, n_heads: int) -> Array:
    """(B, S, KV, hd) -> (B, S, H, hd) by repeating each kv head H/KV times."""
    b, s, kv, hd = k.shape
    reps = n_heads // kv
    if reps == 1:
        return k
    return jnp.repeat(k, reps, axis=2)


def chunked_attention(q: Array, k: Array, v: Array, *, causal: bool,
                      window: int, q_chunk: int) -> Array:
    """Memory-efficient attention.

    q: (B, S, H, hd); k, v: (B, S_kv, KV, hd). KV heads are expanded to H.
    ``window > 0`` restricts each query to the last ``window`` keys (SWA) and
    makes compute O(S * window); ``window == 0`` means full attention, computed
    as a scan over query chunks each attending to all keys (memory O(S_kv) per
    chunk, compute O(S * S_kv)).
    Returns (B, S, H, hd).
    """
    b, s, h, hd = q.shape
    s_kv = k.shape[1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = 1.0 / np.sqrt(hd)

    qc = min(q_chunk, s)
    n_chunks = -(-s // qc)
    s_pad = n_chunks * qc
    if s_pad != s:
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))

    # KV slice length per query chunk: the window plus the chunk itself (SWA),
    # or everything (full).
    slice_len = min(window + qc, s_kv) if window > 0 else s_kv

    q_blocks = jnp.moveaxis(q.reshape(b, n_chunks, qc, h, hd), 1, 0)
    starts = jnp.arange(n_chunks) * qc

    def one_chunk(carry, inp):
        q_blk, q_start = inp                                   # (B, qc, H, hd)
        k_start = jnp.clip(q_start + qc - slice_len, 0, max(s_kv - slice_len, 0))
        k_blk = jax.lax.dynamic_slice_in_dim(k, k_start, slice_len, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, k_start, slice_len, axis=1)
        q_pos = q_start + jnp.arange(qc)                       # (qc,)
        k_pos = k_start + jnp.arange(slice_len)                # (slice_len,)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                            preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((qc, slice_len), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask &= (k_pos < s_kv)[None, :]
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q_blk.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v_blk)
        return carry, out

    _, outs = jax.lax.scan(one_chunk, None, (q_blocks, starts))  # (nc, B, qc, H, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s_pad, h, hd)
    return out[:, :s]


def decode_attention(q: Array, k_cache: Array, v_cache: Array,
                     entry_pos: Array, t: Array, *, window: int) -> Array:
    """One-token attention against a cache.

    q: (B, H, hd); caches: (B, C, KVe, hd) with KVe | H; entry_pos: (C,) int32
    absolute position of each cache entry (-1 = empty, shared across batch);
    t: scalar current position. Works for both linear caches (C = max_len) and
    SWA ring buffers (C = window).
    """
    b, c, kve, hd = k_cache.shape
    h = q.shape[1]
    g = h // kve
    qg = q.reshape(b, kve, g, hd)
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("bkgd,bckd->bkgc", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    valid = (entry_pos >= 0) & (entry_pos <= t)
    if window > 0:
        valid &= entry_pos > t - window
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgc,bckd->bkgd", probs, v_cache)
    return out.reshape(b, h, hd)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + attention), shared by all families
# ---------------------------------------------------------------------------

def init_attention(key: Array, cfg, dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    scale = d ** -0.5
    out_scale = scale / np.sqrt(2 * cfg.n_layers)
    return {
        "wq": normal_init(ks[0], (d, h, hd), scale, dtype),
        "wk": normal_init(ks[1], (d, kv, hd), scale, dtype),
        "wv": normal_init(ks[2], (d, kv, hd), scale, dtype),
        "wo": normal_init(ks[3], (h, hd, d), out_scale, dtype),
    }


@jax.custom_vjp
def _qkv_fused(x, wq, wk, wv):
    return (jnp.einsum("...d,dhk->...hk", x, wq),
            jnp.einsum("...d,dhk->...hk", x, wk),
            jnp.einsum("...d,dhk->...hk", x, wv))


def _qkv_fused_fwd(x, wq, wk, wv):
    return _qkv_fused(x, wq, wk, wv), (x, wq, wk, wv)


def _qkv_fused_bwd(res, cts):
    x, wq, wk, wv = res
    dq, dk, dv = cts
    # sum the three model-partial dx contributions BEFORE the TP reduction:
    # autodiff emits three dots whose partial outputs each get their own
    # all-reduce; this collapses them to one (measured in §Perf).
    dx = (jnp.einsum("...hk,dhk->...d", dq, wq)
          + jnp.einsum("...hk,dhk->...d", dk, wk)
          + jnp.einsum("...hk,dhk->...d", dv, wv))
    dwq = jnp.einsum("...d,...hk->dhk", x, dq)
    dwk = jnp.einsum("...d,...hk->dhk", x, dk)
    dwv = jnp.einsum("...d,...hk->dhk", x, dv)
    return dx, dwq, dwk, dwv


_qkv_fused.defvjp(_qkv_fused_fwd, _qkv_fused_bwd)


def qkv_project(p: dict, x: Array, cfg) -> tuple[Array, Array, Array]:
    """x: (..., D) -> q (..., H, hd), k, v (..., KV, hd).

    ``cfg.fused_qkv`` keeps the parameters and forward identical but fuses the
    backward dx reduction (one TP all-reduce instead of three).
    """
    dtype = x.dtype
    wq = p["wq"].astype(dtype)
    wk = p["wk"].astype(dtype)
    wv = p["wv"].astype(dtype)
    if getattr(cfg, "fused_qkv", False):
        return _qkv_fused(x, wq, wk, wv)
    return (jnp.einsum("...d,dhk->...hk", x, wq),
            jnp.einsum("...d,dhk->...hk", x, wk),
            jnp.einsum("...d,dhk->...hk", x, wv))


def attention_block(p: dict, x: Array, positions: Array, cfg, *,
                    causal: bool = True, window: int | None = None,
                    kv_override: tuple[Array, Array] | None = None) -> Array:
    """x: (B, S, D) -> (B, S, D). ``kv_override`` supplies cross-attention K/V."""
    dtype = x.dtype
    w = cfg.sliding_window if window is None else window
    if kv_override is None:
        q, k, v = qkv_project(p, x, cfg)
        k = apply_rope(k, positions, cfg.rope_theta)
        q = apply_rope(q, positions, cfg.rope_theta)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dtype))
        k, v = kv_override
        # cross-attention: no rope, not causal
    out = chunked_attention(q, k, v, causal=causal, window=w, q_chunk=cfg.q_chunk)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dtype))


def project_kv(p: dict, x: Array, positions: Array, cfg) -> tuple[Array, Array]:
    """K/V projections (cache building / cross-attention memory)."""
    _, k, v = qkv_project(p, x, cfg)
    return k, v


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key: Array, cfg, dtype) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    scale = d ** -0.5
    down_scale = f ** -0.5 / np.sqrt(2 * cfg.n_layers)
    return {
        "w_gate": normal_init(ks[0], (d, f), scale, dtype),
        "w_up": normal_init(ks[1], (d, f), scale, dtype),
        "w_down": normal_init(ks[2], (f, d), down_scale, dtype),
    }


def mlp_block(p: dict, x: Array) -> Array:
    dtype = x.dtype
    gate = jax.nn.silu(x @ p["w_gate"].astype(dtype))
    up = x @ p["w_up"].astype(dtype)
    return (gate * up) @ p["w_down"].astype(dtype)
