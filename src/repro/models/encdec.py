"""Encoder–decoder backbone (Seamless-M4T-medium assignment).

The audio frontend is a stub per the assignment: ``input_specs()`` supplies
precomputed frame embeddings (B, S_enc, D). Encoder: non-causal self-attention
+ SwiGLU; decoder: causal self-attention + cross-attention + SwiGLU. Both sides
scan over stacked layers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import (apply_rope, attention_block, chunked_attention,
                     decode_attention, init_attention, init_mlp, mlp_block,
                     normal_init, project_kv, rmsnorm)
from .transformer import _dtype, _maybe_remat, _pdtype, _repeat_kv_to, kv_eff_heads

Array = jax.Array


def init_enc_layer(key: Array, cfg) -> dict:
    dt = _pdtype(cfg)
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.ones((cfg.d_model,), dt),
            "attn": init_attention(k1, cfg, dt),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "mlp": init_mlp(k2, cfg, dt)}


def init_dec_layer(key: Array, cfg) -> dict:
    dt = _pdtype(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": jnp.ones((cfg.d_model,), dt),
            "attn": init_attention(k1, cfg, dt),
            "lnx": jnp.ones((cfg.d_model,), dt),
            "xattn": init_attention(k2, cfg, dt),
            "ln2": jnp.ones((cfg.d_model,), dt),
            "mlp": init_mlp(k3, cfg, dt)}


def init_params(key: Array, cfg) -> dict:
    dt = _pdtype(cfg)
    ke, kd, kemb, kh = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: init_enc_layer(k, cfg))(
        jax.random.split(ke, cfg.n_enc_layers))
    dec = jax.vmap(lambda k: init_dec_layer(k, cfg))(
        jax.random.split(kd, cfg.n_layers))
    return {
        "embed": normal_init(kemb, (cfg.vocab_size, cfg.d_model), 0.02, dt),
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_norm": jnp.ones((cfg.d_model,), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": normal_init(kh, (cfg.d_model, cfg.vocab_size),
                               cfg.d_model ** -0.5, dt),
    }


def encode(params: dict, frames: Array, cfg) -> Array:
    """frames: (B, S_enc, D) stub embeddings -> encoder states (B, S_enc, D)."""
    dt = _dtype(cfg)
    x = frames.astype(dt)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(xx, lp):
        xx = xx + attention_block(lp["attn"], rmsnorm(xx, lp["ln1"], cfg.norm_eps),
                                  positions, cfg, causal=False, window=0)
        xx = xx + mlp_block(lp["mlp"], rmsnorm(xx, lp["ln2"], cfg.norm_eps))
        return xx, None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc_layers"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block(lp: dict, x: Array, positions: Array, enc_out: Array, cfg) -> Array:
    x = x + attention_block(lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps),
                            positions, cfg, causal=True)
    xk, xv = project_kv(lp["xattn"], enc_out, positions, cfg)
    x = x + attention_block(lp["xattn"], rmsnorm(x, lp["lnx"], cfg.norm_eps),
                            positions, cfg, causal=False, window=0,
                            kv_override=(xk, xv))
    x = x + mlp_block(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps))
    return x


def forward(params: dict, frames: Array, tokens: Array, cfg) -> tuple[Array, Array]:
    """Teacher-forced training forward. Returns (logits (B, S_dec, V), aux=0)."""
    dt = _dtype(cfg)
    enc_out = encode(params, frames, cfg)
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(xx, lp):
        return _dec_block(lp, xx, positions, enc_out, cfg), None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["dec_layers"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(dt)
    return logits, jnp.zeros((), jnp.float32)


def prefill(params: dict, frames: Array, tokens: Array, cfg, *, tp: int = 1,
            max_len: int | None = None) -> tuple[Array, dict]:
    """Encode + run the decoder prompt; returns (last logits, cache)."""
    dt = _dtype(cfg)
    enc_out = encode(params, frames, cfg)
    b, s = tokens.shape
    max_len = max_len or s
    kve = kv_eff_heads(cfg, tp)
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(xx, lp):
        xn = rmsnorm(xx, lp["ln1"], cfg.norm_eps)
        k, v = project_kv(lp["attn"], xn, positions, cfg)
        k = apply_rope(k, positions, cfg.rope_theta)
        entries = {
            "k": jnp.pad(_repeat_kv_to(k, kve),
                         ((0, 0), (0, max_len - s), (0, 0), (0, 0))),
            "v": jnp.pad(_repeat_kv_to(v, kve),
                         ((0, 0), (0, max_len - s), (0, 0), (0, 0))),
        }
        xk, xv = project_kv(lp["xattn"], enc_out, positions, cfg)
        entries["xk"], entries["xv"] = xk, xv
        return _dec_block(lp, xx, positions, enc_out, cfg), entries

    x, entries = jax.lax.scan(_maybe_remat(body, cfg), x, params["dec_layers"])
    x_last = rmsnorm(x[:, -1], params["final_norm"], cfg.norm_eps)
    logits = x_last @ params["lm_head"].astype(dt)

    cache = dict(entries)
    cache["t"] = jnp.asarray(s, jnp.int32)
    pos0 = jnp.arange(max_len)
    cache["entry_pos"] = jnp.where(pos0 < s, pos0, -1).astype(jnp.int32)
    return logits, cache


def decode_step(params: dict, cache: dict, token: Array, cfg) -> tuple[Array, dict]:
    """One decoder token; cross K/V are fixed in the cache."""
    dt = _dtype(cfg)
    b = token.shape[0]
    t = cache["t"]
    c = cache["k"].shape[2]
    slot = t % c
    entry_pos = cache["entry_pos"].at[slot].set(t)
    pos_b = jnp.broadcast_to(t, (b, 1))
    x = jnp.take(params["embed"], token, axis=0).astype(dt)

    xs = {"lp": params["dec_layers"], "k": cache["k"], "v": cache["v"],
          "xk": cache["xk"], "xv": cache["xv"]}
    s_enc = cache["xk"].shape[2]
    enc_pos = jnp.arange(s_enc)

    def body(xx, layer):
        lp = layer["lp"]
        xn = rmsnorm(xx, lp["ln1"], cfg.norm_eps)
        ap = lp["attn"]
        q = jnp.einsum("bd,dhk->bhk", xn, ap["wq"].astype(dt))
        k_new = jnp.einsum("bd,dhk->bhk", xn, ap["wk"].astype(dt))
        v_new = jnp.einsum("bd,dhk->bhk", xn, ap["wv"].astype(dt))
        q = apply_rope(q[:, None], pos_b, cfg.rope_theta)[:, 0]
        k_new = apply_rope(k_new[:, None], pos_b, cfg.rope_theta)[:, 0]
        kve = layer["k"].shape[-2]
        k_c = layer["k"].at[:, slot].set(_repeat_kv_to(k_new, kve))
        v_c = layer["v"].at[:, slot].set(_repeat_kv_to(v_new, kve))
        out = decode_attention(q, k_c, v_c, entry_pos, t, window=0)
        xx = xx + jnp.einsum("bhk,hkd->bd", out, ap["wo"].astype(dt))

        xp = lp["xattn"]
        qx = jnp.einsum("bd,dhk->bhk", rmsnorm(xx, lp["lnx"], cfg.norm_eps),
                        xp["wq"].astype(dt))
        out = decode_attention(qx, layer["xk"], layer["xv"], enc_pos,
                               jnp.asarray(s_enc, jnp.int32), window=0)
        xx = xx + jnp.einsum("bhk,hkd->bd", out, xp["wo"].astype(dt))
        xx = xx + mlp_block(lp["mlp"], rmsnorm(xx, lp["ln2"], cfg.norm_eps))
        return xx, {"k": k_c, "v": v_c}

    x, new_entries = jax.lax.scan(body, x, xs)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"].astype(dt)

    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = new_entries["k"], new_entries["v"]
    new_cache["t"] = t + 1
    new_cache["entry_pos"] = entry_pos
    return logits, new_cache
