"""Model registry: ModelConfig -> a uniform bundle of pure functions.

Batch conventions:
  decoder families : {"tokens": (B, S) i32 [, "patches": (B, P, D) f32 (vlm)]}
  encdec           : {"frames": (B, S_enc, D) f32, "tokens": (B, S) i32}
  decode step      : token (B,) i32 + cache pytree
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import encdec, transformer

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: Any
    init: Callable[[Array], dict]
    loss_fn: Callable[..., tuple[Array, dict]]     # (params, batch, mesh) -> loss, metrics
    forward: Callable[..., Array]
    prefill: Callable[..., tuple[Array, dict]]      # (params, batch, tp, max_len, mesh)
    decode_step: Callable[..., tuple[Array, dict]]  # (params, cache, token, mesh)
    init_cache: Callable[..., dict]                 # (batch, max_len, tp)


AUX_WEIGHT = 0.01


def _decoder_bundle(cfg) -> ModelBundle:
    def init(key):
        return transformer.init_params(key, cfg)

    def _prefix(batch):
        return batch.get("patches") if cfg.frontend == "patches" else None

    def loss_fn(params, batch, mesh=None):
        tokens = batch["tokens"]
        logits, aux = transformer.forward(params, tokens, cfg, mesh,
                                          prefix_embeddings=_prefix(batch))
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(tokens, jnp.float32)
        if cfg.frontend == "patches":  # no LM loss on the image prefix
            p = batch["patches"].shape[1]
            mask = mask.at[:, :p].set(0.0)
        ce = transformer.lm_loss(logits[:, :-1], tokens[:, 1:], mask[:, 1:])
        loss = ce + AUX_WEIGHT * aux
        return loss, {"ce": ce, "aux": aux}

    def forward(params, batch, mesh=None):
        logits, _ = transformer.forward(params, batch["tokens"], cfg, mesh,
                                        prefix_embeddings=_prefix(batch))
        return logits

    def prefill(params, batch, mesh=None, tp=1, max_len=None):
        return transformer.prefill(params, batch["tokens"], cfg, mesh, tp=tp,
                                   max_len=max_len,
                                   prefix_embeddings=_prefix(batch))

    def decode_step(params, cache, token, mesh=None):
        return transformer.decode_step(params, cache, token, cfg, mesh)

    def init_cache(batch, max_len, tp=1):
        return transformer.init_cache(cfg, batch, max_len, tp=tp)

    return ModelBundle(cfg, init, loss_fn, forward, prefill, decode_step,
                       init_cache)


def _encdec_bundle(cfg) -> ModelBundle:
    def init(key):
        return encdec.init_params(key, cfg)

    def loss_fn(params, batch, mesh=None):
        tokens = batch["tokens"]
        logits, aux = encdec.forward(params, batch["frames"], tokens, cfg)
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(tokens, jnp.float32)
        ce = transformer.lm_loss(logits[:, :-1], tokens[:, 1:], mask[:, 1:])
        return ce, {"ce": ce, "aux": aux}

    def forward(params, batch, mesh=None):
        logits, _ = encdec.forward(params, batch["frames"], batch["tokens"], cfg)
        return logits

    def prefill(params, batch, mesh=None, tp=1, max_len=None):
        return encdec.prefill(params, batch["frames"], batch["tokens"], cfg,
                              tp=tp, max_len=max_len)

    def decode_step(params, cache, token, mesh=None):
        return encdec.decode_step(params, cache, token, cfg)

    def init_cache(batch, max_len, tp=1):
        raise NotImplementedError(
            "encdec caches come from prefill (cross-K/V need encoder states)")

    return ModelBundle(cfg, init, loss_fn, forward, prefill, decode_step,
                       init_cache)


def build(cfg) -> ModelBundle:
    if cfg.is_encdec:
        return _encdec_bundle(cfg)
    return _decoder_bundle(cfg)
