"""Production meshes. A function, not a module constant: importing this module
must never touch jax device state (the dry-run sets XLA_FLAGS first)."""

from __future__ import annotations

import jax


def _axis_types(n: int) -> dict:
    # jax < 0.4.35 has no sharding.AxisType; Auto is its only behavior there
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n}
    return {}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; multi_pod stacks 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"need {data * model} devices, have {n}")
    return jax.make_mesh((data, model), ("data", "model"), **_axis_types(2))
