"""Serving drivers: LM generation and signature-based similarity search.

    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch llama3_2_1b
    PYTHONPATH=src python -m repro.launch.serve --mode search --docs 400

Observability (search mode): ``--metrics-dump PATH`` appends one JSONL
snapshot of the process metrics registry (+ drained trace spans) every
``--metrics-interval`` seconds while the driver runs, plus a final line at
shutdown — validate with ``python -m repro.obs.dump --check PATH``.
``--trace-sample-rate`` sets the root-span sampling probability (1.0 =
trace every query batch; sampled traces ride the wire to tcp shard workers
and come back stitched).
"""

from __future__ import annotations

import argparse
import contextlib
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.dump import MetricsDumper
from repro.serve.decode import generate


def serve_lm(args) -> None:
    cfg = reduced(get_config(args.arch))
    bundle = build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": np.asarray(
        rng.integers(0, cfg.vocab_size_real, (args.batch, args.prompt_len)),
        np.int32)}
    if cfg.frontend == "frames":
        batch["frames"] = rng.normal(
            size=(args.batch, args.prompt_len, cfg.d_model)).astype(np.float32)
    if cfg.frontend == "patches":
        batch["patches"] = rng.normal(
            size=(args.batch, args.prompt_len // 8, cfg.d_model)
        ).astype(np.float32)
    t0 = time.perf_counter()
    toks = generate(bundle, params, batch, max_new_tokens=args.new_tokens,
                    temperature=args.temperature)
    dt = time.perf_counter() - t0
    n = args.batch * args.new_tokens
    print(f"[serve] {cfg.name}: generated {n} tokens in {dt:.2f}s "
          f"({n / dt:.0f} tok/s, batch={args.batch})")
    print(f"[serve] sample: {toks[0][:16].tolist()}")


def serve_search(args) -> None:
    from repro.data.shingle import batch_shingles
    from repro.data.synthetic import corpus_with_duplicates
    from repro.serve.search import SearchConfig, SimilaritySearchService
    obs_trace.default().sample_rate = args.trace_sample_rate
    docs, _ = corpus_with_duplicates(args.docs, vocab=30_000, doc_len=256,
                                     dup_fraction=0.4, seed=0)
    idx = batch_shingles(docs, n=3, d=1 << 14)
    dumper = (MetricsDumper(args.metrics_dump,
                            interval_s=args.metrics_interval)
              if args.metrics_dump else contextlib.nullcontext())
    # tcp: one shard worker process per shard on localhost, reaped by
    # close() — same answers as inproc, bit-for-bit
    with dumper, SimilaritySearchService(SearchConfig(
            d=1 << 14, k=256, n_bands=64, rows_per_band=4,
            n_shards=args.shards, partition=args.partition,
            probe_impl=args.probe, query_impl=args.query_impl,
            transport=args.transport,
            query_timeout_s=args.query_timeout,
            hedge=args.hedge,
            hedge_delay_ms=args.hedge_delay_ms,
            n_replicas=args.replicas,
            journal_dir=args.journal_dir,
            supervisor=args.supervisor)) as svc:
        # pipelined fused ingest: batch N+1 signs while batch N scatters
        # (--pipeline-depth 1 = serial; answers identical at any depth)
        bs = max(1, min(args.ingest_batch, len(idx)))
        t0 = time.perf_counter()
        with svc.pipeline(depth=args.pipeline_depth) as pipe:
            for lo in range(0, len(idx), bs):
                pipe.submit(idx[lo: lo + bs])
        t_ingest = time.perf_counter() - t0
        tm = pipe.timings
        print(f"[serve] ingest {svc.size} docs in {t_ingest * 1e3:.1f} ms "
              f"(depth={args.pipeline_depth}, "
              f"{svc.size / t_ingest:.0f} docs/s; sign={tm['sign_s'] * 1e3:.0f}ms "
              f"wait={tm['wait_s'] * 1e3:.0f}ms "
              f"scatter={tm['scatter_s'] * 1e3:.0f}ms)")
        t0 = time.perf_counter()
        ids, scores = svc.query_sparse(idx[: args.batch], top_k=5)
        dt = time.perf_counter() - t0
        sizes = svc.store.shard_sizes().tolist()
        print(f"[serve] search over {svc.size} docs "
              f"({args.shards} shard(s) {sizes}, probe={args.probe}, "
              f"query={args.query_impl}, transport={args.transport}): "
              f"{args.batch} queries in {dt * 1e3:.1f} ms; top-1 self-hit "
              f"{(ids[:, 0] == np.arange(args.batch)).mean() * 100:.0f}%")
        if args.stream:
            # open-loop streaming demo: Poisson arrivals at --stream-qps
            # through the admission queue; the percentiles are client-side
            # end-to-end (admission wait + batch wall), the honest number
            # an outside caller would see
            rng = np.random.default_rng(1)
            n_q = args.stream_queries
            qrows = idx[rng.integers(0, len(idx), n_q)]
            gaps = rng.exponential(1.0 / args.stream_qps, n_q)
            with svc.stream(max_batch=args.max_batch,
                            max_delay_ms=args.max_delay_ms,
                            depth=args.stream_depth) as stream:
                t0 = time.perf_counter()
                tickets = []
                for i in range(n_q):
                    target = t0 + gaps[: i + 1].sum()
                    while time.perf_counter() < target:
                        time.sleep(min(target - time.perf_counter(), 1e-3))
                    tickets.append(stream.submit_sparse(qrows[i], top_k=5))
                for t in tickets:
                    t.result(timeout=svc.cfg.query_timeout_s + 30)
                wall = time.perf_counter() - t0
            lat = np.sort([t.latency_s for t in tickets])
            print(f"[serve] stream: {n_q} queries at {args.stream_qps:.0f} "
                  f"qps offered -> {n_q / wall:.0f} qps served "
                  f"({stream.n_batches} batches, depth={args.stream_depth}, "
                  f"hedge={'on' if args.hedge else 'off'}); e2e p50 "
                  f"{lat[int(0.50 * (n_q - 1))] * 1e3:.2f} ms, p99 "
                  f"{lat[int(0.99 * (n_q - 1))] * 1e3:.2f} ms")
        # one merged plane snapshot (coordinator + tcp workers): the
        # per-shard partial-latency split is the skew evidence
        snap = svc.store.obs_snapshot()
        shard_p50 = [
            obs_metrics.hist_quantile(
                snap["hists"].get(f"query.shard{i}.partial",
                                  {"count": 0, "buckets": {}}), 0.5)
            for i in range(args.shards)]
        print(f"[serve] obs: {len(snap['counters'])} counters, "
              f"{len(snap['hists'])} hists; shard partial p50(ms) "
              f"{[None if p is None else round(p * 1e3, 2) for p in shard_p50]}")
        # stitched-trace summary (skipped when a dumper already drained the
        # ring — the spans live in the dump file then)
        tid = obs_trace.default().last_trace_id()
        spans = obs_trace.default().for_trace(tid) if tid is not None else []
        if spans:
            legs = sorted({(s["proc"], s["name"]) for s in spans})
            print(f"[serve] trace {tid:x}: {len(spans)} spans across "
                  f"{len({p for p, _ in legs})} proc(s): "
                  f"{', '.join(f'{p}/{n}' for p, n in legs)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["lm", "search"], default="lm")
    ap.add_argument("--arch", default="llama3_2_1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--docs", type=int, default=400)
    ap.add_argument("--shards", type=int, default=1,
                    help="index partitions (search mode)")
    ap.add_argument("--partition", choices=["round_robin", "hash"],
                    default="round_robin")
    ap.add_argument("--probe", choices=["auto", "numpy", "jnp", "pallas"],
                    default="auto", help="LSH bucket-probe backend")
    ap.add_argument("--query-impl",
                    choices=["auto", "jnp", "pallas", "host"],
                    default="auto",
                    help="fused device query pipeline backend (host = "
                         "legacy fold + planner walk, the reference oracle)")
    ap.add_argument("--transport", choices=["inproc", "tcp"],
                    default="inproc",
                    help="shard backend: in-process loop or spawned tcp "
                         "shard workers (search mode)")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="ingest batches signed-but-unscattered in flight "
                         "(1 = serial sign->scatter; search mode)")
    ap.add_argument("--ingest-batch", type=int, default=128,
                    help="documents per ingest pipeline batch (search mode)")
    ap.add_argument("--query-timeout", type=float, default=30.0,
                    dest="query_timeout",
                    help="query fan-out deadline in seconds (tcp transport; "
                         "TransportTimeout errors name this knob)")
    ap.add_argument("--hedge", action="store_true",
                    help="hedge slow shard reads on a second connection "
                         "(tcp transport; never changes results)")
    ap.add_argument("--hedge-delay-ms", type=float, default=None,
                    help="fixed hedge delay in ms (default: derived from "
                         "observed per-shard reply latencies; 0 hedges "
                         "immediately)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="replica workers per shard (tcp transport; 1 = "
                         "the classic unreplicated plane, bit-identical)")
    ap.add_argument("--journal-dir", default=None,
                    help="directory for the write-ahead ingest journal "
                         "(tcp transport; required for replica resync)")
    ap.add_argument("--supervisor", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="self-heal dead replicas: respawn, replay the "
                         "journal, digest-verify, rejoin (--replicas > 1)")
    ap.add_argument("--stream", action="store_true",
                    help="run the open-loop streaming demo after ingest "
                         "(search mode)")
    ap.add_argument("--stream-qps", type=float, default=500.0,
                    help="offered Poisson arrival rate for --stream")
    ap.add_argument("--stream-queries", type=int, default=512,
                    help="queries to stream for --stream")
    ap.add_argument("--max-batch", type=int, default=256,
                    help="admission queue flush size (--stream)")
    ap.add_argument("--max-delay-ms", type=float, default=2.0,
                    help="admission queue flush deadline in ms (--stream)")
    ap.add_argument("--stream-depth", type=int, default=2,
                    help="streaming pipeline depth: batches in flight "
                         "(1 = serial; --stream)")
    ap.add_argument("--metrics-dump", metavar="PATH", default=None,
                    help="append periodic JSONL registry snapshots + trace "
                         "spans here while serving (search mode); validate "
                         "with `python -m repro.obs.dump --check PATH`")
    ap.add_argument("--metrics-interval", type=float, default=1.0,
                    help="seconds between --metrics-dump lines")
    ap.add_argument("--trace-sample-rate", type=float, default=1.0,
                    help="probability a query batch opens a (cross-process) "
                         "trace; 0 disables tracing (search mode)")
    args = ap.parse_args()
    if args.mode == "lm":
        serve_lm(args)
    else:
        serve_search(args)


if __name__ == "__main__":
    main()
