"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

No device allocation anywhere: batches, params, optimizer states and caches are
all abstract shapes; modality frontends are stubs supplying embeddings
(DESIGN.md §6).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeCell) -> dict:
    """Host batch specs for train/prefill kinds (decode adds the cache)."""
    gb, s = shape.global_batch, shape.seq_len
    batch: dict = {"tokens": SDS((gb, s), jnp.int32)}
    if cfg.frontend == "patches":
        batch["patches"] = SDS((gb, s // 8, cfg.d_model), jnp.float32)
    if cfg.frontend == "frames":
        batch["frames"] = SDS((gb, s, cfg.d_model), jnp.float32)
    return batch


def token_specs(cfg: ModelConfig, shape: ShapeCell) -> SDS:
    return SDS((shape.global_batch,), jnp.int32)


def params_shape(bundle) -> dict:
    return jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))


def cache_shape(bundle, cfg: ModelConfig, shape: ShapeCell, tp: int,
                p_shape=None) -> dict:
    """Abstract decode-cache pytree for a cache of seq_len entries."""
    gb, s = shape.global_batch, shape.seq_len
    if cfg.is_encdec:  # cross-K/V sizes come from the encoder: shape prefill
        p_shape = p_shape if p_shape is not None else params_shape(bundle)
        batch = input_specs(cfg, shape)
        _, cache = jax.eval_shape(
            lambda p, b: bundle.prefill(p, b, tp=tp, max_len=s),
            p_shape, batch)
        return cache
    return jax.eval_shape(lambda: bundle.init_cache(gb, s, tp=tp))


def runnable(cfg: ModelConfig, shape: ShapeCell) -> tuple[bool, str]:
    """Is this (arch, shape) cell runnable? (long_500k needs sub-quadratic.)"""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skipped: pure full attention cannot decode at 524288 "
                       "context (DESIGN.md §6)")
    return True, ""
