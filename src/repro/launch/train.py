"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b \
        --reduced --steps 50 --dedup --workdir runs/train_llama

On real hardware drop --reduced and point the mesh at the pod; on this CPU
container --reduced exercises the identical code path end to end (dedup ->
sharded batches -> fault-tolerant loop -> checkpoints).
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import TrainConfig
from repro.data.dedup import DedupConfig, dedup_corpus
from repro.data.loader import PrefetchIterator, deduped_token_batches
from repro.data.synthetic import corpus_with_duplicates, token_batches
from repro.models import build
from repro.train.train_loop import TrainLoop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--dedup", action="store_true",
                    help="run the C-MinHash dedup pipeline first")
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16"])
    ap.add_argument("--workdir", default="runs/train")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    bundle = build(cfg)
    print(f"[launch] {cfg.name}: {cfg.param_count() / 1e6:.1f}M params "
          f"({'reduced' if args.reduced else 'FULL'})")

    if args.dedup:
        docs, _ = corpus_with_duplicates(
            400, vocab=cfg.vocab_size_real, doc_len=max(args.seq, 128),
            dup_fraction=0.25, seed=0)
        res = dedup_corpus(docs, DedupConfig(
            d=1 << 14, k=256, n_bands=64, rows_per_band=4, threshold=0.5))
        print(f"[launch] dedup kept {len(res.keep)}/{len(docs)} docs")
        data = deduped_token_batches(docs, res.keep, args.batch, args.seq,
                                     vocab=cfg.vocab_size_real)
    else:
        data = token_batches(cfg.vocab_size_real, args.batch, args.seq)

    tc = TrainConfig(total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1),
                     microbatches=args.microbatches,
                     grad_compression=args.grad_compression,
                     checkpoint_every=max(args.steps // 4, 1))
    out = TrainLoop(bundle, tc, PrefetchIterator(data), args.workdir).run()
    if out["losses"]:
        print(f"[launch] final loss {np.mean(out['losses'][-5:]):.4f}, "
              f"stragglers flagged: {out['stragglers']}")


if __name__ == "__main__":
    main()
