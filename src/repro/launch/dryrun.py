import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
CPU devices host the production meshes; every cell must .lower().compile(), and
we record memory_analysis / cost_analysis / scan-aware HLO costs for the
roofline (EXPERIMENTS.md §Dry-run, §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out runs/dryrun [--force]
"""

import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402

from repro.analysis import hlo as hlo_analysis                      # noqa: E402
from repro.configs import ARCH_IDS, get_config                      # noqa: E402
from repro.configs.base import SHAPES, TrainConfig, shape_by_name   # noqa: E402
from repro.distributed.sharding import (batch_shardings,            # noqa: E402
                                        cache_specs, param_shardings)
from repro.launch import specs as S                                 # noqa: E402
from repro.launch.mesh import make_production_mesh                  # noqa: E402
from repro.models import build                                      # noqa: E402
from repro.train.optimizer import init_opt_state                    # noqa: E402
from repro.train.train_loop import jit_train_step                   # noqa: E402
from jax.sharding import NamedSharding                              # noqa: E402


def lower_cell(arch: str, shape_name: str, mesh, *, tc: TrainConfig,
               cfg_overrides: dict | None = None):
    """Build + lower + compile one cell. Returns (lowered, compiled, meta).

    ``cfg_overrides`` supports the §Perf hillclimb: the same cell re-lowered
    with e.g. {"fused_qkv": True} or {"param_dtype": "bfloat16"}.
    """
    import dataclasses
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = shape_by_name(shape_name)
    ok, why = S.runnable(cfg, shape)
    if not ok:
        return None, None, {"status": "skipped", "reason": why}

    bundle = build(cfg)
    p_shape = S.params_shape(bundle)
    tp = mesh.shape["model"]
    t0 = time.time()

    if shape.kind == "train":
        batch = S.input_specs(cfg, shape)
        step = jit_train_step(bundle, tc, mesh, p_shape, batch)
        opt_shape = jax.eval_shape(init_opt_state, p_shape)
        lowered = step.lower(p_shape, opt_shape, batch)
    elif shape.kind == "prefill":
        batch = S.input_specs(cfg, shape)
        p_shard = param_shardings(p_shape, mesh)
        b_shard = batch_shardings(batch, mesh)

        def prefill_fn(params, b):
            return bundle.prefill(params, b, mesh=mesh, tp=tp,
                                  max_len=shape.seq_len)

        lowered = jax.jit(prefill_fn,
                          in_shardings=(p_shard, b_shard)).lower(p_shape, batch)
    else:  # decode
        from repro.distributed.sharding import batch_axes as _baxes
        import numpy as _np
        cache = S.cache_shape(bundle, cfg, shape, tp, p_shape=p_shape)
        token = S.token_specs(cfg, shape)
        # batch-starved decode (e.g. long_500k, B=1): the data axes would
        # replicate the work — shard tensor dims over (data x model) instead
        # (2D serve sharding, EXPERIMENTS.md §Perf D). Gated on the arch's
        # dims dividing the full axis product: partial divisibility makes the
        # partitioner reshard mid-layer and costs more than it saves
        # (measured: hymba/danube regress 3-5x).
        n_batch = int(_np.prod([mesh.shape[a] for a in _baxes(mesh)]))
        n_total = n_batch * mesh.shape["model"]
        fits_2d = (cfg.family == "ssm"
                   and cfg.d_inner % n_total == 0
                   and cfg.vocab_size % n_total == 0)
        if shape.global_batch % n_batch != 0 and fits_2d:
            tensor_axes = tuple(_baxes(mesh)) + ("model",)
        else:
            tensor_axes = "model"
        p_shard = param_shardings(p_shape, mesh, tensor_axes=tensor_axes)
        c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                               cache_specs(cache, mesh,
                                           tensor_axes=tensor_axes))
        t_shard = batch_shardings({"token": token}, mesh)["token"]

        def serve_step(params, c, tok):
            return bundle.decode_step(params, c, tok, mesh=mesh)

        lowered = jax.jit(serve_step,
                          in_shardings=(p_shard, c_shard, t_shard),
                          donate_argnums=(1,)).lower(p_shape, cache, token)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    meta = {"status": "ok", "lower_s": t_lower, "compile_s": t_compile}
    return lowered, compiled, meta


def analyze_cell(arch: str, shape_name: str, mesh, mesh_name: str,
                 tc: TrainConfig) -> dict:
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mesh_shape": dict(mesh.shape), "n_chips": mesh.size,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "kind": shape.kind,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
    }
    try:
        lowered, compiled, meta = lower_cell(arch, shape_name, mesh, tc=tc)
        rec.update(meta)
        if meta["status"] == "skipped":
            return rec
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        }
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        rec["xla_cost"] = {k: ca[k] for k in ("flops", "bytes accessed")
                           if k in ca}
        txt = compiled.as_text()
        rec["hlo_chars"] = len(txt)
        cost = hlo_analysis.analyze(txt)
        rec["hlo_cost"] = {
            "flops": cost.flops, "bytes": cost.bytes,
            "bytes_naive": cost.bytes_naive,
            "collective_bytes": cost.collective_bytes,
            "collective_breakdown": cost.collective_breakdown,
            "n_collectives": cost.n_collectives,
        }
    except Exception as e:  # a failing cell is a bug; record it loudly
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [
        a.replace("-", "_").replace(".", "_") for a in args.arch.split(",")]
    shapes = [s.name for s in SHAPES] if args.shape == "all" \
        else args.shape.split(",")
    mesh_names = {"single": ["single_pod"], "multi": ["multi_pod"],
                  "both": ["single_pod", "multi_pod"]}[args.mesh]
    tc = TrainConfig()

    os.makedirs(args.out, exist_ok=True)
    meshes = {}
    for mesh_name in mesh_names:
        meshes[mesh_name] = make_production_mesh(
            multi_pod=(mesh_name == "multi_pod"))

    for mesh_name in mesh_names:
        mesh = meshes[mesh_name]
        for arch in archs:
            for shape_name in shapes:
                path = os.path.join(args.out,
                                    f"{mesh_name}__{arch}__{shape_name}.json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip cached] {path}")
                    continue
                t0 = time.time()
                rec = analyze_cell(arch, shape_name, mesh, mesh_name, tc)
                rec["wall_s"] = time.time() - t0
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f"compile {rec['compile_s']:.1f}s "
                             f"flops/dev {rec['hlo_cost']['flops']:.3e} "
                             f"coll {rec['hlo_cost']['collective_bytes']:.3e}B")
                elif status == "error":
                    extra = rec["error"][:160]
                print(f"[{status}] {mesh_name} {arch} {shape_name} "
                      f"({rec['wall_s']:.1f}s) {extra}", flush=True)


if __name__ == "__main__":
    main()
