"""repro — C-MinHash (Li & Li, 2021) as a production-scale JAX framework.

Layers: core/ (the paper's algorithm + theory), kernels/ (Pallas TPU),
models/ (10-arch LM zoo), distributed/, train/, serve/, data/, launch/,
analysis/ (roofline). See DESIGN.md.
"""

__version__ = "0.1.0"
