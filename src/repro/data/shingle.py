"""Documents -> n-gram shingles -> sparse binary vectors in a D-dim universe."""

from __future__ import annotations

import numpy as np

_P1 = np.uint64(11400714819323198485)
_P2 = np.uint64(14029467366897019727)


def shingle_indices(tokens: np.ndarray, *, n: int = 3, d: int = 1 << 16,
                    max_nnz: int | None = None) -> np.ndarray:
    """n-gram rolling hash of a token array -> sorted unique indices in [0, d).

    Returns an int32 array; pad with -1 to ``max_nnz`` if given.
    """
    t = np.asarray(tokens, np.uint64)
    if t.size < n:
        h = np.zeros(1, np.uint64)
    else:
        h = np.zeros(t.size - n + 1, np.uint64)
        for i in range(n):
            h = (h * _P1 + t[i: t.size - n + 1 + i] * _P2)
    idx = np.unique((h % np.uint64(d)).astype(np.int64)).astype(np.int32)
    if max_nnz is not None:
        out = np.full(max_nnz, -1, np.int32)
        out[: min(len(idx), max_nnz)] = idx[:max_nnz]
        return out
    return idx


def batch_shingles(docs: list[np.ndarray], *, n: int = 3, d: int = 1 << 16,
                   max_nnz: int | None = None) -> np.ndarray:
    """(B, max_nnz) padded sparse index matrix for a list of documents."""
    idxs = [shingle_indices(doc, n=n, d=d) for doc in docs]
    width = max_nnz or max(len(i) for i in idxs)
    out = np.full((len(docs), width), -1, np.int32)
    for row, idx in enumerate(idxs):
        out[row, : min(len(idx), width)] = idx[:width]
    return out


def densify(idx: np.ndarray, d: int) -> np.ndarray:
    """(B, NNZ) padded indices -> (B, D) int8 binary."""
    b = idx.shape[0]
    out = np.zeros((b, d), np.int8)
    rows, cols = np.nonzero(idx >= 0)
    out[rows, idx[rows, cols]] = 1
    return out
