"""Host data loading: sharded batching with a prefetch thread (overlaps host
data prep with device compute — one of the async tricks in DESIGN.md §5)."""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax
import numpy as np


class PrefetchIterator:
    """Wraps a host iterator; a daemon thread keeps ``depth`` batches ready and
    (optionally) pre-places them onto devices."""

    def __init__(self, it: Iterator, *, depth: int = 2,
                 place: Callable | None = None):
        self._it = it
        self._place = place
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        try:
            for item in self._it:
                if self._place is not None:
                    item = self._place(item)
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def device_placer(mesh, shardings_fn: Callable) -> Callable:
    """Returns a function placing a host batch onto the mesh with the given
    sharding builder (e.g. distributed.sharding.batch_shardings)."""

    def place(batch: dict):
        shardings = shardings_fn(batch, mesh)
        return jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(x), s), batch, shardings)

    return place


def deduped_token_batches(docs: list[np.ndarray], keep: np.ndarray,
                          batch: int, seq: int, *, vocab: int,
                          seed: int = 0) -> Iterator[dict]:
    """Pack retained documents into fixed-length training batches (infinite,
    reshuffling each epoch)."""
    rng = np.random.default_rng(seed)
    kept = [docs[i] for i in keep]
    while True:
        order = rng.permutation(len(kept))
        stream = np.concatenate([kept[i] for i in order])
        stream = np.clip(stream, 0, vocab - 1).astype(np.int32)
        n_tok = batch * seq
        for off in range(0, len(stream) - n_tok + 1, n_tok):
            yield {"tokens": stream[off: off + n_tok].reshape(batch, seq)}
