"""Near-duplicate removal with C-MinHash + banded LSH — the LLM-corpus use of
the paper's technique, and the training pipeline's first stage.

Stages (DESIGN.md §3):
  docs -> shingles (data/shingle.py)
       -> C-MinHash signatures (SketchEngine: 2 permutations, sharded/kernel)
       -> banded LSH candidate pairs
       -> signature-similarity verification (collision kernel)
       -> union-find clusters -> keep one representative per cluster.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.engine import SketchConfig, SketchEngine
from repro.core.lsh import UnionFind
from repro.store import SketchStore, StoreConfig

from .shingle import batch_shingles


@dataclasses.dataclass(frozen=True)
class DedupConfig:
    d: int = 1 << 16            # shingle universe
    k: int = 256                # signature length
    shingle_n: int = 3
    n_bands: int = 64           # b=64, r=4: P[candidate] ~= 1-(1-J^4)^64,
    rows_per_band: int = 4      # >99% for J >= 0.5, <2% for J <= 0.15
    threshold: float = 0.5      # verified Jaccard-estimate cut
    seed: int = 0


@dataclasses.dataclass
class DedupResult:
    keep: np.ndarray            # indices of retained docs
    cluster_of: np.ndarray      # cluster id per doc (singletons included)
    n_candidates: int
    n_verified: int
    signatures: np.ndarray      # (n_docs, K)


def dedup_corpus(docs: list[np.ndarray], cfg: DedupConfig,
                 mesh=None) -> DedupResult:
    if cfg.n_bands * cfg.rows_per_band != cfg.k:
        raise ValueError("n_bands * rows_per_band must equal k")
    idx = batch_shingles(docs, n=cfg.shingle_n, d=cfg.d)
    engine = SketchEngine(SketchConfig(d=cfg.d, k=cfg.k, seed=cfg.seed),
                          mesh=mesh)
    sigs = np.asarray(engine.signatures_sparse(jnp.asarray(idx)))

    # SketchStore's vectorized LSH table replaces host-side dict bucketing;
    # candidate_pairs() is exact (spilled entries are paired via their
    # recorded band keys), so clusters match the reference dict path.
    store = SketchStore(StoreConfig.sized_for(
        len(docs), k=cfg.k, n_bands=cfg.n_bands,
        rows_per_band=cfg.rows_per_band,
        store_signatures=False))    # dedup only needs candidate pairs
    store.add(sigs)
    pairs = store.candidate_pairs()                 # (P, 2) sorted unique

    uf = UnionFind(len(docs))
    n_verified = 0
    if len(pairs):
        # aligned row-wise verification (the pairwise collision kernel is for
        # query-vs-index search; candidate pairs are 1:1)
        eq = (sigs[pairs[:, 0]] == sigs[pairs[:, 1]]).mean(axis=1)
        for (i, j), sim in zip(pairs, eq):
            if sim >= cfg.threshold:
                uf.union(int(i), int(j))
                n_verified += 1

    cluster_of = np.asarray([uf.find(i) for i in range(len(docs))])
    keep = np.asarray(sorted({uf.find(i) for i in range(len(docs))}))
    return DedupResult(keep=keep, cluster_of=cluster_of,
                       n_candidates=len(pairs), n_verified=n_verified,
                       signatures=sigs)


def dedup_metrics(result: DedupResult, truth_labels: np.ndarray) -> dict:
    """Pair-level precision/recall against planted duplicate clusters."""
    n = len(result.cluster_of)
    tp = fp = fn = 0
    for i in range(n):
        for j in range(i + 1, n):
            truth = truth_labels[i] >= 0 and truth_labels[i] == truth_labels[j]
            pred = result.cluster_of[i] == result.cluster_of[j]
            tp += truth and pred
            fp += pred and not truth
            fn += truth and not pred
    precision = tp / max(tp + fp, 1)
    recall = tp / max(tp + fn, 1)
    return {"precision": precision, "recall": recall, "tp": tp, "fp": fp,
            "fn": fn, "kept": len(result.keep), "total": n}
