"""Synthetic corpora: Zipf token streams and document sets with planted
near-duplicates (ground truth for the dedup pipeline) plus binary datasets with
text/image-like sparsity statistics for the paper's Fig. 7-style MAE benches.
"""

from __future__ import annotations

import numpy as np


def zipf_tokens(rng: np.random.Generator, n: int, vocab: int,
                alpha: float = 1.2) -> np.ndarray:
    """Zipf-distributed token ids in [2, vocab) (0/1 reserved for pad/bos)."""
    ranks = rng.zipf(alpha, size=n)
    return (2 + (ranks - 1) % (vocab - 2)).astype(np.int32)


def token_batches(vocab: int, batch: int, seq: int, seed: int = 0):
    """Infinite iterator of {'tokens': (B, S) int32} training batches."""
    rng = np.random.default_rng(seed)
    while True:
        yield {"tokens": zipf_tokens(rng, batch * seq, vocab).reshape(batch, seq)}


def corpus_with_duplicates(n_docs: int, *, vocab: int = 50_000,
                           doc_len: int = 256, dup_fraction: float = 0.3,
                           cluster_size: int = 3, edit_fraction: float = 0.05,
                           seed: int = 0):
    """Documents (list of int32 arrays) + ground-truth duplicate clusters.

    A ``dup_fraction`` of docs are near-copies: each cluster shares a base doc
    with ``edit_fraction`` of tokens resampled.
    Returns (docs, cluster_id per doc: -1 for unique docs).
    """
    rng = np.random.default_rng(seed)
    n_clustered = int(n_docs * dup_fraction)
    n_clusters = max(n_clustered // cluster_size, 1)
    docs: list[np.ndarray] = []
    labels: list[int] = []
    for c in range(n_clusters):
        base = zipf_tokens(rng, doc_len, vocab)
        for _ in range(cluster_size):
            doc = base.copy()
            n_edit = int(doc_len * edit_fraction)
            if n_edit:
                pos = rng.choice(doc_len, n_edit, replace=False)
                doc[pos] = zipf_tokens(rng, n_edit, vocab)
            docs.append(doc)
            labels.append(c)
    while len(docs) < n_docs:
        docs.append(zipf_tokens(rng, doc_len, vocab))
        labels.append(-1)
    order = rng.permutation(len(docs))
    return [docs[i] for i in order], np.asarray(labels)[order]


def binary_pairs(rng: np.random.Generator, n_pairs: int, d: int, f: int,
                 a: int, *, structured: bool = True):
    """(v, w) batches that are exact (D, f, a)-data pairs (paper Fig. 6 setup).

    ``structured=True`` uses the paper's pattern (runs of O / x / -), which is
    exactly the case where C-MinHash-(0,pi) degrades; False scatters uniformly.
    """
    v = np.zeros((n_pairs, d), np.int8)
    w = np.zeros((n_pairs, d), np.int8)
    for i in range(n_pairs):
        if structured:
            idx = np.arange(d)
        else:
            idx = rng.permutation(d)
        both = idx[:a]
        only = idx[a:f]
        v[i, both] = 1
        w[i, both] = 1
        half = (f - a) // 2
        v[i, only[:half]] = 1
        w[i, only[half:]] = 1
    return v, w


def textlike_binary_dataset(rng: np.random.Generator, n: int, d: int,
                            mean_nnz: int) -> np.ndarray:
    """Sparse docs with Zipf-weighted feature popularity (text statistics)."""
    popularity = 1.0 / np.arange(1, d + 1) ** 1.1
    popularity /= popularity.sum()
    out = np.zeros((n, d), np.int8)
    for i in range(n):
        nnz = max(1, int(rng.poisson(mean_nnz)))
        feats = rng.choice(d, size=min(nnz, d), replace=False, p=popularity)
        out[i, feats] = 1
    return out


def imagelike_binary_dataset(rng: np.random.Generator, n: int, d: int,
                             block: int = 16, p_on: float = 0.35) -> np.ndarray:
    """Binarized-image statistics: spatially correlated runs of on-pixels
    (the structured data where the initial permutation sigma matters)."""
    out = np.zeros((n, d), np.int8)
    n_blocks = d // block
    for i in range(n):
        on = rng.random(n_blocks) < p_on
        base = np.repeat(on, block)
        noise = rng.random(d) < 0.03
        out[i, : n_blocks * block] = (base ^ noise[: n_blocks * block])
    return out
