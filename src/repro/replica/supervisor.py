"""Supervisor — heartbeats, respawn, journal replay, digest-gated rejoin.

The supervisor is the plane's self-healing loop.  Each tick it walks every
``ReplicaSet`` lane and checks three liveness signals: the lane's marked
state (a write leg or read failover already downed it), the worker process
itself (``WorkerHandle.alive``), and a STATS heartbeat over a private
control connection (a process can be alive but wedged).  A lane that fails
any check is recovered:

  1. **terminate** whatever is left of the old worker;
  2. **respawn** a fresh worker for the same (shard, replica) slot — booted
     from the plane snapshot when one exists (then only the journal tail
     past ``replica_state.npz``'s recorded seq needs replay), else empty;
  3. **replay** the ingest journal against it: each record's batch is
     sliced through the coordinator's own partitioner
     (``store._shard_of(gid0 + arange(B))``), so the worker re-applies
     exactly the slices its shard saw, in the same seq order — which makes
     the rebuilt signature buffer bit-identical, not just same-sized.
     Replay loops outside the plane lock until it catches up (ingest may
     be racing it), then takes the lock for the final tail;
  4. **verify** the rebuilt worker's signature-buffer digest
     (``MsgType.DIGEST``: CRC-32 of the packed buffer + size) against a
     live peer replica — a corrupt snapshot, a lost journal record, or a
     divergent peer all fail closed here, and the lane stays down rather
     than serve wrong answers;
  5. **rejoin** atomically (``ReplicaSet.rejoin`` under the plane lock):
     the next round sees the lane up, re-wired as a hedge target.

A failed recovery counts ``replica.recover_failures``, tears down the
half-built worker, and leaves the lane down — the next tick retries.
Successful failovers count ``replica.failovers`` and observe the
``replica.resync`` histogram (kill-to-rejoin wall time, the availability
number the bench reports).

Crash-loop protection keeps a sick lane from eating the plane: a lane
that dies again within ``stable_window_s`` of its last rejoin extends a
per-lane streak, and each streak step delays the next respawn by
exponential backoff with jitter (so a deterministic crasher doesn't
respawn in lockstep with its trigger).  A lane whose streak reaches
``max_respawns`` is capped: it stays down, counts once into
``replica.crash_loops``, and the supervisor stops burning snapshots,
journal replays, and digest checks on it.  A lane that survives the
stable window resets its streak to zero.
"""

from __future__ import annotations

import os
import random
import threading
import time
import traceback

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.transport.client import ShardConnection, TransportError
from repro.transport.server import spawn_workers
from repro.transport.wire import Message, MsgType

from .journal import JournalRecord
from .replicaset import (ReplicaLane, ReplicaSet, ReplicatedSketchStore,
                         snapshot_journal_seq)

#: replay passes outside the lock before forcing the final locked pass
_MAX_REPLAY_PASSES = 20


class Supervisor:
    """Background self-healing for a ``ReplicatedSketchStore`` plane."""

    def __init__(self, store: ReplicatedSketchStore, *,
                 interval_s: float = 0.5, heartbeat_timeout_s: float = 5.0,
                 snapshot_dir: str | None = None,
                 probe_impl: str = "auto", query_impl: str = "auto",
                 start_timeout: float = 120.0,
                 backoff_base_s: float = 0.25, backoff_max_s: float = 30.0,
                 max_respawns: int = 5, stable_window_s: float = 30.0):
        self.store = store
        self.interval_s = float(interval_s)
        self.heartbeat_timeout_s = float(heartbeat_timeout_s)
        self.snapshot_dir = snapshot_dir
        self.probe_impl = probe_impl
        self.query_impl = query_impl
        self.start_timeout = float(start_timeout)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_max_s = float(backoff_max_s)
        self.max_respawns = int(max_respawns)
        self.stable_window_s = float(stable_window_s)
        reg = obs_metrics.default()
        self._m_failovers = reg.counter("replica.failovers")
        self._m_recover_fail = reg.counter("replica.recover_failures")
        self._m_heartbeats = reg.counter("replica.heartbeats")
        self._m_crash_loops = reg.counter("replica.crash_loops")
        self._h_resync = reg.histogram("replica.resync")
        # per-lane crash-loop state: streak of quick deaths, earliest next
        # respawn, last rejoin instant (-1 = none pending), capped flag
        self._backoff: dict[tuple[int, int], dict] = {}
        # private control conns, one per (shard, replica) slot — heartbeats
        # never ride the query lanes, so a stalled fan-out cannot fake a
        # dead worker and a heartbeat cannot queue behind a big ADD
        self._ctrl: dict[tuple[int, int], ShardConnection] = {}
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="replica-supervisor")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        t, self._thread = self._thread, None
        if t is not None:
            t.join(self.heartbeat_timeout_s + 30.0)
        for c in self._ctrl.values():
            c.close()
        self._ctrl.clear()

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self.check_once()
            except Exception:
                # the healer must not die of one bad tick
                traceback.print_exc()

    # -- one tick ------------------------------------------------------------
    def check_once(self) -> int:
        """Walk every lane; recover the dead ones.  Returns lanes healed."""
        healed = 0
        for rset in self.store.shards:
            if not isinstance(rset, ReplicaSet):
                continue
            for lane in list(rset.lanes):
                if self._stop.is_set():
                    return healed
                if lane.up and lane.handle is not None \
                        and not lane.handle.alive:
                    rset._mark_down(lane, "worker process died")
                if lane.up and not self._heartbeat(lane):
                    rset._mark_down(lane, "heartbeat failed")
                if not lane.up:
                    healed += bool(self._recover(rset, lane))
        return healed

    def _heartbeat(self, lane: ReplicaLane) -> bool:
        key = (lane.shard, lane.replica)
        conn = self._ctrl.get(key)
        target = lane.handle.address if lane.handle is not None \
            else lane.conn.address
        if conn is None or conn.broken or conn.address != tuple(target):
            if conn is not None:
                conn.close()
            try:
                conn = ShardConnection(target,
                                       timeout=self.heartbeat_timeout_s,
                                       deadline_name="heartbeat_timeout_s",
                                       shard=lane.shard,
                                       replica=lane.replica)
            except TransportError:
                self._ctrl.pop(key, None)
                return False
            self._ctrl[key] = conn
        try:
            conn.request(Message(MsgType.STATS, {}))
        except TransportError:
            return False
        self._m_heartbeats.inc()
        return True

    # -- crash-loop gate -----------------------------------------------------
    def _crash_gate(self, lane: ReplicaLane) -> bool:
        """May this down lane be respawned *now*?  Advances the per-lane
        crash-loop streak the first time a post-rejoin death is seen; a
        capped lane never passes again."""
        key = (lane.shard, lane.replica)
        st = self._backoff.setdefault(
            key, {"streak": 0, "not_before": 0.0, "rejoined": -1.0,
                  "capped": False})
        if st["capped"]:
            return False
        now = time.monotonic()
        if st["rejoined"] >= 0.0:
            # first tick that sees this lane down again after a rejoin:
            # a quick death extends the streak, a long-stable lane resets it
            quick = (now - st["rejoined"]) < self.stable_window_s
            st["streak"] = st["streak"] + 1 if quick else 0
            st["rejoined"] = -1.0
            if st["streak"] >= self.max_respawns:
                st["capped"] = True
                self._m_crash_loops.inc()
                return False
            if st["streak"] > 0:
                delay = min(self.backoff_max_s,
                            self.backoff_base_s * 2.0 ** (st["streak"] - 1))
                st["not_before"] = now + delay * (0.5 + random.random())
        return now >= st["not_before"]

    # -- recovery ------------------------------------------------------------
    def _recover(self, rset: ReplicaSet, lane: ReplicaLane) -> bool:
        if not self._crash_gate(lane):
            return False               # backing off / capped — not a failure
        t0 = time.perf_counter()
        handle = None
        conn = None
        try:
            if lane.handle is not None:
                lane.handle.terminate()
            self._ctrl.pop((lane.shard, lane.replica), None)
            snap, after = None, -1
            if self.snapshot_dir is not None:
                seq = snapshot_journal_seq(self.snapshot_dir)
                if seq >= 0 or os.path.exists(os.path.join(
                        self.snapshot_dir, f"shard_{rset.shard}.npz")):
                    snap, after = self.snapshot_dir, seq
            handle = spawn_workers(self.store.cfg, 1, snapshot_dir=snap,
                                   probe_impl=self.probe_impl,
                                   query_impl=self.query_impl,
                                   start_timeout=self.start_timeout,
                                   shards=[rset.shard],
                                   replicas=[lane.replica])[0]
            conn = ShardConnection(handle.address,
                                   timeout=lane.conn.timeout,
                                   deadline_name="query_timeout_s",
                                   shard=rset.shard, replica=lane.replica)
            # catch-up replay outside the lock: ingest may be racing us, so
            # loop until a pass finds nothing new (bounded), then take the
            # lock for the final tail + verification + rejoin
            last = after
            for _ in range(_MAX_REPLAY_PASSES):
                recs = self._tail(last)
                if not recs:
                    break
                last = self._replay(conn, rset.shard, recs)
            with self.store.lock:
                recs = self._tail(last)
                if recs:
                    last = self._replay(conn, rset.shard, recs)
                self._verify(rset, lane, conn)
                rset.rejoin(lane, conn, handle)
            st = self._backoff.get((lane.shard, lane.replica))
            if st is not None:
                st["rejoined"] = time.monotonic()
            self._m_failovers.inc()
            self._h_resync.observe(time.perf_counter() - t0)
            return True
        except BaseException:
            self._m_recover_fail.inc()
            if conn is not None:
                conn.close()
            if handle is not None:
                handle.terminate()
            traceback.print_exc()
            return False               # lane stays down; next tick retries

    def _tail(self, after: int) -> list[JournalRecord]:
        j = self.store.journal
        return j.records(after=after) if j is not None else []

    def _replay(self, conn: ShardConnection, shard: int,
                recs: list[JournalRecord]) -> int:
        """Apply this shard's slice of each record, in seq order; returns
        the last seq applied.  Slicing uses the coordinator's own
        partitioner, so the worker re-sees exactly the rows (and row
        order) its shard's live replicas indexed."""
        last = -1
        for rec in recs:
            gids = np.arange(rec.gid0, rec.gid0 + len(rec.batch),
                             dtype=np.int64)
            sel = self.store._shard_of(gids) == shard
            if sel.any():
                key = "words" if rec.packed else "rows"
                conn.request(Message(MsgType.ADD,
                                     {key: np.ascontiguousarray(
                                         rec.batch[sel])}))
            last = rec.seq
        return last

    def _verify(self, rset: ReplicaSet, lane: ReplicaLane,
                conn: ShardConnection) -> None:
        """Fail closed unless the rebuilt worker provably matches: its row
        count must equal the coordinator's gid map for the shard, and its
        buffer digest must equal a live peer replica's."""
        d = dict(conn.request(Message(MsgType.DIGEST, {})).fields)
        want = self.store._gid_len[rset.shard]
        if int(d["size"]) != want:
            raise RuntimeError(
                f"resynced worker {conn._name} holds {int(d['size'])} "
                f"items but the coordinator's gid map has {want}")
        for peer in rset.up_lanes():
            if peer is lane:
                continue
            try:
                with self.store.lock:
                    rset.group.ensure_clean(peer.conn)
                    pd = dict(peer.conn.request(
                        Message(MsgType.DIGEST, {})).fields)
            except TransportError:
                continue               # dying peer cannot veto the rejoin
            if (int(pd["size"]), int(pd["crc"])) \
                    != (int(d["size"]), int(d["crc"])):
                raise RuntimeError(
                    f"resynced worker {conn._name} digest "
                    f"(size={int(d['size'])}, crc={int(d['crc']):#x}) "
                    f"diverges from live peer {peer.conn._name} "
                    f"(size={int(pd['size'])}, crc={int(pd['crc']):#x})")
            return                     # one live peer's word is enough
