"""Replicated, self-healing serving plane (R replicas per shard).

``ReplicaSet`` slots in behind the ``ShardBackend`` protocol, so the
coordinator, partitioning, merge, and service layers are unchanged; the
``IngestJournal`` write-ahead log plus the ``Supervisor``'s
respawn-replay-verify-rejoin loop make a killed replica a transient
redundancy loss instead of an outage.  See each module's docstring for
the design; ``store/README.md`` has the operator's runbook.
"""

from .journal import IngestJournal, JournalRecord, scan_journal
from .replicaset import (REPLICA_STATE_FILE, ReplicaLane, ReplicaSet,
                         ReplicatedSketchStore, connect_replicated,
                         snapshot_journal_seq, spawn_replicated)
from .supervisor import Supervisor

__all__ = [
    "IngestJournal", "JournalRecord", "scan_journal",
    "REPLICA_STATE_FILE", "ReplicaLane", "ReplicaSet",
    "ReplicatedSketchStore", "connect_replicated", "snapshot_journal_seq",
    "spawn_replicated", "Supervisor",
]
