"""Append-only, checksummed, seq-numbered ingest journal.

Every ADD batch the coordinator accepts is journalled BEFORE it scatters to
the shard plane, so a replica that died mid-traffic can be rebuilt without
re-signing the corpus: boot it from the last directory snapshot (or empty),
then replay the journal tail — slicing each recorded batch through the
plane's partitioner reproduces the exact per-shard insertion sequence the
live replicas saw, hence a bit-identical signature buffer (verified by
``SketchStore.digest`` before the replica rejoins).

Records reuse the transport's wire framing (``transport.wire``): one frame
per record, ``MsgType.ADD``, CRC-32 checksummed, carrying

    seq     record sequence number (monotone from 0; authoritative — the
            16-byte header's uint32 seq is just its low bits)
    gid0    the coordinator's ``n_items`` when the batch was accepted (the
            global id of the batch's first row) — what makes replay
            deterministic: ``owner = partitioner(gid0 + arange(B))``
    rows    (B, K) int32 raw signatures, OR
    words   (B, W) uint32 packed words (the fused-ingest path)

Durability model: ``append`` writes one complete frame and flushes it
(``fsync=True`` adds an fsync per record for crash-consistency against
power loss, at a large throughput cost).  A crash mid-append leaves a torn
tail; opening the journal recovers every complete prior record, truncates
the torn bytes, and reports the torn offset (``torn_offset``, plus the
``journal.torn_recoveries`` counter).  A batch whose scatter provably
landed nowhere is rolled back (``rollback``) so the journal never replays a
batch the coordinator's gid maps never saw.

Lifecycle: append → snapshot → truncate.  After a plane snapshot covers
records through seq S (``ReplicatedSketchStore.save`` records S next to the
manifest), ``truncate_through(S)`` drops the covered prefix — the journal
holds only the tail a snapshot-booted replica still needs.
"""

from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.transport import wire
from repro.transport.wire import Message, MsgType


@dataclasses.dataclass
class JournalRecord:
    """One complete journalled ADD batch."""

    seq: int                  # record sequence number (monotone from 0)
    gid0: int                 # coordinator n_items when the batch landed
    packed: bool              # words (packed) vs rows (raw signatures)
    batch: np.ndarray         # (B, W) uint32 or (B, K) int32
    offset: int               # byte offset of the record's frame
    end: int                  # byte offset one past the frame


def scan_journal(path: str) -> tuple[list[JournalRecord], int, int | None]:
    """Read every complete record out of a journal file.

    Returns ``(records, end_offset, torn_offset)``: ``end_offset`` is one
    past the last complete record; ``torn_offset`` is where a torn/corrupt
    tail begins (None for a clean file).  A record cut mid-frame — or
    corrupted so its header/CRC no longer validates — ends the scan there:
    framing is lost beyond that point, so everything before it is recovered
    and everything from it on is reported torn.
    """
    with open(path, "rb") as f:
        data = f.read()
    mv = memoryview(data)
    records: list[JournalRecord] = []
    off, n = 0, len(data)
    while off < n:
        if off + wire.HEADER_SIZE > n:
            return records, off, off            # torn mid-header
        try:
            mtype, _, length, _ = wire.decode_header(
                data[off: off + wire.HEADER_SIZE])
        except wire.WireError:
            return records, off, off            # corrupt header
        end = off + wire.HEADER_SIZE + length
        if end > n:
            return records, off, off            # torn mid-payload
        try:
            msg = wire.decode_frame(mv[off:end])
        except wire.WireError:
            return records, off, off            # payload CRC / decode fail
        f_ = msg.fields
        if msg.type != MsgType.ADD or "seq" not in f_ or "gid0" not in f_ \
                or not ("rows" in f_ or "words" in f_):
            return records, off, off            # not a journal record
        packed = "words" in f_
        # copy out of the file buffer so records outlive the scan
        batch = np.array(f_["words"] if packed else f_["rows"])
        records.append(JournalRecord(int(f_["seq"]), int(f_["gid0"]),
                                     packed, batch, off, end))
        off = end
    return records, off, None


def _record_frame(seq: int, gid0: int, batch: np.ndarray,
                  *, packed: bool) -> bytes:
    key = "words" if packed else "rows"
    arr = np.ascontiguousarray(batch, np.uint32 if packed else np.int32)
    return wire.message_bytes(Message(MsgType.ADD,
                                      {"seq": int(seq), "gid0": int(gid0),
                                       key: arr},
                                      seq=seq & 0xFFFFFFFF))


class IngestJournal:
    """The coordinator's write-ahead record of every accepted ADD batch.

    One writer (the coordinator's scatter, serialized under the plane
    lock); readers (``records`` — the supervisor's replay) re-open the file
    per pass and see only complete flushed frames.
    """

    def __init__(self, path: str, *, fsync: bool = False):
        self.path = str(path)
        self.fsync = fsync
        reg = obs_metrics.default()
        self._m_appends = reg.counter("journal.appends")
        self._m_rollbacks = reg.counter("journal.rollbacks")
        self._m_torn = reg.counter("journal.torn_recoveries")
        self._m_bytes = reg.counter("journal.bytes")
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        self.torn_offset: int | None = None
        if os.path.exists(self.path):
            records, end, torn = scan_journal(self.path)
            if torn is not None:
                # crash mid-append: keep every complete record, drop the
                # torn bytes so the next append starts frame-aligned
                self.torn_offset = torn
                self._m_torn.inc()
                with open(self.path, "r+b") as f:
                    f.truncate(torn)
                end = torn
            self.next_seq = records[-1].seq + 1 if records else 0
            self._end = end
        else:
            self.next_seq = 0
            self._end = 0
        self._f = open(self.path, "ab")
        self._last_off: int | None = None      # offset of the last append

    @property
    def last_seq(self) -> int:
        """Seq of the most recent record (-1 for an empty journal)."""
        return self.next_seq - 1

    def append(self, batch: np.ndarray, *, packed: bool, gid0: int) -> int:
        """Journal one ADD batch; returns the record's byte offset (the
        rollback token).  The frame is flushed before this returns, so a
        reader never sees a partial record from a live writer."""
        frame = _record_frame(self.next_seq, gid0, batch, packed=packed)
        off = self._end
        self._f.write(frame)
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())
        self._end += len(frame)
        self._last_off = off
        self.next_seq += 1
        self._m_appends.inc()
        self._m_bytes.inc(len(frame))
        return off

    def rollback(self, offset: int) -> None:
        """Undo the LAST append (truncate back to its offset) — for a
        batch whose scatter provably landed on no shard: the plane stays
        usable and the batch was never applied, so replaying it would
        diverge a resynced replica from its peers."""
        if offset != self._last_off:
            raise ValueError(
                f"rollback offset {offset} is not the last append "
                f"({self._last_off}); only the most recent record can be "
                "rolled back")
        self._f.flush()
        self._f.truncate(offset)
        self._end = offset
        self._last_off = None
        self.next_seq -= 1
        self._m_rollbacks.inc()

    def records(self, *, after: int = -1) -> list[JournalRecord]:
        """Every complete record with ``seq > after`` (fresh file read —
        safe against the live writer, which flushes whole frames)."""
        if not os.path.exists(self.path):
            return []
        records, _, _ = scan_journal(self.path)
        return [r for r in records if r.seq > after]

    def truncate_through(self, seq: int) -> int:
        """Drop records with ``seq <= seq`` (they are covered by a plane
        snapshot): survivors are rewritten to a temp file and atomically
        swapped in.  Returns the number of records dropped."""
        if not os.path.exists(self.path):
            return 0
        with open(self.path, "rb") as f:
            data = f.read()
        records, _, _ = scan_journal(self.path)
        keep = [r for r in records if r.seq > seq]
        dropped = len(records) - len(keep)
        tmp = self.path + ".tmp"
        with open(tmp, "wb") as f:
            for r in keep:
                f.write(data[r.offset: r.end])
            f.flush()
            os.fsync(f.fileno())
        self._f.close()
        os.replace(tmp, self.path)
        self._f = open(self.path, "ab")
        self._end = os.path.getsize(self.path)
        self._last_off = None
        return dropped

    def close(self) -> None:
        try:
            self._f.close()
        except OSError:
            pass

    def __enter__(self) -> "IngestJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
