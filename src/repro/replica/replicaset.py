"""ReplicaSet — R-way replicated shards behind the ``ShardBackend`` seam.

One ``ReplicaSet`` stands where one shard backend used to: the coordinator
(``ShardedSketchStore``) still sees S shards, but each shard is now R
worker processes holding bit-identical copies of the same rows.  The seam
is what keeps every layer above unchanged — partitioning, gid maps, the
merge, the service — while the plane underneath gains redundancy:

  * **Reads** (QUERY/BRUTE) are idempotent, so they are submitted on the
    shard's PRIMARY lane and protected twice over by the transport's
    existing hedge machinery: the replica set wires the primary's hedge
    twin to ANOTHER replica's connection (``FanoutGroup.set_twin``), so a
    slow primary is raced against a different machine and a primary that
    dies mid-round fails over in-round (the failure-triggered hedge).
    Replies are bit-identical whichever lane answers, because writes reach
    every up lane before any later read.  If the whole round still dies,
    ``result()`` falls back to a blocking per-lane retry, marking lanes
    down only when their OWN request fails.

  * **Writes** (ADD) fan out to every up lane as TOLERANT legs
    (``FanoutGroup.submit(tolerate=True)``): a dead replica's leg fails
    alone — parked, surfaced, the lane marked down for the supervisor to
    rebuild — while the sibling legs complete.  One dead replica costs
    redundancy, not the plane.  Only when EVERY lane of a shard fails does
    the write surface as the poisoning failure the unreplicated plane
    would have seen (dirty / unknown-outcome flags OR-reduced across
    lanes, so the coordinator's all-or-nothing scatter decision still
    sees the worst case).

``ReplicatedSketchStore`` is the coordinator over replica sets: same
scatter/merge as ``ShardedSketchStore`` plus (a) a write-ahead
``IngestJournal`` append before every scatter (rolled back when a scatter
provably landed nowhere), and (b) a plane ``lock`` serializing rounds
against the supervisor's atomic rejoin.
"""

from __future__ import annotations

import dataclasses
import os
import threading

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.store.sharded import ShardedSketchStore
from repro.transport import wire
from repro.transport.client import (DeadlineExceeded, FanoutGroup,
                                    HedgePolicy, RetryBudget,
                                    ShardConnection, TransportError,
                                    WorkerError, _partial_from,
                                    attach_deadline)
from repro.transport.server import WorkerHandle, spawn_workers
from repro.transport.wire import Message, MsgType

from .journal import IngestJournal

#: next to the plane manifest: which journal seq the snapshot covers
REPLICA_STATE_FILE = "replica_state.npz"


@dataclasses.dataclass(eq=False)      # identity semantics: lanes key dicts
class ReplicaLane:
    """One replica of one shard: its worker and the coordinator's lane."""

    shard: int
    replica: int
    conn: ShardConnection
    handle: WorkerHandle | None = None     # None for externally-run workers
    up: bool = True
    why_down: str | None = None


def _traced(fields: dict) -> dict:
    """Attach the ambient trace context and deadline as wire fields (same
    contract as ``RemoteShard._traced`` — worker spans join the
    coordinator's trace; expired reads drop server-side)."""
    ctx = obs_trace.current()
    if ctx is not None:
        fields[wire.TRACE_ID_FIELD] = ctx.trace_id
        fields[wire.TRACE_PARENT_FIELD] = ctx.span_id
    return attach_deadline(fields)


class _ReplicaRead:
    """Pending read with failover: the fan-out leg when it lands, else a
    blocking per-lane retry (idempotent reads may re-ask any replica)."""

    lazy = False

    def __init__(self, rset: "ReplicaSet", pend, msg: Message, decode):
        self._rset = rset
        self._pend = pend
        self._msg = msg
        self._decode = decode

    def result(self):
        try:
            return self._pend.result()
        except DeadlineExceeded:
            raise          # the caller is gone: no lane can answer in time
        except TransportError as first:
            return self._failover(first)

    @property
    def latency_s(self) -> float | None:
        return getattr(self._pend, "latency_s", None)

    def _failover(self, first: TransportError):
        rs = self._rset
        rs._m_read_failover.inc()
        last: TransportError = first
        candidates = rs.breaker_ordered(rs.up_lanes())
        for i, lane in enumerate(candidates):
            # an open breaker means this lane has been flapping: skip it
            # (no probe due yet) unless it is the LAST candidate — an
            # all-open shard still gets one attempt rather than none
            if i < len(candidates) - 1 \
                    and not lane.conn.breaker.allow():
                continue
            # every failover re-ask is retry traffic from the shared
            # budget; an exhausted budget surfaces the original failure
            # instead of feeding a retry storm
            if not rs.group.budget.try_spend():
                raise WorkerError(
                    f"shard {rs.shard}: read failover stopped — retry "
                    f"budget exhausted (original failure: "
                    f"{type(first).__name__}: {first})") from first
            try:
                rs.group.ensure_clean(lane.conn)
                reply = lane.conn.request(Message(self._msg.type,
                                                  dict(self._msg.fields)))
            except TransportError as e:
                if lane.conn.broken is None:
                    # an ERROR/OVERLOADED reply over an intact stream: the
                    # worker is alive and deterministically rejected the
                    # request — the caller's own retry policy (budget +
                    # deadline) decides what happens next; burning lanes
                    # on it would take a healthy shard down
                    raise
                last = e
                rs._mark_down(lane, f"read failover failed: {e}")
                continue
            return self._decode(reply)
        err = WorkerError(
            f"shard {rs.shard}: every replica lane failed the read "
            f"(last: {type(last).__name__}: {last})")
        raise err from last


class _ReplicaAdd:
    """Pending write over all up lanes: gathers every leg, downs the
    failed ones, and succeeds if at least one replica indexed the batch."""

    lazy = False

    def __init__(self, rset: "ReplicaSet", pend: dict, submit_errs: dict):
        self._rset = rset
        self._pend = pend              # lane -> _Pending
        self._errs = dict(submit_errs)  # lane -> submit-phase failure

    def result(self) -> int:
        rs = self._rset
        results: dict[ReplicaLane, int] = {}
        errors = dict(self._errs)
        for lane, p in self._pend.items():
            try:
                results[lane] = int(p.result())
            except BaseException as e:
                errors[lane] = e
        if not results:
            # every replica failed this shard's slice: surface the worst
            # case so the coordinator's scatter makes the same poisoning
            # decision it would for an unreplicated shard
            first = next(iter(errors.values()))
            legs = ", ".join(f"replica {l.replica}: {type(e).__name__}"
                             for l, e in errors.items())
            err = WorkerError(
                f"shard {rs.shard}: every replica lane failed the write "
                f"({legs}): {first}")
            err.dirty = any(getattr(e, "dirty", False)
                            for e in errors.values())
            err.unknown_outcome = any(getattr(e, "unknown_outcome", False)
                                      for e in errors.values())
            raise err from first
        # >=1 replica landed the batch: the failed lanes are divergent —
        # down them (the supervisor rebuilds from the journal) and keep
        # serving on reduced redundancy
        for lane, e in errors.items():
            rs._m_write_leg.inc()
            rs._mark_down(lane, f"write leg failed: {type(e).__name__}: {e}")
        counts = set(results.values())
        if len(counts) != 1:
            # replicas that all said OK disagree on rows indexed — the
            # copies have diverged and no lane is provably right
            per = {l.replica: n for l, n in results.items()}
            err = WorkerError(
                f"shard {rs.shard}: replicas disagree on rows indexed "
                f"({per})")
            err.dirty = True
            raise err
        return counts.pop()


class ReplicaSet:
    """``ShardBackend`` over R replica lanes of one shard (see module doc).

    All membership changes (lane down, rejoin, rewire) run under the
    shared plane ``lock`` — the same lock the coordinator holds across a
    fan-out round — so the supervisor thread never mutates the group's
    lane tables while a round is in flight.
    """

    def __init__(self, shard: int, lanes: list[ReplicaLane],
                 group: FanoutGroup, lock: threading.RLock):
        if not lanes:
            raise ValueError("a ReplicaSet needs at least one lane")
        self.shard = shard
        self.lanes = list(lanes)
        self.group = group
        self.lock = lock
        reg = obs_metrics.default()
        self._m_up = reg.gauge(f"replica.shard{shard}.up")
        self._m_lane_down = reg.counter("replica.lanes_down")
        self._m_read_failover = reg.counter("replica.read_failovers")
        self._m_write_leg = reg.counter("replica.write_leg_failures")
        with self.lock:
            self._rewire()

    # -- membership ----------------------------------------------------------
    def up_lanes(self) -> list[ReplicaLane]:
        with self.lock:
            return [l for l in self.lanes if l.up]

    @staticmethod
    def breaker_ordered(lanes: list[ReplicaLane]) -> list[ReplicaLane]:
        """Stable order with breaker-healthy lanes first: a flapping lane
        (breaker open / half-open) is deprioritized, not banished — it is
        still attempted when it is the only option or its probe is due."""
        return sorted(lanes, key=lambda l: not l.conn.breaker.healthy)

    def primary(self) -> ReplicaLane:
        with self.lock:
            for l in self.lanes:
                if l.up:
                    return l
        raise WorkerError(f"shard {self.shard}: no replica lane is up")

    def _rewire(self) -> None:
        """Recompute primary + hedge twin from the up set (lock held).
        The primary's twin is the NEXT up replica, so a hedge — timer- or
        failure-triggered — is a read failover to a different machine."""
        ups = [l for l in self.lanes if l.up]
        self._m_up.set(len(ups))
        for l in self.lanes:
            self.group.set_twin(l.conn, None)
        if len(ups) > 1:
            self.group.set_twin(ups[0].conn, ups[1].conn)

    def _mark_down(self, lane: ReplicaLane, why: str) -> None:
        with self.lock:
            if not lane.up:
                return
            lane.up = False
            lane.why_down = str(why)
            self._m_lane_down.inc()
            self.group.retire_conn(lane.conn)
            self._rewire()

    def rejoin(self, lane: ReplicaLane, conn: ShardConnection,
               handle: WorkerHandle | None) -> None:
        """Swap a rebuilt worker into the lane and bring it back up —
        called by the supervisor AFTER the digest parity check, under the
        plane lock so no round straddles the membership change."""
        with self.lock:
            old = lane.conn
            if old is not conn:
                self.group.retire_conn(old)
                old.close()
            lane.conn = conn
            lane.handle = handle
            lane.up = True
            lane.why_down = None
            self.group.adopt_conn(conn)
            self._rewire()

    # -- reads ---------------------------------------------------------------
    def _start_read(self, msg: Message, decode) -> _ReplicaRead:
        last: TransportError | None = None
        candidates = self.breaker_ordered(self.up_lanes())
        for i, lane in enumerate(candidates):
            if i < len(candidates) - 1 \
                    and not lane.conn.breaker.allow():
                continue       # breaker open and a sibling is available
            try:
                pend = self.group.submit(lane.conn, msg, decode=decode,
                                         reset_on_error=False,
                                         hedgeable=True,
                                         keep_round_on_error=True)
            except TransportError as e:
                # this lane cannot even carry the request: down it and
                # submit on the next replica — siblings already queued
                # this round stay live (keep_round_on_error)
                last = e
                self._mark_down(lane, f"submit failed: {e}")
                continue
            return _ReplicaRead(self, pend, msg, decode)
        raise last if last is not None else WorkerError(
            f"shard {self.shard}: no replica lane is up")

    def start_query(self, hashes: np.ndarray, qwords: np.ndarray,
                    top_k: int, mode: str) -> _ReplicaRead:
        lo, hi = wire.split_u64(hashes)
        msg = Message(MsgType.QUERY, _traced({
            "hash_lo": lo, "hash_hi": hi,
            "qwords": np.ascontiguousarray(qwords, np.uint32),
            "top_k": int(top_k), "mode": mode}))
        return self._start_read(msg, lambda m: _partial_from(m))

    def start_brute(self, qwords: np.ndarray, top_k: int) -> _ReplicaRead:
        msg = Message(MsgType.BRUTE, _traced({
            "qwords": np.ascontiguousarray(qwords, np.uint32),
            "top_k": int(top_k)}))
        return self._start_read(msg, lambda m: _partial_from(m))

    # -- writes --------------------------------------------------------------
    def start_add(self, batch: np.ndarray, *,
                  packed: bool = False) -> _ReplicaAdd:
        lanes = self.up_lanes()
        if not lanes:
            raise WorkerError(f"shard {self.shard}: no replica lane is up")
        arr = np.ascontiguousarray(batch,
                                   np.uint32 if packed else np.int32)
        key = "words" if packed else "rows"
        pend: dict[ReplicaLane, object] = {}
        errs: dict[ReplicaLane, BaseException] = {}
        for lane in lanes:
            # one Message per leg: the group re-assigns seq per connection
            msg = Message(MsgType.ADD, _traced({key: arr}))
            try:
                pend[lane] = self.group.submit(
                    lane.conn, msg, decode=lambda m: int(m["n"]),
                    reset_on_error=False, tolerate=True,
                    keep_round_on_error=True)
            except BaseException as e:
                errs[lane] = e
        if not pend:
            # no leg of this shard made it onto the wire: abandon the whole
            # round (sibling shards' queued-but-unsent frames included) so
            # the coordinator's submit-phase failure stays provably clean
            self.group.reset()
            first = next(iter(errs.values()))
            raise WorkerError(
                f"shard {self.shard}: every replica lane failed at submit: "
                f"{type(first).__name__}: {first}") from first
        for lane, e in errs.items():
            self._m_write_leg.inc()
            self._mark_down(lane,
                            f"write submit failed: {type(e).__name__}: {e}")
        return _ReplicaAdd(self, pend, {})

    def add(self, sigs: np.ndarray) -> int:
        return self.start_add(np.asarray(sigs), packed=False).result()

    def add_packed(self, words: np.ndarray) -> int:
        return self.start_add(np.asarray(words, np.uint32),
                              packed=True).result()

    # -- control -------------------------------------------------------------
    def stats(self) -> dict:
        return dict(self.primary().conn.request(
            Message(MsgType.STATS, {})).fields)

    def stats_all(self) -> list[tuple[int, dict]]:
        """Per-lane stats as ``(replica, stats)`` pairs — the hook
        ``ShardedSketchStore.obs_snapshot`` uses to label every worker's
        registry snapshot with its (shard, replica) coordinates."""
        out = []
        for lane in self.up_lanes():
            try:
                out.append((lane.replica, dict(lane.conn.request(
                    Message(MsgType.STATS, {})).fields)))
            except TransportError:
                continue               # a lane dying mid-stats is not fatal
        return out

    def digest(self) -> dict:
        return dict(self.primary().conn.request(
            Message(MsgType.DIGEST, {})).fields)

    def save(self, path: str) -> None:
        # replicas are bit-identical (that is the digest-checked invariant),
        # so one lane's snapshot IS the shard's snapshot
        self.primary().conn.request(
            Message(MsgType.SNAPSHOT, {"path": str(path)}))

    def shutdown(self) -> None:
        for lane in self.up_lanes():
            try:
                lane.conn.request(Message(MsgType.SHUTDOWN, {}))
            except TransportError:
                pass
        self.close()

    def close(self) -> None:
        for lane in self.lanes:
            lane.conn.close()


class ReplicatedSketchStore(ShardedSketchStore):
    """``ShardedSketchStore`` + write-ahead journal + plane lock.

    The journal append happens BEFORE the scatter (write-ahead), under the
    plane lock, so the journal's seq order IS the plane's batch order and a
    resynced replica replaying ``records(after=...)`` reproduces exactly
    the insertion sequence the live lanes saw.  A scatter that provably
    landed on no shard rolls its record back — the journal never replays a
    batch the coordinator's gid maps never admitted.
    """

    def __init__(self, cfg, n_shards: int = 1, *,
                 journal: IngestJournal | None = None,
                 lock: threading.RLock | None = None, **kw):
        super().__init__(cfg, n_shards, **kw)
        self.journal = journal
        self.lock = lock if lock is not None else threading.RLock()

    def _scatter(self, batch: np.ndarray, *, packed: bool) -> np.ndarray:
        with self.lock:
            if self.journal is None:
                return super()._scatter(batch, packed=packed)
            off = self.journal.append(np.asarray(batch), packed=packed,
                                      gid0=self.n_items)
            try:
                return super()._scatter(batch, packed=packed)
            except BaseException:
                if self._failed is None:
                    # provably-clean failure: no shard indexed the batch,
                    # so the record must not survive to be replayed
                    self.journal.rollback(off)
                raise

    def _merged_query(self, *args, **kw):
        with self.lock:
            return super()._merged_query(*args, **kw)

    def replay_tail(self) -> int:
        """Re-apply journal records beyond the coordinator's current state
        (a plane rebooted from a snapshot older than the journal tail).
        Returns the number of batches re-applied."""
        if self.journal is None:
            return 0
        n = 0
        with self.lock:
            for rec in self.journal.records(after=-1):
                if rec.gid0 < self.n_items:
                    continue           # already covered by the snapshot
                if rec.gid0 != self.n_items:
                    raise RuntimeError(
                        f"journal record seq={rec.seq} starts at gid "
                        f"{rec.gid0} but the plane holds {self.n_items} "
                        "items — journal/snapshot mismatch")
                # bypass the journal append: this batch is already recorded
                ShardedSketchStore._scatter(self, rec.batch,
                                            packed=rec.packed)
                n += 1
        return n

    def save(self, dirpath: str) -> None:
        with self.lock:
            super().save(dirpath)
            if self.journal is not None:
                np.savez(os.path.join(dirpath, REPLICA_STATE_FILE),
                         journal_seq=self.journal.last_seq)

    def compact(self, dirpath: str) -> int:
        """Snapshot the plane, then drop the journal prefix the snapshot
        covers (append -> snapshot -> truncate).  Returns records dropped."""
        with self.lock:
            seq = self.journal.last_seq if self.journal is not None else -1
            self.save(dirpath)
            if self.journal is None:
                return 0
            return self.journal.truncate_through(seq)


def snapshot_journal_seq(dirpath: str) -> int:
    """The journal seq a plane snapshot covers (-1: none recorded)."""
    path = os.path.join(dirpath, REPLICA_STATE_FILE)
    if not os.path.exists(path):
        return -1
    with np.load(path) as z:
        return int(z["journal_seq"])


def spawn_replicated(cfg, n_shards: int, n_replicas: int, *,
                     snapshot_dir: str | None = None,
                     probe_impl: str = "auto", query_impl: str = "auto",
                     host: str = "127.0.0.1", start_timeout: float = 120.0,
                     slow_lanes: dict[tuple[int, int],
                                      tuple[float, float]] | None = None,
                     gate_limit: int | None = None,
                     faults: dict[tuple[int, int], object] | None = None,
                     ) -> list[list[WorkerHandle]]:
    """Spawn an S x R worker grid; returns ``grid[shard][replica]``.

    Every replica of shard s boots from the SAME ``shard_{s}.npz`` when
    ``snapshot_dir`` is given — replicas start bit-identical by
    construction.  ``slow_lanes`` maps ``(shard, replica)`` to the
    ``(prob, sleep_s)`` injected read latency of ``spawn_workers``;
    ``faults`` maps ``(shard, replica)`` to that lane's deterministic
    ``FaultPlan`` (or encoded spec) — explicit per-spawn plans, so a
    supervisor respawn of the slot does NOT re-inherit the schedule.
    """
    shards = [s for s in range(n_shards) for _ in range(n_replicas)]
    replicas = [r for _ in range(n_shards) for r in range(n_replicas)]
    slow = None
    if slow_lanes:
        slow = {i: slow_lanes[(shards[i], replicas[i])]
                for i in range(len(shards))
                if (shards[i], replicas[i]) in slow_lanes}
    plans = None
    if faults:
        plans = {i: faults[(shards[i], replicas[i])]
                 for i in range(len(shards))
                 if (shards[i], replicas[i]) in faults}
    handles = spawn_workers(cfg, n_shards * n_replicas,
                            snapshot_dir=snapshot_dir,
                            probe_impl=probe_impl, query_impl=query_impl,
                            host=host, start_timeout=start_timeout,
                            slow_shards=slow, shards=shards,
                            replicas=replicas, gate_limit=gate_limit,
                            faults=plans)
    return [[handles[s * n_replicas + r] for r in range(n_replicas)]
            for s in range(n_shards)]


def connect_replicated(grid: list[list[WorkerHandle]], cfg=None, *,
                       journal: IngestJournal | None = None,
                       snapshot_dir: str | None = None,
                       partition: str = "round_robin",
                       query_impl: str = "auto", timeout: float = 30.0,
                       hedge: "HedgePolicy | bool | None" = True,
                       budget: RetryBudget | None = None,
                       ) -> ReplicatedSketchStore:
    """Build a ``ReplicatedSketchStore`` over a ``spawn_replicated`` grid.

    One ``FanoutGroup`` spans every lane of every shard; each shard's
    ``ReplicaSet`` wires its primary's hedge twin to the next replica, so
    the default ``hedge=True`` (a stock ``HedgePolicy``) is what arms both
    tail-latency hedging AND in-round read failover.  ``journal`` is the
    plane's write-ahead ingest journal (required for supervisor resync);
    ``snapshot_dir`` restores coordinator state exactly like
    ``connect_sharded``, then replays any journal tail past the snapshot.
    """
    if hedge is True:
        hedge = HedgePolicy()
    elif hedge is False:
        hedge = None
    conns: list[ShardConnection] = []
    lanes_by_shard: list[list[ReplicaLane]] = []
    try:
        for s, row in enumerate(grid):
            lanes = []
            for r, h in enumerate(row):
                conn = ShardConnection(h.address, timeout=timeout,
                                       deadline_name="query_timeout_s",
                                       shard=s, replica=r)
                conns.append(conn)
                lanes.append(ReplicaLane(s, r, conn, h))
            lanes_by_shard.append(lanes)
        group = FanoutGroup(conns, timeout=timeout, hedge=hedge,
                            deadline_name="query_timeout_s", budget=budget)
        lock = threading.RLock()
        rsets = [ReplicaSet(s, lanes, group, lock)
                 for s, lanes in enumerate(lanes_by_shard)]
        if snapshot_dir is not None:
            store = ReplicatedSketchStore.load(snapshot_dir, backends=rsets,
                                               query_impl=query_impl)
            store.journal = journal
            store.lock = lock
            store.replay_tail()
        elif cfg is None:
            raise ValueError("connect_replicated needs cfg or snapshot_dir")
        else:
            store = ReplicatedSketchStore(cfg, len(rsets),
                                          partition=partition,
                                          query_impl=query_impl,
                                          backends=rsets, journal=journal,
                                          lock=lock)
        # every lane of shard s must hold exactly the coordinator's count
        # for s — a stale or wrong-snapshot replica would serve shard-LOCAL
        # ids as global answers with no error
        for s, rset in enumerate(rsets):
            want = store._gid_len[s]
            for lane in rset.lanes:
                size = int(lane.conn.request(
                    Message(MsgType.STATS, {}))["size"])
                if size != want:
                    raise WorkerError(
                        f"worker {lane.conn._name} holds {size} items but "
                        f"the coordinator's gid map has {want} — wrong "
                        "snapshot_dir (or none) for these workers?")
        return store
    except BaseException:
        for c in conns:                # no fd leak on failure
            c.close()
        raise
