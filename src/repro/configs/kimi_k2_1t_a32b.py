"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, 384 experts top-8 — trillion-parameter paper-table entry.
[arXiv:2501.kimi2; unverified]  (Spec'd as GQA; the real K2 uses MLA + a
shared expert — we follow the assignment sheet.)"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="kimi_k2_1t_a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=2048,           # per-expert FFN width
    vocab_size=163840,
    n_experts=384,
    top_k=8,
    rope_theta=5e4,
    fused_qkv=True,   # single bwd dx all-reduce under TP (§Perf)
)
