"""llama3.2-1b [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256, tied embeddings. [hf:meta-llama/Llama-3.2-1B; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3_2_1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=128256,
    tie_embeddings=True,
    rope_theta=5e5,
    fused_qkv=True,   # single bwd dx all-reduce under TP (§Perf)
)
