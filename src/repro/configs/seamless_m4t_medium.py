"""seamless-m4t-medium [audio]: enc-dec 12L+12L d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206 (padded to 256208 for 16-way TP). Audio frontend is a
STUB (input_specs supplies frame embeddings). [arXiv:2308.11596; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless_m4t_medium",
    family="encdec",
    n_layers=12,          # decoder depth
    n_enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256208,    # padded
    vocab_size_real=256206,
    rope_theta=1e4,
    frontend="frames",
    fused_qkv=True,   # single bwd dx all-reduce under TP (§Perf)
)
