"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) expert d_ff=768
vocab=151936, 128 experts top-8, head_dim=128 override.
[hf:Qwen/Qwen3-30B-A3B; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3_moe_30b_a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,            # per-expert FFN width
    vocab_size=151936,
    n_experts=128,
    top_k=8,
    rope_theta=1e6,
    fused_qkv=True,   # single bwd dx all-reduce under TP (§Perf)
)
