"""Model / run configuration dataclasses shared by the whole framework."""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description. One instance per assigned arch (configs/<id>.py)."""

    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (Mamba-1)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    dt_rank: int = 0             # 0 -> ceil(d_model / 16)

    # Attention flavour
    sliding_window: int = 0      # 0 = full attention
    rope_theta: float = 1e6

    # Encoder-decoder
    n_enc_layers: int = 0        # >0 -> encdec; n_layers is the decoder depth

    # Modality frontend stubs (DESIGN.md: input_specs supplies embeddings)
    frontend: str = "none"       # none | patches | frames

    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    vocab_size_real: int = 0     # pre-padding vocab (0 -> vocab_size); data gen
                                 # samples targets below this bound

    # Numerics / memory policy
    dtype: str = "bfloat16"      # compute dtype
    param_dtype: str = "float32"
    remat: str = "block"         # none | block

    # Attention chunking (memory-efficient train/prefill path)
    q_chunk: int = 512

    # Fused QKV projection (one dot, one backward dx all-reduce under TP;
    # only engaged when (H + 2*KV) divides the model axis — see §Perf)
    fused_qkv: bool = False

    # SSM seq chunking + scan numerics (§Perf: the 4D (B,Q,Di,N) scan tensors
    # dominate the SSM memory term; bf16 halves them, h carry stays fp32)
    ssm_chunk: int = 128
    ssm_scan_dtype: str = "float32"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.vocab_size_real == 0:
            object.__setattr__(self, "vocab_size_real", self.vocab_size)
        if self.family in ("ssm", "hybrid") and self.dt_rank == 0:
            object.__setattr__(self, "dt_rank", -(-self.d_model // 16))
        if self.family == "moe" and (self.n_experts <= 0 or self.top_k <= 0):
            raise ValueError("moe family requires n_experts and top_k")
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError("n_heads must be divisible by n_kv_heads")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM state or SWA ring cache.)"""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D in rooflines)."""
        d, hd = self.d_model, self.head_dim
        h, kv = self.n_heads, self.n_kv_heads
        n = self.vocab_size * d                    # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size               # lm head
        attn = d * h * hd + 2 * d * kv * hd + h * hd * d
        mlp = 3 * d * self.d_ff if self.d_ff else 0
        moe = 3 * d * self.d_ff * self.n_experts if self.n_experts else 0
        di, s, r = self.d_inner, self.ssm_state, self.dt_rank
        # in_proj + conv(w+b) + x_proj + dt_proj(w+b) + A_log + D + out_proj
        ssm = (d * 2 * di + self.ssm_conv * di + di + di * (r + 2 * s)
               + r * di + di + di * s + di + di * d) \
            if self.family in ("ssm", "hybrid") else 0
        if self.family == "ssm":
            per_layer = ssm + d                      # ln1 only (no MLP)
        elif self.family == "hybrid":
            per_layer = attn + ssm + mlp + 2 * d
        elif self.family == "moe":
            per_layer = attn + moe + d * self.n_experts + 2 * d
        else:
            per_layer = attn + mlp + 2 * d
        n += self.n_layers * per_layer
        n += d                                        # final_norm
        if self.is_encdec:
            # encoder layers + enc_norm + decoder cross-attention (+ lnx)
            n += self.n_enc_layers * (attn + mlp + 2 * d) + d
            n += self.n_layers * (attn + d)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        full = self.param_count()
        moe_all = 3 * self.d_model * self.d_ff * self.n_experts * self.n_layers
        moe_act = 3 * self.d_model * self.d_ff * self.top_k * self.n_layers
        return full - moe_all + moe_act


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeCell:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Optimizer / loop hyperparameters."""

    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1        # gradient accumulation
    zero1: bool = False          # shard optimizer state over the data axis
    grad_compression: str = "none"   # none | bf16 | int8
    checkpoint_every: int = 100
    keep_checkpoints: int = 3
    seed: int = 0
    sharding_mode: str = "tp"    # tp | fsdp (weights gathered per use; for
                                 # small dense models at big TP — §Perf E)
