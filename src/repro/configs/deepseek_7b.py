"""deepseek-7b [dense]: 30L d_model=4096 32H (GQA kv=32) d_ff=11008
vocab=102400, llama-arch. [arXiv:2401.02954; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek_7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,      # MHA
    head_dim=128,
    d_ff=11008,
    vocab_size=102400,
    rope_theta=1e4,
    fused_qkv=True,   # single bwd dx all-reduce under TP (§Perf)
)
