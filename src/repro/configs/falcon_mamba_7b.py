"""falcon-mamba-7b [ssm]: 64L d_model=4096 attn-free, vocab=65024, ssm_state=16.
[arXiv:2410.05355; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="falcon_mamba_7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,          # attention-free; unused
    n_kv_heads=1,
    head_dim=1,
    d_ff=0,             # pure Mamba blocks, no MLP
    vocab_size=65024,
    ssm_state=16,
    ssm_expand=2,       # d_inner = 8192
    ssm_conv=4,
    ssm_chunk=32,     # tuned: fewer assoc-scan levels (§Perf)
)
