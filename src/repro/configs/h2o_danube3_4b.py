"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000, llama+mistral mix with SWA. [arXiv:2401.16818; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o_danube3_4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,   # SWA -> ring cache; long_500k runnable
    rope_theta=1e4,
    fused_qkv=True,   # single bwd dx all-reduce under TP (§Perf)
)
