"""Assigned-architecture configs. Each <id>.py exports CONFIG (full, exact
assignment) ; ``reduced(cfg)`` shrinks any config for CPU smoke tests while
preserving family structure (GQA grouping, MoE routing, SSM, SWA, enc-dec)."""

from __future__ import annotations

import dataclasses
import importlib

from .base import SHAPES, ModelConfig, ShapeCell, TrainConfig, shape_by_name

ARCH_IDS = (
    "falcon_mamba_7b",
    "mistral_nemo_12b",
    "deepseek_7b",
    "h2o_danube3_4b",
    "llama3_2_1b",
    "pixtral_12b",
    "qwen3_moe_30b_a3b",
    "kimi_k2_1t_a32b",
    "seamless_m4t_medium",
    "hymba_1_5b",
)


def get_config(arch_id: str) -> ModelConfig:
    arch_id = arch_id.replace("-", "_").replace(".", "_")
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 128,
            vocab: int = 512) -> ModelConfig:
    """Family-preserving shrink for smoke tests."""
    kv = 2 if cfg.n_kv_heads < cfg.n_heads else 4
    changes: dict = dict(
        n_layers=layers,
        d_model=d_model,
        n_heads=4,
        n_kv_heads=kv,
        head_dim=32,
        d_ff=256 if cfg.d_ff > 0 else 0,
        vocab_size=vocab,
        vocab_size_real=0,
        dt_rank=0,
        q_chunk=64,
        ssm_chunk=32,
    )
    if cfg.n_experts:
        changes.update(n_experts=8, top_k=2)
    if cfg.ssm_state:
        changes.update(ssm_state=8)
    if cfg.sliding_window:
        changes.update(sliding_window=64)
    if cfg.n_enc_layers:
        changes.update(n_enc_layers=layers)
    return dataclasses.replace(cfg, **changes)
