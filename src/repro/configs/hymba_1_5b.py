"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001 (padded 32016), parallel attention+SSM heads, ssm_state=16.
25 heads don't divide the 16-way model axis -> attention stays replicated
under the divisor rule (DESIGN.md §5). [arXiv:2411.13676; hf]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba_1_5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32016,     # padded
    vocab_size_real=32001,
    ssm_state=16,
    ssm_expand=2,         # d_inner = 3200
    sliding_window=1024,  # Hymba uses SWA in most layers; long_500k runnable
    rope_theta=1e4,
    ssm_chunk=32,     # tuned: fewer assoc-scan levels (§Perf)
)
