"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
Vision frontend is a STUB (input_specs supplies patch embeddings); backbone =
Mistral-Nemo dims. [hf:mistralai/Pixtral-12B-2409; unverified]"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral_12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1e6,
    frontend="patches",
    fused_qkv=True,   # single bwd dx all-reduce under TP (§Perf)
)
