"""Bit-packed C-MinHash kernel (beyond-paper §Perf optimization).

The int8 kernel's HBM traffic is dominated by the circulant mask bands:
~2*B*D*(K/Kt) bytes per signature batch. Packing the binary vector into uint32
words (32 positions/word) cuts that operand 8x; the kernel funnel-shifts the
word pair straddling each window offset and unpacks bits in VREGs (VPU work is
cheap next to the HBM stream — see the §Perf napkin math).

Layout: ``vpacked[b, w]`` holds positions ``32w .. 32w+31`` with position
``32w + j`` at bit ``j``. Blocks stay Kt == Dt with Dt % 32 == 0; the band for
(hash-block j, data-block d) is the word range of flat positions
[(d+j)*Dt, (d+j+2)*Dt) — two adjacent word-blocks, as in the int8 kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .packfmt import pack_block, pack_geometry

Array = jax.Array
SENTINEL = jnp.iinfo(jnp.int32).max


def pack_bits(v: Array) -> Array:
    """(B, D) binary -> (B, ceil(D/32)) uint32, position 32w+j at bit j.

    Folded as 32 strided slices OR'd into the word lanes — no (B, nw, 32)
    int32 intermediate (the shift+sum formulation materialized one, 32x the
    output size, before reducing).
    """
    b, d = v.shape
    nw = -(-d // 32)
    pad = nw * 32 - d
    bits = (v > 0).astype(jnp.uint32)
    if pad:
        bits = jnp.pad(bits, ((0, 0), (0, pad)))
    return functools.reduce(
        jnp.bitwise_or,
        [bits[:, j::32] << jnp.uint32(j) for j in range(32)])


def _kernel(pi_ref, wlo_ref, whi_ref, out_ref, acc_scratch=None, *, bt: int,
            dt: int, off: int, nd: int = 0, k: int = 0,
            pack_b: int | None = None):
    d_idx = pl.program_id(2)
    # see cminhash_kernel._kernel: fused pack accumulates in VMEM scratch
    acc_ref = out_ref if pack_b is None else acc_scratch

    @pl.when(d_idx == 0)
    def _init():
        acc_ref[...] = jnp.full(acc_ref.shape, SENTINEL, acc_ref.dtype)

    words = jnp.concatenate([wlo_ref[...], whi_ref[...]], axis=1)  # (Bt, 2*Dt/32)
    pvals = pi_ref[...]                                            # (Dt,) int32
    n_win = dt // 32
    bit_ids = jnp.arange(32, dtype=jnp.uint32)

    def body(k_local, acc):
        shift = k_local + off
        w0 = shift // 32
        b_off = (shift % 32).astype(jnp.uint32)
        lo = jax.lax.dynamic_slice(words, (0, w0), (bt, n_win))
        hi = jax.lax.dynamic_slice(words, (0, w0 + 1), (bt, n_win))
        # funnel shift: window word w = lo >> b_off | hi << (32 - b_off)
        win = jnp.where(
            b_off == 0, lo,
            (lo >> b_off) | (hi << ((32 - b_off) % 32)))
        bits = (win[:, :, None] >> bit_ids) & 1                    # (Bt, n_win, 32)
        mask = bits.reshape(bt, dt) > 0
        masked = jnp.where(mask, pvals[None, :], SENTINEL)
        return acc.at[:, k_local].min(jnp.min(masked, axis=1))

    acc_ref[...] = jax.lax.fori_loop(0, dt, body, acc_ref[...])

    if pack_b is not None:
        # fused sign->pack epilogue (see cminhash_kernel._kernel)
        col0 = pl.program_id(1) * dt

        @pl.when(d_idx == nd - 1)
        def _pack():
            out_ref[...] = pack_block(acc_ref[...], col0, k=k, b=pack_b)


@functools.partial(
    jax.jit,
    static_argnames=("k", "shift_offset", "block_b", "block_d", "interpret",
                     "pack_b"),
)
def cminhash_packed_pallas(v: Array, pi: Array, k: int, *,
                           shift_offset: int = 1, block_b: int = 8,
                           block_d: int = 256, interpret: bool = True,
                           pack_b: int | None = None) -> Array:
    """Signatures from a dense binary (B, D) via the bit-packed kernel.

    With ``pack_b`` set, returns (B, ceil(K / (32/pack_b))) uint32 packed
    words from the fused truncate+pack epilogue instead of (B, K) int32.
    """
    if shift_offset not in (0, 1):
        raise ValueError("shift_offset must be 0 or 1")
    if block_d % 32:
        raise ValueError("block_d must be a multiple of 32")
    b, d = v.shape
    if k > d:
        raise ValueError(f"K <= D required (K={k}, D={d})")
    bt, dt = block_b, block_d
    kt = dt
    nb, nd, nk = -(-b // bt), -(-d // dt), -(-k // kt)

    pi_pad = jnp.full((nd * dt,), SENTINEL, jnp.int32).at[:d].set(
        pi.astype(jnp.int32))

    mask = (v > 0).astype(jnp.int8)
    n_vblocks = nd + nk
    flat = jnp.zeros((nb * bt, n_vblocks * dt), jnp.int8)
    flat = flat.at[:b, :d].set(mask)
    wrap = min(k + shift_offset, d, n_vblocks * dt - d)
    flat = flat.at[:b, d:d + wrap].set(mask[:, :wrap])
    words = pack_bits(flat)                       # (B', n_vblocks * Dt/32)
    # (the in-kernel hi-slice can only run past the 2-block window when
    # b_off == 0, where its value is unused — dynamic_slice clamps safely)

    wpb = dt // 32  # words per block
    grid = (nb, nk, nd)
    in_specs = [
        pl.BlockSpec((dt,), lambda i, j, dd: (dd,)),
        pl.BlockSpec((bt, wpb), lambda i, j, dd: (i, dd + j)),
        pl.BlockSpec((bt, wpb), lambda i, j, dd: (i, dd + j + 1)),
    ]
    sig_spec = pl.BlockSpec((bt, kt), lambda i, j, dd: (i, j))
    sig_shape = jax.ShapeDtypeStruct((nb * bt, nk * kt), jnp.int32)

    if pack_b is None:
        out = pl.pallas_call(
            functools.partial(_kernel, bt=bt, dt=dt, off=shift_offset),
            grid=grid, in_specs=in_specs, out_specs=sig_spec,
            out_shape=sig_shape, interpret=interpret,
        )(pi_pad, words, words)
        return out[:b, :k]

    cpw, n_words = pack_geometry(k, pack_b)  # kt % cpw == 0: kt % 32 == 0
    owords = pl.pallas_call(
        functools.partial(_kernel, bt=bt, dt=dt, off=shift_offset, nd=nd,
                          k=k, pack_b=pack_b),
        grid=grid, in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, kt // cpw), lambda i, j, dd: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nb * bt, nk * kt // cpw), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((bt, kt), jnp.int32)],
        interpret=interpret,
    )(pi_pad, words, words)
    return owords[:b, :n_words]
