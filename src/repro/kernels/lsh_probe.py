"""Device-resident LSH bucket probe (the last host-bound leg of a query).

``BandedLSHTable.lookup`` resolves (Q, n_bands) uint64 band hashes to
candidate rows by quadratic-probing the fused records array

    records (n_bands, n_slots, 2 + W) int32
    records[b, s, :2] = band-hash halves (-1, -1 = unused)
    records[b, s, 2:] = posting item ids (-1 padded)

The numpy loop is the CPU-tuned reference (early-terminating chains, ~1
gather per entry at sane load).  These twins run the same probe on device
over the *same* records layout: the table uploads its records once
(``BandedLSHTable.device_records``, cached by mutation version) and each
query batch is a fixed-depth branchless probe — correct without early
termination because the open-addressing invariant guarantees at most one
matching slot per (band, key) and no record ever sits past an unused slot
on its own chain (slots are never freed), so probing the full chain and
keeping the single hit reproduces the early-terminating walk exactly.

The uint64 leg (band-hash fold + ``key % n_slots``) stays on host — numpy
uint64 is exact and JAX's default int32 domain is not; ``probe_operands``
reduces each entry to five int32s (band offset, base slot, key halves,
validity) and everything after that is device work:

* ``lsh_probe_jnp``    — compiled-jnp twin: one (E, 2+W) gather per probe
  depth, hit-select folded across depths.  The dispatchable device path on
  CPU-hosted backends and the oracle-equivalent of the kernel.
* ``lsh_probe_pallas`` — Pallas kernel: grid over query-entry tiles,
  records block resident in VMEM, fori_loop of per-entry dynamic slices
  with a statically unrolled probe chain.  ``interpret=True`` runs on CPU.

Sentinel-valued hashes (the empty-slot sentinel, routed to the spill list
at insert) are masked via the validity flag — their halves (-1, -1) would
otherwise match every unused slot.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

Array = jax.Array

# The one definition of the probe geometry: store/table.py (the numpy walk)
# imports both of these, so host and device can never disagree on the chain
# or the empty-slot sentinel.
SENTINEL_KEY = np.uint64(0xFFFFFFFFFFFFFFFF)


def probe_offset(t: int) -> int:
    """Quadratic (triangular) probe offset t(t+1)/2 — breaks the primary
    clustering that gives linear probing its heavy chain-length tail.
    Inserts, the numpy walk, and both device impls all walk this sequence.
    """
    return t * (t + 1) // 2


META_COLS = 5    # lin_band, base_slot, key_lo, key_hi, valid


def probe_operands(hashes: np.ndarray, n_slots: int) -> np.ndarray:
    """(Q, n_bands) uint64 band hashes -> (Q * n_bands, 5) int32 operands.

    The host-side uint64 leg: columns are [band * n_slots, key % n_slots,
    key_lo, key_hi, valid].  Key halves use the same native-endian int32
    view as the records array, so the in-kernel compare is bit-exact with
    the numpy path.
    """
    q, nb = hashes.shape
    key = np.ascontiguousarray(hashes.reshape(-1))
    meta = np.empty((q * nb, META_COLS), np.int32)
    meta[:, 0] = np.tile(np.arange(nb, dtype=np.int32) * n_slots, q)
    meta[:, 1] = (key % np.uint64(n_slots)).astype(np.int32)
    meta[:, 2:4] = key.view(np.int32).reshape(-1, 2)
    meta[:, 4] = (key != SENTINEL_KEY)
    return meta


def _offsets(max_probes: int) -> np.ndarray:
    """The full probe chain as an int32 vector (for the jnp fori_loop)."""
    return np.asarray([probe_offset(t) for t in range(max_probes)], np.int32)


@functools.partial(jax.jit, static_argnames=("n_slots", "max_probes"))
def lsh_probe_jnp(flat_records: Array, meta: Array, *, n_slots: int,
                  max_probes: int) -> Array:
    """Compiled-jnp probe: (E, 5) operands -> (E, W) candidate ids, -1 pad.

    ``flat_records`` is the (n_bands * n_slots, 2 + W) device records view.
    One fused-record gather per probe depth; the single possible hit per
    entry is folded in with a select, so depths can run in any order.
    """
    w = flat_records.shape[1] - 2
    lin_band, base = meta[:, 0], meta[:, 1]
    valid = meta[:, 4] != 0
    offs = jnp.asarray(_offsets(max_probes))

    def body(t, out):
        slot = (base + offs[t]) % n_slots
        rec = flat_records[lin_band + slot]                # (E, 2+W) gather
        hit = (rec[:, 0] == meta[:, 2]) & (rec[:, 1] == meta[:, 3]) & valid
        return jnp.where(hit[:, None], rec[:, 2:], out)

    out0 = jnp.full((meta.shape[0], w), -1, jnp.int32)
    return jax.lax.fori_loop(0, max_probes, body, out0)


def _probe_kernel(rec_ref, meta_ref, out_ref, *, et: int, ns: int, w: int,
                  max_probes: int):
    recs = rec_ref[...]                                    # (R, 2+W) resident
    meta = meta_ref[...]                                   # (et, 5)

    def body(e, out):
        m = jax.lax.dynamic_slice(meta, (e, 0), (1, META_COLS))
        lin, base = m[0, 0], m[0, 1]
        klo, khi, valid = m[0, 2], m[0, 3], m[0, 4] != 0
        row = jnp.full((1, w), -1, jnp.int32)
        for t in range(max_probes):                        # static chain
            slot = (base + probe_offset(t)) % ns
            rec = jax.lax.dynamic_slice(recs, (lin + slot, 0), (1, 2 + w))
            hit = (rec[0, 0] == klo) & (rec[0, 1] == khi) & valid
            row = jnp.where(hit, rec[:, 2:], row)
        return jax.lax.dynamic_update_slice(out, row, (e, 0))

    out_ref[...] = jax.lax.fori_loop(
        0, et, body, jnp.full((et, w), -1, jnp.int32))


@functools.partial(
    jax.jit,
    static_argnames=("n_slots", "max_probes", "block_e", "interpret"),
)
def lsh_probe_pallas(flat_records: Array, meta: Array, *, n_slots: int,
                     max_probes: int, block_e: int = 128,
                     interpret: bool = True) -> Array:
    """Pallas probe kernel: (E, 5) operands -> (E, W) candidate ids, -1 pad.

    Grid over entry tiles of ``block_e``; the records block is VMEM-resident
    across the whole grid (4 * n_bands * n_slots * (2 + W) bytes — size the
    table's geometry accordingly on real accelerators), so per-tile HBM
    traffic is just the operand block and the output rows.
    """
    e, mc = meta.shape
    r, rw = flat_records.shape
    w = rw - 2
    et = max(1, block_e)
    ne = -(-e // et)
    if ne * et != e:                  # pad with invalid entries (valid=0)
        pad = np.zeros((ne * et - e, META_COLS), np.int32)
        meta = jnp.concatenate([meta, jnp.asarray(pad)])
    out = pl.pallas_call(
        functools.partial(_probe_kernel, et=et, ns=n_slots, w=w,
                          max_probes=max_probes),
        grid=(ne,),
        in_specs=[
            pl.BlockSpec((r, rw), lambda i: (0, 0)),
            pl.BlockSpec((et, META_COLS), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((et, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ne * et, w), jnp.int32),
        interpret=interpret,
    )(flat_records, meta)
    return out[:e]
