"""Kernel dispatch for the signing and probing hot paths (the one front door).

Every signature request — dense or sparse, engine or pipeline — lands here and
is routed to one of the implementations by shape and backend:

dense (B, D) binary:
  * ``int8``    — kernels.cminhash_kernel (int8 circulant bands in VMEM)
  * ``packed``  — kernels.cminhash_packed (uint32 bit-packed bands: 8x less
                  HBM per band; wins once the band stream dominates, i.e.
                  large D on a real accelerator)
  * ``ref``     — kernels.ref jnp oracle (also the fastest dense path on CPU,
                  where Pallas runs in interpret mode)
sparse (B, NNZ) padded index lists:
  * ``pallas``  — kernels.cminhash_sparse Pallas window-min kernel (TPU)
  * ``windows`` — same algorithm as compiled jnp (the CPU fast path)
  * ``gather``  — core.cminhash.cminhash_sparse O(B*nnz*K) gather loop
                  (the economical oracle; what ``use_kernel=False`` selects)

``impl="auto"`` policy: on TPU, dense picks ``packed`` when the band stream
is large enough to be HBM-bound (D >= PACKED_MIN_D) else ``int8``; sparse
picks ``pallas``.  On CPU (no real accelerator) the compiled-jnp twins win:
dense ``ref``, sparse ``windows``.  ``use_kernel=False`` always forces the
reference formulation (``ref``/``gather``).

Block sizes left as ``None`` are resolved through the autotuner
(``autotune.recommend``: cached winner else heuristic; pass
``autotune_measure=True`` to sweep-and-cache on first miss).

``pack_b`` fuses the b-bit truncate+pack epilogue into the dense kernels AND
the sparse window-min kernels (packed words come straight off the kernel /
the compiled scan); only the gather oracle still packs as a separate step.
No shape gate is needed on the fused epilogue: off-TPU the resolved impls
(``ref``/``windows``) have no in-kernel epilogue — ``pack_b`` there is the
same ``pack_codes`` call the two-step form makes, so the two forms dispatch
identical work (an early benchmark artifact recording fused ~10% slower at
B8/D4096/K256 was non-interleaved timing on a shared box; interleaved
min-of-N shows them equal — see bench_sign.py).  On TPU the epilogue packs
from VMEM scratch it already holds, which is never worse than a second
HBM round trip.

``lsh_probe`` is the serving-side twin of the signing front door: the LSH
bucket-probe leg of a query batch, run on device over the table's resident
fused records (``kernels.lsh_probe``: Pallas kernel + compiled-jnp twin).
``impl="auto"`` picks the Pallas kernel on TPU and defers to the numpy host
loop otherwise (the CPU-tuned early-terminating walk in store/table.py).

``query_fused`` is the device-resident query pipeline: uint32-lane band-hash
fold (``kernels.query_fused``, two planes, bit-identical to the host uint64
fold) -> probe meta -> ``lsh_probe`` -> packed-code top-k scoring, one
dispatch entry with no host round trip between stages.  ``impl="auto"``
picks the Pallas legs on TPU and the compiled-jnp twins elsewhere; the
legacy host fold + planner walk stays available as the reference oracle
(``impl="host"`` is the *store's* decision — this front door serves device
impls only, mirroring ``lsh_probe``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import numpy as np

from ..core import cminhash
from ..core.permutations import apply_permutation_dense, apply_permutation_sparse
from ..obs import metrics as obs_metrics
from . import autotune, lsh_probe as _lsh_probe, packfmt, ref
from . import query_fused as _query_fused
from .cminhash_kernel import cminhash_pallas
from .cminhash_packed import cminhash_packed_pallas
from .cminhash_sparse import cminhash_sparse_pallas, cminhash_sparse_windows

Array = jax.Array

# below this universe size the packed kernel's 8x band-stream saving cannot
# beat its funnel-shift overhead (see kernels/README.md napkin math)
PACKED_MIN_D = 16384

DENSE_IMPLS = ("auto", "int8", "packed", "ref")
SPARSE_IMPLS = ("auto", "pallas", "windows", "gather")
PROBE_IMPLS = ("auto", "numpy", "jnp", "pallas")
QUERY_IMPLS = ("auto", "jnp", "pallas", "host")


def _backend() -> str:
    return jax.default_backend()


def _interpret() -> bool:
    return _backend() != "tpu"


def select_dense_impl(d: int, *, use_kernel: bool = True,
                      backend: str | None = None) -> str:
    """Resolve impl="auto" for a dense (B, D) signing request."""
    if not use_kernel:
        return "ref"
    backend = backend or _backend()
    if backend != "tpu":
        return "ref"        # compiled jnp beats interpret-mode Pallas on CPU
    return "packed" if d >= PACKED_MIN_D else "int8"


def select_sparse_impl(*, use_kernel: bool = True,
                       backend: str | None = None) -> str:
    """Resolve impl="auto" for a sparse signing request."""
    if not use_kernel:
        return "gather"
    backend = backend or _backend()
    return "pallas" if backend == "tpu" else "windows"


def _resolve_blocks(kind: str, b: int, d: int, k: int,
                    overrides: dict[str, int | None],
                    autotune_measure: bool, nnz: int = 0) -> dict[str, int]:
    if all(v is not None for v in overrides.values()):
        return {n: int(v) for n, v in overrides.items()}  # fully pinned
    if autotune_measure:
        blocks = autotune.measure(kind, b, d, k, nnz=nnz)
    else:
        blocks = autotune.recommend(kind, b, d, k, nnz=nnz)
    blocks = {n: blocks[n] for n in overrides}
    blocks.update({n: int(v) for n, v in overrides.items() if v is not None})
    return blocks


def signatures_dense(v: Array, pi: Array, k: int, sigma: Array | None = None,
                     *, shift_offset: int = 1, use_kernel: bool = True,
                     impl: str = "auto", block_b: int | None = None,
                     block_d: int | None = None, pack_b: int | None = None,
                     autotune_measure: bool = False) -> Array:
    """(B, D) binary -> (B, K) int32 signatures, or (B, W) uint32 packed
    words when ``pack_b`` is set."""
    if impl not in DENSE_IMPLS:
        raise ValueError(f"impl must be one of {DENSE_IMPLS} (got {impl!r})")
    if impl == "auto":
        impl = select_dense_impl(v.shape[-1], use_kernel=use_kernel)
    # per-resolved-impl call counts: which kernel actually serves the fleet
    obs_metrics.default().counter(f"kernel.dense.{impl}").inc()
    if sigma is not None:
        v = apply_permutation_dense(v, sigma)
    b, d = v.shape

    if impl == "ref":
        sig = ref.cminhash_dense_ref(v, pi, k, shift_offset=shift_offset)
        return sig if pack_b is None else packfmt.pack_codes(sig, pack_b)

    kind = "dense_int8" if impl == "int8" else "dense_packed"
    blocks = _resolve_blocks(kind, b, d, k,
                             {"block_b": block_b, "block_d": block_d},
                             autotune_measure)
    if pack_b is not None:
        cpw = 32 // pack_b
        if blocks["block_d"] % cpw:    # keep word boundaries on block edges
            blocks["block_d"] = -(-blocks["block_d"] // cpw) * cpw
    kernel = cminhash_pallas if impl == "int8" else cminhash_packed_pallas
    return kernel(v, pi, k, shift_offset=shift_offset,
                  interpret=_interpret(), pack_b=pack_b, **blocks)


def signatures_sparse(idx: Array, pi: Array, k: int,
                      sigma: Array | None = None, *, shift_offset: int = 1,
                      use_kernel: bool = True, impl: str = "auto",
                      block_b: int | None = None, block_j: int | None = None,
                      pack_b: int | None = None,
                      autotune_measure: bool = False) -> Array:
    """(B, NNZ) padded index lists -> (B, K) int32 signatures, or (B, W)
    uint32 packed words when ``pack_b`` is set (fused sign->pack in both
    window-min kernels; only the gather oracle packs as a separate step)."""
    if impl not in SPARSE_IMPLS:
        raise ValueError(f"impl must be one of {SPARSE_IMPLS} (got {impl!r})")
    if impl == "auto":
        impl = select_sparse_impl(use_kernel=use_kernel)
    obs_metrics.default().counter(f"kernel.sparse.{impl}").inc()
    if sigma is not None:
        idx = apply_permutation_sparse(idx, sigma)
    b, nnz = idx.shape
    d = pi.shape[0]

    if impl == "gather":
        sig = cminhash.cminhash_sparse(idx, pi, k, shift_offset=shift_offset)
        return sig if pack_b is None else packfmt.pack_codes(sig, pack_b)
    if impl == "windows":
        blocks = _resolve_blocks("sparse_windows", b, d, k,
                                 {"block_j": block_j}, autotune_measure,
                                 nnz=nnz)
        return cminhash_sparse_windows(idx, pi, k, shift_offset=shift_offset,
                                       pack_b=pack_b, **blocks)
    blocks = _resolve_blocks("sparse_pallas", b, d, k,
                             {"block_b": block_b, "block_j": block_j},
                             autotune_measure, nnz=nnz)
    return cminhash_sparse_pallas(idx, pi, k, shift_offset=shift_offset,
                                  interpret=_interpret(), pack_b=pack_b,
                                  **blocks)


# -- LSH bucket probe (the serving-side device leg) ---------------------------

def select_probe_impl(backend: str | None = None) -> str:
    """Resolve impl="auto" for a bucket-probe request: the Pallas kernel on
    a real accelerator, the numpy host loop otherwise (interpret-mode Pallas
    and the jnp twin both lose to the cache-tuned early-terminating walk on
    CPU)."""
    backend = backend or _backend()
    return "pallas" if backend == "tpu" else "numpy"


def lsh_probe(records_dev: Array, hashes: np.ndarray, *, n_slots: int,
              max_probes: int, impl: str = "auto",
              block_e: int = 128) -> np.ndarray:
    """(Q, n_bands) uint64 band hashes -> (Q, n_bands * W) candidate ids.

    ``records_dev`` is the table's uploaded (n_bands * n_slots, 2 + W) fused
    records (``BandedLSHTable.device_records``).  The uint64 leg (base slot,
    key halves, validity) runs on host (``lsh_probe.probe_operands``);
    everything after is device work.  This front door serves the *device*
    impls only: ``impl="auto"`` here means "the device impl for this
    backend" (Pallas on TPU, the jnp twin elsewhere) — the numpy-vs-device
    decision is ``BandedLSHTable.lookup``'s (via ``select_probe_impl``),
    since the numpy walk needs the table's host state, not an upload.
    """
    if impl not in PROBE_IMPLS:
        raise ValueError(f"impl must be one of {PROBE_IMPLS} (got {impl!r})")
    if impl == "auto":
        impl = "pallas" if _backend() == "tpu" else "jnp"
    obs_metrics.default().counter(f"kernel.probe.{impl}").inc()
    if impl == "numpy":
        raise ValueError("impl='numpy' is BandedLSHTable.lookup's own host "
                         "loop; call the table, not the dispatch layer")
    q, nb = hashes.shape
    w = records_dev.shape[1] - 2
    meta = jnp.asarray(_lsh_probe.probe_operands(hashes, n_slots))
    if impl == "jnp":
        out = _lsh_probe.lsh_probe_jnp(records_dev, meta, n_slots=n_slots,
                                       max_probes=max_probes)
    else:
        out = _lsh_probe.lsh_probe_pallas(records_dev, meta, n_slots=n_slots,
                                          max_probes=max_probes,
                                          block_e=block_e,
                                          interpret=_interpret())
    return np.asarray(out).reshape(q, nb * w)


# -- fused device-resident query path -----------------------------------------

def select_query_impl(backend: str | None = None) -> str:
    """Resolve impl="auto" for a fused query request: the Pallas legs on a
    real accelerator, the compiled-jnp twins elsewhere.  Never "host" — the
    store decides when the legacy host fold + planner walk must run (non-pow2
    slot counts, no stored signatures, empty buffer)."""
    backend = backend or _backend()
    return "pallas" if backend == "tpu" else "jnp"


def _fold_planes(rows_hi: Array, rows_lo: Array, *, impl: str,
                 block_q: int | None,
                 autotune_measure: bool) -> tuple[Array, Array]:
    if impl == "pallas":
        q, nb, r = rows_lo.shape
        blocks = _resolve_blocks("query_fold", q, nb, r,
                                 {"block_q": block_q}, autotune_measure)
        return _query_fused.fold_planes_pallas(rows_hi, rows_lo,
                                               interpret=_interpret(),
                                               **blocks)
    return _query_fused.fold_planes_jnp(rows_hi, rows_lo)


def fold_hashes(qwords: Array, *, n_bands: int, impl: str = "auto",
                block_q: int | None = None,
                autotune_measure: bool = False) -> np.ndarray:
    """(Q, W) packed uint32 query words -> (Q, n_bands) uint64 band hashes
    via the device uint32-lane fold.  Bit-identical to
    ``core.lsh.band_hashes_packed`` — this is the coordinator's fold leg when
    hashes must come back to host anyway (broadcast to shards)."""
    if impl not in QUERY_IMPLS:
        raise ValueError(f"impl must be one of {QUERY_IMPLS} (got {impl!r})")
    if impl == "auto":
        impl = select_query_impl()
    if impl == "host":
        raise ValueError("impl='host' is core.lsh.band_hashes_packed; call "
                         "it directly, not the dispatch layer")
    obs_metrics.default().counter(f"kernel.fold.{impl}").inc()
    rows_hi, rows_lo = _query_fused.words_to_planes(jnp.asarray(qwords),
                                                    n_bands)
    hi, lo = _fold_planes(rows_hi, rows_lo, impl=impl, block_q=block_q,
                          autotune_measure=autotune_measure)
    return _query_fused.planes_to_hashes(np.asarray(hi), np.asarray(lo))


def query_fused(records_dev: Array, words_dev: Array, qwords: Array, *,
                n_bands: int, n_slots: int, max_probes: int, k: int, b: int,
                top_k: int, impl: str = "auto",
                hashes: np.ndarray | None = None,
                spill_lookup=None, block_q: int | None = None,
                block_e: int | None = None, autotune_measure: bool = False,
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fused fold -> probe -> score over resident store state: (Q, W) packed
    query words -> ``(ids, scores, has_candidates)`` partial-top-k triple,
    bit-identical to the host-fold planner partial.

    * ``records_dev`` — the table's uploaded fused records
      (``BandedLSHTable.device_records``).
    * ``words_dev``   — the buffer's uploaded packed signature words
      (``PackedSignatureBuffer.device_words``), scored against on device.
    * ``hashes=None`` (single-store / shard-local fold): the uint32-lane
      fold runs on device and probe meta is built there too — requires
      power-of-two ``n_slots`` (callers gate; the store falls back to host).
    * ``hashes=`` host uint64 band hashes (shard workers: the coordinator
      folds ONCE and broadcasts): the fold is skipped and the probe meta
      takes the host uint64 leg (any ``n_slots``).
    * ``spill_lookup`` — optional ``hashes -> (Q, M) int64 rows`` host
      callable for the table's rare spilled keys; invoked with the (possibly
      reconstructed) host hashes and concatenated before scoring.

    Returns host arrays: ids (Q, top_k) int64 (-1 padded), scores (Q, top_k)
    float32 (NEG_INF padded), has_candidates (Q,) bool.
    """
    if impl not in QUERY_IMPLS:
        raise ValueError(f"impl must be one of {QUERY_IMPLS} (got {impl!r})")
    if impl == "auto":
        impl = select_query_impl()
    if impl == "host":
        raise ValueError("impl='host' is the store's legacy fold + planner "
                         "walk; call the store, not the dispatch layer")
    obs_metrics.default().counter(f"kernel.query_fused.{impl}").inc()
    qwords = jnp.asarray(qwords)
    q = qwords.shape[0]
    w = records_dev.shape[1] - 2

    if hashes is None:
        rows_hi, rows_lo = _query_fused.words_to_planes(qwords, n_bands)
        hi, lo = _fold_planes(rows_hi, rows_lo, impl=impl, block_q=block_q,
                              autotune_measure=autotune_measure)
        meta = _query_fused.meta_from_planes(hi, lo, n_slots=n_slots)
        if spill_lookup is not None:   # rare host leg needs uint64 hashes
            hashes = _query_fused.planes_to_hashes(np.asarray(hi),
                                                   np.asarray(lo))
    else:
        meta = jnp.asarray(_lsh_probe.probe_operands(hashes, n_slots))

    if impl == "pallas":
        blocks = _resolve_blocks("probe_pallas", meta.shape[0], n_slots, w,
                                 {"block_e": block_e}, autotune_measure)
        cand = _lsh_probe.lsh_probe_pallas(records_dev, meta, n_slots=n_slots,
                                           max_probes=max_probes,
                                           interpret=_interpret(), **blocks)
    else:
        cand = _lsh_probe.lsh_probe_jnp(records_dev, meta, n_slots=n_slots,
                                        max_probes=max_probes)
    cand = cand.reshape(q, n_bands * w)
    if spill_lookup is not None:
        spill = np.asarray(spill_lookup(hashes))
        if spill.size:
            cand = jnp.concatenate(
                [cand, jnp.asarray(spill.astype(np.int32))], axis=1)
    ids, scores, has = _query_fused.score_topk(cand, words_dev, qwords,
                                               k=k, b=b, top_k=top_k)
    return (np.asarray(ids).astype(np.int64), np.asarray(scores),
            np.asarray(has))
