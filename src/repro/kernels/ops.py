"""Public jit'd wrappers around the Pallas kernels with reference fallbacks.

`use_kernel` policy: Pallas kernels run compiled on TPU and in interpret mode on
CPU (functionally identical, slower).  The wrappers keep signature semantics
identical across paths so callers (engine, dedup pipeline, benchmarks) can switch
freely; tests sweep shapes/dtypes asserting kernel == ref.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.permutations import apply_permutation_dense
from . import ref
from .cminhash_kernel import cminhash_pallas
from .collision_kernel import collision_count_pallas

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def cminhash_signatures(v: Array, pi: Array, k: int, sigma: Array | None = None,
                        *, shift_offset: int = 1, use_kernel: bool = True,
                        block_b: int = 8, block_d: int = 256) -> Array:
    """Dense C-MinHash signatures (B, D) -> (B, K) via kernel or oracle."""
    if sigma is not None:
        v = apply_permutation_dense(v, sigma)
    if use_kernel:
        return cminhash_pallas(v, pi, k, shift_offset=shift_offset,
                               block_b=block_b, block_d=block_d,
                               interpret=_interpret())
    return ref.cminhash_dense_ref(v, pi, k, shift_offset=shift_offset)


def collision_counts(sig_q: Array, sig_n: Array, *, use_kernel: bool = True,
                     block_q: int = 64, block_n: int = 64,
                     block_k: int = 128) -> Array:
    """(Q, K) x (N, K) -> (Q, N) int32 match counts via kernel or oracle."""
    if use_kernel:
        return collision_count_pallas(sig_q, sig_n, block_q=block_q,
                                      block_n=block_n, block_k=block_k,
                                      interpret=_interpret())
    return ref.collision_count_ref(sig_q, sig_n)


def estimated_jaccard_matrix(sig_q: Array, sig_n: Array, **kw) -> Array:
    """(Q, N) float32 estimated Jaccard from signatures."""
    k = sig_q.shape[-1]
    return collision_counts(sig_q, sig_n, **kw).astype(jnp.float32) / k
