"""Public jit'd wrappers around the Pallas kernels with reference fallbacks.

`use_kernel` policy: Pallas kernels run compiled on TPU and in interpret mode on
CPU (functionally identical, slower).  The wrappers keep signature semantics
identical across paths so callers (engine, dedup pipeline, benchmarks) can switch
freely; tests sweep shapes/dtypes asserting kernel == ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.bbit import lowest_b_bits
from ..core.permutations import apply_permutation_dense
from . import ref
from .cminhash_kernel import cminhash_pallas
from .collision_kernel import collision_count_pallas

Array = jax.Array

PACK_BITS = (1, 2, 4, 8, 16, 32)  # b values whose codes tile an int32 word


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def cminhash_signatures(v: Array, pi: Array, k: int, sigma: Array | None = None,
                        *, shift_offset: int = 1, use_kernel: bool = True,
                        block_b: int = 8, block_d: int = 256) -> Array:
    """Dense C-MinHash signatures (B, D) -> (B, K) via kernel or oracle."""
    if sigma is not None:
        v = apply_permutation_dense(v, sigma)
    if use_kernel:
        return cminhash_pallas(v, pi, k, shift_offset=shift_offset,
                               block_b=block_b, block_d=block_d,
                               interpret=_interpret())
    return ref.cminhash_dense_ref(v, pi, k, shift_offset=shift_offset)


def collision_counts(sig_q: Array, sig_n: Array, *, use_kernel: bool = True,
                     block_q: int = 64, block_n: int = 64,
                     block_k: int = 128) -> Array:
    """(Q, K) x (N, K) -> (Q, N) int32 match counts via kernel or oracle."""
    if use_kernel:
        return collision_count_pallas(sig_q, sig_n, block_q=block_q,
                                      block_n=block_n, block_k=block_k,
                                      interpret=_interpret())
    return ref.collision_count_ref(sig_q, sig_n)


def estimated_jaccard_matrix(sig_q: Array, sig_n: Array, **kw) -> Array:
    """(Q, N) float32 estimated Jaccard from signatures."""
    k = sig_q.shape[-1]
    return collision_counts(sig_q, sig_n, **kw).astype(jnp.float32) / k


# -- b-bit packed codes (SketchStore storage format) -------------------------
#
# K codes of b bits each are packed little-endian into ceil(K / (32/b)) uint32
# words: code j of a row lives at bit (j % (32/b)) * b of word j // (32/b).
# b == 32 is a bitcast (one code per word, codes == signatures), so scoring on
# packed words at b = 32 is bit-exact with scoring the raw signatures.

def _pack_geometry(k: int, b: int) -> tuple[int, int]:
    if b not in PACK_BITS:
        raise ValueError(f"b must be one of {PACK_BITS} (got {b})")
    codes_per_word = 32 // b
    return codes_per_word, -(-k // codes_per_word)


@functools.partial(jax.jit, static_argnames=("b",))
def pack_codes(sig: Array, b: int) -> Array:
    """(B, K) int32 signatures -> (B, W) uint32 b-bit packed words."""
    bsz, k = sig.shape
    cpw, n_words = _pack_geometry(k, b)
    if b == 32:
        return jax.lax.bitcast_convert_type(sig, jnp.uint32)
    codes = lowest_b_bits(sig, b).astype(jnp.uint32)
    pad = n_words * cpw - k
    if pad:
        codes = jnp.pad(codes, ((0, 0), (0, pad)))
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * b)
    return jnp.sum(codes.reshape(bsz, n_words, cpw) << shifts, axis=-1,
                   dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("k", "b"))
def unpack_codes(words: Array, k: int, b: int) -> Array:
    """(B, W) uint32 packed words -> (B, K) int32 codes in [0, 2^b)."""
    bsz = words.shape[0]
    cpw, n_words = _pack_geometry(k, b)
    if b == 32:
        return jax.lax.bitcast_convert_type(words, jnp.int32)[:, :k]
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * b)
    mask = jnp.uint32((1 << b) - 1)
    codes = (words[:, :, None] >> shifts) & mask
    return codes.reshape(bsz, n_words * cpw)[:, :k].astype(jnp.int32)


def packed_collision_counts(words_q: Array, words_n: Array, k: int, b: int,
                            *, unpack_block_n: int = 16384, **kw) -> Array:
    """(Q, W) x (N, W) packed uint32 -> (Q, N) int32 matching-code counts.

    Unpacks and reuses the pairwise collision kernel.  The index side is
    processed in blocks of ``unpack_block_n`` rows so the unpacked (N', K)
    int32 intermediate stays bounded — the resident index keeps its b/32
    packed footprint even when a brute-force fallback scores all of it.
    """
    uq = unpack_codes(words_q, k, b)
    n = words_n.shape[0]
    if n <= unpack_block_n:
        return collision_counts(uq, unpack_codes(words_n, k, b), **kw)
    parts = [collision_counts(
        uq, unpack_codes(words_n[lo: lo + unpack_block_n], k, b), **kw)
        for lo in range(0, n, unpack_block_n)]
    return jnp.concatenate(parts, axis=1)


def packed_estimated_jaccard_matrix(words_q: Array, words_n: Array, k: int,
                                    b: int, **kw) -> Array:
    """(Q, N) float32 estimated Jaccard from b-bit packed codes.

    At b < 32 this is the raw collision fraction of b-bit codes — biased up by
    ~2^-b relative to true Jaccard (Li & Koenig, 2011); at b = 32 it equals
    ``estimated_jaccard_matrix`` exactly.
    """
    counts = packed_collision_counts(words_q, words_n, k, b, **kw)
    return counts.astype(jnp.float32) / k
