"""Public jit'd wrappers around the Pallas kernels with reference fallbacks.

Signing requests route through ``kernels.dispatch`` (shape/backend kernel
selection + autotuned block sizes); pairwise scoring wraps the collision
kernel directly.  The wrappers keep signature semantics identical across
paths so callers (engine, dedup pipeline, benchmarks) can switch freely;
tests sweep shapes/dtypes asserting kernel == ref.

The b-bit packed-code format lives in ``kernels.packfmt``; its geometry,
``pack_codes`` and ``unpack_codes`` are re-exported here for the store/planner.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import dispatch, ref
from .collision_kernel import collision_count_pallas
from .packfmt import (PACK_BITS, pack_codes,  # noqa: F401  (re-exports)
                      pack_geometry, unpack_codes)

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def cminhash_signatures(v: Array, pi: Array, k: int, sigma: Array | None = None,
                        *, shift_offset: int = 1, use_kernel: bool = True,
                        block_b: int | None = None, block_d: int | None = None,
                        impl: str = "auto") -> Array:
    """Dense C-MinHash signatures (B, D) -> (B, K) via the dispatch layer.

    ``use_kernel=True`` lets dispatch pick the kernel by shape/backend (pass
    ``impl`` to force one); ``use_kernel=False`` is the jnp oracle.  Blocks
    left as None come from the autotune cache.
    """
    if use_kernel and impl == "auto" and (block_b, block_d) != (None, None):
        impl = "int8"   # explicit block request pins the historical kernel
    return dispatch.signatures_dense(
        v, pi, k, sigma, shift_offset=shift_offset, use_kernel=use_kernel,
        impl=impl, block_b=block_b, block_d=block_d)


def cminhash_signatures_packed(v: Array, pi: Array, k: int, b: int,
                               sigma: Array | None = None, *,
                               shift_offset: int = 1, use_kernel: bool = True,
                               impl: str = "auto") -> Array:
    """Fused sign->pack: (B, D) binary -> (B, ceil(K/(32/b))) uint32 words,
    bit-identical to ``pack_codes(cminhash_signatures(...), b)``."""
    return dispatch.signatures_dense(
        v, pi, k, sigma, shift_offset=shift_offset, use_kernel=use_kernel,
        impl=impl, pack_b=b)


def collision_counts(sig_q: Array, sig_n: Array, *, use_kernel: bool = True,
                     block_q: int = 64, block_n: int = 64,
                     block_k: int = 128) -> Array:
    """(Q, K) x (N, K) -> (Q, N) int32 match counts via kernel or oracle."""
    if use_kernel:
        return collision_count_pallas(sig_q, sig_n, block_q=block_q,
                                      block_n=block_n, block_k=block_k,
                                      interpret=_interpret())
    return ref.collision_count_ref(sig_q, sig_n)


def estimated_jaccard_matrix(sig_q: Array, sig_n: Array, **kw) -> Array:
    """(Q, N) float32 estimated Jaccard from signatures."""
    k = sig_q.shape[-1]
    return collision_counts(sig_q, sig_n, **kw).astype(jnp.float32) / k


# -- b-bit packed-code scoring (SketchStore storage format) ------------------
# (format + pack/unpack live in kernels.packfmt; re-exported above)

def packed_collision_counts(words_q: Array, words_n: Array, k: int, b: int,
                            *, unpack_block_n: int = 16384, **kw) -> Array:
    """(Q, W) x (N, W) packed uint32 -> (Q, N) int32 matching-code counts.

    Unpacks and reuses the pairwise collision kernel.  The index side is
    processed in blocks of ``unpack_block_n`` rows so the unpacked (N', K)
    int32 intermediate stays bounded — the resident index keeps its b/32
    packed footprint even when a brute-force fallback scores all of it.
    """
    uq = unpack_codes(words_q, k, b)
    n = words_n.shape[0]
    if n <= unpack_block_n:
        return collision_counts(uq, unpack_codes(words_n, k, b), **kw)
    parts = [collision_counts(
        uq, unpack_codes(words_n[lo: lo + unpack_block_n], k, b), **kw)
        for lo in range(0, n, unpack_block_n)]
    return jnp.concatenate(parts, axis=1)


def packed_estimated_jaccard_matrix(words_q: Array, words_n: Array, k: int,
                                    b: int, **kw) -> Array:
    """(Q, N) float32 estimated Jaccard from b-bit packed codes.

    At b < 32 this is the raw collision fraction of b-bit codes — biased up by
    ~2^-b relative to true Jaccard (Li & Koenig, 2011); at b = 32 it equals
    ``estimated_jaccard_matrix`` exactly.
    """
    counts = packed_collision_counts(words_q, words_n, k, b, **kw)
    return counts.astype(jnp.float32) / k
