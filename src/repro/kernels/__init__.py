"""Pallas TPU kernels (validated on CPU via interpret=True) + jnp oracles.

Signing requests route through ``dispatch`` (see README.md for the policy);
``autotune`` owns block-size selection; ``packfmt`` is the b-bit packed-code
format shared by the store and the fused in-kernel sign->pack epilogue.
"""

from .ops import (cminhash_signatures, cminhash_signatures_packed,  # noqa: F401
                  collision_counts, estimated_jaccard_matrix)
