"""Pallas TPU kernels (validated on CPU via interpret=True) + jnp oracles."""

from .ops import cminhash_signatures, collision_counts, estimated_jaccard_matrix  # noqa: F401
