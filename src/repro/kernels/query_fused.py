"""Device-resident query pipeline: fold -> probe -> score without host hops.

Before this module a query batch bounced host<->device three times: the
band-hash fold ran on host in uint64 (``core.lsh._poly_fold``), the probe
either ran on host or shipped its candidates back, and scoring gathered
candidate rows through numpy.  These pieces keep a query batch on the
accelerator from packed words to ranked (id, score) rows:

* **uint32-lane fold** — JAX's default domain is 32-bit and XLA has no
  uint64 on most backends, so the polynomial fold is emulated on two uint32
  planes (``lo``/``hi``), carry-correct through the 64-bit multiply
  (16-bit limb decomposition), the ``+ x + 1`` double carry, and the
  ``h ^= h >> 29`` cross-plane shift.  Bit-identical to the host fold for
  every input — including negative int32 signature codes, whose host-side
  ``astype(np.uint64)`` sign-extends (the ``hi`` plane is all-ones there).
  Both a Pallas kernel (``fold_planes_pallas``, grid over batch tiles) and
  a compiled-jnp twin (``fold_planes_jnp``) are provided; parity is swept
  in tests/test_query_fused.py.
* **device probe meta** — ``meta_from_planes`` builds the ``lsh_probe``
  operand block (band offset, base slot, key halves, validity) from the
  fold planes without leaving the device.  Requires power-of-two
  ``n_slots`` so ``key % n_slots`` is ``lo & (n_slots - 1)`` (the default
  geometry and every doubling of it; non-pow2 configs take the host path).
* **fused scorer** — ``score_topk`` turns (Q, C) -1-padded candidate rows
  plus the resident packed-word buffers into ranked (Q, top_k) partials:
  sort-by-id dedup, one row gather, b-bit unpack, integer collision
  counts, and a two-key ``lax.sort`` on (count desc, id asc) — the exact
  tie-break the host planner's stable argsort produces, so fused and
  host-fold answers are bit-identical (scores are the same
  ``counts.astype(float32) / k`` division both ways).

The wire protocol already ships band hashes as two uint32 planes
(``transport.wire.split_u64``); this module is the compute-side twin of
that representation.  ``kernels.dispatch.query_fused`` is the front door
that composes these stages with the resident records/words uploads.
"""

from __future__ import annotations

import functools
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .lsh_probe import META_COLS
from .packfmt import unpack_codes

Array = jax.Array

# the host fold's Fibonacci multiplier, split into uint32 halves
BASE_HI = 0x9E3779B9
BASE_LO = 0x7F4A7C15

_M16 = 0xFFFF
_INVALID_ID = np.int32(2**31 - 1)   # in-scorer sentinel: sorts after real ids

# records/meta key halves use the NATIVE int32 view of the uint64 key
# (store/table.py ``_halves``): on little-endian hosts column 0 is the low
# word.  The device meta builder must agree with however the records were
# written, so the plane->column mapping follows the host byte order.
_LITTLE_ENDIAN = sys.byteorder == "little"


# -- two-plane uint64 emulation ----------------------------------------------

def _mul32_hi_lo(a: Array, b: Array) -> tuple[Array, Array]:
    """Full 64-bit product of two uint32 arrays as (hi, lo) uint32 planes.

    16-bit limb decomposition: every partial product and the carry
    accumulator fit uint32 (max (2^16-1)^2 + 2*(2^16-1) < 2^32), so no
    intermediate ever needs a wider lane."""
    m16 = jnp.uint32(_M16)
    a0, a1 = a & m16, a >> jnp.uint32(16)
    b0, b1 = b & m16, b >> jnp.uint32(16)
    p00 = a0 * b0
    p01 = a0 * b1
    p10 = a1 * b0
    mid = (p00 >> jnp.uint32(16)) + (p01 & m16) + (p10 & m16)
    lo = (p00 & m16) | (mid << jnp.uint32(16))
    hi = a1 * b1 + (p01 >> jnp.uint32(16)) + (p10 >> jnp.uint32(16)) \
        + (mid >> jnp.uint32(16))
    return hi, lo


def _fold_step(hi: Array, lo: Array, xhi: Array,
               xlo: Array) -> tuple[Array, Array]:
    """One fold round on the planes: ``h = h * BASE + x + 1; h ^= h >> 29``.

    * multiply: lo * BASE is a full 32x32->64 product; the high plane adds
      the two cross terms (wrapping, as uint64 mul does);
    * add x + 1: two carry checks — ``lo + xlo`` can wrap, and the ``+ 1``
      can wrap again when the sum landed on 0xFFFFFFFF;
    * shift-xor: ``(h >> 29).lo`` takes 3 bits from the high plane.
    """
    phi, plo = _mul32_hi_lo(lo, jnp.uint32(BASE_LO))
    phi = phi + lo * jnp.uint32(BASE_HI) + hi * jnp.uint32(BASE_LO)
    s = plo + xlo
    c1 = (s < plo).astype(jnp.uint32)
    s1 = s + jnp.uint32(1)
    c2 = (s1 == 0).astype(jnp.uint32)
    lo = s1
    hi = phi + xhi + c1 + c2
    slo = (lo >> jnp.uint32(29)) | (hi << jnp.uint32(3))
    shi = hi >> jnp.uint32(29)
    return hi ^ shi, lo ^ slo


def _fold_planes(rows_hi: Array, rows_lo: Array) -> tuple[Array, Array]:
    """(..., R) uint32 planes -> (...,) hi/lo fold planes (R unrolled)."""
    hi = jnp.zeros(rows_lo.shape[:-1], jnp.uint32)
    lo = jnp.zeros_like(hi)
    for r in range(rows_lo.shape[-1]):
        hi, lo = _fold_step(hi, lo, rows_hi[..., r], rows_lo[..., r])
    return hi, lo


def words_to_planes(words: Array, n_bands: int) -> tuple[Array, Array]:
    """(B, W) uint32 packed words -> (B, n_bands, W/n_bands) hi/lo planes.

    The packed twin of ``core.lsh.band_hashes_packed``'s reshape: words are
    non-negative 32-bit values, so the high plane is zero."""
    b, w = words.shape
    if w % n_bands:
        raise ValueError(f"W={w} not divisible by n_bands={n_bands}")
    lo = words.astype(jnp.uint32).reshape(b, n_bands, w // n_bands)
    return jnp.zeros_like(lo), lo


def sig_to_planes(sig: Array, n_bands: int,
                  rows_per_band: int) -> tuple[Array, Array]:
    """(B, K) int32 signatures -> (B, n_bands, rows_per_band) hi/lo planes.

    Matches the host fold's ``astype(np.uint64)`` on int32: negative codes
    sign-extend, so their high plane is all-ones."""
    b, k = sig.shape
    if n_bands * rows_per_band != k:
        raise ValueError(f"K={k} != n_bands*rows_per_band")
    s = sig.reshape(b, n_bands, rows_per_band)
    lo = s.astype(jnp.uint32)
    hi = jnp.where(s < 0, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
    return hi, lo


@jax.jit
def fold_planes_jnp(rows_hi: Array, rows_lo: Array) -> tuple[Array, Array]:
    """Compiled-jnp fold: (B, nb, R) uint32 planes -> (B, nb) hi/lo planes.

    Bit-identical to ``core.lsh._poly_fold`` on the joined uint64 values;
    the dispatchable device fold on CPU-hosted backends and the
    oracle-equivalent of the Pallas kernel."""
    return _fold_planes(rows_hi, rows_lo)


def _fold_kernel(hi_ref, lo_ref, out_hi_ref, out_lo_ref):
    hi, lo = _fold_planes(hi_ref[...], lo_ref[...])
    out_hi_ref[...] = hi
    out_lo_ref[...] = lo


@functools.partial(jax.jit, static_argnames=("block_q", "interpret"))
def fold_planes_pallas(rows_hi: Array, rows_lo: Array, *, block_q: int = 128,
                       interpret: bool = True) -> tuple[Array, Array]:
    """Pallas fold kernel: grid over batch tiles of ``block_q`` queries.

    Each tile folds its (block_q, nb, R) planes fully in VMEM — the R
    rounds are statically unrolled, so per-tile HBM traffic is one read of
    the input planes and one write of the (block_q, nb) key planes.
    ``interpret=True`` runs on CPU."""
    q, nb, r = rows_lo.shape
    qt = max(1, block_q)
    nq = -(-q // qt)
    if nq * qt != q:
        pad = ((0, nq * qt - q), (0, 0), (0, 0))
        rows_hi = jnp.pad(rows_hi, pad)
        rows_lo = jnp.pad(rows_lo, pad)
    out_hi, out_lo = pl.pallas_call(
        _fold_kernel,
        grid=(nq,),
        in_specs=[
            pl.BlockSpec((qt, nb, r), lambda i: (i, 0, 0)),
            pl.BlockSpec((qt, nb, r), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((qt, nb), lambda i: (i, 0)),
            pl.BlockSpec((qt, nb), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq * qt, nb), jnp.uint32),
            jax.ShapeDtypeStruct((nq * qt, nb), jnp.uint32),
        ],
        interpret=interpret,
    )(rows_hi, rows_lo)
    return out_hi[:q], out_lo[:q]


def planes_to_hashes(hi, lo) -> np.ndarray:
    """(Q, nb) uint32 planes -> (Q, nb) uint64 host hashes (the rare host
    leg: spill matching and the wire broadcast both want uint64)."""
    hi = np.asarray(hi, np.uint64)
    lo = np.asarray(lo, np.uint64)
    return (hi << np.uint64(32)) | lo


# -- device probe meta --------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n_slots",))
def meta_from_planes(hi: Array, lo: Array, *, n_slots: int) -> Array:
    """(Q, nb) fold planes -> (Q * nb, 5) int32 probe operands on device.

    The device twin of ``lsh_probe.probe_operands``: requires pow2
    ``n_slots`` (``key % n_slots == lo & (n_slots - 1)``).  Column order of
    the key halves follows the host byte order, because the records array
    the probe compares against was written through a native int32 view.
    """
    if n_slots & (n_slots - 1):
        raise ValueError(f"meta_from_planes needs pow2 n_slots (got {n_slots})")
    q, nb = lo.shape
    ones = jnp.uint32(0xFFFFFFFF)
    flat_lo = lo.reshape(-1)
    flat_hi = hi.reshape(-1)
    lin_band = jnp.tile(jnp.arange(nb, dtype=jnp.int32) * n_slots, q)
    base = (flat_lo & jnp.uint32(n_slots - 1)).astype(jnp.int32)
    klo = jax.lax.bitcast_convert_type(flat_lo, jnp.int32)
    khi = jax.lax.bitcast_convert_type(flat_hi, jnp.int32)
    valid = (~((flat_lo == ones) & (flat_hi == ones))).astype(jnp.int32)
    if not _LITTLE_ENDIAN:                      # pragma: no cover
        klo, khi = khi, klo
    cols = [lin_band, base, klo, khi, valid]
    assert len(cols) == META_COLS
    return jnp.stack(cols, axis=1)


# -- fused candidate scoring + top-k -----------------------------------------

@functools.partial(jax.jit, static_argnames=("k", "b", "top_k"))
def score_topk(cand: Array, words: Array, qwords: Array, *, k: int, b: int,
               top_k: int) -> tuple[Array, Array, Array]:
    """(Q, C) -1-padded candidate ids + resident buffers -> ranked partials.

    Returns (ids (Q, top_k) int32 [-1 pad], scores (Q, top_k) float32
    [NEG_INF pad], has_candidates (Q,) bool) — the device image of the
    planner's ``TopKPartial`` rows, in the same (score desc, id asc) order:

    * dedup: sort ids ascending, mask repeats and -1 padding (-1 maps to an
      INT32_MAX sentinel so padding sorts last, not first);
    * score: one row gather from the (N, W) resident packed words, b-bit
      unpack, integer collision counts vs the unpacked query codes —
      invalid columns count -1;
    * rank: two-key ``lax.sort`` on (-count, id): count desc, id asc,
      invalid columns sink.  Identical output to the host planner's stable
      argsort over the ascending candidate union, so fused answers are
      bit-identical; the score is the same ``count.astype(f32) / k``.
    """
    qn, c = cand.shape
    has = jnp.any(cand >= 0, axis=1)
    ids = jnp.where(cand >= 0, cand, _INVALID_ID)
    ids = jax.lax.sort(ids, dimension=1)
    dup = jnp.concatenate(
        [jnp.zeros((qn, 1), bool), ids[:, 1:] == ids[:, :-1]], axis=1)
    valid = (ids != _INVALID_ID) & ~dup
    n = words.shape[0]
    rows = words[jnp.clip(ids, 0, max(n - 1, 0))]          # (Q, C, W)
    ccodes = unpack_codes(rows.reshape(qn * c, -1), k, b).reshape(qn, c, k)
    qcodes = unpack_codes(qwords, k, b)                    # (Q, K)
    counts = jnp.sum(qcodes[:, None, :] == ccodes, axis=-1,
                     dtype=jnp.int32)                      # (Q, C)
    counts = jnp.where(valid, counts, jnp.int32(-1))
    neg, ids = jax.lax.sort((-counts, ids), dimension=1, num_keys=2)
    kk = min(top_k, c)
    out_ids = jnp.full((qn, top_k), -1, jnp.int32)
    out_scores = jnp.full((qn, top_k), -jnp.inf, jnp.float32)
    hit = neg[:, :kk] <= 0                                  # count >= 0
    out_ids = out_ids.at[:, :kk].set(
        jnp.where(hit, ids[:, :kk], jnp.int32(-1)))
    out_scores = out_scores.at[:, :kk].set(
        jnp.where(hit, (-neg[:, :kk]).astype(jnp.float32) / k, -jnp.inf))
    return out_ids, out_scores, has
