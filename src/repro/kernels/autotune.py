"""Block-size autotuner for the signing kernels.

Keys: ``(kind, backend, pow2-bucketed B/D/K)`` — shapes are bucketed to the
next power of two so one measurement serves a whole shape class.  Kinds:

* ``dense_int8``   -> {block_b, block_d}   (kernels.cminhash_kernel)
* ``dense_packed`` -> {block_b, block_d}   (kernels.cminhash_packed)
* ``sparse_pallas``-> {block_b, block_j}   (kernels.cminhash_sparse, Pallas)
* ``sparse_windows``-> {block_j}           (kernels.cminhash_sparse, jnp)

Cache semantics (documented contract, see kernels/README.md):

* ``recommend()`` never measures.  It returns the cached winner when one
  exists, else a shape-clamped heuristic default.  This is what the engine
  and dispatch layer call on every signing request — cheap and deterministic.
* ``measure()`` times every valid candidate on synthetic data of the request
  shape (median of ``iters`` after ``warmup``), stores the winner in the
  in-process cache, and appends it to the JSON file at
  ``$REPRO_AUTOTUNE_CACHE`` (if set) so later processes start warm.
* The JSON file is loaded lazily once per path and merged under the
  in-process entries; ``clear_cache()`` forgets both (the file is untouched).

Benchmarks (and ``SketchConfig(autotune_measure=True)``) run ``measure``;
everything else rides the cache.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.obs import metrics as obs_metrics

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"

KINDS = ("dense_int8", "dense_packed", "sparse_pallas", "sparse_windows")

_DEFAULTS: dict[str, dict[str, int]] = {
    "dense_int8": {"block_b": 8, "block_d": 256},
    "dense_packed": {"block_b": 8, "block_d": 256},
    "sparse_pallas": {"block_b": 8, "block_j": 32},
    "sparse_windows": {"block_j": 64},
}

_CANDIDATES: dict[str, tuple[dict[str, int], ...]] = {
    "dense_int8": tuple({"block_b": bb, "block_d": bd}
                        for bb in (4, 8, 16) for bd in (128, 256, 512)),
    "dense_packed": tuple({"block_b": bb, "block_d": bd}
                          for bb in (4, 8, 16) for bd in (128, 256, 512)),
    "sparse_pallas": tuple({"block_b": bb, "block_j": bj}
                           for bb in (4, 8, 16) for bj in (16, 32, 64)),
    "sparse_windows": tuple({"block_j": bj}
                            for bj in (16, 32, 64, 128, 256)),
}

_cache: dict[str, dict[str, int]] = {}
_loaded_paths: set[str] = set()


def _pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def cache_key(kind: str, b: int, d: int, k: int, backend: str,
              nnz: int = 0) -> str:
    if kind not in KINDS:
        raise ValueError(f"unknown kind {kind!r} (want one of {KINDS})")
    key = f"{kind}:{backend}:B{_pow2(b)}:D{_pow2(d)}:K{_pow2(k)}"
    if kind.startswith("sparse"):
        # nnz is the dimension block_j tiles — a winner at one density is
        # not a winner at another, so it belongs in the key
        key += f":N{_pow2(max(nnz, 1))}"
    return key


def _cache_path() -> str | None:
    return os.environ.get(CACHE_ENV) or None


def _load_file(path: str) -> None:
    if path in _loaded_paths:
        return
    _loaded_paths.add(path)
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return
    for key, blocks in data.items():
        _cache.setdefault(key, {str(n): int(v) for n, v in blocks.items()})


def _save_file(path: str) -> None:
    try:
        existing: dict[str, Any] = {}
        if os.path.exists(path):
            with open(path) as f:
                existing = json.load(f)
        existing.update(_cache)
        with open(path, "w") as f:
            json.dump(existing, f, indent=1, sort_keys=True)
    except (OSError, ValueError):
        pass                        # cache persistence is best-effort


def clear_cache() -> None:
    """Forget in-process entries and loaded-file markers (file untouched)."""
    _cache.clear()
    _loaded_paths.clear()


def cached(kind: str, b: int, d: int, k: int, backend: str | None = None,
           nnz: int = 0) -> dict[str, int] | None:
    backend = backend or jax.default_backend()
    path = _cache_path()
    if path:
        _load_file(path)
    hit = _cache.get(cache_key(kind, b, d, k, backend, nnz))
    return dict(hit) if hit else None


def _clamp(kind: str, blocks: dict[str, int], b: int, d: int,
           k: int) -> dict[str, int]:
    out = dict(blocks)
    if "block_b" in out:
        out["block_b"] = max(1, min(out["block_b"], _pow2(b)))
    if "block_d" in out:
        # dense kernels want block_d % 32 == 0 (bit-packed words / pack
        # epilogue); never clamp below 32
        out["block_d"] = max(32, min(out["block_d"], _pow2(max(d, 32))))
    if "block_j" in out:
        out["block_j"] = max(1, out["block_j"])
    return out


def recommend(kind: str, b: int, d: int, k: int,
              backend: str | None = None, nnz: int = 0) -> dict[str, int]:
    """Cached winner if one exists, else a shape-clamped heuristic. Never
    measures."""
    backend = backend or jax.default_backend()
    hit = cached(kind, b, d, k, backend, nnz)
    if hit is not None:
        obs_metrics.default().counter("autotune.hit").inc()
        return _clamp(kind, hit, b, d, k)
    obs_metrics.default().counter("autotune.heuristic").inc()
    return _clamp(kind, _DEFAULTS[kind], b, d, k)


def _make_runner(kind: str, b: int, d: int, k: int, nnz: int,
                 seed: int) -> Callable[[dict[str, int]], Any]:
    """Build synthetic inputs once; return blocks -> timed thunk."""
    import jax.numpy as jnp

    from ..core.permutations import make_two_permutations
    from . import dispatch

    rng = np.random.default_rng(seed)
    _, pi = make_two_permutations(jax.random.PRNGKey(seed), d)
    impl = {"dense_int8": "int8", "dense_packed": "packed",
            "sparse_pallas": "pallas", "sparse_windows": "windows"}[kind]

    if kind.startswith("dense"):
        dens = (nnz / d) if nnz else 0.05
        v = jnp.asarray((rng.random((b, d)) < dens).astype(np.int8))
        return lambda blocks: (lambda: dispatch.signatures_dense(
            v, pi, k, impl=impl, **blocks))
    nnz = max(1, nnz or int(0.05 * d))
    idx = jnp.asarray(np.sort(
        rng.integers(0, d, (b, nnz)).astype(np.int32), axis=1))
    return lambda blocks: (lambda: dispatch.signatures_sparse(
        idx, pi, k, impl=impl, **blocks))


def _valid(kind: str, blocks: dict[str, int], b: int, d: int, k: int) -> bool:
    return not ("block_d" in blocks and blocks["block_d"] % 32)


def measure(kind: str, b: int, d: int, k: int, *, backend: str | None = None,
            nnz: int = 0, warmup: int = 1, iters: int = 3,
            candidates: tuple[dict[str, int], ...] | None = None,
            seed: int = 0, force: bool = False) -> dict[str, int]:
    """Sweep-and-cache on miss: time every valid candidate at this shape and
    cache the winner — but return a cached winner immediately when one exists
    (``force=True`` re-sweeps), so engines with ``autotune_measure`` pay for
    the sweep once per shape class, not once per batch.

    ``nnz`` sizes the synthetic sparse inputs (and enters the sparse cache
    key); 0 means a 5% density default."""
    backend = backend or jax.default_backend()
    if not force:
        hit = cached(kind, b, d, k, backend, nnz)
        if hit is not None:
            obs_metrics.default().counter("autotune.hit").inc()
            return hit
    obs_metrics.default().counter("autotune.sweeps").inc()
    sweep_t0 = time.perf_counter()
    runner = _make_runner(kind, b, d, k, nnz, seed)
    best: tuple[float, dict[str, int]] | None = None
    seen: set[tuple] = set()     # clamping can collapse candidates; time once
    for cand in (candidates or _CANDIDATES[kind]):
        blocks = _clamp(kind, cand, b, d, k)
        key = tuple(sorted(blocks.items()))
        if key in seen or not _valid(kind, blocks, b, d, k):
            continue
        seen.add(key)
        fn = runner(blocks)
        try:
            for _ in range(warmup):
                jax.block_until_ready(fn())
            times = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                times.append(time.perf_counter() - t0)
            elapsed = sorted(times)[len(times) // 2]
        except Exception:
            continue                       # candidate invalid on this backend
        if best is None or elapsed < best[0]:
            best = (elapsed, blocks)
    obs_metrics.default().histogram("autotune.sweep").observe(
        time.perf_counter() - sweep_t0)
    if best is None:
        return recommend(kind, b, d, k, backend, nnz)
    _cache[cache_key(kind, b, d, k, backend, nnz)] = dict(best[1])
    path = _cache_path()
    if path:
        _save_file(path)
    return dict(best[1])
