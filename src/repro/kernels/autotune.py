"""Block-size autotuner for the signing kernels.

Keys: ``(kind, backend, pow2-bucketed B/D/K)`` — shapes are bucketed to the
next power of two so one measurement serves a whole shape class.  Kinds:

* ``dense_int8``   -> {block_b, block_d}   (kernels.cminhash_kernel)
* ``dense_packed`` -> {block_b, block_d}   (kernels.cminhash_packed)
* ``sparse_pallas``-> {block_b, block_j}   (kernels.cminhash_sparse, Pallas)
* ``sparse_windows``-> {block_j}           (kernels.cminhash_sparse, jnp)
* ``query_fold``   -> {block_q}            (kernels.query_fused, Pallas fold;
                                            keyed B=queries, D=n_bands,
                                            K=rows_per_band)
* ``probe_pallas`` -> {block_e}            (kernels.lsh_probe, Pallas probe;
                                            keyed B=meta entries, D=n_slots,
                                            K=record width)

Cache semantics (documented contract, see kernels/README.md):

* ``recommend()`` never measures.  It returns the cached winner when one
  exists, else a shape-clamped heuristic default.  This is what the engine
  and dispatch layer call on every signing request — cheap and deterministic.
* ``measure()`` times every valid candidate on synthetic data of the request
  shape (interleaved min-of-``iters`` rounds — shared-box noise hits all
  candidates equally, see kernels/dispatch.py), stores the winner in the
  in-process cache, and appends it to the JSON file at
  ``$REPRO_AUTOTUNE_CACHE`` (if set) so later processes start warm.
* Default sweeps (``candidates=None``) always include the clamped heuristic
  default and re-duel the would-be winner against it head-to-head before
  caching: a winner that cannot beat the default in the duel is REJECTED
  (``autotune.guard_rejects`` counter) and the default is cached instead.
  This guards against caching a noise artifact that would then make every
  later ``recommend()`` slower than not tuning at all (seen in practice:
  a cached ``block_j=128`` 1.6x slower than the un-tuned default).
  Explicit ``candidates=`` sweeps are trusted verbatim — no default
  injection, no guard — so callers can force a specific winner.
* The JSON file is loaded lazily once per path and merged under the
  in-process entries; ``clear_cache()`` forgets both (the file is untouched).

Benchmarks (and ``SketchConfig(autotune_measure=True)``) run ``measure``;
everything else rides the cache.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.obs import metrics as obs_metrics

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"

KINDS = ("dense_int8", "dense_packed", "sparse_pallas", "sparse_windows",
         "query_fold", "probe_pallas")

_DEFAULTS: dict[str, dict[str, int]] = {
    "dense_int8": {"block_b": 8, "block_d": 256},
    "dense_packed": {"block_b": 8, "block_d": 256},
    "sparse_pallas": {"block_b": 8, "block_j": 32},
    "sparse_windows": {"block_j": 64},
    "query_fold": {"block_q": 128},
    "probe_pallas": {"block_e": 128},
}

_CANDIDATES: dict[str, tuple[dict[str, int], ...]] = {
    "dense_int8": tuple({"block_b": bb, "block_d": bd}
                        for bb in (4, 8, 16) for bd in (128, 256, 512)),
    "dense_packed": tuple({"block_b": bb, "block_d": bd}
                          for bb in (4, 8, 16) for bd in (128, 256, 512)),
    "sparse_pallas": tuple({"block_b": bb, "block_j": bj}
                           for bb in (4, 8, 16) for bj in (16, 32, 64)),
    "sparse_windows": tuple({"block_j": bj}
                            for bj in (16, 32, 64, 128, 256)),
    "query_fold": tuple({"block_q": bq} for bq in (32, 64, 128, 256, 512)),
    "probe_pallas": tuple({"block_e": be} for be in (32, 64, 128, 256, 512)),
}

_cache: dict[str, dict[str, int]] = {}
_loaded_paths: set[str] = set()


def _pow2(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def cache_key(kind: str, b: int, d: int, k: int, backend: str,
              nnz: int = 0) -> str:
    if kind not in KINDS:
        raise ValueError(f"unknown kind {kind!r} (want one of {KINDS})")
    key = f"{kind}:{backend}:B{_pow2(b)}:D{_pow2(d)}:K{_pow2(k)}"
    if kind.startswith("sparse"):
        # nnz is the dimension block_j tiles — a winner at one density is
        # not a winner at another, so it belongs in the key
        key += f":N{_pow2(max(nnz, 1))}"
    return key


def _cache_path() -> str | None:
    return os.environ.get(CACHE_ENV) or None


def _load_file(path: str) -> None:
    if path in _loaded_paths:
        return
    _loaded_paths.add(path)
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return
    for key, blocks in data.items():
        _cache.setdefault(key, {str(n): int(v) for n, v in blocks.items()})


def _save_file(path: str) -> None:
    try:
        existing: dict[str, Any] = {}
        if os.path.exists(path):
            with open(path) as f:
                existing = json.load(f)
        existing.update(_cache)
        with open(path, "w") as f:
            json.dump(existing, f, indent=1, sort_keys=True)
    except (OSError, ValueError):
        pass                        # cache persistence is best-effort


def clear_cache() -> None:
    """Forget in-process entries and loaded-file markers (file untouched)."""
    _cache.clear()
    _loaded_paths.clear()


def cached(kind: str, b: int, d: int, k: int, backend: str | None = None,
           nnz: int = 0) -> dict[str, int] | None:
    backend = backend or jax.default_backend()
    path = _cache_path()
    if path:
        _load_file(path)
    hit = _cache.get(cache_key(kind, b, d, k, backend, nnz))
    return dict(hit) if hit else None


def _clamp(kind: str, blocks: dict[str, int], b: int, d: int,
           k: int) -> dict[str, int]:
    out = dict(blocks)
    if "block_b" in out:
        out["block_b"] = max(1, min(out["block_b"], _pow2(b)))
    if "block_d" in out:
        # dense kernels want block_d % 32 == 0 (bit-packed words / pack
        # epilogue); never clamp below 32
        out["block_d"] = max(32, min(out["block_d"], _pow2(max(d, 32))))
    if "block_j" in out:
        out["block_j"] = max(1, out["block_j"])
    if "block_q" in out:
        # fold tiles the query batch (keyed as B)
        out["block_q"] = max(1, min(out["block_q"], _pow2(b)))
    if "block_e" in out:
        # probe tiles the flat (Q * n_bands) meta entries (keyed as B)
        out["block_e"] = max(1, min(out["block_e"], _pow2(b)))
    return out


def recommend(kind: str, b: int, d: int, k: int,
              backend: str | None = None, nnz: int = 0) -> dict[str, int]:
    """Cached winner if one exists, else a shape-clamped heuristic. Never
    measures."""
    backend = backend or jax.default_backend()
    hit = cached(kind, b, d, k, backend, nnz)
    if hit is not None:
        obs_metrics.default().counter("autotune.hit").inc()
        return _clamp(kind, hit, b, d, k)
    obs_metrics.default().counter("autotune.heuristic").inc()
    return _clamp(kind, _DEFAULTS[kind], b, d, k)


def _make_runner(kind: str, b: int, d: int, k: int, nnz: int,
                 seed: int) -> Callable[[dict[str, int]], Any]:
    """Build synthetic inputs once; return blocks -> timed thunk."""
    import jax.numpy as jnp

    from ..core.permutations import make_two_permutations
    from . import dispatch

    rng = np.random.default_rng(seed)
    interpret = jax.default_backend() != "tpu"

    if kind == "query_fold":
        # b=queries, d=n_bands, k=rows_per_band (uint32 words per band)
        from . import query_fused
        lo = jnp.asarray(rng.integers(0, 2**32, (b, d, max(k, 1)),
                                      dtype=np.uint32))
        hi = jnp.zeros_like(lo)
        return lambda blocks: (lambda: query_fused.fold_planes_pallas(
            hi, lo, interpret=interpret, **blocks))
    if kind == "probe_pallas":
        # b=meta entries, d=n_slots, k=record width W
        from . import lsh_probe
        n_slots = max(1, d)
        records = jnp.full((n_slots, 2 + max(k, 1)), -1, jnp.int32)
        hashes = rng.integers(0, 2**63, (max(b, 1), 1), dtype=np.uint64)
        meta = jnp.asarray(lsh_probe.probe_operands(hashes, n_slots))
        return lambda blocks: (lambda: lsh_probe.lsh_probe_pallas(
            records, meta, n_slots=n_slots, max_probes=8,
            interpret=interpret, **blocks))

    _, pi = make_two_permutations(jax.random.PRNGKey(seed), d)
    impl = {"dense_int8": "int8", "dense_packed": "packed",
            "sparse_pallas": "pallas", "sparse_windows": "windows"}[kind]

    if kind.startswith("dense"):
        dens = (nnz / d) if nnz else 0.05
        v = jnp.asarray((rng.random((b, d)) < dens).astype(np.int8))
        return lambda blocks: (lambda: dispatch.signatures_dense(
            v, pi, k, impl=impl, **blocks))
    nnz = max(1, nnz or int(0.05 * d))
    idx = jnp.asarray(np.sort(
        rng.integers(0, d, (b, nnz)).astype(np.int32), axis=1))
    return lambda blocks: (lambda: dispatch.signatures_sparse(
        idx, pi, k, impl=impl, **blocks))


def _valid(kind: str, blocks: dict[str, int], b: int, d: int, k: int) -> bool:
    return not ("block_d" in blocks and blocks["block_d"] % 32)


def _sweep(runner: Callable[[dict[str, int]], Any],
           cands: list[dict[str, int]], warmup: int,
           iters: int) -> tuple[float, dict[str, int]] | None:
    """Time candidates INTERLEAVED (round-robin min-of-``iters``): on a
    shared box, drift and noise bursts then hit every candidate equally
    instead of penalizing whichever ran during the burst — the same
    convention bench_sign.py uses (see kernels/dispatch.py).  A candidate
    that raises during warmup is dropped (invalid on this backend); one that
    raises mid-round keeps its best earlier time.  Returns the fastest
    ``(seconds, blocks)`` or None when nothing ran."""
    import math

    live: list[tuple[dict[str, int], Any, list[float]]] = []
    for blocks in cands:
        fn = runner(blocks)
        try:
            for _ in range(max(warmup, 1)):
                jax.block_until_ready(fn())
        except Exception:
            continue                       # candidate invalid on this backend
        live.append((blocks, fn, [math.inf]))
    for _ in range(max(iters, 1)):
        for blocks, fn, t in live:
            try:
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                t[0] = min(t[0], time.perf_counter() - t0)
            except Exception:
                pass
    live = [(blocks, fn, t) for blocks, fn, t in live if t[0] < math.inf]
    if not live:
        return None
    blocks, _, t = min(live, key=lambda e: e[2][0])
    return (t[0], blocks)


def _duel(runner: Callable[[dict[str, int]], Any],
          winner: dict[str, int], default: dict[str, int], warmup: int,
          iters: int) -> bool:
    """Head-to-head re-measurement of the sweep winner against the heuristic
    default.  True iff the winner is strictly faster — i.e. the sweep result
    survives confirmation and deserves the cache slot."""
    best = _sweep(runner, [winner, default], warmup, iters)
    return best is not None and best[1] == winner


def measure(kind: str, b: int, d: int, k: int, *, backend: str | None = None,
            nnz: int = 0, warmup: int = 1, iters: int = 3,
            candidates: tuple[dict[str, int], ...] | None = None,
            seed: int = 0, force: bool = False) -> dict[str, int]:
    """Sweep-and-cache on miss: time every valid candidate at this shape and
    cache the winner — but return a cached winner immediately when one exists
    (``force=True`` re-sweeps), so engines with ``autotune_measure`` pay for
    the sweep once per shape class, not once per batch.

    Default sweeps (``candidates=None``) include the clamped heuristic
    default in the field and re-duel the winner against it before caching;
    a winner that loses the duel is rejected (``autotune.guard_rejects``)
    and the default is cached instead — a cached "winner" must never make
    ``recommend()`` slower than not tuning at all.  Explicit ``candidates=``
    bypass both the injection and the guard (the caller pins the field).

    ``nnz`` sizes the synthetic sparse inputs (and enters the sparse cache
    key); 0 means a 5% density default."""
    backend = backend or jax.default_backend()
    if not force:
        hit = cached(kind, b, d, k, backend, nnz)
        if hit is not None:
            obs_metrics.default().counter("autotune.hit").inc()
            return hit
    obs_metrics.default().counter("autotune.sweeps").inc()
    sweep_t0 = time.perf_counter()
    runner = _make_runner(kind, b, d, k, nnz, seed)
    guard = candidates is None
    default = _clamp(kind, _DEFAULTS[kind], b, d, k)
    field: list[dict[str, int]] = []
    seen: set[tuple] = set()     # clamping can collapse candidates; time once
    pool = _CANDIDATES[kind] + (default,) if guard else candidates
    for cand in pool:
        blocks = _clamp(kind, cand, b, d, k)
        key = tuple(sorted(blocks.items()))
        if key in seen or not _valid(kind, blocks, b, d, k):
            continue
        seen.add(key)
        field.append(blocks)
    best = _sweep(runner, field, warmup, iters)
    if best is not None:
        blocks = best[1]
        if guard and blocks != default and not _duel(
                runner, blocks, default, warmup, max(iters, 3)):
            obs_metrics.default().counter("autotune.guard_rejects").inc()
            blocks = default
        best = (best[0], blocks)
    obs_metrics.default().histogram("autotune.sweep").observe(
        time.perf_counter() - sweep_t0)
    if best is None:
        return recommend(kind, b, d, k, backend, nnz)
    _cache[cache_key(kind, b, d, k, backend, nnz)] = dict(best[1])
    path = _cache_path()
    if path:
        _save_file(path)
    return dict(best[1])
