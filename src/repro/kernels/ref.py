"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Deliberately written in the most obvious form (explicit rolls / broadcasts) and kept
independent from the tiled kernels so a tiling bug cannot cancel out.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array
SENTINEL = jnp.iinfo(jnp.int32).max


@functools.partial(jax.jit, static_argnames=("k", "shift_offset"))
def cminhash_dense_ref(v: Array, pi: Array, k: int, *, shift_offset: int = 1) -> Array:
    """h_q = min_m { pi[m] : v[(m + q + shift_offset) mod D] != 0 },  q = 0..K-1.

    v: (B, D) binary; pi: (D,) int32. Returns (B, K) int32.
    (sigma, when used, is applied by the caller — kernels hash the permuted vector.)
    """
    d = v.shape[-1]
    mask = v > 0

    def one(q):
        rolled = jnp.roll(mask, -(q + shift_offset), axis=-1)
        vals = jnp.where(rolled, pi[None, :], SENTINEL)
        return jnp.min(vals, axis=-1)

    sig = jax.lax.map(one, jnp.arange(k))
    return sig.T.astype(jnp.int32)


@jax.jit
def collision_count_ref(sig_q: Array, sig_n: Array) -> Array:
    """(Q, K) x (N, K) int32 -> (Q, N) int32 match counts."""
    eq = sig_q[:, None, :] == sig_n[None, :, :]
    return jnp.sum(eq.astype(jnp.int32), axis=-1)


@functools.partial(jax.jit, static_argnames=("k", "b"))
def packed_collision_count_ref(words_q: Array, words_n: Array, k: int,
                               b: int) -> Array:
    """(Q, W) x (N, W) b-bit packed uint32 -> (Q, N) matching-code counts.

    Works on the XOR of the word pair directly (a b-bit field matches iff its
    XOR field is zero) — no shared unpack helper with ops.pack_codes, so a
    packing-layout bug cannot cancel out.
    """
    x = words_q[:, None, :] ^ words_n[None, :, :]          # (Q, N, W)
    cpw = 32 // b
    shifts = jnp.arange(cpw, dtype=jnp.uint32) * jnp.uint32(b)
    mask = jnp.uint32((1 << b) - 1) if b < 32 else jnp.uint32(0xFFFFFFFF)
    fields = (x[..., None] >> shifts) & mask               # (Q, N, W, cpw)
    q, n, w = x.shape
    match = (fields == 0).reshape(q, n, w * cpw)[..., :k]
    return jnp.sum(match.astype(jnp.int32), axis=-1)
