"""Pallas TPU kernel for the C-MinHash circulant min-reduce (the hashing hot loop).

TPU-native formulation (DESIGN.md §4): hash q is a masked min of the fixed value
vector ``pi`` against a circulantly rolled window of the (sigma-permuted) bit
vector:

    h_q = min_m { pi[m] : vpad[m + q + off] != 0 },    vpad = [v, v[:K+off], 0...]

Tiling: with ``Kt == Dt``, the window needed by hash-block ``j`` and data-block
``d`` lies entirely inside the two adjacent Dt-blocks ``d+j`` and ``d+j+1`` of the
flat padded vector — so the kernel consumes two *disjoint* BlockSpecs (no
overlapping windows, no gathers, no mod arithmetic on the data path).  The inner
loop is a VPU select+min over a VMEM band; the output block is min-accumulated
across the innermost grid dimension.

VMEM working set per program instance (defaults Bt=8, Dt=Kt=256):
  band 2*Bt*Dt int8 + pi Dt int32 + acc Bt*Kt int32 ≈ 13 KB  — far under budget;
larger Dt (512/1024) trades grid steps for VMEM and stays aligned to the 128-lane
VPU geometry (Dt % 128 == 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .packfmt import pack_block, pack_geometry

Array = jax.Array
SENTINEL = jnp.iinfo(jnp.int32).max


def _kernel(pi_ref, vlo_ref, vhi_ref, out_ref, acc_scratch=None, *, bt: int,
            dt: int, off: int, nd: int = 0, k: int = 0,
            pack_b: int | None = None):
    d_idx = pl.program_id(2)
    # plain mode: accumulate straight into the int32 output block.  fused
    # pack mode: accumulate in a VMEM scratch (re-initialized whenever the
    # innermost data dim restarts) so the only HBM output is the packed words
    acc_ref = out_ref if pack_b is None else acc_scratch

    @pl.when(d_idx == 0)
    def _init():
        acc_ref[...] = jnp.full(acc_ref.shape, SENTINEL, acc_ref.dtype)

    band = jnp.concatenate([vlo_ref[...], vhi_ref[...]], axis=1)  # (Bt, 2*Dt) int8
    pvals = pi_ref[...]  # (Dt,) int32

    def body(k_local, acc):
        window = jax.lax.dynamic_slice(band, (0, k_local + off), (bt, dt))
        masked = jnp.where(window > 0, pvals[None, :], SENTINEL)
        return acc.at[:, k_local].min(jnp.min(masked, axis=1))

    acc_ref[...] = jax.lax.fori_loop(0, dt, body, acc_ref[...])

    if pack_b is not None:
        # fused sign->pack epilogue: once the min over the last data block is
        # folded in, truncate to b bits and pack — the (B, K) int32 form never
        # leaves VMEM.  (program_id must be read outside the pl.when closure:
        # interpret mode does not rewrite it inside cond branches.)
        col0 = pl.program_id(1) * dt

        @pl.when(d_idx == nd - 1)
        def _pack():
            out_ref[...] = pack_block(acc_ref[...], col0, k=k, b=pack_b)


@functools.partial(
    jax.jit,
    static_argnames=("k", "shift_offset", "block_b", "block_d", "interpret",
                     "pack_b"),
)
def cminhash_pallas(v: Array, pi: Array, k: int, *, shift_offset: int = 1,
                    block_b: int = 8, block_d: int = 256,
                    interpret: bool = True,
                    pack_b: int | None = None) -> Array:
    """Dense C-MinHash signatures via the tiled Pallas kernel.

    v: (B, D) int8/bool/int32 binary data (already sigma-permuted by the caller);
    pi: (D,) int32 permutation values. Returns (B, K) int32 with column q holding
    the paper's h_{q+shift_offset} — unless ``pack_b`` is set, in which case the
    fused epilogue truncates each hash to its lowest pack_b bits and returns the
    (B, ceil(K / (32/pack_b))) uint32 packed words directly (bit-identical to
    sign-then-``packfmt.pack_codes``); requires block_d % (32/pack_b) == 0.
    """
    if shift_offset not in (0, 1):
        raise ValueError("shift_offset must be 0 or 1 (band fits 2 blocks)")
    b, d = v.shape
    if k > d:
        raise ValueError(f"K <= D required (K={k}, D={d})")
    bt, dt = block_b, block_d
    kt = dt  # tiling invariant: hash blocks are the size of data blocks

    nb = -(-b // bt)
    nd = -(-d // dt)
    nk = -(-k // kt)

    # Value vector padded with SENTINEL so out-of-range m never wins the min.
    pi_pad = jnp.full((nd * dt,), SENTINEL, jnp.int32).at[:d].set(pi.astype(jnp.int32))

    # Flat circular buffer: [v, v[:, :K+off], zeros...] then block-pad.
    mask = (v > 0).astype(jnp.int8)
    n_vblocks = nd + nk  # max block index used is (nd-1) + (nk-1) + 1
    vpad = jnp.zeros((nb * bt, n_vblocks * dt), jnp.int8)
    vpad = vpad.at[:b, :d].set(mask)
    # Real reads touch flat positions up to (d-1) + (K-1+off): a wrap copy of
    # length K+off-1 suffices; clamp to D (single wrap; K <= D) and to the
    # allocated width (the clipped tail is only ever read for padded hash
    # columns, which are sliced off below).
    wrap = min(k + shift_offset, d, n_vblocks * dt - d)
    vpad = vpad.at[:b, d:d + wrap].set(mask[:, :wrap])

    grid = (nb, nk, nd)
    in_specs = [
        pl.BlockSpec((dt,), lambda i, j, dd: (dd,)),
        pl.BlockSpec((bt, dt), lambda i, j, dd: (i, dd + j)),
        pl.BlockSpec((bt, dt), lambda i, j, dd: (i, dd + j + 1)),
    ]
    sig_spec = pl.BlockSpec((bt, kt), lambda i, j, dd: (i, j))
    sig_shape = jax.ShapeDtypeStruct((nb * bt, nk * kt), jnp.int32)

    if pack_b is None:
        out = pl.pallas_call(
            functools.partial(_kernel, bt=bt, dt=dt, off=shift_offset),
            grid=grid, in_specs=in_specs, out_specs=sig_spec,
            out_shape=sig_shape, interpret=interpret,
        )(pi_pad, vpad, vpad)
        return out[:b, :k]

    cpw, n_words = pack_geometry(k, pack_b)
    if kt % cpw:
        raise ValueError(
            f"block_d={dt} must be a multiple of {cpw} for pack_b={pack_b}")
    words = pl.pallas_call(
        functools.partial(_kernel, bt=bt, dt=dt, off=shift_offset, nd=nd,
                          k=k, pack_b=pack_b),
        grid=grid, in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, kt // cpw), lambda i, j, dd: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nb * bt, nk * kt // cpw), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((bt, kt), jnp.int32)],
        interpret=interpret,
    )(pi_pad, vpad, vpad)
    return words[:b, :n_words]
