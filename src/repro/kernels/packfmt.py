"""b-bit packed-code format: the one definition of the storage layout.

K codes of b bits each are packed little-endian into ceil(K / (32/b)) uint32
words: code j of a row lives at bit (j % (32/b)) * b of word j // (32/b).
b == 32 is a bitcast (one code per word, codes == signatures), so scoring on
packed words at b = 32 is bit-exact with scoring the raw signatures.

This module is a leaf (jax-only imports) so both the host-side packers
(``pack_codes``/``unpack_codes``) and the in-kernel fused epilogue
(``pack_block``) share the exact same geometry — the fused sign->pack path in
the dense Pallas kernels is asserted bit-identical to sign-then-``pack_codes``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

Array = jax.Array

PACK_BITS = (1, 2, 4, 8, 16, 32)  # b values whose codes tile an int32 word


def pack_geometry(k: int, b: int) -> tuple[int, int]:
    """-> (codes_per_word, n_words) for K b-bit codes."""
    if b not in PACK_BITS:
        raise ValueError(f"b must be one of {PACK_BITS} (got {b})")
    codes_per_word = 32 // b
    return codes_per_word, -(-k // codes_per_word)


@functools.partial(jax.jit, static_argnames=("b",))
def pack_codes(sig: Array, b: int) -> Array:
    """(B, K) int32 signatures -> (B, W) uint32 b-bit packed words."""
    bsz, k = sig.shape
    cpw, n_words = pack_geometry(k, b)
    if b == 32:
        return jax.lax.bitcast_convert_type(sig, jnp.uint32)
    codes = (sig & ((1 << b) - 1)).astype(jnp.uint32)
    pad = n_words * cpw - k
    if pad:
        codes = jnp.pad(codes, ((0, 0), (0, pad)))
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * b)
    return jnp.sum(codes.reshape(bsz, n_words, cpw) << shifts, axis=-1,
                   dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("k", "b"))
def unpack_codes(words: Array, k: int, b: int) -> Array:
    """(B, W) uint32 packed words -> (B, K) int32 codes in [0, 2^b)."""
    bsz = words.shape[0]
    cpw, n_words = pack_geometry(k, b)
    if b == 32:
        return jax.lax.bitcast_convert_type(words, jnp.int32)[:, :k]
    shifts = (jnp.arange(cpw, dtype=jnp.uint32) * b)
    mask = jnp.uint32((1 << b) - 1)
    codes = (words[:, :, None] >> shifts) & mask
    return codes.reshape(bsz, n_words * cpw)[:, :k].astype(jnp.int32)


def pack_block(acc: Array, col0, *, k: int, b: int) -> Array:
    """In-kernel fused epilogue: (Bt, Kt) int32 mins -> (Bt, Kt*b/32) words.

    ``col0`` is the global hash column of ``acc[:, 0]`` (may be traced);
    columns at global index >= k are zeroed to match ``pack_codes`` padding.
    Kt must be a multiple of 32/b.  Uses a static bitwise-OR fold — no
    (Bt, W, cpw) sum intermediate — safe inside Pallas (2D iota only).
    """
    bt, kt = acc.shape
    cpw, _ = pack_geometry(kt, b)  # validates b; kt stands in for k here
    if kt % cpw:
        raise ValueError(f"block K width {kt} not a multiple of {cpw}")
    col = jax.lax.broadcasted_iota(jnp.int32, (bt, kt), 1) + col0
    if b == 32:
        codes = jax.lax.bitcast_convert_type(acc, jnp.uint32)
    else:
        codes = (acc & ((1 << b) - 1)).astype(jnp.uint32)
    codes = jnp.where(col < k, codes, jnp.uint32(0))
    if cpw == 1:
        return codes
    grp = codes.reshape(bt, kt // cpw, cpw)
    return functools.reduce(
        jnp.bitwise_or,
        [grp[:, :, i] << jnp.uint32(i * b) for i in range(cpw)])
